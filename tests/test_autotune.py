"""Autotune subsystem: guarded calibration fits, the device-spec
registry, VMEM-pressure lane chunking, and the drift-driven
calibrate-and-replan loop end to end (plan swap atomicity + bit-identical
results across a retune).
"""
import dataclasses
import json
import os
import time

import numpy as np
import pytest

from repro import api
from repro.autotune import (AutoTuner, Calibrator, DeviceSpec, RetunePolicy,
                            SpecRegistry, candidate_configs,
                            default_device_kind, geometry_key, hw_from_dict,
                            hw_to_dict, search_plan)
from repro.core import gas, perf_model
from repro.core.executor import Executor
from repro.core.planner import PlanConfig
from repro.core.store import GraphStore
from repro.core.types import Geometry
from repro.graphs.rmat import rmat
from repro.kernels import ops
from repro.serve_graph import GraphService

WAIT = 300.0


@pytest.fixture(scope="module")
def geom():
    # partitions are U-sized dst ranges: 1024 vertices / U=256 gives 4
    # partitions, so plans get real lane structure to search over
    return Geometry(U=256, W=128, T=128, E_BLK=128, big_batch=2)


@pytest.fixture(scope="module")
def graph():
    return rmat(10, 8, seed=4, weighted=True)   # 1024 vertices


@pytest.fixture(scope="module")
def store(graph, geom):
    return GraphStore(graph, geom=geom)


def _synth_samples(store, geom, true_hw, n=None, noise=None, seed=0):
    """Lane-style samples whose times come from a KNOWN ground-truth HW:
    y = feature_row(info) . [c_edges, c_edges_big|c_edges, c_vertices,
    c_compute, c_store, t_const]."""
    coef = np.array([true_hw.c_edges,
                     true_hw.c_edges_big or true_hw.c_edges,
                     true_hw.c_vertices, true_hw.c_compute,
                     true_hw.c_store, max(true_hw.t_const, 0.0)])
    rng = np.random.default_rng(seed)
    rows, kinds, ys = [], [], []
    infos = [i for i in store.infos if i.num_edges > 0]
    for rep in range(4):
        for info in infos:
            for kind in ("little", "big"):
                row = np.asarray(perf_model.feature_row(
                    info, geom, kind, perf_model.TPU_V5E))
                y = float(row @ coef)
                if noise is not None:
                    y *= float(rng.uniform(1 - noise, 1 + noise))
                rows.append(row)
                kinds.append(kind)
                ys.append(y)
                if n is not None and len(rows) >= n:
                    return rows, kinds, ys
    return rows, kinds, ys


# ------------------------------------------------------- calibration fit
def test_calibration_round_trip(store, geom):
    """Noiseless synthetic timings from a known HW: the fitted model
    must reproduce the synthesized lane times almost exactly (the
    coefficients themselves are not identifiable — te and tc are
    collinear — so the contract is on predictions, not parameters)."""
    true = perf_model.TPU_V5E.clone(c_edges=7.0, c_edges_big=19.0,
                                    c_vertices=3.0, c_store=2.0,
                                    t_const=4e-5, combine="sum")
    rows, kinds, ys = _synth_samples(store, geom, true)
    cal = Calibrator()
    for r, k, y in zip(rows, kinds, ys):
        cal.add_lane(r, k, y)
    assert cal.ready()
    fit = cal.fit(perf_model.TPU_V5E)
    assert fit is not None and fit.ok, fit.diag
    assert fit.hw.combine == "sum"
    coef = np.array([fit.hw.c_edges, fit.hw.c_edges_big or fit.hw.c_edges,
                     fit.hw.c_vertices, fit.hw.c_compute, fit.hw.c_store,
                     max(fit.hw.t_const, 0.0)])
    pred = np.array([r @ coef for r in rows])
    np.testing.assert_allclose(pred, ys, rtol=0.02)
    # diagnostics are reported
    assert fit.diag["n"] == len(rows)
    assert fit.diag["residual_rel"] < 0.02
    assert "cond" in fit.diag


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_calibration_recovers_under_noise(store, geom, seed):
    """10% multiplicative timing noise: predictions stay within ~15%
    of the noiseless ground truth."""
    true = perf_model.TPU_V5E.clone(c_edges=5.0, c_vertices=2.0,
                                    c_store=1.5, t_const=2e-5,
                                    combine="sum")
    rows, kinds, ys_clean = _synth_samples(store, geom, true)
    _, _, ys_noisy = _synth_samples(store, geom, true, noise=0.10,
                                    seed=seed)
    hw, diag = perf_model.fit_terms(rows, ys_noisy, perf_model.TPU_V5E)
    assert diag["fallback"] is None, diag
    coef = np.array([hw.c_edges, hw.c_edges_big or hw.c_edges,
                     hw.c_vertices, hw.c_compute, hw.c_store,
                     max(hw.t_const, 0.0)])
    pred = np.array([np.asarray(r) @ coef for r in rows])
    rel = np.abs(pred - np.asarray(ys_clean)) / np.asarray(ys_clean)
    assert np.median(rel) < 0.15, np.median(rel)


def test_calibration_noise_property(store, geom):
    """Hypothesis property: any bounded multiplicative noise level up to
    20% keeps the guarded fit from falling back, and predictions track
    ground truth. Skips when hypothesis is not installed."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    true = perf_model.TPU_V5E.clone(c_edges=4.0, c_vertices=2.5,
                                    c_store=1.2, t_const=1e-5,
                                    combine="sum")
    rows, kinds, _ = _synth_samples(store, geom, true)

    @settings(max_examples=15, deadline=None)
    @given(noise=st.floats(0.0, 0.2), seed=st.integers(0, 2**16))
    def prop(noise, seed):
        _, _, ys = _synth_samples(store, geom, true, noise=noise or None,
                                  seed=seed)
        hw, diag = perf_model.fit_terms(rows, ys, perf_model.TPU_V5E)
        assert diag["fallback"] is None
        coef = np.array([hw.c_edges, hw.c_edges_big or hw.c_edges,
                         hw.c_vertices, hw.c_compute, hw.c_store,
                         max(hw.t_const, 0.0)])
        pred = np.array([np.asarray(r) @ coef for r in rows])
        rel = np.median(np.abs(pred - ys) / np.maximum(ys, 1e-12))
        assert rel < 0.25, rel

    prop()


def test_underdetermined_fit_keeps_prior(store, geom):
    """Too few samples must NOT silently zero the coefficients: the fit
    falls back to the prior and says so in the diagnostics."""
    prior = perf_model.TPU_V5E.clone(c_edges=123.0, c_vertices=7.0)
    info = next(i for i in store.infos if i.num_edges > 0)
    row = perf_model.feature_row(info, geom, "little", perf_model.TPU_V5E)
    hw, diag = perf_model.fit_terms([row], [1e-3], prior)
    assert diag["fallback"] == "insufficient_samples"
    assert hw.c_edges == prior.c_edges          # untouched
    assert hw.c_vertices == prior.c_vertices
    assert hw.combine == prior.combine          # no partial application
    # Calibrator-level: below min_samples -> no fit at all
    cal = Calibrator(min_samples=6)
    cal.add_lane(row, "little", 1e-3)
    assert cal.fit(prior) is None


def test_fit_preserves_big_share_sentinel(store, geom):
    """Little-only samples with the c_edges_big=0 share sentinel: the
    fitted HW must keep the sentinel (so Big keeps sharing the fitted
    c_edges) instead of materializing a stale absolute prior."""
    true = perf_model.TPU_V5E.clone(c_edges=9.0, combine="sum")
    rows, kinds, ys = [], [], []
    for info in [i for i in store.infos if i.num_edges > 0]:
        for _ in range(3):
            r = np.asarray(perf_model.feature_row(info, geom, "little",
                                                  perf_model.TPU_V5E))
            rows.append(r)
            kinds.append("little")
            ys.append(float(r @ np.array([9.0, 0, 1, 1, 1, 5e-6])))
    hw, diag = perf_model.fit_terms(rows, ys, perf_model.TPU_V5E)
    assert diag["fallback"] is None
    assert "c_edges_big" in diag["kept_prior"]
    assert hw.c_edges_big == 0.0                # sentinel, not 1.0


def test_high_residual_falls_back(store, geom):
    """Timings that the model structurally cannot explain (random) must
    be rejected, keeping the prior."""
    rng = np.random.default_rng(0)
    rows, _, _ = _synth_samples(store, geom, perf_model.TPU_V5E)
    ys = [float(rng.uniform(1.0, 100.0)) for _ in rows]   # pure noise, huge
    prior = perf_model.TPU_V5E.clone(c_edges=5.0)
    hw, diag = perf_model.fit_terms(rows, ys, prior, max_residual=0.05)
    assert diag["fallback"] == "high_residual"
    assert hw.c_edges == prior.c_edges


# ------------------------------------------------------- device specs
def test_spec_registry_round_trip(tmp_path, geom):
    reg = SpecRegistry(root=str(tmp_path))
    hw = perf_model.TPU_V5E.clone(c_edges=3.25, vmem_lane_budget=16e6,
                                  combine="sum")
    spec = DeviceSpec(device_kind="cpu@test", geom_key=geometry_key(geom),
                      hw=hw, version=3, created_at=time.time() - 60,
                      source="calibrated", fit={"residual_rel": 0.01})
    path = reg.put(spec)
    assert os.path.exists(path)
    back = reg.get("cpu@test", geom)
    assert back is not None
    assert back.version == 3 and back.source == "calibrated"
    assert back.hw == hw                         # full HW round-trips
    assert 50 < back.age_s() < 3600
    assert back.fit["residual_rel"] == 0.01
    # different geometry -> different spec file -> miss
    other = Geometry(U=1024, W=512, T=512, E_BLK=128, big_batch=4)
    assert reg.get("cpu@test", other) is None


def test_spec_registry_corrupt_and_env(tmp_path, geom, monkeypatch):
    reg = SpecRegistry(root=str(tmp_path))
    p = reg.path_for("k", geom)
    os.makedirs(str(tmp_path), exist_ok=True)
    with open(p, "w") as f:
        f.write("{not json")
    assert reg.get("k", geom) is None            # degrade, don't raise
    # REGRAPH_SPEC_DIR steers the default root
    monkeypatch.setenv("REGRAPH_SPEC_DIR", str(tmp_path / "envdir"))
    assert SpecRegistry().root == str(tmp_path / "envdir")


def test_hw_dict_tolerant():
    d = hw_to_dict(perf_model.TPU_V5E.clone(c_edges=2.0))
    d["unknown_future_field"] = 42
    del d["c_store"]
    base = perf_model.TPU_V5E.clone(c_store=9.0)
    hw = hw_from_dict(d, base=base)
    assert hw.c_edges == 2.0
    assert hw.c_store == 9.0                     # missing -> base
    assert not hasattr(hw, "unknown_future_field")


def test_default_device_kind_stable():
    k1, k2 = default_device_kind(), default_device_kind()
    assert k1 == k2 and "@" in k1


# ------------------------------------------------- VMEM-pressure chunking
def test_vmem_chunking_bit_identical(graph, geom):
    store = GraphStore(graph, geom=geom)
    base = api.compile(None, "pagerank", store=store, n_lanes=2)
    hw_budget = perf_model.TPU_V5E.clone(vmem_lane_budget=4096.0)
    tight = api.compile(None, "pagerank", store=store,
                        config=PlanConfig(n_lanes=2, hw=hw_budget))
    n_base = sum(len(l) for l in base.executor.packed_lanes)
    n_tight = sum(len(l) for l in tight.executor.packed_lanes)
    assert n_tight >= n_base          # chunking only ever adds payloads
    # every chunked payload respects entry-boundary working sets where
    # possible (single oversized entries still form their own chunk)
    r_base, _ = base.run(max_iters=5)
    r_tight, _ = tight.run(max_iters=5)
    assert np.array_equal(r_base, r_tight)       # bit-identical


def test_vmem_chunking_splits_budget(graph, geom):
    store = GraphStore(graph, geom=geom)
    bundle = store.plan(PlanConfig(n_lanes=1))
    lane = max(bundle.plan.lanes, key=len)
    # replicate _pack_lane_np's grouping to reach the host entry dicts
    groups = {"little": [], "big": []}
    for e in lane:
        work = (bundle.little_works[e.work_id] if e.kind == "little"
                else bundle.big_works[e.work_id])
        p = ops._entry_np(work, e.block_lo, e.block_hi)
        if p is not None:
            groups[e.kind].append(p)
    entries = max(groups.values(), key=len)
    assert len(entries) >= 2, "fixture graph should span partitions"
    full = ops.estimate_working_set(entries, geom)
    assert full > 0
    assert ops._chunk_entries(entries, geom, 0.0) == [entries]  # off
    halves = ops._chunk_entries(entries, geom, full / 2)
    assert len(halves) >= 2
    assert sum(len(c) for c in halves) == len(entries)   # nothing dropped
    for chunk in halves:
        if len(chunk) > 1:      # single oversized entries are the floor
            assert ops.estimate_working_set(chunk, geom) <= full / 2
    # the packed-payload count grows accordingly, via the public path
    one = ops._pack_lane_np(lane, bundle.little_works, bundle.big_works)
    capped = ops._pack_lane_np(lane, bundle.little_works, bundle.big_works,
                               max_working_set=full / 2)
    assert len(capped) > len(one)


# ------------------------------------------------- candidate plan search
def test_candidate_configs_cover_split_sweep():
    base = PlanConfig(mode="model", n_lanes=4)
    hw = perf_model.TPU_V5E.clone(c_edges=2.0)
    cands = candidate_configs(base, hw)
    modes = [(c.mode, c.forced_little, c.forced_big) for c in cands]
    assert ("model", 0, 0) in modes
    for m in range(1, 4):
        assert ("fixed", m, 4 - m) in modes
    assert all(c.hw is hw for c in cands)
    assert not any(c.mode == "monolithic" for c in cands)
    assert any(c.mode == "monolithic"
               for c in candidate_configs(base, hw, include_monolithic=True))


def test_search_plan_picks_minimum(store):
    hw = perf_model.TPU_V5E.clone(c_edges=3.0, combine="sum")
    best_cfg, best_bundle, scores = search_plan(
        store, PlanConfig(n_lanes=4), hw)
    assert len(scores) == 4                      # model + 3 fixed splits
    best_est = float(best_bundle.plan.est_makespan)
    assert best_est == pytest.approx(min(s["est_makespan"] for s in scores))
    assert best_cfg.hw is hw


def test_store_adopt_plan_atomic_swap(store):
    from repro.core.planner import Planner
    cfg = PlanConfig(n_lanes=3, hw=perf_model.TPU_V5E.clone(c_edges=1.7))
    assert not store.has_plan(cfg)
    bundle = Planner(store, cfg).build()         # built OUTSIDE the cache
    store.adopt_plan(bundle)
    assert store.has_plan(cfg)
    assert store.plan(cfg) is bundle             # the exact adopted object


# ------------------------------------------------- the loop, end to end
def _mk_tuner(**kw):
    kw.setdefault("policy", RetunePolicy(drift_threshold=1.2,
                                         min_samples=4, cooldown_s=0.0))
    kw.setdefault("registry", False)
    return AutoTuner(**kw)


def test_forced_retune_swaps_plan_bit_identically(store, geom):
    app = gas.make_pagerank(max_iters=4)
    cfg = PlanConfig(mode="model", n_lanes=2)
    tuner = _mk_tuner()
    bundle_a = store.plan(cfg)
    ex_a = Executor(store, bundle_a, app, calibrator=tuner.calibrator)
    res_a, _ = ex_a.run()
    event = tuner.retune(store, ex_a, cfg, force=True)
    assert event["applied"], event
    assert tuner.version == 1
    assert tuner.hw is not None and tuner.hw.combine == "sum"
    assert event["chosen"]["est_makespan"] == pytest.approx(
        min(c["est_makespan"] for c in event["candidates"]))
    # the winner was adopted into the plan LRU: resolving + planning is
    # a pure cache hit returning the exact swapped-in bundle
    cfg_b = tuner.resolve_config(PlanConfig(mode="model", n_lanes=2))
    assert cfg_b.hw is tuner.hw
    assert store.has_plan(cfg_b)
    bundle_b = store.plan(cfg_b)
    res_b, _ = Executor(store, bundle_b, app).run()
    assert np.array_equal(res_a, res_b)          # replan != new semantics


def test_resolve_config_respects_user_hw():
    tuner = _mk_tuner()
    tuner.hw = perf_model.TPU_V5E.clone(c_edges=5.0)
    custom = PlanConfig(hw=perf_model.TPU_V5E.clone(c_edges=0.5))
    assert tuner.resolve_config(custom) is custom       # untouched
    scaled = PlanConfig(hw=perf_model.TPU_V5E_SCALED)
    assert tuner.resolve_config(scaled) is scaled
    default = PlanConfig()
    assert tuner.resolve_config(default).hw is tuner.hw


def test_retune_cooldown_and_hysteresis(store):
    tuner = _mk_tuner(policy=RetunePolicy(drift_threshold=1.5,
                                          min_samples=2, cooldown_s=3600.0,
                                          hysteresis=2.0))
    for _ in range(4):
        tuner.drift.add("makespan", 1e-3, 1e-1)   # 100x drift
    assert tuner.should_retune() is not None
    tuner._last_retune_mono = time.monotonic()    # as if one just ran
    assert tuner.should_retune() is None          # cooldown holds
    # hysteresis: after a retune (disarmed), drift must exceed the
    # WIDENED band to trip again
    tuner2 = _mk_tuner(policy=RetunePolicy(drift_threshold=1.5,
                                           min_samples=2, cooldown_s=0.0,
                                           hysteresis=3.0))
    tuner2._armed = False
    for _ in range(4):
        tuner2.drift.add("makespan", 1e-3, 2e-3)  # 2.0x: in widened band
    assert tuner2.should_retune() is None
    for _ in range(8):
        tuner2.drift.add("makespan", 1e-3, 8e-3)  # 8x: beyond 1.5*3.0
    assert tuner2.should_retune() is not None


def test_spec_persist_and_reload_across_tuners(store, geom, tmp_path):
    reg = SpecRegistry(root=str(tmp_path))
    app = gas.make_pagerank(max_iters=3)
    cfg = PlanConfig(n_lanes=2)
    tuner = _mk_tuner(registry=reg, device_kind="cpu@test")
    ex = Executor(store, store.plan(cfg), app, calibrator=tuner.calibrator)
    ex.run()
    event = tuner.retune(store, ex, cfg, force=True)
    assert event["applied"] and event["spec_path"]
    with open(event["spec_path"]) as f:
        on_disk = json.load(f)
    assert on_disk["version"] == 1 and on_disk["source"] == "calibrated"
    # a fresh tuner (fresh process analogue) starts from the calibration
    tuner2 = AutoTuner(registry=reg, device_kind="cpu@test")
    spec = tuner2.load(geom)
    assert spec is not None and tuner2.version == 1
    assert tuner2.hw == tuner.hw


def test_service_drift_triggered_retune(graph, geom):
    tuner = _mk_tuner()
    svc = GraphService(default_geom=geom, default_path="ref",
                       autotune=tuner)
    try:
        svc.register(graph)
        r0, _ = svc.submit(graph, "pagerank").result(timeout=WAIT)
        deadline = time.monotonic() + WAIT
        while tuner.retunes == 0 and time.monotonic() < deadline:
            if any("error" in e or e.get("rejected")
                   for e in tuner.events):
                break
            time.sleep(0.1)
        assert tuner.retunes >= 1, tuner.events   # analytic HW on a CPU
        assert tuner.version >= 1
        r1, _ = svc.submit(graph, "pagerank").result(timeout=WAIT)
        assert np.array_equal(r0, r1)             # swap is invisible
        st = svc.stats()
        assert st["autotune"]["retunes"] >= 1
        assert st["service"]["calibration"]["version"] >= 1
        prom = svc.metrics.render_prometheus()
        assert "regraph_retunes_total" in prom
        assert "regraph_calibration_version" in prom
        assert "regraph_calibration_age_seconds" in prom
    finally:
        svc.close()


def test_service_retune_now_and_control_plane(graph, geom):
    from repro.control import ControlPlane
    tuner = _mk_tuner()
    svc = GraphService(default_geom=geom, default_path="ref",
                       autotune=tuner)
    cp = ControlPlane(svc)
    try:
        svc.register(graph)
        rec = cp.retune_job(graph)
        assert str(rec.state).lower().endswith("done")
        assert rec.metrics["applied"] is True
        assert tuner.retunes == 1
        assert svc.metrics.retunes == 1
        snap = cp.metrics_snapshot()
        assert snap["autotune"]["version"] == 1
    finally:
        cp.close()


def test_service_without_autotune_unchanged(graph, geom):
    svc = GraphService(default_geom=geom, default_path="ref")
    try:
        svc.register(graph)
        svc.submit(graph, "pagerank").result(timeout=WAIT)
        assert svc.stats()["autotune"] is None
        assert svc.stats()["service"]["calibration"] is None
        with pytest.raises(RuntimeError):
            svc.retune_now(graph)
    finally:
        svc.close()


def test_serial_host_makespan_estimate(store):
    """combine="sum" executors compare measured iterations against the
    SUM of lane estimates (lanes run back-to-back on the host), not the
    parallel-lanes plan makespan."""
    cfg_sum = PlanConfig(n_lanes=2,
                         hw=perf_model.TPU_V5E.clone(c_edges=2.0,
                                                     combine="sum"))
    app = gas.make_pagerank(max_iters=2)
    ex = Executor(store, store.plan(cfg_sum), app)
    lane_sum = sum(e for e, _ in ex._lane_est)
    assert ex._est_iteration == pytest.approx(lane_sum)
    ex2 = Executor(store, store.plan(PlanConfig(n_lanes=2)), app)
    assert ex2._est_iteration == ex2.plan.est_makespan
