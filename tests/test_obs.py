"""Observability tests: tracer/span mechanics, carriers across the
queue and process-pool boundaries, per-lane perf-model drift, and the
Chrome-trace export through the HTTP job API.

Tracer unit tests are pure Python. The integration tests run tiny RMAT
graphs on the ref path (control-plane suite geometry); the pool test
pays one spawn startup and is the slowest item here.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import api
from repro import obs
from repro.control import ControlPlane
from repro.core.types import Geometry
from repro.graphs.rmat import rmat
from repro.obs import NOOP_SPAN, DriftAccumulator, SpanContext, Tracer

GEOM = Geometry(U=512, W=512, T=512, E_BLK=128, big_batch=2)
WAIT = 300.0


@pytest.fixture(scope="module")
def g1():
    return rmat(8, 6, seed=1, weighted=True)


# ---------------------------------------------------------------------------
# tracer / span mechanics (no jax)
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_off_is_noop(self):
        # library code calls obs.span unconditionally; with no tracer
        # bound to the thread it must return the shared no-op
        assert obs.span("anything") is NOOP_SPAN
        with obs.span("anything") as sp:
            sp.set(x=1).end()           # all inert

    def test_nesting_follows_thread_local_context(self):
        tr = Tracer()
        root = tr.start_trace("root", "test")
        with tr.activate(root.context):
            with obs.span("outer") as outer:
                with obs.span("inner") as inner:
                    assert inner.parent_id == outer.span_id
                assert obs.current_ctx().span_id == outer.span_id
            assert obs.current_ctx() == root.context
        root.end()
        spans = {d["name"]: d for d in tr.export(root.trace_id)}
        assert spans["outer"]["parent_id"] == root.span_id
        assert spans["inner"]["parent_id"] == spans["outer"]["span_id"]
        assert all(d["trace_id"] == root.trace_id for d in spans.values())

    def test_end_is_idempotent_and_records_once(self):
        tr = Tracer()
        root = tr.start_trace("r")
        root.end(outcome="first")
        dur = root.dur
        root.end(outcome="second")      # no re-record, no new duration
        assert root.dur == dur
        spans = tr.export(root.trace_id)
        assert len(spans) == 1
        # the recorded dict is the FIRST end()'s snapshot
        assert spans[0]["attrs"]["outcome"] == "first"

    def test_exception_marks_error_attr(self):
        tr = Tracer()
        root = tr.start_trace("r")
        with pytest.raises(ValueError):
            with tr.activate(root.context):
                with obs.span("doomed"):
                    raise ValueError("boom")
        d = tr.export(root.trace_id)[0]
        assert d["name"] == "doomed"
        assert "ValueError: boom" in d["attrs"]["error"]

    def test_backdated_start(self):
        tr = Tracer()
        t0 = time.time() - 5.0
        sp = tr.start_trace("r", t_start=t0)
        sp.end(t_end=t0 + 2.0)
        d = tr.export(sp.trace_id)[0]
        assert d["t_start"] == t0 and d["dur"] == pytest.approx(2.0)

    def test_adopt_reparents_foreign_spans(self):
        # simulate the pool worker: its own tracer, own trace id
        worker = Tracer()
        wroot = worker.start_trace("pool.worker.build", "pool-worker")
        with worker.activate(wroot.context):
            with obs.span("store.dbg"):
                pass
        wroot.end()
        shipped = worker.export(wroot.trace_id)

        parent = Tracer()
        proot = parent.start_trace("job")
        dispatch = parent.start_span("pool.build_store",
                                     parent=proot.context)
        n = parent.adopt(shipped, dispatch.context)
        dispatch.end()
        proot.end()
        assert n == len(shipped) == 2
        spans = {d["name"]: d for d in parent.export(proot.trace_id)}
        # every adopted span now belongs to the parent's trace, and the
        # worker's ROOT hangs off the dispatch span; the child keeps its
        # worker-side parent link
        assert all(d["trace_id"] == proot.trace_id
                   for d in spans.values())
        assert (spans["pool.worker.build"]["parent_id"]
                == spans["pool.build_store"]["span_id"])
        assert (spans["store.dbg"]["parent_id"]
                == spans["pool.worker.build"]["span_id"])

    def test_bounded_spans_and_traces(self):
        tr = Tracer(max_traces=2, max_spans_per_trace=3)
        roots = [tr.start_trace(f"t{i}") for i in range(4)]
        for r in roots:
            r.end()
        assert len(tr.trace_ids()) == 2     # LRU kept the newest two
        keep = tr.start_trace("keep")
        with tr.activate(keep.context):
            for i in range(10):
                with obs.span(f"s{i}"):
                    pass
        keep.end()
        assert len(tr.export(keep.trace_id)) == 3
        assert tr.stats()["spans_dropped"] >= 8

    def test_chrome_trace_format(self, tmp_path):
        tr = Tracer()
        root = tr.start_trace("job", "service", app="pagerank")
        with tr.activate(root.context):
            with obs.span("work", "executor", lane=0):
                pass
        root.end()
        path = tmp_path / "trace.json"
        doc = tr.to_chrome_trace(path=str(path), trace_id=root.trace_id)
        loaded = json.loads(path.read_text())
        assert loaded == json.loads(json.dumps(doc))    # serializable
        assert loaded["displayTimeUnit"] == "ms"
        events = loaded["traceEvents"]
        assert len(events) == 2
        for ev in events:
            assert ev["ph"] == "X"
            assert ev["ts"] > 0 and ev["dur"] >= 0      # microseconds
            assert {"name", "cat", "pid", "tid", "args"} <= ev.keys()
        by_name = {e["name"]: e for e in events}
        assert by_name["work"]["args"]["lane"] == 0
        assert (by_name["work"]["args"]["parent_id"]
                == by_name["job"]["args"]["span_id"])

    def test_span_context_is_picklable_tuple(self):
        import pickle
        ctx = SpanContext("tid", "sid")
        assert pickle.loads(pickle.dumps(ctx)) == ("tid", "sid")
        assert ctx.trace_id == "tid" and ctx.span_id == "sid"

    def test_cross_thread_carrier(self):
        tr = Tracer()
        root = tr.start_trace("root")
        done = threading.Event()

        def worker(ctx):
            with tr.activate(ctx):
                with obs.span("in-worker"):
                    pass
            done.set()

        t = threading.Thread(target=worker, args=(root.context,))
        t.start()
        t.join(10)
        assert done.is_set()
        root.end()
        d = {s["name"]: s for s in tr.export(root.trace_id)}
        assert d["in-worker"]["parent_id"] == root.span_id


class TestDrift:
    def test_ratios(self):
        d = DriftAccumulator()
        d.add("little", 1.0, 2.0)
        d.add("little", 1.0, 4.0)
        rep = d.report()["little"]
        assert rep["n"] == 2
        assert rep["ratio"] == pytest.approx(3.0)       # 6.0 / 2.0
        assert rep["ratio_min"] == pytest.approx(2.0)
        assert rep["ratio_max"] == pytest.approx(4.0)

    def test_nonpositive_estimate_excluded_from_ratio(self):
        d = DriftAccumulator()
        d.add("idle", 0.0, 1.0)
        rep = d.report()["idle"]
        assert rep["n"] == 1 and rep["ratio"] is None

    def test_parent_chaining(self):
        parent = DriftAccumulator()
        child = DriftAccumulator(parent=parent)
        child.add("big", 2.0, 3.0)
        assert parent.report()["big"]["n"] == 1
        child.clear()
        assert parent.report()["big"]["n"] == 1     # parent unaffected


# ---------------------------------------------------------------------------
# executor: traced per-lane path
# ---------------------------------------------------------------------------

class TestExecutorTracing:
    @pytest.fixture(scope="class")
    def compiled(self, g1):
        return api.compile(g1, "pagerank", geom=GEOM, path="ref",
                           n_lanes=2)

    def test_traced_path_bit_identical_to_fused(self, g1, compiled):
        ref, _ = compiled.run(max_iters=4)
        other = api.compile(g1, "pagerank", geom=GEOM, path="ref",
                            n_lanes=2)
        tr = Tracer()
        root = tr.start_trace("run")
        with tr.activate(root.context):
            traced, meta = other.run(max_iters=4)
        root.end()
        # same single merge+apply program region -> bit identity
        np.testing.assert_array_equal(np.asarray(traced), np.asarray(ref))
        names = [d["name"] for d in tr.export(root.trace_id)]
        assert names.count("executor.iteration") == meta["iterations"]
        assert "executor.lane" in names and "executor.merge_apply" in names

    def test_lane_spans_carry_model_estimates(self, g1):
        c = api.compile(g1, "pagerank", geom=GEOM, path="ref", n_lanes=2)
        tr = Tracer()
        root = tr.start_trace("run")
        with tr.activate(root.context):
            c.run(max_iters=2)
        root.end()
        lanes = [d for d in tr.export(root.trace_id)
                 if d["name"] == "executor.lane"]
        assert lanes
        for d in lanes:
            assert d["attrs"]["kind"] in ("little", "big", "mixed", "idle")
            assert d["attrs"]["est_time"] >= 0.0
            assert d["attrs"]["n_entries"] >= 1
        # measured-vs-estimated drift was fed from the same runs
        drift = c.executor.stats()["drift"]
        assert "makespan" in drift and drift["makespan"]["n"] >= 2
        lane_kinds = {d["attrs"]["kind"] for d in lanes}
        assert lane_kinds <= set(drift)

    def test_lane_detail_off_keeps_fused_path(self, g1):
        c = api.compile(g1, "pagerank", geom=GEOM, path="ref", n_lanes=2)
        tr = Tracer(lane_detail=False)
        root = tr.start_trace("run")
        with tr.activate(root.context):
            c.run(max_iters=2)
        root.end()
        names = [d["name"] for d in tr.export(root.trace_id)]
        assert "executor.lane" not in names
        # coarse drift still sampled
        assert c.executor.stats()["drift"]["makespan"]["n"] >= 2


# ---------------------------------------------------------------------------
# end-to-end: HTTP job API -> Chrome trace, across queue + pool
# ---------------------------------------------------------------------------

def _get(url):
    try:
        with urllib.request.urlopen(url) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _post(url, body=None):
    req = urllib.request.Request(
        url, data=json.dumps(body or {}).encode(), method="POST",
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


class TestEndToEndTrace:
    @pytest.fixture(scope="class")
    def plane(self, g1):
        # pool=1: the store build crosses the process boundary, so the
        # trace must carry across the pickled envelope. prepare=False so
        # the build happens INSIDE the traced job, not at register time.
        with ControlPlane(workers=1, pool=1, default_geom=GEOM,
                          default_path="ref") as cp:
            cp.register(g1, prepare=False)
            cp.serve_http()
            yield cp

    @pytest.fixture(scope="class")
    def base(self, plane):
        return f"http://127.0.0.1:{plane._http_server.server_address[1]}"

    def test_trace_covers_queue_pool_plan_execute(self, plane, base, g1):
        st, rec = _post(base + "/jobs", {
            "fingerprint": g1.fingerprint(), "app": "pagerank",
            "max_iters": 3})
        assert st == 201
        jid = rec["id"]
        st, _ = _get(base + f"/jobs/{jid}/result?timeout={WAIT}")
        assert st == 200
        st, doc = _get(base + f"/jobs/{jid}/trace")
        assert st == 200
        events = doc["traceEvents"]
        names = [e["name"] for e in events]
        # end-to-end span coverage: submit -> queue -> pool worker ->
        # store/plan -> per-lane execute -> merge/apply
        for needle in ("control.submit", "job:pagerank", "queue.wait",
                       "pool.build_store", "pool.worker.build",
                       "store.dbg", "store.partition", "service.plan",
                       "plan.build", "plan.pack", "service.execute",
                       "executor.iteration", "executor.lane",
                       "executor.merge_apply"):
            assert needle in names, (needle, sorted(set(names)))
        # the job record carries the trace id, and every event —
        # including the ones recorded in the worker PROCESS — was
        # re-parented into that one trace
        st, full = _get(base + f"/jobs/{jid}")
        by_name = {e["name"]: e for e in events}
        ids = {e["args"]["span_id"] for e in events}
        for e in events:
            parent = e["args"].get("parent_id")
            assert parent is None or parent in ids, e["name"]
        wroot = by_name["pool.worker.build"]
        assert (wroot["args"]["parent_id"]
                == by_name["pool.build_store"]["args"]["span_id"])
        assert wroot["args"]["pid"] != by_name["queue.wait"]["args"].get(
            "pid")  # really another process (worker stamps its os.getpid)
        # lane spans expose the perf-model estimate next to measured dur
        lane = by_name["executor.lane"]
        assert "est_time" in lane["args"] and lane["dur"] >= 0
        # drift aggregated into service stats and the prometheus gauges
        snap = plane.metrics_snapshot()
        assert snap["drift"]["makespan"]["n"] >= 1
        with urllib.request.urlopen(base + "/metrics") as r:
            prom = r.read().decode()
        assert 'regraph_perf_model_drift{kind="makespan"}' in prom
        # valid, self-consistent Chrome JSON: ph/ts/dur on every event
        assert doc["displayTimeUnit"] == "ms"
        assert all(e["ph"] == "X" and e["ts"] > 0 for e in events)
        assert full["trace_id"]

    def test_trace_404s(self, plane, base):
        st, err = _get(base + "/jobs/job-99999999/trace")
        assert st == 404 and err["error"] == "no_trace"

    def test_update_job_gets_its_own_trace(self, plane, g1):
        from repro.streaming import random_delta
        d = random_delta(g1, churn=0.02, seed=11)
        rec = plane.update_job(g1.fingerprint(), d)
        doc = plane.trace(rec.id)
        assert doc is not None
        names = [e["name"] for e in doc["traceEvents"]]
        assert "service.update" in names
        # the splice ran in the apply-lane worker process
        assert "pool.apply" in names and "pool.worker.apply" in names
        assert "plan.rebuild" in names

    def test_tracer_stats_exposed(self, plane):
        snap = plane.metrics_snapshot()
        assert snap["tracer"]["spans_recorded"] > 0
        assert snap["tracer"]["traces"] >= 1
