"""Multi-device tests — each spawns a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test
process keeps seeing exactly one device."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

ENV = {**os.environ,
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": os.path.abspath(
           os.path.join(os.path.dirname(__file__), "..", "src"))}

# The LM-side sharding tests are written against the jax>=0.6 mesh API
# (jax.shard_map, jax.sharding.AxisType, make_mesh axis_types). The graph
# engine's own distributed path ships a 0.4.x compat shim
# (core/distributed.py), but porting the off-paper LM/optimizer sharding
# stack is not worth it on the pinned 0.4.x line.
NEEDS_JAX06 = pytest.mark.xfail(
    not (hasattr(jax, "shard_map") and hasattr(jax.sharding, "AxisType")),
    reason="needs jax>=0.6 sharding APIs (jax.shard_map, "
           "jax.sharding.AxisType); pinned jax is 0.4.x",
    strict=False)


def run_py(code: str, timeout=600):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_distributed_graph_engine_matches_single():
    """DistributedEngine now builds directly on the layered API: one
    GraphStore shared by the single-device baseline and the shard_map
    path (one plan cache, one preprocessing pass)."""
    run_py("""
        import numpy as np
        from repro.graphs.rmat import rmat
        from repro.core.types import Geometry
        from repro.core import gas
        from repro.core.planner import PlanConfig
        from repro.core.store import GraphStore
        from repro.core.distributed import DistributedEngine
        g = rmat(10, 8, seed=3)
        geom = Geometry(U=1024, W=512, T=512, E_BLK=128, big_batch=4)
        store = GraphStore(g, geom=geom)
        cfg = PlanConfig(n_lanes=8)
        for mk, iters in [(lambda: gas.make_pagerank(max_iters=4), 4),
                          (lambda: gas.make_bfs(root=2), 8)]:
            app = mk()
            p1,_ = store.executor(app, cfg, path="ref").run(max_iters=iters)
            d = DistributedEngine(store, app, config=cfg)
            p2,_ = d.run(max_iters=iters)
            assert np.allclose(p1, p2, rtol=1e-5, atol=1e-7), app.name
        assert store.stats()["cached_plans"] == 1   # one shared plan
        print("OK")
    """)


@NEEDS_JAX06
def test_sharded_train_step_matches_single_device():
    run_py("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, reduced
        from repro.models.api import build_model
        from repro.optim.adamw import adamw
        from repro.train.step import make_train_step
        from repro.sharding.specs import tree_shardings, batch_shardings
        cfg = dataclasses.replace(reduced(get_config("qwen2_1p5b")),
                                  dtype="float32")
        model = build_model(cfg)
        opt = adamw(lr=1e-2, weight_decay=0.0)
        params = model.init(jax.random.key(0))
        st = opt.init(params)
        rs = np.random.RandomState(0)
        tok = jnp.asarray(rs.randint(0, cfg.vocab_size, (8, 32)), jnp.int32)
        batch = {"tokens": tok, "labels": tok}
        step = make_train_step(model, opt)
        p1, s1, m1 = jax.jit(step)(params, st, batch)
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        with mesh:
            psh = tree_shardings(params, mesh)
            ssh = tree_shardings(st, mesh)
            bsh = batch_shardings(batch, mesh)
            pd = jax.device_put(params, psh)
            sd = jax.device_put(st, ssh)
            bd = jax.device_put(batch, bsh)
            p2, s2, m2 = jax.jit(step, in_shardings=(psh, ssh, bsh),
                                 out_shardings=(psh, ssh, None))(pd, sd, bd)
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-3
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-3, atol=1e-4)
        print("OK")
    """)


@NEEDS_JAX06
def test_sharded_moe_matches_local():
    run_py("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, reduced
        from repro.models import moe
        cfg = dataclasses.replace(reduced(get_config("granite_moe_3b_a800m")),
                                  moe_dispatch="biglittle")
        lp_full = moe.init_layer_params(cfg, jax.random.key(1))
        lp = {k: jax.tree.map(lambda a: a.astype(jnp.float32), lp_full[k])
              for k in ("router", "we_gate", "we_up", "we_down")}
        x = jax.random.normal(jax.random.key(2), (8, 16, cfg.d_model),
                              jnp.float32) * 0.5
        out_local, _ = moe.moe_ffn(cfg, lp, x, capacity_factor=50.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        with mesh:
            out_shard, _ = jax.jit(lambda lp, x: moe.moe_ffn(
                cfg, lp, x, capacity_factor=50.0))(lp, x)
        assert np.allclose(np.asarray(out_local), np.asarray(out_shard),
                           rtol=1e-4, atol=1e-5)
        print("OK")
    """)


@NEEDS_JAX06
def test_compressed_psum_cross_pod():
    run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.optim import grad_compress as gc
        mesh = jax.make_mesh((2, 4), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        grads = {"w": jnp.arange(32.0).reshape(4, 8) / 100}
        resid = gc.zero_residual(grads)

        @partial(jax.shard_map, mesh=mesh, in_specs=(P(), P()),
                 out_specs=(P(), P()), check_vma=False)
        def reduce_fn(g, r):
            red, r2 = gc.compressed_psum(g, r, "pod", codec="int8")
            red = jax.tree.map(lambda x: x / 2, red)  # pods held same grads
            return red, r2
        red, r2 = reduce_fn(grads, resid)
        # mean over 2 pods of identical grads == g (within int8 error)
        err = np.abs(np.asarray(red["w"]) - np.asarray(grads["w"])).max()
        assert err < 0.01, err
        print("OK")
    """)


@NEEDS_JAX06
def test_elastic_checkpoint_restore_new_mesh(tmp_path):
    run_py(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
        mesh1 = jax.make_mesh((8,), ("data",),
                              axis_types=(jax.sharding.AxisType.Auto,))
        t1 = jax.device_put(tree, NamedSharding(mesh1, P("data")))
        mgr = CheckpointManager(r"{tmp_path}")
        mgr.save(5, t1, blocking=True)
        # restore onto a DIFFERENT mesh layout (elastic rescale)
        mesh2 = jax.make_mesh((2, 4), ("a", "b"),
                              axis_types=(jax.sharding.AxisType.Auto,)*2)
        sh2 = {{"w": NamedSharding(mesh2, P("b", "a"))}}
        step, back = mgr.restore(like=tree, shardings=sh2)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(back["w"]),
                                      np.asarray(tree["w"]))
        print("OK")
    """)
