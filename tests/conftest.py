"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 device;
multi-device tests spawn subprocesses with their own flags."""
import numpy as np
import pytest

from repro.core.types import Geometry
from repro.graphs.rmat import rmat


@pytest.fixture(scope="session")
def small_graph():
    return rmat(10, 8, seed=3)          # 1024 vertices, ~6.6K edges


@pytest.fixture(scope="session")
def tiny_graph():
    return rmat(8, 6, seed=1)           # 256 vertices


@pytest.fixture(scope="session")
def small_geom():
    return Geometry(U=1024, W=512, T=512, E_BLK=128, big_batch=4)


@pytest.fixture(scope="session")
def tiny_geom():
    return Geometry(U=512, W=512, T=512, E_BLK=128, big_batch=2)


@pytest.fixture
def rng():
    return np.random.RandomState(0)
