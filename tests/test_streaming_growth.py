"""Grow-the-graph streaming tests.

Covers the vertex-growth delta path end to end: growth deltas
bit-identical to a cold rebuild of the post-growth graph under the
extended frozen permutation (all five apps, ref and pallas-interpret),
grow-then-remove via delta composition, growth crossing a partition
boundary, growth on a sharded store with resident-payload accounting,
delta-chain compaction with preserved lineage, the placement-drift
rebalance trigger, the DBG re-registration swap, and the serving /
control-plane integration (executor purges, job records, typed HTTP
errors, regraph_* gauges). A hypothesis differential property fuzzes
chains mixing growth, removes, updates and compaction.
"""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import api
from repro.core import gas
from repro.core.planner import PlanConfig
from repro.core.store import GraphStore
from repro.core.types import Geometry
from repro.graphs.formats import from_edges
from repro.graphs.rmat import rmat
from repro.serve_graph.fingerprint import store_key
from repro.sharding import LanePlacement
from repro.streaming import (RegroupPolicy, apply_delta,
                             apply_delta_to_graph, chain_fingerprint,
                             compact_deltas, compose_deltas,
                             grouping_drift, grown_num_vertices,
                             make_delta, random_delta, rebuild_plans,
                             reregister)

GEOM = Geometry(U=256, W=128, T=128, E_BLK=128, big_batch=2)
CFG = PlanConfig(n_lanes=4)

APPS = [
    ("pagerank", {}),
    ("bfs", {"root": 0}),
    ("sssp", {"root": 0}),
    ("wcc", {}),
    ("closeness", {"sources": np.arange(4)}),
]


@pytest.fixture(scope="module")
def wgraph():
    return rmat(11, 8, seed=3, weighted=True)   # 2048 V -> 8 partitions


@pytest.fixture(scope="module")
def wstore(wgraph):
    return GraphStore(wgraph, geom=GEOM)


def _run(store, app, kw, path, max_iters=5):
    a = api.BUILTIN_APPS[app](**kw)
    return api.compile(None, a, store=store, config=CFG,
                       path=path).run(max_iters=max_iters)[0]


def _grown_perm(base_perm, new_v):
    """The documented growth layout: new vertices identity-mapped onto
    the tail of the frozen DBG id space."""
    v = base_perm.shape[0]
    return np.concatenate([np.asarray(base_perm),
                           np.arange(v, new_v, dtype=np.int32)])


def _assert_stores_identical(inc, cold):
    for k in ("src", "dst", "weights"):
        assert np.array_equal(inc.edges[k], cold.edges[k]), k
    assert inc.infos == cold.infos
    assert inc.V_pad == cold.V_pad
    assert np.array_equal(inc.perm, cold.perm)


# ---------------------------------------------------------------------------
# Growth delta construction
# ---------------------------------------------------------------------------

def test_grown_num_vertices_unit():
    fp = "ab" * 16
    assert grown_num_vertices(10, make_delta(fp)) == 10
    assert grown_num_vertices(10, make_delta(fp, add=([1], [12]))) == 13
    assert grown_num_vertices(10, make_delta(fp, add=([14], [1]))) == 15
    assert grown_num_vertices(10, make_delta(fp, grow_to=20)) == 20
    # grow_to below the base count is a harmless floor
    assert grown_num_vertices(10, make_delta(fp, grow_to=4)) == 10
    # the max of adds and grow_to wins
    d = make_delta(fp, add=([1], [25]), grow_to=12)
    assert grown_num_vertices(10, d) == 26
    with pytest.raises(ValueError):
        make_delta(fp, grow_to=-1)


def test_grow_to_changes_fingerprint_but_absence_is_legacy():
    """grow_to folds into the delta fingerprint only when SET, so every
    pre-growth delta digest (and every chained snapshot fingerprint
    built from one) is unchanged."""
    fp = "cd" * 16
    plain = make_delta(fp, add=([0], [1]))
    grown = make_delta(fp, add=([0], [1]), grow_to=50)
    assert plain.fingerprint() != grown.fingerprint()
    assert plain.fingerprint() == make_delta(fp, add=([0], [1])).fingerprint()


def test_random_delta_grow_frac(wgraph):
    d = random_delta(wgraph, churn=0.01, seed=7, grow_frac=0.02)
    V = wgraph.num_vertices
    assert d.grow_to is not None and d.grow_to > V
    new_mask = (d.add_src >= V) | (d.add_dst >= V)
    assert new_mask.sum() >= 1, "grow_frac must add edges on new ids"
    # growth edges attach preferentially: every new-id edge touches
    # either a sampled existing endpoint or another new id
    assert d.add_weights is not None and \
        d.add_weights.shape[0] == d.num_adds
    # no growth requested -> classic churn delta, no floor
    d0 = random_delta(wgraph, churn=0.01, seed=7)
    assert d0.grow_to is None
    assert (d0.add_src < V).all() and (d0.add_dst < V).all()


# ---------------------------------------------------------------------------
# Growth apply == cold rebuild (the tentpole equivalence, grown)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("churn,grow_frac", [
    (0.0, 0.02),     # pure growth
    (0.01, 0.02),    # growth + uniform churn
    (0.05, 0.10),    # heavy both
])
def test_growth_apply_matches_cold_rebuild(wgraph, wstore, churn,
                                           grow_frac):
    delta = random_delta(wgraph, churn=churn, seed=17,
                         grow_frac=grow_frac)
    res = apply_delta(wstore, delta)
    post = apply_delta_to_graph(wgraph, delta)
    assert post.num_vertices == grown_num_vertices(wgraph.num_vertices,
                                                   delta)
    cold = GraphStore(post, geom=GEOM,
                      perm=_grown_perm(wstore.perm, post.num_vertices))
    _assert_stores_identical(res.store, cold)
    assert res.stats["grown_vertices"] == \
        post.num_vertices - wgraph.num_vertices
    assert res.fingerprint == chain_fingerprint(wgraph.fingerprint(),
                                                delta.fingerprint())


@pytest.mark.parametrize("app,kw", APPS)
def test_growth_apps_bit_identical_ref(wgraph, wstore, app, kw):
    """Growth delta applied incrementally runs every builtin app
    bit-identically to a cold GraphStore of the post-growth graph
    (extended frozen permutation) — the acceptance gate, ref path."""
    delta = random_delta(wgraph, churn=0.02, seed=23, update_frac=0.005,
                         grow_frac=0.03)
    res = apply_delta(wstore, delta)
    post = apply_delta_to_graph(wgraph, delta)
    cold = GraphStore(post, geom=GEOM,
                      perm=_grown_perm(wstore.perm, post.num_vertices))
    assert np.array_equal(_run(res.store, app, kw, "ref"),
                          _run(cold, app, kw, "ref")), app


@pytest.mark.parametrize("app,kw", APPS)
def test_growth_apps_bit_identical_pallas_interpret(app, kw):
    """Same growth equivalence through the Pallas kernels (interpret on
    CPU). Smaller graph: interpret mode is slow."""
    g = rmat(9, 6, seed=5, weighted=True)   # 512 V -> 2 partitions
    store = GraphStore(g, geom=GEOM)
    delta = random_delta(g, churn=0.03, seed=29, update_frac=0.01,
                         grow_frac=0.05)
    res = apply_delta(store, delta)
    post = apply_delta_to_graph(g, delta)
    cold = GraphStore(post, geom=GEOM,
                      perm=_grown_perm(store.perm, post.num_vertices))
    assert np.array_equal(_run(res.store, app, kw, "pallas", max_iters=3),
                          _run(cold, app, kw, "pallas", max_iters=3)), app


def test_growth_crosses_partition_boundary(wgraph, wstore):
    """Growth spanning MULTIPLE new dst-range partitions: new vertices
    land in two fresh tail partitions and the old last partition's
    dst_hi stays correct."""
    V, U = wgraph.num_vertices, GEOM.U
    fp = wgraph.fingerprint()
    delta = make_delta(
        fp,
        add=([1, 2, 3], [V, V + U, V + U + 3],
             [0.5, 0.25, 0.125]))
    res = apply_delta(wstore, delta)
    assert res.stats["new_partitions"] == 2
    assert res.store.graph.num_vertices == V + U + 4
    post = apply_delta_to_graph(wgraph, delta)
    cold = GraphStore(post, geom=GEOM,
                      perm=_grown_perm(wstore.perm, post.num_vertices))
    _assert_stores_identical(res.store, cold)
    # the two tail partitions own exactly the new dst ranges
    assert res.store.infos[-2].dst_lo == V and \
        res.store.infos[-2].dst_hi == V + U
    assert res.store.infos[-1].dst_hi == V + U + 4


def test_grow_to_only_materializes_empty_tail(wgraph, wstore):
    """A delta with ONLY grow_to (no adds) grows the vertex set with
    zero-degree vertices and empty tail partitions."""
    V = wgraph.num_vertices
    delta = make_delta(wgraph.fingerprint(), grow_to=V + 300)
    res = apply_delta(wstore, delta)
    assert res.store.graph.num_vertices == V + 300
    assert res.stats["grown_vertices"] == 300
    assert res.stats["dirty_partitions"] == 0
    post = apply_delta_to_graph(wgraph, delta)
    cold = GraphStore(post, geom=GEOM,
                      perm=_grown_perm(wstore.perm, V + 300))
    _assert_stores_identical(res.store, cold)
    for info in res.store.infos[8:]:
        assert info.num_edges == 0
    assert np.array_equal(_run(res.store, "pagerank", {}, "ref"),
                          _run(cold, "pagerank", {}, "ref"))


def test_grow_then_remove_still_grows(wgraph, wstore):
    """grow (add an edge on a new vertex) then remove that edge: the
    composed delta must still grow V — grow_to carries the floor —
    and chained incremental apply == composed apply == cold."""
    V = wgraph.num_vertices
    fp = wgraph.fingerprint()
    d1 = make_delta(fp, add=([1], [V], [0.5]))
    r1 = apply_delta(wstore, d1)
    d2 = make_delta(r1.fingerprint, remove=([1], [V]))
    r2 = apply_delta(r1.store, d2)
    assert r2.store.graph.num_vertices == V + 1
    assert r2.store.graph.num_edges == wgraph.num_edges

    composed, tip = compact_deltas([d1, d2])
    assert tip == r2.fingerprint
    assert composed.num_changes == 0 and composed.grow_to == V + 1
    post = apply_delta_to_graph(wgraph, composed, check_fp=False)
    assert post.num_vertices == V + 1
    cold = GraphStore(post, geom=GEOM, perm=_grown_perm(wstore.perm, V + 1))
    _assert_stores_identical(r2.store, cold)


# ---------------------------------------------------------------------------
# Delta composition / compaction
# ---------------------------------------------------------------------------

def test_compose_deltas_state_machine():
    fp = "ef" * 16
    # add+remove cancels; add+update keeps the add with the new weight
    d1 = make_delta(fp, add=([0, 1], [5, 6], [1.0, 2.0]))
    fp1 = chain_fingerprint(fp, d1.fingerprint())
    d2 = make_delta(fp1, remove=([0], [5]), update=([1], [6], [9.0]))
    c = compose_deltas(d1, d2)
    assert c.num_adds == 1 and c.num_removes == 0 and c.num_updates == 0
    assert float(c.add_weights[0]) == 9.0
    # remove+add (weighted) folds to an update; update+remove to remove
    d3 = make_delta(fp, remove=([2], [7]), update=([3], [8], [4.0]))
    fp3 = chain_fingerprint(fp, d3.fingerprint())
    d4 = make_delta(fp3, add=([2], [7], [5.0]), remove=([3], [8]))
    c2 = compose_deltas(d3, d4)
    assert c2.num_updates == 1 and float(c2.update_weights[0]) == 5.0
    assert c2.num_removes == 1 and int(c2.remove_src[0]) == 3
    # update+update keeps the LAST weight
    d5 = make_delta(fp, update=([4], [9], [1.5]))
    d6 = make_delta(chain_fingerprint(fp, d5.fingerprint()),
                    update=([4], [9], [2.5]))
    c3 = compose_deltas(d5, d6)
    assert c3.num_updates == 1 and float(c3.update_weights[0]) == 2.5
    # invalid sequences surface corruption instead of hiding it
    da = make_delta(fp, add=([0], [1], [1.0]))
    with pytest.raises(ValueError):   # add then add of the same edge
        compose_deltas(da, make_delta(
            chain_fingerprint(fp, da.fingerprint()), add=([0], [1], [2.0])))
    dr = make_delta(fp, remove=([0], [1]))
    with pytest.raises(ValueError):   # remove then remove
        compose_deltas(dr, make_delta(
            chain_fingerprint(fp, dr.fingerprint()), remove=([0], [1])))


def test_compact_deltas_lineage_and_equivalence(wgraph, wstore):
    """A compacted chain replays as ONE delta yet keeps the chain's
    original tip fingerprint — identity is preserved, replay is O(1)."""
    graph, fp = wgraph, wgraph.fingerprint()
    store = wstore
    deltas = []
    for i, seed in enumerate((43, 47, 53)):
        d = random_delta(graph, churn=0.01, seed=seed, base_fp=fp,
                         grow_frac=0.02 if i == 1 else 0.0)
        deltas.append(d)
        res = apply_delta(store, d)
        graph = apply_delta_to_graph(graph, d, check_fp=False)
        store, fp = res.store, res.fingerprint

    composed, tip = compact_deltas(deltas)
    assert tip == fp, "compaction must keep the ORIGINAL tip identity"
    replay = apply_delta_to_graph(wgraph, composed, check_fp=False)
    assert replay.fingerprint() == graph.fingerprint()
    assert replay.num_vertices == graph.num_vertices
    # strict mode rejects a non-contiguous chain
    with pytest.raises(ValueError, match="not contiguous"):
        compact_deltas([deltas[0], deltas[2]])
    # non-strict composes anyway (caller owns lineage then)
    compact_deltas([deltas[0], deltas[2]], strict=False)


# ---------------------------------------------------------------------------
# Sharded growth + placement drift
# ---------------------------------------------------------------------------

def test_sharded_growth_keeps_resident_payloads():
    """Growth on a sharded store: clean lanes' device payloads stay
    resident (shards_reused accounting) and the grown sharded run is
    bit-identical to the fused path."""
    g = rmat(12, 8, seed=7, weighted=True)   # 4096 V -> 16 partitions
    store = GraphStore(g, geom=GEOM)
    cfg = PlanConfig(n_lanes=8)
    ex = store.executor(gas.make_pagerank(max_iters=2), cfg, path="ref",
                        shard=1)
    ex.run(max_iters=2)
    old_sh = store.plan(cfg).sharded_lanes(ex.devices)
    delta = random_delta(g, churn=0.005, seed=11, hot_frac=0.05,
                         grow_frac=0.01)
    res = apply_delta(store, delta)
    s = res.stats
    assert s["grown_vertices"] > 0
    assert s["shards_reused"] >= 1, "clean lanes must stay resident"
    assert s["shard_bytes_reused"] > 0
    new_sh = res.store.plan(cfg).sharded_lanes(ex.devices)
    shared = sum(1 for a, b in zip(old_sh.lanes, new_sh.lanes)
                 if a and a is b)
    assert shared == s["shards_reused"]
    pf, _ = res.store.executor(gas.make_pagerank(max_iters=2), cfg,
                               path="ref").run(max_iters=2)
    ps, _ = res.store.executor(gas.make_pagerank(max_iters=2), cfg,
                               path="ref", shard=1).run(max_iters=2)
    np.testing.assert_array_equal(pf, ps)


def test_placement_rebalance_trigger(monkeypatch):
    """rebuild_plans drops keep= pins and re-places from scratch when
    the re-placement's imbalance exceeds the threshold. One CPU device
    can never exceed max/mean = 1.0, so the drift predicate is forced
    to fire — the machinery under test is the pop-and-replace path and
    its accounting."""
    assert not LanePlacement(
        n_devices=2, num_little_lanes=1, device_of_lane=(0, 1),
        lane_ests=(1.0, 1.0)).needs_rebalance(1.5)
    assert LanePlacement(
        n_devices=2, num_little_lanes=1, device_of_lane=(0, 0),
        lane_ests=(1.0, 1.0)).needs_rebalance(1.5)

    g = rmat(11, 8, seed=5, weighted=True)
    store = GraphStore(g, geom=GEOM)
    cfg = PlanConfig(n_lanes=8)
    ex = store.executor(gas.make_pagerank(max_iters=2), cfg, path="ref",
                        shard=1)
    ex.run(max_iters=2)
    delta = random_delta(g, churn=0.01, seed=13, hot_frac=0.05,
                         grow_frac=0.01)
    # without a threshold: pins kept, nothing re-placed
    base = apply_delta(store, delta)
    assert base.stats["placements_rebalanced"] == 0
    assert base.stats["placement_imbalance"] >= 1.0
    monkeypatch.setattr(LanePlacement, "needs_rebalance",
                        lambda self, t: True)
    res = apply_delta(store, delta, rebalance_threshold=1.0)
    assert res.stats["placements_rebalanced"] == 1
    pf, _ = res.store.executor(gas.make_pagerank(max_iters=2), cfg,
                               path="ref").run(max_iters=2)
    ps, _ = res.store.executor(gas.make_pagerank(max_iters=2), cfg,
                               path="ref", shard=1).run(max_iters=2)
    np.testing.assert_array_equal(pf, ps)


# ---------------------------------------------------------------------------
# Regroup (DBG re-registration)
# ---------------------------------------------------------------------------

def test_grouping_drift_and_reregister(wgraph, wstore):
    # drift is profile-relative; test-scale graphs only separate
    # dense from sparse under the scale-model HW (README §Perf model)
    hw = api.TPU_V5E_SCALED
    fresh = grouping_drift(wstore, hw=hw)
    assert fresh["drift"] == 0.0, "a fresh store has no grouping drift"
    assert fresh["partitions"] == len(wstore.infos)
    # a re-registered store preserves identity and content, and its own
    # drift is zero by construction
    re = reregister(wstore, fingerprint="ff" * 16)
    assert re.fingerprint() == "ff" * 16
    assert re.graph.num_edges == wgraph.num_edges
    assert grouping_drift(re, hw=hw)["drift"] == 0.0
    # heavy uniform churn decays the frozen degree ordering: a fresh
    # DBG pass classifies dense/sparse differently
    d = random_delta(wgraph, churn=0.4, seed=9)
    res = apply_delta(wstore, d)
    drift = grouping_drift(res.store, hw=hw)
    assert drift["drift"] > 0.0, \
        "heavy churn must register as grouping drift"
    assert drift["mismatched_partitions"] >= 1
    # the repair: reregister and the drift is gone
    repaired = reregister(res.store)
    assert repaired.fingerprint() == res.store.fingerprint()
    assert grouping_drift(repaired, hw=hw)["drift"] == 0.0
    # results are preserved across the swap (min-gather app is exact)
    assert np.array_equal(
        _run(res.store, "bfs", {"root": 0}, "ref"),
        _run(repaired, "bfs", {"root": 0}, "ref"))


def test_regroup_policy_validation():
    with pytest.raises(ValueError):
        RegroupPolicy(drift_threshold=0.0)
    with pytest.raises(ValueError):
        RegroupPolicy(min_churn_frac=-0.1)
    p = RegroupPolicy(min_churn_frac=0.5)
    assert not p.churn_ready(4, 10)
    assert p.churn_ready(5, 10)


# ---------------------------------------------------------------------------
# Hypothesis: differential chains mixing growth/remove/update/compaction
# ---------------------------------------------------------------------------

def test_hypothesis_growth_chain_equivalence():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    geom = Geometry(U=128, W=128, T=128, E_BLK=128, big_batch=2)

    @hyp.settings(max_examples=15, deadline=None)
    @hyp.given(data=st.data())
    def prop(data):
        V = data.draw(st.integers(min_value=32, max_value=300), label="V")
        n_edges = data.draw(st.integers(min_value=4, max_value=250),
                            label="E")
        rng = np.random.default_rng(
            data.draw(st.integers(0, 2**31), label="seed"))
        src = rng.integers(0, V, n_edges)
        dst = rng.integers(0, V, n_edges)
        w = rng.random(n_edges).astype(np.float32)
        g = from_edges(src, dst, num_vertices=V, weights=w)
        if g.num_edges == 0:
            return
        store0 = GraphStore(g, geom=geom)
        store, graph, fp = store0, g, g.fingerprint()
        deltas = []
        n_steps = data.draw(st.integers(1, 3), label="steps")
        for i in range(n_steps):
            delta = random_delta(
                graph,
                churn=data.draw(st.floats(0.01, 0.3), label=f"churn{i}"),
                seed=data.draw(st.integers(0, 2**31), label=f"ds{i}"),
                update_frac=data.draw(st.floats(0.0, 0.2),
                                      label=f"uf{i}"),
                grow_frac=data.draw(
                    st.sampled_from([0.0, 0.05, 0.2]), label=f"gf{i}"),
                base_fp=fp)
            deltas.append(delta)
            res = apply_delta(store, delta)
            graph = apply_delta_to_graph(graph, delta, check_fp=False)
            store, fp = res.store, res.fingerprint

        # incremental == cold rebuild under the extended frozen perm
        perm_ext = np.concatenate([
            np.asarray(store0.perm),
            np.arange(g.num_vertices, graph.num_vertices,
                      dtype=np.int32)])
        cold = GraphStore(graph, geom=geom, perm=perm_ext)
        _assert_stores_identical(store, cold)
        assert np.array_equal(
            _run(store, "pagerank", {}, "ref", max_iters=3),
            _run(cold, "pagerank", {}, "ref", max_iters=3))
        # compaction: the whole chain as ONE delta reproduces the same
        # content AND the same tip identity
        composed, tip = compact_deltas(deltas)
        assert tip == fp
        replay = apply_delta_to_graph(g, composed, check_fp=False)
        assert replay.fingerprint() == graph.fingerprint()
        assert replay.num_vertices == graph.num_vertices

    prop()


# ---------------------------------------------------------------------------
# Serving integration: growth updates, compaction, regroup
# ---------------------------------------------------------------------------

@pytest.fixture()
def svc():
    with api.GraphService(workers=2, default_geom=GEOM,
                          default_path="ref") as s:
        yield s


def test_service_growth_update_retires_and_purges_executors(svc, wgraph):
    """A growth update re-keys the snapshot like any delta: the old
    store retires on drain and its warm executors — compiled against
    the pre-growth layout — are purged with it."""
    fp = svc.register(wgraph)
    old_key = store_key(fp, GEOM, True)
    svc.run(fingerprint=fp, app="pagerank", n_lanes=4, max_iters=3,
            timeout=120)
    assert any(k[0] == old_key for k in svc._executors), \
        "warm run must cache an executor"
    delta = random_delta(wgraph, churn=0.01, seed=5, grow_frac=0.02)
    res = svc.update(fp, delta)
    assert res.mode == "incremental"
    assert res.retired == "now"
    assert res.stats["grown_vertices"] > 0
    assert old_key not in svc.cache
    assert not any(k[0] == old_key for k in svc._executors), \
        "retired snapshot's executors must not outlive it"
    # the grown snapshot serves, bit-identical to a direct build
    r, _ = svc.run(fingerprint=res.fingerprint, app="bfs",
                   app_kwargs={"root": 0}, n_lanes=4, max_iters=4,
                   timeout=120)
    post = apply_delta_to_graph(wgraph, delta)
    direct, _ = api.compile(post, "bfs", geom=GEOM, n_lanes=4,
                            path="ref").run(max_iters=4)
    assert np.array_equal(r, direct)


def test_service_chain_compaction_bounds_replay(wgraph):
    """max_chain_depth= compacts automatically: after many updates the
    registered chain stays bounded, the compaction counter moves, and
    a post-eviction cold rebuild (which replays the chain) still
    serves the correct graph."""
    with api.GraphService(workers=1, default_geom=GEOM,
                          default_path="ref", max_chain_depth=2) as svc:
        fp = svc.register(wgraph)
        cur_fp, cur_g = fp, wgraph
        for i in range(5):
            d = random_delta(cur_g, churn=0.01, seed=60 + i,
                             grow_frac=0.02 if i % 2 else 0.0,
                             base_fp=cur_fp)
            res = svc.update(cur_fp, d)
            cur_g = apply_delta_to_graph(cur_g, d, check_fp=False)
            cur_fp = res.fingerprint
            assert svc._chain_depth(cur_fp) <= 2
        snap = svc.metrics.snapshot()
        assert snap["compactions"] >= 1
        assert snap["max_chain_depth"] <= 2
        # evict the live store: the cold rebuild replays the COMPACTED
        # chain (O(1) deltas) and must reproduce the exact graph
        skey = store_key(cur_fp, GEOM, True)
        assert svc.cache.evict(skey)
        r, _ = svc.run(fingerprint=cur_fp, app="bfs",
                       app_kwargs={"root": 0}, n_lanes=4, max_iters=4,
                       timeout=300)
        direct, _ = api.compile(cur_g, "bfs", geom=GEOM, n_lanes=4,
                                path="ref").run(max_iters=4)
        assert np.array_equal(r, direct)
        # explicit compaction on an already-flat chain is a no-op
        out = svc.compact_chain(cur_fp)
        assert out["compacted"] is False
        with pytest.raises(KeyError):
            svc.compact_chain("00" * 16)


def test_service_regroup_swap(svc, wgraph):
    """regroup_now(force=True): atomic in-place store swap under the
    SAME key, executors purged (a put-replace fires no eviction hook),
    counter recorded, results preserved."""
    fp = svc.register(wgraph)
    skey = store_key(fp, GEOM, True)
    r0, _ = svc.run(fingerprint=fp, app="bfs", app_kwargs={"root": 0},
                    n_lanes=4, max_iters=4, timeout=120)
    assert any(k[0] == skey for k in svc._executors)
    ev = svc.regroup_now(fingerprint=fp, force=True)
    assert ev["applied"]
    assert not any(k[0] == skey for k in svc._executors), \
        "regroup swap must purge the old layout's executors"
    assert skey in svc.cache, "swap replaces, never evicts the key"
    assert svc.cache.peek(skey).fingerprint() == fp
    assert svc.metrics.snapshot()["regroups"] == 1
    r1, _ = svc.run(fingerprint=fp, app="bfs", app_kwargs={"root": 0},
                    n_lanes=4, max_iters=4, timeout=120)
    assert np.array_equal(r0, r1)
    with pytest.raises(KeyError):
        svc.regroup_now(fingerprint="00" * 16)


def test_service_regroup_policy_triggers_on_churned_updates(wgraph):
    """The policy path end to end: sync policy with a tiny churn floor
    runs the drift check inside update(); heavy churn that decays the
    frozen degree ordering past the threshold triggers the swap. The
    policy carries the perf-model profile (drift is profile-relative)."""
    policy = RegroupPolicy(drift_threshold=0.05, min_churn_frac=0.01,
                           sync=True, hw=api.TPU_V5E_SCALED)
    with api.GraphService(workers=1, default_geom=GEOM,
                          default_path="ref", regroup=policy) as svc:
        fp = svc.register(wgraph)
        d = random_delta(wgraph, churn=0.4, seed=9, base_fp=fp)
        res = svc.update(fp, d)
        assert res.mode == "incremental"
        assert svc.metrics.snapshot()["regroups"] == 1, \
            "churn past the drift threshold must trigger regroup"
        # the swapped store still answers to the chained fingerprint
        skey = store_key(res.fingerprint, GEOM, True)
        assert svc.cache.peek(skey).fingerprint() == res.fingerprint
        r, _ = svc.run(fingerprint=res.fingerprint, app="bfs",
                       app_kwargs={"root": 0}, n_lanes=4, max_iters=4,
                       timeout=300)
        post = apply_delta_to_graph(wgraph, d)
        direct, _ = api.compile(post, "bfs", geom=GEOM, n_lanes=4,
                                path="ref").run(max_iters=4)
        assert np.array_equal(r, direct)


def test_service_constructor_validation():
    with pytest.raises(ValueError):
        api.GraphService(max_chain_depth=0)
    with pytest.raises(ValueError):
        api.GraphService(rebalance_threshold=0.5)
    with pytest.raises(TypeError):
        api.GraphService(regroup="yes")


# ---------------------------------------------------------------------------
# Control plane: job records, typed HTTP errors, regraph_* gauges
# ---------------------------------------------------------------------------

def _post(url, body):
    req = urllib.request.Request(
        url + "/jobs", data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    return urllib.request.urlopen(req)


def test_control_plane_growth_jobs_and_gauges(wgraph):
    from repro.control import ControlPlane
    with ControlPlane(default_geom=GEOM, default_path="ref",
                      max_chain_depth=2) as plane:
        fp = plane.register(wgraph)
        V = wgraph.num_vertices
        server, url = plane.serve_http()

        # growth update over HTTP -> done record with the new lineage
        with _post(url, {"kind": "update", "fingerprint": fp,
                         "delta": {"add": {"src": [1, 2],
                                           "dst": [V, V + 1],
                                           "weights": [0.5, 0.25]},
                                   "grow_to": V + 4}}) as r:
            assert r.status == 201
            rec = json.loads(r.read())
        assert rec["kind"] == "update" and rec["state"] == "done"
        assert rec["metrics"]["mode"] == "incremental"
        assert rec["metrics"]["stats"]["grown_vertices"] == 4
        new_fp = rec["metrics"]["fingerprint"]

        # malformed growth delta: remove of a never-grown id -> typed 400
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(url, {"kind": "update", "fingerprint": new_fp,
                        "delta": {"remove": {"src": [1],
                                             "dst": [V + 100]}}})
        assert ei.value.code == 400
        assert json.loads(ei.value.read())["error"] == "bad_delta"
        # structurally-bad delta bodies are 400 too, not 500
        for bad in (None, [], {"bogus": 1},
                    {"add": {"src": [1]}},          # dst missing
                    {"add": [1, 2, 3, 4]},          # not edge lists
                    {"grow_to": -5}):
            with pytest.raises(urllib.error.HTTPError) as ei:
                _post(url, {"kind": "update", "fingerprint": new_fp,
                            "delta": bad})
            assert ei.value.code == 400, bad
        # unknown base fingerprint stays 404, unknown kind 400
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(url, {"kind": "update", "fingerprint": "00" * 16,
                        "delta": {"grow_to": 10}})
        assert ei.value.code == 404
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(url, {"kind": "defrag", "fingerprint": new_fp})
        assert ei.value.code == 400

        # compact job: the in-process chain (depth 1) has nothing to
        # squash — the record still lands with the accounting
        with _post(url, {"kind": "compact", "fingerprint": new_fp}) as r:
            rec = json.loads(r.read())
        assert rec["kind"] == "compact" and rec["state"] == "done"
        assert rec["metrics"]["depth_before"] == 1
        # regroup job (forced): applied + drift metric in the record
        with _post(url, {"kind": "regroup", "fingerprint": new_fp,
                         "force": True}) as r:
            rec = json.loads(r.read())
        assert rec["kind"] == "regroup" and rec["state"] == "done"
        assert rec["metrics"]["applied"] is True
        assert "drift" in rec["metrics"]

        # deeper chain via the in-process API: compact_job does squash
        cur_fp, cur_g = new_fp, None
        post_g = apply_delta_to_graph(
            wgraph, make_delta(fp, add=([1, 2], [V, V + 1],
                                        [0.5, 0.25]), grow_to=V + 4),
            check_fp=False)
        cur_g = post_g
        for i in range(2):
            d = random_delta(cur_g, churn=0.01, seed=80 + i,
                             base_fp=cur_fp)
            cur_g = apply_delta_to_graph(cur_g, d, check_fp=False)
            cur_fp = plane.service.update(cur_fp, d).fingerprint
        rec = plane.compact_job(cur_fp)
        assert rec.state == "done"
        assert rec.metrics["depth_before"] >= 1

        # regraph_* gauges in the merged exposition
        prom = urllib.request.urlopen(url + "/metrics").read().decode()
        for fam in ("regraph_compactions_total", "regraph_regroups_total",
                    "regraph_chain_depth",
                    "regraph_placements_rebalanced_total"):
            assert fam in prom, fam
        snap = plane.metrics_snapshot()
        assert snap["service"]["regroups"] >= 1
