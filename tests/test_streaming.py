"""Streaming graph tests: GraphDelta validation, incremental-vs-cold
bit-identical equivalence (all five apps, ref and pallas-interpret),
packed-payload reuse accounting, snapshot immutability, and the
GraphService.update serving integration."""
import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core.planner import PlanConfig
from repro.core.store import GraphStore
from repro.core.types import Geometry
from repro.graphs.formats import from_edges
from repro.graphs.rmat import rmat
from repro.serve_graph.fingerprint import store_key
from repro.streaming import (apply_delta, apply_delta_to_graph,
                             chain_fingerprint, make_delta, random_delta)

GEOM = Geometry(U=256, W=128, T=128, E_BLK=128, big_batch=2)
CFG = PlanConfig(n_lanes=4)

APPS = [
    ("pagerank", {}),
    ("bfs", {"root": 0}),
    ("sssp", {"root": 0}),
    ("wcc", {}),
    ("closeness", {"sources": np.arange(4)}),
]


@pytest.fixture(scope="module")
def wgraph():
    return rmat(11, 8, seed=3, weighted=True)   # 2048 V -> 8 partitions


@pytest.fixture(scope="module")
def wstore(wgraph):
    return GraphStore(wgraph, geom=GEOM)


def _run(store, app, kw, path, max_iters=5):
    a = api.BUILTIN_APPS[app](**kw)
    return api.compile(None, a, store=store, config=CFG,
                       path=path).run(max_iters=max_iters)[0]


# ---------------------------------------------------------------------------
# Graph immutability (satellite: deltas are the only mutation path)
# ---------------------------------------------------------------------------

def test_graph_arrays_are_immutable(wgraph):
    for arr in (wgraph.src, wgraph.dst, wgraph.weights):
        with pytest.raises(ValueError):
            arr[0] = 1
    g2 = from_edges([0, 1, 2], [1, 2, 0])
    with pytest.raises(ValueError):
        g2.src[0] = 5
    with pytest.raises(ValueError):
        g2.reversed().dst[0] = 5
    # frozen arrays make the cached fingerprint trustworthy
    fp = g2.fingerprint()
    assert g2.fingerprint() == fp


# ---------------------------------------------------------------------------
# GraphDelta format validation
# ---------------------------------------------------------------------------

def test_make_delta_rejects_bad_input():
    fp = "ab" * 16
    with pytest.raises(ValueError):        # duplicate within a list
        make_delta(fp, add=([0, 0], [1, 1]))
    with pytest.raises(ValueError):        # same edge in add AND remove
        make_delta(fp, add=([0], [1]), remove=([0], [1]))
    with pytest.raises(ValueError):        # same edge in remove AND update
        make_delta(fp, remove=([0], [1]), update=([0], [1], [0.5]))
    with pytest.raises(ValueError):        # update without weights
        make_delta(fp, update=([0], [1]))
    with pytest.raises(ValueError):        # negative ids
        make_delta(fp, add=([-1], [1]))
    with pytest.raises(ValueError):        # mismatched lengths
        make_delta(fp, add=([0, 1], [1]))
    with pytest.raises(ValueError):        # empty fingerprint
        make_delta("", add=([0], [1]))
    d = make_delta(fp)                     # empty delta is legal
    assert d.num_changes == 0


def test_make_delta_never_freezes_caller_buffers():
    """make_delta freezes ITS arrays; a caller's reusable int32/float32
    buffers must stay writable afterwards."""
    s = np.array([0, 1], np.int32)
    d = np.array([1, 2], np.int32)
    w = np.array([0.1, 0.2], np.float32)
    delta = make_delta("ab" * 16, add=(s, d, w))
    s[0] = 5
    d[0] = 6
    w[0] = 0.9                              # no ValueError: buffers ours
    assert delta.add_src[0] == 0, "delta kept its own frozen copy"


def test_delta_fingerprint_and_chaining():
    fp = "cd" * 16
    d1 = make_delta(fp, add=([0], [1]))
    d2 = make_delta(fp, add=([0], [2]))
    assert d1.fingerprint() == d1.fingerprint()
    assert d1.fingerprint() != d2.fingerprint()
    # same edit against a different base snapshot hashes differently
    d3 = make_delta("ef" * 16, add=([0], [1]))
    assert d1.fingerprint() != d3.fingerprint()
    c = chain_fingerprint(fp, d1.fingerprint())
    assert c == chain_fingerprint(fp, d1.fingerprint())
    assert c != fp and len(c) == len(fp)
    # delta arrays are frozen like graph arrays
    with pytest.raises(ValueError):
        d1.add_src[0] = 7
    # identity equality + hashable (value comparison via fingerprint)
    assert d1 != make_delta(fp, add=([0], [1]))
    assert len({d1, d2}) == 2


def test_apply_strictness(wgraph, wstore):
    fp = wgraph.fingerprint()
    # removing a non-existent edge
    keys = set(zip(wgraph.src.tolist(), wgraph.dst.tolist()))
    s, d = next((a, b) for a in range(5) for b in range(2040, 2048)
                if (a, b) not in keys and a != b)
    bad_rm = make_delta(fp, remove=([s], [d]))
    with pytest.raises(ValueError, match="not in the base graph"):
        apply_delta_to_graph(wgraph, bad_rm)
    with pytest.raises(ValueError, match="not in the base graph"):
        apply_delta(wstore, bad_rm)
    # adding an existing edge
    bad_add = make_delta(fp, add=([int(wgraph.src[0])],
                                  [int(wgraph.dst[0])],
                                  [0.5]))
    with pytest.raises(ValueError, match="already exists"):
        apply_delta_to_graph(wgraph, bad_add)
    with pytest.raises(ValueError, match="already exists"):
        apply_delta(wstore, bad_add)
    # wrong base fingerprint
    wrong = make_delta("12" * 16, add=([s], [d], [0.5]))
    with pytest.raises(ValueError, match="targets snapshot"):
        apply_delta_to_graph(wgraph, wrong)
    with pytest.raises(ValueError, match="targets snapshot"):
        apply_delta(wstore, wrong)
    # adds beyond V are the GROWTH path now — but removes/updates of
    # never-seen ids stay errors, and the message names the growth path
    V = wgraph.num_vertices
    grow = make_delta(fp, add=([1], [V], [0.5]))
    assert apply_delta(wstore, grow).store.graph.num_vertices == V + 1
    oob_rm = make_delta(fp, remove=([1], [V]))
    with pytest.raises(ValueError, match="vertex growth"):
        apply_delta(wstore, oob_rm)
    oob_up = make_delta(fp, update=([1], [V], [0.5]))
    with pytest.raises(ValueError, match="add list"):
        apply_delta_to_graph(wgraph, oob_up)
    # unweighted base rejects weight updates
    ug = rmat(8, 4, seed=2)
    upd = make_delta(ug.fingerprint(),
                     update=([int(ug.src[0])], [int(ug.dst[0])], [1.0]))
    with pytest.raises(ValueError, match="unweighted"):
        apply_delta_to_graph(ug, upd)


def test_delta_roundtrip_restores_content(wgraph):
    """Applying a churn delta and then its exact inverse restores the
    original content fingerprint (content hashes are order-free)."""
    d = random_delta(wgraph, churn=0.02, seed=11)
    post = apply_delta_to_graph(wgraph, d)
    assert post.fingerprint() != wgraph.fingerprint()
    # inverse: remove what was added, re-add what was removed (with the
    # original weights, recovered from the base graph)
    keys = {(int(s), int(t)): float(w) for s, t, w in
            zip(wgraph.src, wgraph.dst, wgraph.weights)}
    back_w = [keys[(int(s), int(t))]
              for s, t in zip(d.remove_src, d.remove_dst)]
    inv = make_delta(post.fingerprint(),
                     add=(d.remove_src, d.remove_dst, back_w),
                     remove=(d.add_src, d.add_dst))
    restored = apply_delta_to_graph(post, inv)
    assert restored.fingerprint() == wgraph.fingerprint()


# ---------------------------------------------------------------------------
# Incremental apply == cold rebuild (the tentpole equivalence)
# ---------------------------------------------------------------------------

def _assert_stores_identical(inc, cold):
    for k in ("src", "dst", "weights"):
        assert np.array_equal(inc.edges[k], cold.edges[k]), k
    assert inc.infos == cold.infos
    assert inc.V_pad == cold.V_pad


@pytest.mark.parametrize("churn,hot_frac,update_frac", [
    (0.01, None, 0.0),       # uniform churn: every partition dirty
    (0.01, 0.02, 0.005),     # degree-skewed churn + weight updates
    (0.10, None, 0.0),       # heavy churn
])
def test_apply_matches_cold_rebuild(wgraph, wstore, churn, hot_frac,
                                    update_frac):
    delta = random_delta(wgraph, churn=churn, seed=17, hot_frac=hot_frac,
                         update_frac=update_frac)
    res = apply_delta(wstore, delta)
    post = apply_delta_to_graph(wgraph, delta)
    cold = GraphStore(post, geom=GEOM, perm=wstore.perm)
    _assert_stores_identical(res.store, cold)
    assert res.stats["dirty_partitions"] <= len(wstore.infos)
    assert res.fingerprint == chain_fingerprint(wgraph.fingerprint(),
                                                delta.fingerprint())


@pytest.mark.parametrize("app,kw", APPS)
def test_apps_bit_identical_ref(wgraph, wstore, app, kw):
    """Delta-applied store runs every builtin app bit-identically to a
    cold GraphStore on the post-delta graph (same frozen permutation)."""
    delta = random_delta(wgraph, churn=0.02, seed=23, update_frac=0.005)
    res = apply_delta(wstore, delta)
    post = apply_delta_to_graph(wgraph, delta)
    cold = GraphStore(post, geom=GEOM, perm=wstore.perm)
    r_inc = _run(res.store, app, kw, "ref")
    r_cold = _run(cold, app, kw, "ref")
    assert np.array_equal(r_inc, r_cold), app


@pytest.mark.parametrize("app,kw", APPS)
def test_apps_bit_identical_pallas_interpret(app, kw):
    """Same equivalence through the Pallas kernels (interpret on CPU).
    Smaller graph: interpret mode is slow."""
    g = rmat(9, 6, seed=5, weighted=True)   # 512 V -> 2 partitions
    store = GraphStore(g, geom=GEOM)
    delta = random_delta(g, churn=0.03, seed=29, update_frac=0.01)
    res = apply_delta(store, delta)
    post = apply_delta_to_graph(g, delta)
    cold = GraphStore(post, geom=GEOM, perm=store.perm)
    r_inc = _run(res.store, app, kw, "pallas", max_iters=3)
    r_cold = _run(cold, app, kw, "pallas", max_iters=3)
    assert np.array_equal(r_inc, r_cold), app


def test_chained_deltas_stay_equivalent(wgraph):
    """Three stacked deltas through apply_delta == oracle replay."""
    store = GraphStore(wgraph, geom=GEOM)
    graph, fp = wgraph, wgraph.fingerprint()
    for seed in (31, 37, 41):
        delta = random_delta(graph, churn=0.02, seed=seed, base_fp=fp)
        res = apply_delta(store, delta)
        graph = apply_delta_to_graph(graph, delta, check_fp=False)
        store, fp = res.store, res.fingerprint
        assert store.fingerprint() == fp
    cold = GraphStore(graph, geom=GEOM, perm=store.perm)
    _assert_stores_identical(store, cold)
    assert np.array_equal(_run(store, "pagerank", {}, "ref"),
                          _run(cold, "pagerank", {}, "ref"))


def test_hypothesis_delta_equivalence():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    geom = Geometry(U=128, W=128, T=128, E_BLK=128, big_batch=2)

    @hyp.settings(max_examples=20, deadline=None)
    @hyp.given(data=st.data())
    def prop(data):
        # V >= 32 keeps the non-edge space ample for random_delta's
        # rejection sampling (E is capped well below V*(V-1))
        V = data.draw(st.integers(min_value=32, max_value=400), label="V")
        n_edges = data.draw(st.integers(min_value=1, max_value=300),
                            label="E")
        rng = np.random.default_rng(
            data.draw(st.integers(0, 2**31), label="seed"))
        src = rng.integers(0, V, n_edges)
        dst = rng.integers(0, V, n_edges)
        w = rng.random(n_edges).astype(np.float32)
        g = from_edges(src, dst, num_vertices=V, weights=w)
        if g.num_edges == 0:
            return
        store = GraphStore(g, geom=geom)
        churn = data.draw(st.floats(min_value=0.01, max_value=0.5),
                          label="churn")
        delta = random_delta(
            g, churn=churn,
            seed=data.draw(st.integers(0, 2**31), label="dseed"),
            update_frac=data.draw(st.floats(0.0, 0.2), label="uf"))
        res = apply_delta(store, delta)
        post = apply_delta_to_graph(g, delta)
        cold = GraphStore(post, geom=geom, perm=store.perm)
        _assert_stores_identical(res.store, cold)
        assert np.array_equal(
            _run(res.store, "pagerank", {}, "ref", max_iters=3),
            _run(cold, "pagerank", {}, "ref", max_iters=3))

    prop()


# ---------------------------------------------------------------------------
# Reuse accounting + snapshot semantics
# ---------------------------------------------------------------------------

def test_packed_payload_reuse_and_accounting():
    g = rmat(13, 8, seed=7, weighted=True)   # 8192 V -> 32 partitions
    store = GraphStore(g, geom=GEOM)
    bundle = store.plan(CFG)
    packed0 = bundle.packed_lanes()
    delta = random_delta(g, churn=0.01, seed=13, hot_frac=0.01)
    res = apply_delta(store, delta)
    s = res.stats
    assert s["dirty_partitions"] < s["partitions"] // 2, \
        "skewed churn must localize (DBG groups hot vertices)"
    assert s["plans_rebuilt"] == 1
    assert s["packed_lanes_reused"] >= 1, "clean lanes must carry over"
    assert s["packed_bytes_reused"] > 0
    assert s["little_blockings_reused"] >= 1
    # reused payload lists are the SAME device objects, not re-uploads
    new_packed = res.store.plan(CFG).packed_lanes()
    identical = sum(1 for a in new_packed if any(a is b for b in packed0))
    assert identical == s["packed_lanes_reused"]
    # results still bit-identical to a cold rebuild of the post graph
    post = apply_delta_to_graph(g, delta)
    cold = GraphStore(post, geom=GEOM, perm=store.perm)
    assert np.array_equal(_run(res.store, "sssp", {"root": 0}, "ref"),
                          _run(cold, "sssp", {"root": 0}, "ref"))


def test_base_store_is_an_untouched_snapshot(wgraph):
    store = GraphStore(wgraph, geom=GEOM)
    store.plan(CFG)
    before = {k: v.copy() for k, v in store.edges.items()}
    infos_before = [dataclasses.replace(i) for i in store.infos]
    delta = random_delta(wgraph, churn=0.05, seed=19)
    res = apply_delta(store, delta)
    assert res.store is not store
    for k in before:
        assert np.array_equal(store.edges[k], before[k])
    assert store.infos == infos_before
    assert store.fingerprint() == wgraph.fingerprint()
    assert store.has_plan(CFG), "base keeps its cached plans"


def test_clear_plans_reports_freed_bytes(wgraph):
    store = GraphStore(wgraph, geom=GEOM)
    store.plan(CFG).packed_lanes()
    assert store.memory_footprint()["plan_bytes"] > 0
    out = store.clear_plans()
    assert out["plans"] == 1
    assert out["freed_bytes"] > 0
    assert store.memory_footprint()["plan_bytes"] == 0
    again = store.clear_plans()
    assert again == {"plans": 0, "freed_bytes": 0}


def test_store_accepts_explicit_perm(wgraph):
    a = GraphStore(wgraph, geom=GEOM)
    b = GraphStore(wgraph, geom=GEOM, perm=a.perm)
    for k in ("src", "dst", "weights"):
        assert np.array_equal(a.edges[k], b.edges[k])
    assert a.infos == b.infos
    with pytest.raises(ValueError):
        GraphStore(wgraph, geom=GEOM, perm=np.arange(3, dtype=np.int32))


# ---------------------------------------------------------------------------
# Serving integration: GraphService.update
# ---------------------------------------------------------------------------

@pytest.fixture()
def svc():
    geom = Geometry(U=256, W=128, T=128, E_BLK=128, big_batch=2)
    with api.GraphService(workers=2, default_geom=geom,
                          default_path="ref") as s:
        yield s


def test_service_update_rekeys_and_serves_post_delta(svc, wgraph):
    fp = svc.register(wgraph)
    svc.run(fingerprint=fp, app="pagerank", n_lanes=4, max_iters=3,
            timeout=120)
    delta = random_delta(wgraph, churn=0.01, seed=5, hot_frac=0.05)
    res = svc.update(fp, delta)
    assert res.mode == "incremental"
    assert res.retired == "now"
    assert res.stats["plans_rebuilt"] >= 1
    # the new fingerprint is served warm from the spliced store
    r, m = svc.run(fingerprint=res.fingerprint, app="pagerank", n_lanes=4,
                   max_iters=3, timeout=120)
    assert m["iterations"] == 3
    post = apply_delta_to_graph(wgraph, delta)
    cold = GraphStore(post, geom=svc.default_geom,
                      perm=np.asarray(
                          svc.cache.get(store_key(res.fingerprint,
                                                  svc.default_geom,
                                                  True)).perm))
    assert np.array_equal(
        r, _run(cold, "pagerank", {}, "ref", max_iters=3))
    # old fingerprint was deregistered and its store retired
    with pytest.raises(KeyError):
        svc.submit(fingerprint=fp, app="pagerank")
    snap = svc.metrics.snapshot()
    assert snap["updates"] == 1
    assert snap["stores_retired"] == 1
    assert snap["packed_lanes_reused"] == res.stats["packed_lanes_reused"]
    assert snap["p50_update_ms"] is not None


def test_service_update_keep_base(svc, wgraph):
    fp = svc.register(wgraph)
    delta = random_delta(wgraph, churn=0.01, seed=7)
    res = svc.update(fp, delta, keep_base=True)
    # both snapshots remain servable: the base rebuilds from the
    # registry, the new one is cached (and rebuildable from the chain)
    h_old = svc.submit(fingerprint=fp, app="bfs", app_kwargs={"root": 0},
                       n_lanes=4, max_iters=4)
    h_new = svc.submit(fingerprint=res.fingerprint, app="bfs",
                       app_kwargs={"root": 0}, n_lanes=4, max_iters=4)
    r_old, _ = h_old.result(timeout=120)
    r_new, _ = h_new.result(timeout=120)
    # BFS is min-gather: exact regardless of vertex ordering, so each
    # snapshot must match a direct build of its own graph
    direct_old, _ = api.compile(wgraph, "bfs", geom=svc.default_geom,
                                n_lanes=4, path="ref").run(max_iters=4)
    post = apply_delta_to_graph(wgraph, delta)
    direct_new, _ = api.compile(post, "bfs", geom=svc.default_geom,
                                n_lanes=4, path="ref").run(max_iters=4)
    assert np.array_equal(r_old, direct_old)
    assert np.array_equal(r_new, direct_new)


def test_service_update_deferred_then_rebuilds(svc, wgraph):
    fp = svc.register(wgraph, prepare=False)   # nothing cached
    delta = random_delta(wgraph, churn=0.02, seed=9)
    res = svc.update(fp, delta)
    assert res.mode == "deferred"
    assert res.stats is None
    # cold submit replays the chain
    r, _ = svc.run(fingerprint=res.fingerprint, app="wcc", n_lanes=4,
                   max_iters=6, timeout=120)
    post = apply_delta_to_graph(wgraph, delta)
    direct, _ = api.compile(post, "wcc", geom=svc.default_geom, n_lanes=4,
                            path="ref").run(max_iters=6)
    assert np.array_equal(r, direct)
    assert svc.metrics.snapshot()["updates_deferred"] == 1


def test_service_update_anchors_unregistered_lineage(svc, wgraph):
    """update() on a base that was only ever SUBMITTED (never
    registered) must still leave the chained fingerprint rebuildable:
    the lineage anchors on the store's own source graph."""
    h = svc.submit(wgraph, "pagerank", n_lanes=4, max_iters=2)
    h.result(timeout=300)
    fp = wgraph.fingerprint()
    delta = random_delta(wgraph, churn=0.01, seed=31)
    res = svc.update(fp, delta)
    assert res.mode == "incremental"
    # evict the derived store, then resubmit by the chained fingerprint
    new_key = store_key(res.fingerprint, svc.default_geom, True)
    assert svc.cache.evict(new_key)
    r, _ = svc.run(fingerprint=res.fingerprint, app="bfs",
                   app_kwargs={"root": 0}, n_lanes=4, max_iters=4,
                   timeout=300)
    post = apply_delta_to_graph(wgraph, delta)
    direct, _ = api.compile(post, "bfs", geom=svc.default_geom,
                            n_lanes=4, path="ref").run(max_iters=4)
    assert np.array_equal(r, direct)


def test_service_deferred_update_validates_now(svc, wgraph):
    """An invalid delta against an UNCACHED (registry-only) snapshot
    must fail the update() call itself — recording it unvalidated would
    poison the lineage for every later cold submit."""
    fp = svc.register(wgraph, prepare=False)
    keys = set(zip(wgraph.src.tolist(), wgraph.dst.tolist()))
    s, d = next((a, b) for a in range(5) for b in range(2040, 2048)
                if (a, b) not in keys and a != b)
    bad = make_delta(fp, remove=([s], [d]))      # edge doesn't exist
    with pytest.raises(ValueError, match="not in the base graph"):
        svc.update(fp, bad)
    assert svc.metrics.snapshot()["update_failures"] == 1
    # the base snapshot is untouched and still serveable
    r, _ = svc.run(fingerprint=fp, app="bfs", app_kwargs={"root": 0},
                   n_lanes=4, max_iters=4, timeout=300)
    direct, _ = api.compile(wgraph, "bfs", geom=svc.default_geom,
                            n_lanes=4, path="ref").run(max_iters=4)
    assert np.array_equal(r, direct)


def test_service_update_validation(svc, wgraph):
    fp = svc.register(wgraph)
    with pytest.raises(ValueError):
        svc.update("00" * 16, random_delta(wgraph, seed=1))
    unknown = rmat(8, 4, seed=99, weighted=True)
    with pytest.raises(KeyError):
        svc.update(unknown.fingerprint(),
                   random_delta(unknown, seed=1))
    assert svc.metrics.snapshot()["update_failures"] == 1


def test_service_update_defers_retire_while_jobs_queued(wgraph):
    """A request QUEUED against the old fingerprint (not yet picked up
    by a worker, so not lease-pinned) must still finish on the old
    snapshot: update() defers retirement until the per-key job count
    drains. Single worker + a slow job in front forces the queue wait."""
    geom = Geometry(U=256, W=128, T=128, E_BLK=128, big_batch=2)
    other = rmat(10, 8, seed=77, weighted=True)
    with api.GraphService(workers=1, default_geom=geom,
                          default_path="ref") as svc:
        fp = svc.register(wgraph)
        skey = store_key(fp, geom, True)
        # head-of-line job keeps the single worker busy...
        slow = svc.submit(other, "pagerank", n_lanes=4, max_iters=16)
        # ...so this old-fp request sits in the queue, unleased
        queued = svc.submit(fingerprint=fp, app="bfs",
                            app_kwargs={"root": 0}, n_lanes=4, max_iters=4)
        delta = random_delta(wgraph, churn=0.01, seed=21)
        res = svc.update(fp, delta)
        assert res.retired == "deferred"
        assert skey in svc.cache, "old snapshot must outlive queued work"
        r, _ = queued.result(timeout=300)      # served, not KeyError'd
        slow.result(timeout=300)
        direct, _ = api.compile(wgraph, "bfs", geom=geom, n_lanes=4,
                                path="ref").run(max_iters=4)
        assert np.array_equal(r, direct), "queued job saw the OLD snapshot"
        # drained -> the deferred retirement actually fired
        deadline = 50
        import time as _t
        while skey in svc.cache and deadline:
            _t.sleep(0.05)
            deadline -= 1
        assert skey not in svc.cache, "retire must fire once drained"
        # and the new snapshot serves
        r2, _ = svc.run(fingerprint=res.fingerprint, app="bfs",
                        app_kwargs={"root": 0}, n_lanes=4, max_iters=4,
                        timeout=300)
        post = apply_delta_to_graph(wgraph, delta)
        direct2, _ = api.compile(post, "bfs", geom=geom, n_lanes=4,
                                 path="ref").run(max_iters=4)
        assert np.array_equal(r2, direct2)


def test_service_update_defers_retire_while_leased(svc, wgraph):
    fp = svc.register(wgraph)
    skey = store_key(fp, svc.default_geom, True)
    delta = random_delta(wgraph, churn=0.01, seed=3)
    with svc.cache.lease(skey) as (store, _):   # simulate in-flight work
        res = svc.update(fp, delta)
        assert res.retired == "deferred"
        assert skey in svc.cache, "old snapshot survives while leased"
        # the leased store is the UNTOUCHED base snapshot
        assert store.fingerprint() == fp
    assert skey not in svc.cache, "drained lease evicts the retired entry"
    assert store_key(res.fingerprint, svc.default_geom, True) in svc.cache
