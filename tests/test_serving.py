"""Serving subsystem: fingerprints, byte-budgeted store LRU, bounded
per-store plan LRU, GraphService queue/coalescing, and cold/warm parity
with the direct api.compile path.

Every blocking wait uses an explicit timeout so a queue/worker bug
fails loudly instead of hanging the suite (CI adds pytest-timeout on
top as a backstop).
"""
import threading

import numpy as np
import pytest

from repro import api
from repro.core import gas
from repro.core.perf_model import TPU_V5E
from repro.core.types import Geometry
from repro.graphs.rmat import rmat
from repro.serve_graph import (GraphService, GraphStoreCache, ServiceClosed,
                               graph_fingerprint, store_key)
from repro.serve_graph.fingerprint import resolve_fingerprint

GEOM = Geometry(U=512, W=512, T=512, E_BLK=128, big_batch=2)
WAIT = 300.0   # generous per-request wait; failures surface as TimeoutError

FIVE_APPS = [
    ("pagerank", {}),
    ("bfs", {"root": 0}),
    ("sssp", {"root": 0}),
    ("wcc", {}),
    ("closeness", {"sources": np.arange(4)}),
]


@pytest.fixture(scope="module")
def graphs():
    return [rmat(8, 6, seed=s, weighted=True) for s in (1, 2, 3)]


def make_service(**kw):
    kw.setdefault("default_geom", GEOM)
    kw.setdefault("default_path", "ref")
    return GraphService(**kw)


# ---------------------------------------------------------------- identity
def test_fingerprint_content_identity(graphs):
    g = graphs[0]
    # name is cosmetic: same content, different name -> same fingerprint
    twin = rmat(8, 6, seed=1, weighted=True, name="other-name")
    assert g.fingerprint() == twin.fingerprint()
    assert g.fingerprint() == graph_fingerprint(g)   # method == function
    assert g.fingerprint() != graphs[1].fingerprint()
    # weights participate in identity
    unweighted = rmat(8, 6, seed=1, weighted=False)
    assert unweighted.fingerprint() != g.fingerprint()
    # rebinding an array attribute invalidates the instance cache
    fp0 = unweighted.fingerprint()
    unweighted.weights = np.ones(unweighted.num_edges, np.float32)
    assert unweighted.fingerprint() != fp0


def test_resolve_fingerprint_and_store_key(graphs):
    g = graphs[0]
    fp = g.fingerprint()
    assert resolve_fingerprint(g) == fp
    assert resolve_fingerprint(fp) == fp
    assert resolve_fingerprint(g, fp) == fp
    with pytest.raises(ValueError):
        resolve_fingerprint(None, None)
    with pytest.raises(ValueError):
        resolve_fingerprint(g, "deadbeef")      # mismatched pair
    with pytest.raises(ValueError):
        store_key("", GEOM, True)
    assert store_key(fp, GEOM, True) != store_key(fp, GEOM, False)


# ------------------------------------------------------------- plan LRU
def test_store_plan_lru_bound_and_order(graphs):
    store = api.GraphStore(graphs[0], geom=GEOM, max_plans=2)
    b1 = store.plan(api.PlanConfig(n_lanes=1))
    b2 = store.plan(api.PlanConfig(n_lanes=2))
    store.plan(api.PlanConfig(n_lanes=1))            # touch: b1 now MRU
    b3 = store.plan(api.PlanConfig(n_lanes=3))       # evicts b2 (LRU)
    assert store.stats()["cached_plans"] == 2
    assert store.plan_evictions == 1
    assert store.plan(api.PlanConfig(n_lanes=1)) is b1
    assert store.plan(api.PlanConfig(n_lanes=3)) is b3
    assert store.plan(api.PlanConfig(n_lanes=2)) is not b2   # rebuilt
    assert store.has_plan(api.PlanConfig(n_lanes=2))
    with pytest.raises(ValueError):
        api.GraphStore(graphs[0], geom=GEOM, max_plans=0)


def test_plan_eviction_does_not_break_running_executor(graphs):
    """An Executor holds its own bundle reference; plan-LRU eviction
    must not invalidate it."""
    store = api.GraphStore(graphs[0], geom=GEOM, max_plans=1)
    ex = store.executor(gas.make_pagerank(max_iters=2),
                        api.PlanConfig(n_lanes=2), path="ref")
    store.plan(api.PlanConfig(n_lanes=1))    # evicts ex's cached bundle
    assert not store.has_plan(api.PlanConfig(n_lanes=2))
    props, meta = ex.run(max_iters=2)        # still runs fine
    assert meta["iterations"] >= 1


def test_quantized_hw_cache_keys_share_plans(graphs):
    """Two near-identical calibrations (differences past the 3rd
    significant digit, as successive host calibrations produce) must
    share one cached plan; a genuinely different calibration must not."""
    noisy_a = TPU_V5E.clone(c_edges=1.0001234, c_store=0.5000321,
                            gather_b=2.0004e-6)
    noisy_b = TPU_V5E.clone(c_edges=1.0002999, c_store=0.5001987,
                            gather_b=2.0009e-6)
    assert (api.PlanConfig(hw=noisy_a).cache_key()
            == api.PlanConfig(hw=noisy_b).cache_key())
    store = api.GraphStore(graphs[0], geom=GEOM)
    assert store.plan(api.PlanConfig(hw=noisy_a)) is \
        store.plan(api.PlanConfig(hw=noisy_b))
    distinct = TPU_V5E.clone(c_edges=1.27)
    assert (api.PlanConfig(hw=distinct).cache_key()
            != api.PlanConfig(hw=noisy_a).cache_key())
    assert store.plan(api.PlanConfig(hw=distinct)) is not \
        store.plan(api.PlanConfig(hw=noisy_a))


# -------------------------------------------------------- memory footprint
def test_memory_footprint_accounting(graphs):
    store = api.GraphStore(graphs[0], geom=GEOM)
    fp0 = store.memory_footprint()
    parts = ("graph_bytes", "edge_bytes", "blocking_bytes", "plan_bytes",
             "aux_bytes")
    assert all(fp0[k] >= 0 for k in parts)
    assert fp0["total_bytes"] == sum(fp0[k] for k in parts)
    assert fp0["graph_bytes"] > 0 and fp0["edge_bytes"] > 0
    assert fp0["plan_bytes"] == 0                 # nothing planned yet

    bundle = store.plan(api.PlanConfig(n_lanes=2))
    fp1 = store.memory_footprint()
    assert fp1["blocking_bytes"] > fp0["blocking_bytes"]
    bundle.lane_entries()                         # materialize on device
    fp2 = store.memory_footprint()
    assert fp2["plan_bytes"] > 0
    assert fp2["total_bytes"] > fp1["total_bytes"]
    assert store.stats()["total_bytes"] == fp2["total_bytes"]


# ------------------------------------------------------------- store cache
def _stores(graphs):
    return [(store_key(g.fingerprint(), GEOM, True),
             api.GraphStore(g, geom=GEOM)) for g in graphs]


def test_store_cache_lru_eviction_order(graphs):
    entries = _stores(graphs)
    cache = GraphStoreCache(max_stores=2)
    for k, s in entries:
        cache.put(k, s)
    assert len(cache) == 2 and cache.evictions == 1
    assert cache.keys() == [k for k, _ in entries[1:]]   # oldest gone
    # touching the LRU entry protects it from the next eviction
    assert cache.get(entries[1][0]) is entries[1][1]
    cache.put(*entries[0])
    assert entries[1][0] in cache and entries[2][0] not in cache


def test_store_cache_byte_budget(graphs):
    entries = _stores(graphs)
    one = entries[0][1].memory_footprint()["total_bytes"]
    cache = GraphStoreCache(byte_budget=int(one * 2.5))
    for k, s in entries:
        cache.put(k, s)
    assert len(cache) == 2
    assert cache.current_bytes <= int(one * 2.5)
    assert cache.evictions == 1
    # a budget smaller than one store still admits it (soft cap), then
    # evicts it as soon as the next store arrives
    tiny = GraphStoreCache(byte_budget=one // 2)
    tiny.put(*entries[0])
    assert len(tiny) == 1
    tiny.put(*entries[1])
    assert len(tiny) == 1 and entries[1][0] in tiny
    with pytest.raises(ValueError):
        GraphStoreCache(byte_budget=0)
    with pytest.raises(ValueError):
        GraphStoreCache(max_stores=0)


def test_store_cache_eviction_releases_plans(graphs):
    k, s = _stores(graphs[:1])[0]
    s.plan(api.PlanConfig(n_lanes=2))
    assert s.stats()["cached_plans"] == 1
    cache = GraphStoreCache()
    cache.put(k, s)
    assert cache.evict(k)
    assert s.stats()["cached_plans"] == 0    # device entries released


def test_store_cache_pinning_blocks_eviction(graphs):
    entries = _stores(graphs)
    cache = GraphStoreCache(max_stores=1)
    cache.put(*entries[0])
    with cache.lease(entries[0][0]) as (store, hit):
        assert hit and store is entries[0][1]
        assert not cache.evict(entries[0][0])          # pinned
        cache.put(*entries[1])                          # over budget...
        assert entries[0][0] in cache                   # ...but pinned stays
        assert cache.stats()["pinned"] == 1
    # lease released -> budget enforced again
    assert len(cache) == 1 and entries[0][0] not in cache
    with pytest.raises(KeyError):
        with cache.lease(entries[2][0]):                # no builder
            pass
    built = []
    with cache.lease(entries[2][0],
                     builder=lambda: built.append(1) or entries[2][1]) \
            as (store, hit):
        assert not hit and built == [1]


def test_store_cache_failed_build_recovers(graphs):
    """A builder that raises must not wedge the key: the placeholder is
    removed and the next lease builds normally."""
    entries = _stores(graphs[:1])
    cache = GraphStoreCache()
    k = entries[0][0]
    with pytest.raises(RuntimeError, match="bad build"):
        with cache.lease(k, builder=lambda: (_ for _ in ()).throw(
                RuntimeError("bad build"))):
            pass
    assert k not in cache and cache.pin_count(k) == 0
    with cache.lease(k, builder=lambda: entries[0][1]) as (store, hit):
        assert store is entries[0][1] and not hit


def test_store_cache_concurrent_builds_dedupe(graphs):
    """Concurrent leases: same key builds once (waiters latch on the
    first build), different keys build concurrently off-lock."""
    entries = _stores(graphs[:2])
    cache = GraphStoreCache()
    calls = []
    barrier = threading.Barrier(4, timeout=WAIT)
    results, errs = [], []

    def worker(i):
        k, s = entries[i % 2]

        def build():
            calls.append(i % 2)
            return s

        try:
            barrier.wait()
            with cache.lease(k, builder=build) as (store, _hit):
                results.append(store is s)
        except BaseException as e:
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=WAIT)
    assert not errs and results == [True] * 4
    assert sorted(calls) == [0, 1]      # exactly one build per key


def test_store_cache_get_or_build_and_stats(graphs):
    entries = _stores(graphs[:1])
    cache = GraphStoreCache()
    calls = []
    k = entries[0][0]
    s1, hit1 = cache.get_or_build(k, lambda: calls.append(1)
                                  or entries[0][1])
    s2, hit2 = cache.get_or_build(k, lambda: calls.append(1)
                                  or entries[0][1])
    assert (hit1, hit2) == (False, True) and s1 is s2 and calls == [1]
    st = cache.stats()
    assert st["hits"] == 1 and st["misses"] == 1 and st["hit_rate"] == 0.5
    assert cache.clear() == 1 and len(cache) == 0


# ---------------------------------------------------------------- service
def test_coalescing_n_submits_one_execution(graphs):
    with make_service(workers=2) as svc:
        hs = [svc.submit(graphs[0], "pagerank", n_lanes=2, max_iters=4)
              for _ in range(8)]
        results = [h.result(timeout=WAIT) for h in hs]
        assert svc.metrics.executions == 1
        assert svc.metrics.submitted == 8
        assert svc.metrics.coalesced >= 1
        # fan-out shares the one result object
        for props, meta in results[1:]:
            assert props is results[0][0]
            assert meta is results[0][1]
        assert sum(1 for h in hs if h.metrics.coalesced) \
            == svc.metrics.coalesced
        # the stage breakdown belongs to the executing request only;
        # twins get their own end-to-end time + the shared hit flags
        leader = [h for h in hs if not h.metrics.coalesced]
        assert len(leader) == 1
        assert leader[0].metrics.t_execute_ms is not None
        for h in hs:
            if h.metrics.coalesced:
                assert h.metrics.t_execute_ms is None
                assert h.metrics.t_queue_ms is None
            assert h.metrics.t_total_ms is not None
            assert h.metrics.store_hit is not None


def test_coalesced_records_never_skew_stage_reservoirs():
    """Regression: a coalesced duplicate must contribute ONLY its own
    end-to-end latency — even if a buggy caller fills its stage times
    in, record_done drops them. One poisoned twin would otherwise drag
    a stage percentile toward a time that stage never spent."""
    from repro.serve_graph.metrics import RequestMetrics, ServiceMetrics
    m = ServiceMetrics()
    for i in range(4):
        m.record_done(RequestMetrics(
            request_id=i, app="pagerank", fingerprint="f",
            t_queue_ms=1.0, t_store_ms=1.0, t_plan_ms=1.0,
            t_execute_ms=10.0, t_total_ms=12.0))
    # a coalesced twin with (bogus) stage times filled in
    m.record_done(RequestMetrics(
        request_id=99, app="pagerank", fingerprint="f", coalesced=True,
        t_queue_ms=9999.0, t_store_ms=9999.0, t_plan_ms=9999.0,
        t_execute_ms=9999.0, t_total_ms=50.0))
    snap = m.snapshot()
    for stage in ("queue", "store", "plan", "execute"):
        assert snap[f"p99_{stage}_ms"] < 9999.0, stage
    # its end-to-end time DOES count (it is a real request outcome)
    assert snap["p99_total_ms"] == 50.0
    assert snap["completed"] == 5


def test_distinct_requests_do_not_coalesce(graphs):
    with make_service(workers=1) as svc:
        a = svc.submit(graphs[0], "bfs", app_kwargs={"root": 0}, n_lanes=2)
        b = svc.submit(graphs[0], "bfs", app_kwargs={"root": 5}, n_lanes=2)
        c = svc.submit(graphs[0], "bfs", app_kwargs={"root": 0}, n_lanes=1)
        for h in (a, b, c):
            h.result(timeout=WAIT)
        assert svc.metrics.executions == 3
        assert svc.metrics.coalesced == 0
        assert not np.array_equal(a.result()[0], b.result()[0])


def test_cold_warm_parity_with_compile(graphs):
    """Serving must be a pure routing layer: cold AND warm results are
    bit-identical to the direct api.compile path."""
    g = graphs[0]
    with make_service(workers=2) as svc:
        cold = svc.submit(g, "pagerank", n_lanes=2, max_iters=6)
        p_cold, m_cold = cold.result(timeout=WAIT)
        warm = svc.submit(g, "pagerank", n_lanes=2, max_iters=6)
        p_warm, m_warm = warm.result(timeout=WAIT)
        assert cold.metrics.store_hit is False
        assert warm.metrics.store_hit is True and warm.metrics.plan_hit
    ref, meta = api.compile(g, "pagerank", geom=GEOM, n_lanes=2,
                            path="ref").run(max_iters=6)
    assert m_cold["iterations"] == meta["iterations"]
    np.testing.assert_array_equal(p_cold, ref)
    np.testing.assert_array_equal(p_warm, ref)


def test_warm_mixed_workload_hit_rate(graphs):
    """Acceptance: five builtin apps × three graphs; after a cold pass,
    the warm pass is 100% store-cache hits and the overall store hit
    rate is >= 80%."""
    with make_service(workers=2) as svc:
        for _round in range(2):
            hs = [svc.submit(g, name, app_kwargs=kw, n_lanes=2, max_iters=3)
                  for g in graphs for name, kw in FIVE_APPS]
            for h in hs:
                h.result(timeout=WAIT)
        assert all(h.metrics.store_hit for h in hs)      # warm round
        assert svc.metrics.store_hit_rate >= 0.8
        assert svc.metrics.plan_hit_rate >= 0.8
        snap = svc.stats()
        assert snap["service"]["executions"] == 2 * len(graphs) * 5
        assert snap["store_cache"]["stores"] == len(graphs)
        assert snap["service"]["p50_total_ms"] is not None
        assert snap["service"]["p99_execute_ms"] is not None


def test_eviction_under_pressure_never_breaks_requests(graphs):
    """max_stores=1 forces an eviction on nearly every alternation;
    every request must still complete and match the direct path."""
    refs = [api.compile(g, "pagerank", geom=GEOM, n_lanes=2,
                        path="ref").run(max_iters=3)[0] for g in graphs[:2]]
    with make_service(workers=2, max_stores=1) as svc:
        handles = [(i % 2, svc.submit(graphs[i % 2], "pagerank", n_lanes=2,
                                      max_iters=3))
                   for i in range(6)]
        for gi, h in handles:
            props, _ = h.result(timeout=WAIT)
            np.testing.assert_array_equal(props, refs[gi])
        assert svc.cache.evictions > 0
        assert svc.cache.stats()["stores"] <= 2


def test_submit_by_fingerprint_and_register(graphs):
    g = graphs[0]
    with make_service(workers=1) as svc:
        with pytest.raises(KeyError):
            svc.submit(fingerprint=g.fingerprint(), app="pagerank")
        fp = svc.register(g)
        assert fp == g.fingerprint()
        assert svc.cache.stats()["stores"] == 1     # prepared eagerly
        h = svc.submit(fingerprint=fp, app="pagerank", n_lanes=2,
                       max_iters=3)
        props, _ = h.result(timeout=WAIT)
        assert h.metrics.store_hit is True
        # registered graphs survive eviction: the store is rebuilt
        svc.cache.clear()
        h2 = svc.submit(fingerprint=fp, app="pagerank", n_lanes=2,
                        max_iters=3)
        p2, _ = h2.result(timeout=WAIT)
        assert h2.metrics.store_hit is False
        np.testing.assert_array_equal(p2, props)
        # submitting a raw Graph does NOT pin it in the registry
        other = rmat(8, 6, seed=9)
        svc.submit(other, "wcc", n_lanes=2, max_iters=2).result(timeout=WAIT)
        svc.cache.clear()
        with pytest.raises(KeyError):
            svc.submit(fingerprint=other.fingerprint(), app="wcc")
        # unregister drops the rebuild path for registered graphs too
        assert svc.unregister(fp) and not svc.unregister(fp)
        with pytest.raises(KeyError):
            svc.submit(fingerprint=fp, app="pagerank")


def test_submit_validation_and_close(graphs):
    svc = make_service(workers=1)
    with pytest.raises(ValueError):
        svc.submit(graphs[0], "nope")
    with pytest.raises(ValueError):
        svc.submit(graphs[0], "pagerank", config=api.PlanConfig(),
                   n_lanes=2)
    with pytest.raises(ValueError):
        svc.submit(graphs[0], gas.make_pagerank(),
                   app_kwargs={"root": 0})     # kwargs need a builtin name
    with pytest.raises(ValueError):
        svc.submit()                            # no graph, no fingerprint
    h = svc.submit(graphs[0], "wcc", n_lanes=2, max_iters=3)
    svc.close()
    assert h.done() and h.exception() is None
    with pytest.raises(ServiceClosed):
        svc.submit(graphs[0], "pagerank")
    svc.close()    # idempotent


def test_request_error_propagates_to_every_twin(graphs):
    def bad_init(aux):
        raise RuntimeError("boom at init")

    app = gas.GASApp("boom", "sum", lambda *a: a[0], lambda a, v, x, it: v,
                     bad_init, lambda old, new, it: True)
    with make_service(workers=1) as svc:
        hs = [svc.submit(graphs[0], app, n_lanes=2) for _ in range(3)]
        for h in hs:
            with pytest.raises(RuntimeError, match="boom at init"):
                h.result(timeout=WAIT)
            assert "boom at init" in h.metrics.error
        assert svc.metrics.failed == 3
        # the worker survived: a good request still completes
        ok = svc.submit(graphs[0], "pagerank", n_lanes=2, max_iters=2)
        ok.result(timeout=WAIT)


def test_gasapp_instance_coalesces_only_with_itself(graphs):
    app = gas.make_pagerank(max_iters=4)
    with make_service(workers=2) as svc:
        hs = [svc.submit(graphs[0], app, n_lanes=2) for _ in range(4)]
        other = svc.submit(graphs[0], gas.make_pagerank(max_iters=4),
                           n_lanes=2)
        for h in hs + [other]:
            h.result(timeout=WAIT)
        # 4 submits of the same instance -> 1 execution; a different
        # instance (opaque params) -> its own execution
        assert svc.metrics.executions == 2


def test_concurrent_submitters_thread_safety(graphs):
    """Many client threads hammering one service: every handle resolves
    and per-graph results agree."""
    with make_service(workers=2) as svc:
        results = {}
        errs = []

        def client(i):
            try:
                g = graphs[i % 2]
                h = svc.submit(g, "wcc", n_lanes=2, max_iters=4)
                results[i] = (i % 2, h.result(timeout=WAIT)[0])
            except BaseException as e:     # surface in main thread
                errs.append(e)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(12)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=WAIT)
        assert not errs and len(results) == 12
        for gi in (0, 1):
            vals = [p for g, p in results.values() if g == gi]
            for v in vals[1:]:
                np.testing.assert_array_equal(v, vals[0])
