"""Fused (packed-lane) execution: bit-identical parity vs the per-entry
path for every builtin app on both pipeline kinds and both kernel paths,
plus pack-time invariants (tile disjointness) as a property test."""
import numpy as np
import pytest

from repro import api
from repro.core import gas
from repro.core.executor import init_props
from repro.core.types import Geometry
from repro.graphs.rmat import rmat
from repro.kernels import ops

GEOM = Geometry(U=128, W=128, T=128, E_BLK=128, big_batch=2)
APPS = ("pagerank", "bfs", "sssp", "wcc", "closeness")

# forced all-Little / all-Big splits: deterministic coverage of both
# pipeline kinds regardless of what the perf model would classify
LITTLE = api.PlanConfig(mode="fixed", forced_little=2, forced_big=0,
                        n_lanes=2)
BIG = api.PlanConfig(mode="fixed", forced_little=0, forced_big=2, n_lanes=2)


@pytest.fixture(scope="module")
def fused_graph():
    return rmat(9, 8, seed=3)   # 512 vertices, 4 partitions at U=128


@pytest.fixture(scope="module")
def fused_store(fused_graph):
    return api.GraphStore(fused_graph, geom=GEOM)


def _run_both(store, app, config, path, max_iters=3):
    f = api.compile(None, app, store=store, config=config, path=path,
                    fuse_lanes=True)
    u = api.compile(None, app, store=store, config=config, path=path,
                    fuse_lanes=False)
    pf, mf = f.run(max_iters=max_iters)
    pu, mu = u.run(max_iters=max_iters)
    return f, u, pf, pu, mf, mu


@pytest.mark.parametrize("app", APPS)
@pytest.mark.parametrize("config", [LITTLE, BIG], ids=["little", "big"])
def test_fused_bit_identical_ref(fused_store, app, config):
    f, u, pf, pu, mf, mu = _run_both(fused_store, app, config, "ref")
    assert mf["iterations"] == mu["iterations"]
    np.testing.assert_array_equal(pf, pu)
    # the fused path must actually fuse: fewer launches than entries
    sf, su = f.stats(), u.stats()
    assert sf["num_entries"] == su["num_entries"] > sf["kernel_dispatches"] \
        or sf["num_entries"] == sf["kernel_dispatches"] <= 2


@pytest.mark.parametrize("app", APPS)
@pytest.mark.parametrize("config", [LITTLE, BIG], ids=["little", "big"])
def test_fused_bit_identical_pallas_interpret(fused_store, app, config):
    _, _, pf, pu, mf, mu = _run_both(fused_store, app, config, "pallas",
                                     max_iters=2)
    assert mf["iterations"] == mu["iterations"]
    np.testing.assert_array_equal(pf, pu)


def test_fused_mixed_lane_parity(fused_store):
    """n_lanes=1 with both dense and sparse work puts BOTH kinds in one
    lane; pack_lane must split it into (at most) one payload per kind."""
    cfg = api.PlanConfig(mode="model", n_lanes=1)
    f, u, pf, pu, _, _ = _run_both(fused_store, "pagerank", cfg, "ref")
    np.testing.assert_array_equal(pf, pu)
    payloads = [p for lane in f.executor.packed_lanes for p in lane]
    assert 1 <= len(payloads) <= 2
    assert len({p["kind"] for p in payloads}) == len(payloads)


def test_packed_big_dedups_shared_table(fused_store):
    """Split entries of the same Big work share one unique-source table
    in the packed payload (window ids rebased to one copy)."""
    work = fused_store.big_work((0, 1, 2, 3))
    interior = np.nonzero(np.asarray(work.tile_first)[1:])[0] + 1
    assert interior.size, "expected a multi-tile big work"
    mid = int(interior[0])      # first interior tile boundary
    e1 = ops._entry_np(work, 0, mid)
    e2 = ops._entry_np(work, mid, work.n_blocks)
    packed = ops._pack_group([e1, e2])
    assert packed["unique_src"].shape == work.unique_src.shape
    assert packed["n_entries"] == 2
    # same table -> no window offset for the second segment
    np.testing.assert_array_equal(
        packed["window_id"],
        np.concatenate([e1["window_id"], e2["window_id"]]))


def test_pack_rejects_overlapping_tiles(fused_store):
    """Packing the same block range twice duplicates destination tiles —
    the pack-time validator must refuse to build such a payload."""
    work = fused_store.little_work(0)
    e1 = ops._entry_np(work, 0, work.n_blocks)
    e2 = ops._entry_np(work, 0, work.n_blocks)
    with pytest.raises(AssertionError):
        ops._pack_group([e1, e2])


def test_property_packing_preserves_tile_disjointness():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(scale=st.integers(7, 9), ef=st.integers(2, 8),
           seed=st.integers(0, 99), n_lanes=st.integers(1, 4))
    def check(scale, ef, seed, n_lanes):
        g = rmat(scale, ef, seed=seed)
        store = api.GraphStore(g, geom=GEOM)
        bundle = store.plan(api.PlanConfig(mode="model", n_lanes=n_lanes))
        packed = bundle.packed_lanes()
        entries = bundle.lane_entries()
        all_idx = []
        for lane in packed:
            for p in lane:
                idx = np.asarray(p["tile_idx"])
                # per-payload: unique tiles, dense non-decreasing local ids
                assert np.unique(idx).shape[0] == idx.shape[0]
                assert p["n_out_tiles"] == idx.shape[0]
                all_idx.append(idx)
        flat = (np.concatenate(all_idx) if all_idx
                else np.zeros(0, np.int32))
        # across payloads: globally disjoint (single scatter-set merge)
        assert np.unique(flat).shape[0] == flat.shape[0]
        # packing loses no tiles vs the per-entry materialization
        entry_idx = np.concatenate(
            [np.asarray(p["tile_idx"]) for lane in entries for p in lane]
        ) if any(lane for lane in entries) else np.zeros(0, np.int32)
        np.testing.assert_array_equal(np.sort(flat), np.sort(entry_idx))

    check()


def test_time_lanes_caches_lane_fns(fused_store):
    ex = fused_store.executor(gas.make_pagerank(max_iters=2), LITTLE,
                              path="ref")
    assert ex._lane_fns is None
    ex.time_lanes(repeats=1)
    fns = ex._lane_fns
    assert fns is not None
    ex.time_lanes(repeats=1)
    assert ex._lane_fns is fns          # no rebuild / re-trace


def test_dispatch_and_trace_stats(fused_store):
    app = gas.make_pagerank(max_iters=2)
    f = fused_store.executor(app, LITTLE, path="ref", fuse_lanes=True)
    u = fused_store.executor(app, LITTLE, path="ref", fuse_lanes=False)
    sf, su = f.dispatch_stats(), u.dispatch_stats()
    assert sf["fuse_lanes"] and not su["fuse_lanes"]
    assert sf["num_entries"] == su["num_entries"]
    assert sf["kernel_dispatches"] <= su["kernel_dispatches"]
    assert sf["merge_dispatches"] == 1
    assert sf["payload_bytes"] > 0 and su["payload_bytes"] > 0
    tf, tu = f.trace_stats(), u.trace_stats()
    assert 0 < tf["jaxpr_eqns"] <= tu["jaxpr_eqns"]
    # padding accounting flows into stats()
    st = f.stats()
    assert st["num_real_edges"] == fused_store.graph.num_edges
    assert 0 < st["padding_efficiency"] <= 1.0


def test_executor_memory_footprint_matches_bundle(fused_store):
    ex = fused_store.executor(gas.make_pagerank(max_iters=2), LITTLE,
                              path="ref")
    db = ex.bundle.device_bytes()
    assert ex.memory_footprint() == db["packed_bytes"] > 0


def test_service_executor_byte_budget(fused_graph):
    from repro.serve_graph import GraphService
    with GraphService(workers=1, default_path="ref",
                      executor_byte_budget=1) as svc:
        svc.run(fused_graph, "pagerank", max_iters=2, n_lanes=2)
        svc.run(fused_graph, "bfs", max_iters=2, n_lanes=2)
        st = svc.stats()
        # 1-byte budget: only the newest executor survives
        assert st["cached_executors"] == 1
        assert st["executor_bytes"] > 0
        assert st["service"]["executor_evictions"] >= 1


def test_service_executor_bytes_tracked(fused_graph):
    from repro.serve_graph import GraphService
    with GraphService(workers=1, default_path="ref") as svc:
        svc.run(fused_graph, "pagerank", max_iters=2, n_lanes=2)
        st = svc.stats()
        assert st["cached_executors"] == 1
        assert st["executor_bytes"] > 0
        assert st["executor_byte_budget"] is None
        assert st["service"]["executor_evictions"] == 0
