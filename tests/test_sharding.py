"""Sharded execution: per-device lane ownership with plan-aware
placement.

Three layers of coverage:
  * bit-identical parity of the sharded path vs the single-device fused
    path for every builtin app on ref and pallas-interpret (in-process,
    1 device — the reduction/apply program restructure is exercised
    regardless of device count — plus an 8-device subprocess);
  * placement properties: every fresh LPT placement respects the greedy
    bound max_load <= total/n + max_est (hypothesis), kinds interleave,
    keep= pins owners;
  * streaming: after apply_delta, clean lanes' resident device payloads
    are NOT re-transferred (shards_moved accounting).

Multi-device tests spawn subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (conftest keeps the
main process at exactly one device).
"""
import os
import subprocess
import sys
import textwrap
import types

import numpy as np
import pytest

from repro import api
from repro.core import gas
from repro.core.types import Geometry
from repro.graphs.rmat import rmat
from repro.sharding import place_lanes, resolve_devices
from repro.streaming import apply_delta, random_delta

GEOM = Geometry(U=128, W=128, T=128, E_BLK=128, big_batch=2)
APPS = ("pagerank", "bfs", "sssp", "wcc", "closeness")

ENV = {**os.environ,
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": os.path.abspath(
           os.path.join(os.path.dirname(__file__), "..", "src"))}


def run_py(code: str, timeout=600):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.fixture(scope="module")
def shard_graph():
    return rmat(9, 8, seed=3)       # 512 vertices, 4 partitions at U=128


@pytest.fixture(scope="module")
def shard_store(shard_graph):
    return api.GraphStore(shard_graph, geom=GEOM)


# -- parity (single device; program restructure is the risky part) -----

@pytest.mark.parametrize("app", APPS)
@pytest.mark.parametrize("path", ["ref", "pallas"])
def test_sharded_bit_identical(shard_store, app, path):
    cfg = api.PlanConfig(n_lanes=4)
    f = api.compile(None, app, store=shard_store, config=cfg, path=path)
    s = api.compile(None, app, store=shard_store, config=cfg, path=path,
                    shard=1)
    pf, mf = f.run(max_iters=3)
    ps, ms = s.run(max_iters=3)
    assert mf["iterations"] == ms["iterations"]
    np.testing.assert_array_equal(pf, ps)
    d = s.executor.dispatch_stats()
    assert d["shard"] and d["cross_device_merges"] == 1


def test_sharded_multi_device_bit_identical_all_apps():
    """8 forced CPU devices: every builtin app bit-identical to the
    single-device fused path on both kernel paths, payloads resident on
    their owner devices, dispatch counts matching the placement, and
    exactly one cross-device merge."""
    run_py("""
        import numpy as np, jax
        from repro import api
        from repro.core.types import Geometry
        from repro.graphs.rmat import rmat
        assert jax.device_count() == 8
        g = rmat(9, 8, seed=3)
        geom = Geometry(U=128, W=128, T=128, E_BLK=128, big_batch=2)
        store = api.GraphStore(g, geom=geom)
        cfg = api.PlanConfig(n_lanes=8)
        for path in ("ref", "pallas"):
            for app in ("pagerank", "bfs", "sssp", "wcc", "closeness"):
                f = api.compile(None, app, store=store, config=cfg,
                                path=path)
                s = api.compile(None, app, store=store, config=cfg,
                                path=path, shard=8)
                pf, mf = f.run(max_iters=3)
                ps, ms = s.run(max_iters=3)
                assert mf["iterations"] == ms["iterations"], (path, app)
                np.testing.assert_array_equal(pf, ps)
        d = s.executor.dispatch_stats()
        assert d["n_devices"] == 8
        assert d["cross_device_merges"] == 1
        sh = store.shard(cfg, 8)
        devs = jax.devices()
        per_dev = d["kernel_dispatches_per_device"]
        for i, lane in enumerate(sh.lanes):
            owner = sh.placement.device_of_lane[i]
            for p in lane:
                loc = next(iter(p["src_local"].devices()))
                assert loc == devs[owner], (i, owner, loc)
        assert per_dev == [len(sh.payloads_of(dv)) for dv in range(8)]
        assert sum(1 for n in per_dev if n) >= 2   # work actually spreads
        print("OK")
    """)


def test_sharded_mixed_lane_parity(shard_store):
    """n_lanes=1 puts both kinds in one lane; the sharded path must
    keep the per-kind payload split and still match exactly."""
    cfg = api.PlanConfig(mode="model", n_lanes=1)
    f = api.compile(None, "pagerank", store=shard_store, config=cfg,
                    path="ref")
    s = api.compile(None, "pagerank", store=shard_store, config=cfg,
                    path="ref", shard=1)
    pf, _ = f.run(max_iters=3)
    ps, _ = s.run(max_iters=3)
    np.testing.assert_array_equal(pf, ps)


# -- placement ----------------------------------------------------------

def _fake_plan(ests, m):
    lanes = [[types.SimpleNamespace(est_time=e)] if e else [] for e in ests]
    return types.SimpleNamespace(lanes=lanes, num_little_lanes=m)


def test_placement_balance_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=100, deadline=None)
    @given(ests=st.lists(st.floats(0.0, 10.0), min_size=1, max_size=24),
           n_dev=st.integers(1, 8), data=st.data())
    def check(ests, n_dev, data):
        m = data.draw(st.integers(0, len(ests)))
        pl = place_lanes(_fake_plan(ests, m), n_dev)
        assert len(pl.device_of_lane) == len(ests)   # every lane owned
        assert all(0 <= d < n_dev for d in pl.device_of_lane)
        # the greedy guarantee no fresh placement may exceed
        assert max(pl.loads) <= pl.lpt_bound() + 1e-9
        assert pl.imbalance >= 1.0 - 1e-9

    check()


def test_placement_interleaves_kinds():
    """2 Little + 2 Big lanes on 2 devices: each device must get one of
    each kind (the shared-load two-pass LPT), not kind-segregated."""
    pl = place_lanes(_fake_plan([1.0, 1.0, 1.0, 1.0], 2), 2)
    for d in range(2):
        kinds = {("little" if i < 2 else "big") for i in pl.lanes_of(d)}
        assert kinds == {"little", "big"}


def test_placement_keep_pins_owners():
    pl = place_lanes(_fake_plan([5.0, 4.0, 3.0, 2.0], 2), 2,
                     keep={0: 1, 1: 1})
    assert pl.device_of_lane[0] == 1 and pl.device_of_lane[1] == 1
    # free lanes fill the other device first (it has zero kept load)
    assert pl.device_of_lane[2] == 0 and pl.device_of_lane[3] == 0


def test_placement_rejects_bad_args():
    plan = _fake_plan([1.0, 2.0], 1)
    with pytest.raises(ValueError):
        place_lanes(plan, 0)
    with pytest.raises(ValueError):
        place_lanes(plan, 2, keep={5: 0})
    with pytest.raises(ValueError):
        place_lanes(plan, 2, lane_ests=[1.0])


def test_resolve_devices():
    import jax
    assert resolve_devices(None) == tuple(jax.devices())
    assert resolve_devices(True) == tuple(jax.devices())
    assert resolve_devices(1) == (jax.devices()[0],)
    with pytest.raises(ValueError):
        resolve_devices(jax.device_count() + 1)
    with pytest.raises(ValueError):
        resolve_devices(())


# -- store / bundle integration ----------------------------------------

def test_shard_memoized_and_counted(shard_store):
    cfg = api.PlanConfig(n_lanes=2)
    sh1 = shard_store.shard(cfg, 1)
    sh2 = shard_store.shard(cfg, 1)
    assert sh1 is sh2                     # memoized per device tuple
    bundle = shard_store.plan(cfg)
    db = bundle.device_bytes()
    assert db["sharded_bytes"] == sh1.nbytes() > 0
    st = shard_store.stats()
    assert st["placement"]["devices"] >= 1
    assert st["placement"]["sharded_plans"] >= 1
    assert sum(st["placement"]["bytes_per_device"]) >= sh1.nbytes()
    assert st["placement"]["imbalance"] >= 1.0
    # sharded payload bytes count toward the plan-cache accounting
    assert shard_store.memory_footprint()["plan_bytes"] >= sh1.nbytes()


def test_merge_program_is_single_scatter(shard_store):
    """Program-derived gate: the traced merge+apply program contains
    exactly one scatter op — the single cross-device merge."""
    ex = shard_store.executor(gas.make_pagerank(max_iters=2),
                              api.PlanConfig(n_lanes=4), path="ref",
                              shard=1)
    assert ex.merge_trace_stats()["merge_scatter_ops"] == 1


def test_sharded_executor_footprint(shard_store):
    ex = shard_store.executor(gas.make_pagerank(max_iters=2),
                              api.PlanConfig(n_lanes=2), path="ref",
                              shard=1)
    assert ex.memory_footprint() == ex.sharded.nbytes() > 0
    st = ex.stats()
    assert st["placement"]["n_devices"] == 1
    assert st["kernel_dispatches"] == sum(
        st["kernel_dispatches_per_device"])


# -- streaming: clean lanes stay resident ------------------------------

def test_streaming_clean_lanes_not_retransferred():
    """After a small skewed-churn delta, at least half of the resident
    sharded lane payloads must be reused without re-transfer — asserted
    via the shards_moved accounting apply_delta surfaces."""
    g = rmat(11, 8, seed=5)
    store = api.GraphStore(g, geom=Geometry(U=128, W=128, T=128,
                                            E_BLK=128, big_batch=4))
    cfg = api.PlanConfig(n_lanes=8)
    ex = store.executor(gas.make_pagerank(max_iters=2), cfg, path="ref",
                        shard=1)
    ex.run(max_iters=2)
    delta = random_delta(g, churn=0.01, hot_frac=0.05,
                         base_fp=store.fingerprint())
    res = apply_delta(store, delta)
    s = res.stats
    assert s["shards_moved"] + s["shards_reused"] > 0
    assert s["shards_reused"] >= s["shards_moved"], s
    assert s["shard_bytes_reused"] > 0
    # reused payload objects are literally the resident ones (no copy)
    old_sh = store.plan(cfg).sharded_lanes(ex.devices)
    new_sh = res.store.plan(cfg).sharded_lanes(ex.devices)
    shared = sum(1 for a, b in zip(old_sh.lanes, new_sh.lanes)
                 if a and a is b)
    assert shared == s["shards_reused"]
    # and the derived store's sharded run is still exact
    pf, _ = res.store.executor(gas.make_pagerank(max_iters=2), cfg,
                               path="ref").run(max_iters=2)
    ps, _ = res.store.executor(gas.make_pagerank(max_iters=2), cfg,
                               path="ref", shard=1).run(max_iters=2)
    np.testing.assert_array_equal(pf, ps)


def test_streaming_shard_reuse_multi_device():
    """Same residency guarantee on a real 8-device topology: clean
    lanes keep their owner device and are not re-uploaded."""
    run_py("""
        import jax, numpy as np
        from repro import api
        from repro.core import gas
        from repro.core.types import Geometry
        from repro.graphs.rmat import rmat
        from repro.streaming import apply_delta, random_delta
        g = rmat(11, 8, seed=5)
        store = api.GraphStore(g, geom=Geometry(U=128, W=128, T=128,
                                                E_BLK=128, big_batch=4))
        cfg = api.PlanConfig(n_lanes=8)
        ex = store.executor(gas.make_pagerank(max_iters=2), cfg,
                            path="ref", shard=8)
        ex.run(max_iters=2)
        old_sh = store.plan(cfg).sharded_lanes(ex.devices)
        delta = random_delta(g, churn=0.01, hot_frac=0.05,
                             base_fp=store.fingerprint())
        res = apply_delta(store, delta)
        s = res.stats
        assert s["shards_reused"] >= s["shards_moved"], s
        new_sh = res.store.plan(cfg).sharded_lanes(ex.devices)
        for i, (a, b) in enumerate(zip(old_sh.lanes, new_sh.lanes)):
            if a and a is b:     # reused: same owner, same arrays
                assert (old_sh.placement.device_of_lane[i]
                        == new_sh.placement.device_of_lane[i])
        p1, _ = res.store.executor(gas.make_pagerank(max_iters=2), cfg,
                                   path="ref").run(max_iters=2)
        p2, _ = res.store.executor(gas.make_pagerank(max_iters=2), cfg,
                                   path="ref", shard=8).run(max_iters=2)
        np.testing.assert_array_equal(p1, p2)
        print("OK")
    """)


# -- serving ------------------------------------------------------------

def test_service_shard_requests(shard_graph):
    from repro.serve_graph import GraphService
    with GraphService(workers=1, default_path="ref") as svc:
        r1, _ = svc.run(shard_graph, "pagerank", max_iters=2, n_lanes=2,
                        shard=1)
        r2, _ = svc.run(shard_graph, "pagerank", max_iters=2, n_lanes=2)
        np.testing.assert_array_equal(r1, r2)
        # sharded and unsharded requests cache distinct executors
        assert svc.stats()["cached_executors"] == 2
        with pytest.raises(ValueError):
            svc.submit(shard_graph, "pagerank", shard="all")
        with pytest.raises(ValueError):
            svc.submit(shard_graph, "pagerank", shard=0)
        # shard=True resolves to the device count at submit time, so it
        # shares keys with an equal explicit int (on 1 device: shard=1)
        r3, _ = svc.run(shard_graph, "pagerank", max_iters=2, n_lanes=2,
                        shard=True)
        np.testing.assert_array_equal(r1, r3)
        assert svc.stats()["cached_executors"] == 2


def test_service_default_shard(shard_graph):
    from repro.serve_graph import GraphService
    with GraphService(workers=1, default_path="ref",
                      default_shard=1) as svc:
        r1, _ = svc.run(shard_graph, "pagerank", max_iters=2, n_lanes=2)
        # shard=False opts a single request out of the default
        r2, _ = svc.run(shard_graph, "pagerank", max_iters=2, n_lanes=2,
                        shard=False)
        np.testing.assert_array_equal(r1, r2)
        assert svc.stats()["cached_executors"] == 2
