"""Optimizer, data pipeline, checkpointing, fault tolerance, serving."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, not a collection error
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import grad_compress as gc
from repro.optim.adafactor import adafactor
from repro.optim.adamw import adamw, global_norm, sgd_momentum
from repro.optim.schedule import warmup_cosine
from repro.train import fault_tolerance as ft


# ---------------------------------------------------------------- optim
def _quadratic(params):
    return sum(jnp.sum(jnp.square(p - 3.0)) for p in jax.tree.leaves(params))


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(lr=0.1, weight_decay=0.0),
    lambda: adafactor(lr=0.5),
    lambda: sgd_momentum(lr=0.05),
])
def test_optimizers_converge_quadratic(make_opt):
    opt = make_opt()
    params = {"a": jnp.zeros((4, 8)), "b": jnp.zeros((3,))}
    state = opt.init(params)
    step = jax.jit(lambda p, s: opt.update(jax.grad(_quadratic)(p), s, p))
    l0 = float(_quadratic(params))
    for _ in range(300):
        params, state = step(params, state)
    assert float(_quadratic(params)) < 0.05 * l0


def test_adamw_state_dtype():
    opt = adamw(state_dtype="bfloat16")
    state = opt.init({"w": jnp.zeros((4, 4), jnp.bfloat16)})
    assert state["m"]["w"].dtype == jnp.bfloat16


def test_adafactor_memory_factored():
    opt = adafactor()
    p = {"w": jnp.zeros((128, 256))}
    st_ = opt.init(p)
    n_state = sum(x.size for x in jax.tree.leaves(st_["s"]))
    assert n_state == 128 + 256          # factored, not 128*256


def test_warmup_cosine_shape():
    f = warmup_cosine(peak=1.0, warmup=10, total=100)
    assert float(f(jnp.int32(0))) == pytest.approx(0.0)
    assert float(f(jnp.int32(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(f(jnp.int32(100))) < 0.15


# ---------------------------------------------------------- grad compress
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_int8_roundtrip_bounded_error(seed):
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(64, 32).astype(np.float32))
    q, scale = gc.int8_encode(x)
    err = np.abs(np.asarray(gc.int8_decode(q, scale)) - np.asarray(x)).max()
    assert err <= float(scale) * 0.5 + 1e-7


def test_error_feedback_accumulates():
    """With EF, the *running sum* of compressed grads tracks the true sum
    far better than compressing each step independently."""
    rs = np.random.RandomState(0)
    g_true = [jnp.asarray(rs.randn(32, 16).astype(np.float32)) * 0.01
              for _ in range(50)]
    resid = jnp.zeros((32, 16))
    acc_ef = np.zeros((32, 16), np.float32)
    acc_raw = np.zeros((32, 16), np.float32)
    for g in g_true:
        gf = g + resid
        q, s = gc.int8_encode(gf)
        deq = gc.int8_decode(q, s)
        resid = gf - deq
        acc_ef += np.asarray(deq)
        q2, s2 = gc.int8_encode(g)
        acc_raw += np.asarray(gc.int8_decode(q2, s2))
    truth = np.sum([np.asarray(g) for g in g_true], axis=0)
    assert np.abs(acc_ef - truth).max() < np.abs(acc_raw - truth).max() * 2
    # EF residual bounded (compressor contraction property)
    assert float(jnp.abs(resid).max()) < 0.01


def test_topk_roundtrip():
    x = jnp.asarray(np.arange(100, dtype=np.float32) - 50)
    vals, idx = gc.topk_encode(x, k_frac=0.1)
    back = gc.topk_decode(vals, idx, (100,))
    assert float(jnp.abs(back).max()) == 50.0
    assert int((back != 0).sum()) == 10


# ------------------------------------------------------------------ data
def test_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=100, seq_len=64, global_batch=8)
    full = TokenPipeline(cfg, host_id=0, num_hosts=1)
    h0 = TokenPipeline(cfg, host_id=0, num_hosts=2)
    h1 = TokenPipeline(cfg, host_id=1, num_hosts=2)
    b_full = full.batch(7)
    b0, b1 = h0.batch(7), h1.batch(7)
    np.testing.assert_array_equal(
        np.concatenate([b0["tokens"], b1["tokens"]]), b_full["tokens"])
    # same step twice -> identical (restart-exactness)
    np.testing.assert_array_equal(full.batch(7)["tokens"],
                                  b_full["tokens"])
    # different steps differ
    assert not np.array_equal(full.batch(8)["tokens"], b_full["tokens"])


def test_pipeline_labels_shifted():
    cfg = DataConfig(vocab_size=50, seq_len=32, global_batch=2)
    b = TokenPipeline(cfg).batch(0)
    assert b["tokens"].shape == (2, 32) and b["labels"].shape == (2, 32)


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_atomic(tmp_path):
    mgr = CheckpointManager(tmp_path, max_to_keep=2)
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "s": jnp.int32(5)}
    mgr.save(10, tree, blocking=True)
    mgr.save(20, tree, blocking=True)
    mgr.save(30, tree, blocking=True)
    assert mgr.all_steps() == [20, 30]        # GC keeps 2
    step, back = mgr.restore(like=tree)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.ones((256, 256))}
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_ignores_partial(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.ones((4,))}
    mgr.save(1, tree, blocking=True)
    # simulate a crash mid-write
    (tmp_path / "step_00000002.tmp").mkdir()
    assert mgr.latest_step() == 1


# ------------------------------------------------------- fault tolerance
def test_heartbeat_detects_dead(tmp_path):
    h0 = ft.HeartbeatMonitor(tmp_path, 0, timeout=0.2)
    h1 = ft.HeartbeatMonitor(tmp_path, 1, timeout=0.2)
    h0.beat(1)
    h1.beat(1)
    assert sorted(h0.alive_hosts()) == [0, 1]
    time.sleep(0.3)
    h0.beat(2)
    assert h0.dead_hosts([0, 1]) == [1]


def test_straggler_detector():
    det = ft.StragglerDetector(alpha=1.0, threshold=1.5)
    for h in range(4):
        det.record(h, 1.0)
    det.record(3, 5.0)
    assert det.stragglers() == [3]


def test_elastic_plan_redistributes():
    plan = ft.ElasticPlan(global_batch=32)
    p8 = plan.plan(list(range(8)))
    assert p8["local_batch"] == 4
    p5 = plan.plan([0, 1, 2, 3, 7])        # 5 hosts -> largest divisor 4
    assert p5["local_batch"] == 8
    assert len(p5["active_hosts"]) == 4


def test_retry_step():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return 42

    assert ft.retry_step(flaky, max_retries=3)() == 42
