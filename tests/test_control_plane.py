"""Control-plane tests: scheduler semantics, process-pool worker tier,
service-level scheduling (priority / deadline / admission / cancel),
job records, and the HTTP job API end-to-end.

Scheduler and job-store tests are pure Python (fake clocks, no jax
work). Service tests run tiny RMAT graphs on the ref path, reusing the
serving-suite geometry.
"""
import concurrent.futures
import json
import pickle
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.control import (ControlPlane, DeadlineExpired, JobScheduler,
                           JobStore, QueueFull, QuotaExceeded, RejectedJob,
                           TenantQuota, WorkerCrashed, WorkerPool)
from repro.control.jobs import JobState
from repro.serve_graph.metrics import (ServiceMetrics, _escape_label,
                                       merge_expositions)
from repro.core.planner import PlanConfig
from repro.core.store import GraphStore
from repro.core.types import Geometry
from repro.graphs.rmat import rmat
from repro.serve_graph import GraphService
from repro.streaming import apply_delta, random_delta, rebuild_plans

GEOM = Geometry(U=512, W=512, T=512, E_BLK=128, big_batch=2)
WAIT = 300.0


@pytest.fixture(scope="module")
def g1():
    return rmat(8, 6, seed=1, weighted=True)


@pytest.fixture(scope="module")
def g2():
    return rmat(8, 6, seed=2, weighted=True)


@pytest.fixture(scope="module")
def pool():
    """One warm single-worker pool shared by the pool tests (spawn
    startup is the expensive part)."""
    with WorkerPool(workers=1, warm=True) as p:
        yield p


def _service(**kw):
    kw.setdefault("default_geom", GEOM)
    kw.setdefault("default_path", "ref")
    kw.setdefault("workers", 1)
    return GraphService(**kw)


# ---------------------------------------------------------------------------
# scheduler unit tests (fake clock, no service)
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t


class TestJobScheduler:
    def test_priority_then_fifo(self):
        s = JobScheduler()
        s.push("a", priority=0)
        s.push("b", priority=5)
        s.push("c", priority=5)
        s.push("d", priority=1)
        assert [s.pop(0) for _ in range(4)] == ["b", "c", "d", "a"]

    def test_deadline_breaks_priority_ties(self):
        clk = FakeClock()
        s = JobScheduler(clock=clk)
        s.push("late", deadline=clk.t + 50.0)
        s.push("soon", deadline=clk.t + 10.0)
        s.push("none")                      # no deadline sorts last
        assert [s.pop(0) for _ in range(3)] == ["soon", "late", "none"]

    def test_cost_breaks_remaining_ties(self):
        s = JobScheduler()
        s.push("slow", cost=9.0)
        s.push("fast", cost=0.1)
        assert s.pop(0) == "fast"

    def test_queue_full_typed(self):
        s = JobScheduler(max_depth=1)
        s.push("a")
        with pytest.raises(QueueFull) as ei:
            s.push("b")
        assert isinstance(ei.value, RejectedJob)
        assert s.stats()["rejected_queue_full"] == 1
        assert s.qsize() == 1               # nothing half-enqueued

    def test_quota_bucket_refills(self):
        clk = FakeClock()
        s = JobScheduler(default_quota=TenantQuota(rate=1.0, burst=2.0),
                         clock=clk)
        s.push("a", tenant="t")
        s.push("b", tenant="t")             # burst of 2 spent
        with pytest.raises(QuotaExceeded) as ei:
            s.push("c", tenant="t")
        assert "retry in" in str(ei.value)
        clk.t += 1.0                        # 1 token back at rate=1/s
        s.push("c", tenant="t")
        assert s.stats()["rejected_quota"] == 1

    def test_per_tenant_quota_isolation(self):
        clk = FakeClock()
        s = JobScheduler(quotas={"stingy": TenantQuota(rate=0.001)},
                         clock=clk)
        s.push("a", tenant="stingy")
        with pytest.raises(QuotaExceeded):
            s.push("b", tenant="stingy")
        for i in range(5):                  # others are unlimited
            s.push(f"x{i}", tenant="rich")
        assert s.stats()["depth_by_tenant"]["rich"] == 5

    def test_deadline_shed_on_pop(self):
        clk = FakeClock()
        shed = []
        s = JobScheduler(clock=clk, on_shed=shed.append)
        s.push("doomed", deadline=clk.t + 1.0)
        s.push("fine")
        clk.t += 2.0
        assert s.pop(0) == "fine"           # expired job never surfaces
        assert shed == ["doomed"]
        assert s.stats()["shed"] == 1

    def test_remove_and_reprioritize(self):
        s = JobScheduler()
        s.push("a", priority=0)
        s.push("b", priority=0)
        assert s.remove("a")
        assert not s.remove("a")            # second remove is a no-op
        s.push("c", priority=0)
        s.reprioritize("c", 9)              # lazy invalidation re-keys
        assert [s.pop(0) for _ in range(2)] == ["c", "b"]

    def test_sentinel_drains_last(self):
        s = JobScheduler(max_depth=1)       # sentinel bypasses admission
        s.push("work")
        s.push_sentinel("stop")
        assert s.pop(0) == "work"
        assert s.pop(0) == "stop"

    def test_pop_timeout(self):
        s = JobScheduler()
        t0 = time.perf_counter()
        assert s.pop(0.05) is None
        assert time.perf_counter() - t0 < 5.0


# ---------------------------------------------------------------------------
# process-pool worker tier
# ---------------------------------------------------------------------------

class TestWorkerPool:
    def test_store_pickle_roundtrip(self, g1):
        st = GraphStore(g1, geom=GEOM, use_dbg=True)
        st.plan(PlanConfig())               # plan cache must NOT travel
        clone = pickle.loads(pickle.dumps(st))
        assert clone.fingerprint() == st.fingerprint()
        for k in ("src", "dst", "weights"):
            assert np.array_equal(clone.edges[k], st.edges[k])
        assert clone.plan(PlanConfig()).plan is not None    # rebuildable

    def test_build_store_matches_local(self, pool, g1):
        st = pool.build_store(g1, geom=GEOM, use_dbg=True,
                              fp=g1.fingerprint())
        ref = GraphStore(g1, geom=GEOM, use_dbg=True,
                         fingerprint=g1.fingerprint())
        assert st.fingerprint() == ref.fingerprint()
        for k in ("src", "dst", "weights"):
            assert np.array_equal(st.edges[k], ref.edges[k])

    def test_apply_cached_and_need_state(self, pool, g1):
        ref = GraphStore(g1, geom=GEOM, use_dbg=True,
                         fingerprint=g1.fingerprint())
        d = random_delta(g1, churn=0.02, seed=5)
        local = apply_delta(ref, d)
        # this pool built g1's store in the previous test -> cached base
        st = pool.build_store(g1, geom=GEOM, use_dbg=True,
                              fp=g1.fingerprint())
        res = pool.apply(st, d)
        assert res.fingerprint == local.fingerprint
        for k in ("src", "dst", "weights"):
            assert np.array_equal(res.store.edges[k], local.store.edges[k])
        # a cold pool has to be shipped the base once, then succeeds
        with WorkerPool(workers=1, warm=True) as cold:
            res2 = cold.apply(ref, d)
            assert cold.stats()["need_state_retries"] == 1
            assert res2.fingerprint == local.fingerprint

    def test_parent_side_plan_rebuild(self, pool, g1):
        ref = GraphStore(g1, geom=GEOM, use_dbg=True,
                         fingerprint=g1.fingerprint())
        ref.plan(PlanConfig())
        d = random_delta(g1, churn=0.02, seed=6)
        res = pool.apply(ref, d)
        s = rebuild_plans(ref, res.store, res.dirty_pids)
        assert s["plans_rebuilt"] == 1

    def test_crash_respawn(self, pool, g1):
        with pytest.raises(WorkerCrashed):
            pool.build_store(g1, geom=GEOM, use_dbg=True, _crash=True)
        # the pool respawned: same call now works
        st = pool.build_store(g1, geom=GEOM, use_dbg=True,
                              fp=g1.fingerprint())
        assert st.fingerprint() == g1.fingerprint()
        assert pool.stats()["crashes"] >= 1


# ---------------------------------------------------------------------------
# service-level scheduling semantics
# ---------------------------------------------------------------------------

class TestServiceScheduling:
    def test_priority_ordering(self, g1, g2):
        """A high-priority submit drains before an earlier low-priority
        one when both are queued behind a held worker."""
        with _service() as svc:
            fp1, fp2 = svc.register(g1), svc.register(g2)
            svc.run(fingerprint=fp1, app="pagerank", max_iters=2,
                    timeout=WAIT)           # warm the store
            order = []
            gate = threading.Event()
            hold = svc.submit(
                fingerprint=fp1, app="pagerank", max_iters=5,
                observer=lambda e, i: gate.wait(60)
                if e == "running" else None)
            time.sleep(0.2)                 # hold reaches the worker
            lo = svc.submit(fingerprint=fp2, app="bfs",
                            app_kwargs={"root": 0}, priority=0,
                            observer=lambda e, i: order.append(("lo", e)))
            hi = svc.submit(fingerprint=fp2, app="pagerank", max_iters=3,
                            priority=5,
                            observer=lambda e, i: order.append(("hi", e)))
            gate.set()
            for h in (hi, lo, hold):
                h.result(timeout=WAIT)
            ran = [t for t, e in order if e == "running"]
            assert ran == ["hi", "lo"], ran

    def test_deadline_shed(self, g1, g2):
        with _service() as svc:
            fp1, fp2 = svc.register(g1), svc.register(g2)
            svc.run(fingerprint=fp1, app="pagerank", max_iters=2,
                    timeout=WAIT)
            gate = threading.Event()
            hold = svc.submit(
                fingerprint=fp1, app="pagerank", max_iters=5,
                observer=lambda e, i: gate.wait(60)
                if e == "running" else None)
            time.sleep(0.1)
            doomed = svc.submit(fingerprint=fp2, app="sssp",
                                app_kwargs={"root": 0}, deadline=0.05)
            time.sleep(0.3)                 # deadline passes in queue
            gate.set()
            with pytest.raises(DeadlineExpired):
                doomed.result(timeout=WAIT)
            hold.result(timeout=WAIT)
            assert svc.metrics.snapshot()["shed_deadline"] == 1

    def test_queue_full_and_coalesce_bypass(self, g1):
        """Admission rejects at max depth — but a coalescing twin rides
        the in-flight job, so identical work is never refused (no
        priority inversion through the quota/depth gate)."""
        with _service(max_queue_depth=1) as svc:
            fp1 = svc.register(g1)
            gate = threading.Event()
            hold = svc.submit(
                fingerprint=fp1, app="pagerank", max_iters=5,
                observer=lambda e, i: gate.wait(60)
                if e == "running" else None)
            time.sleep(0.2)
            q1 = svc.submit(fingerprint=fp1, app="bfs",
                            app_kwargs={"root": 0})
            with pytest.raises(QueueFull):
                svc.submit(fingerprint=fp1, app="sssp",
                           app_kwargs={"root": 0})
            # identical submit coalesces: bypasses the full queue
            twin = svc.submit(fingerprint=fp1, app="bfs",
                              app_kwargs={"root": 0})
            gate.set()
            for h in (hold, q1, twin):
                h.result(timeout=WAIT)
            assert svc.stats()["service"]["rejected_queue_full"] >= 1

    def test_coalesce_boosts_priority(self, g1, g2):
        """A high-priority duplicate of a queued low-priority job boosts
        that job instead of waiting behind admission."""
        with _service() as svc:
            fp1, fp2 = svc.register(g1), svc.register(g2)
            svc.run(fingerprint=fp1, app="pagerank", max_iters=2,
                    timeout=WAIT)
            order = []
            gate = threading.Event()
            hold = svc.submit(
                fingerprint=fp1, app="pagerank", max_iters=5,
                observer=lambda e, i: gate.wait(60)
                if e == "running" else None)
            time.sleep(0.2)
            lo = svc.submit(fingerprint=fp2, app="bfs",
                            app_kwargs={"root": 0}, priority=0,
                            observer=lambda e, i: order.append(("lo", e)))
            mid = svc.submit(fingerprint=fp2, app="wcc", priority=3,
                             observer=lambda e, i: order.append(("mid", e)))
            # duplicate of lo at priority 9 -> boosts the queued job
            boost = svc.submit(fingerprint=fp2, app="bfs",
                               app_kwargs={"root": 0}, priority=9)
            gate.set()
            for h in (lo, mid, boost, hold):
                h.result(timeout=WAIT)
            ran = [t for t, e in order if e == "running"]
            assert ran == ["lo", "mid"], ran
            assert boost.result(timeout=WAIT)[1] is lo.result(
                timeout=WAIT)[1]            # coalesced: same meta object

    def test_quota_rejection_per_tenant(self, g1):
        with _service(quotas={"stingy": TenantQuota(rate=0.001,
                                                    burst=1)}) as svc:
            fp1 = svc.register(g1)
            ok = svc.submit(fingerprint=fp1, app="wcc", tenant="stingy")
            with pytest.raises(QuotaExceeded):
                svc.submit(fingerprint=fp1, app="closeness",
                           app_kwargs={"sources": [0]}, tenant="stingy")
            ok.result(timeout=WAIT)
            # other tenants unaffected
            svc.run(fingerprint=fp1, app="pagerank", max_iters=2,
                    timeout=WAIT)
            t = svc.stats()["service"]["tenants"]["stingy"]
            assert t["rejected"] == 1 and t["completed"] == 1

    def test_cancel_queued_job(self, g1, g2):
        with _service() as svc:
            fp1, fp2 = svc.register(g1), svc.register(g2)
            svc.run(fingerprint=fp1, app="pagerank", max_iters=2,
                    timeout=WAIT)
            gate = threading.Event()
            hold = svc.submit(
                fingerprint=fp1, app="pagerank", max_iters=5,
                observer=lambda e, i: gate.wait(60)
                if e == "running" else None)
            time.sleep(0.1)
            victim = svc.submit(fingerprint=fp2, app="bfs",
                                app_kwargs={"root": 0})
            assert svc.cancel(victim)
            assert not svc.cancel(victim)   # already detached
            gate.set()
            with pytest.raises(concurrent.futures.CancelledError):
                victim.result(timeout=WAIT)
            hold.result(timeout=WAIT)

    def test_pool_backed_service(self, g1):
        with _service(pool=1) as svc:
            fp1 = svc.register(g1)
            props, _ = svc.run(fingerprint=fp1, app="pagerank",
                               max_iters=3, timeout=WAIT)
            # reference: threads-only service, same graph/config
            with _service() as ref_svc:
                ref_svc.register(g1)
                ref_props, _ = ref_svc.run(fingerprint=fp1, app="pagerank",
                                           max_iters=3, timeout=WAIT)
            np.testing.assert_array_equal(np.asarray(props),
                                          np.asarray(ref_props))
            d = random_delta(g1, churn=0.02, seed=7)
            up = svc.update(fp1, d)
            assert up.mode == "incremental" and "path" in up.stats
            svc.run(fingerprint=up.fingerprint, app="pagerank",
                    max_iters=3, timeout=WAIT)
            assert svc.stats()["pool"]["jobs"] >= 2

    def test_worker_crash_releases_lease(self, g1):
        """Regression: a worker-process crash mid-update must not leak
        the base store's cache lease — the entry stays usable, pins
        return to zero, and the respawned pool serves the retry."""
        with _service(pool=1) as svc:
            fp1 = svc.register(g1)
            svc.run(fingerprint=fp1, app="pagerank", max_iters=2,
                    timeout=WAIT)
            old_key = next(iter(svc.cache.keys()))
            d = random_delta(g1, churn=0.02, seed=8)
            real_apply = svc._pool.apply
            svc._pool.apply = lambda store, delta, **kw: real_apply(
                store, delta, _crash=True)
            try:
                with pytest.raises(WorkerCrashed):
                    svc.update(fp1, d)
            finally:
                svc._pool.apply = real_apply
            # lease audit: no pins leaked, entry still cached
            assert svc.cache.pin_count(old_key) == 0
            assert old_key in svc.cache
            assert svc.metrics.snapshot()["update_failures"] == 1
            # the job was NOT silently retried; an explicit retry works
            up = svc.update(fp1, d)
            assert up.mode == "incremental"
            assert svc._pool.stats()["crashes"] == 1


# ---------------------------------------------------------------------------
# job records
# ---------------------------------------------------------------------------

class TestJobStore:
    def test_lifecycle_and_timestamps(self):
        js = JobStore()
        rec = js.create(kind="run", app="pagerank", tenant="t")
        assert rec.state == JobState.SUBMITTED
        js.transition(rec.id, JobState.QUEUED)
        js.transition(rec.id, JobState.RUNNING)
        js.transition(rec.id, JobState.DONE, metrics={"x": 1})
        r = js.get(rec.id)
        assert r.state == JobState.DONE and r.metrics == {"x": 1}
        assert r.timestamps.keys() >= {"submitted", "queued", "running",
                                       "done"}
        assert r.to_dict()["terminal"] is True

    def test_transitions_never_go_backwards(self):
        js = JobStore()
        rec = js.create(kind="run", app="bfs")
        js.transition(rec.id, JobState.RUNNING)
        js.transition(rec.id, JobState.QUEUED)      # late observer race
        assert js.get(rec.id).state == JobState.RUNNING
        js.transition(rec.id, JobState.CANCELLED)
        js.transition(rec.id, JobState.DONE)        # cannot resurrect
        assert js.get(rec.id).state == JobState.CANCELLED

    def test_retention_evicts_only_terminal(self):
        js = JobStore(max_records=3)
        live = js.create(kind="run", app="a")       # stays non-terminal
        done = [js.create(kind="run", app=f"d{i}") for i in range(3)]
        for r in done:
            js.transition(r.id, JobState.DONE)
        js.create(kind="run", app="new")            # forces eviction
        assert js.get(live.id) is not None          # live never evicted
        assert js.get(done[0].id) is None           # oldest terminal gone
        assert js.stats()["records"] <= 4

    def test_log_ring_and_chunked_reads(self):
        js = JobStore(log_lines=8)
        rec = js.create(kind="run", app="a")        # 1 creation line
        for i in range(20):
            js.append_log(rec.id, f"line {i}")
        lines, off, done = js.read_logs(rec.id, offset=0, limit=5)
        assert len(lines) == 5 and not done
        # offset 0 is older than the ring: skipped forward, so the
        # first line returned is the oldest RETAINED one
        assert "line 12" in lines[0]
        lines2, off2, done2 = js.read_logs(rec.id, offset=off, limit=100)
        assert "line 19" in lines2[-1] and not done2    # not terminal yet
        js.transition(rec.id, JobState.DONE)
        lines3, _, done3 = js.read_logs(rec.id, offset=off2, limit=100)
        assert done3 and any("done" in ln for ln in lines3)

    def test_jsonl_persistence(self, tmp_path):
        path = tmp_path / "jobs.jsonl"
        js = JobStore(persist_path=str(path))
        a = js.create(kind="run", app="pagerank")
        b = js.create(kind="run", app="bfs")
        js.transition(a.id, JobState.DONE)
        js.transition(b.id, JobState.FAILED, error="boom")
        js.transition(a.id, JobState.FAILED)        # no double-persist
        rows = [json.loads(ln) for ln in path.read_text().splitlines()]
        assert [r["id"] for r in rows] == [a.id, b.id]
        assert rows[1]["error"] == "boom" and rows[1]["logs"]


# ---------------------------------------------------------------------------
# control plane + HTTP API end-to-end
# ---------------------------------------------------------------------------

def _get(url):
    try:
        with urllib.request.urlopen(url) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _post(url, body=None):
    req = urllib.request.Request(
        url, data=json.dumps(body or {}).encode(), method="POST",
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


class TestControlPlaneHTTP:
    @pytest.fixture(scope="class")
    def plane(self, g1):
        with ControlPlane(workers=2, default_geom=GEOM,
                          default_path="ref") as cp:
            cp.register(g1)
            cp.serve_http()
            yield cp

    @pytest.fixture(scope="class")
    def base(self, plane):
        return f"http://127.0.0.1:{plane._http_server.server_address[1]}"

    def test_submit_to_done_over_http(self, plane, base, g1):
        st, rec = _post(base + "/jobs", {
            "fingerprint": g1.fingerprint(), "app": "pagerank",
            "max_iters": 5, "tenant": "alice", "priority": 2})
        assert st == 201 and rec["state"] in ("submitted", "queued",
                                              "running")
        jid = rec["id"]
        st, res = _get(base + f"/jobs/{jid}/result?timeout={WAIT}")
        assert st == 200 and res["num_properties"] == g1.num_vertices
        deadline = time.time() + 10         # observer fires async
        while time.time() < deadline:
            st, rec = _get(base + f"/jobs/{jid}")
            if rec["terminal"]:
                break
            time.sleep(0.05)
        assert rec["state"] == JobState.DONE
        assert "t_execute_ms" in rec["metrics"]
        assert rec["timestamps"].keys() >= {"submitted", "queued",
                                            "running", "done"}
        # chunked log stream (urllib decodes chunked transfer)
        st, logs = _get(base + f"/jobs/{jid}/logs")
        assert st == 200 and logs["done"]
        assert any("running" in ln for ln in logs["lines"])
        # listing + filters
        st, lst = _get(base + "/jobs?tenant=alice")
        assert st == 200 and any(j["id"] == jid for j in lst["jobs"])
        st, lst = _get(base + "/jobs?tenant=nobody")
        assert lst["jobs"] == []

    def test_typed_http_errors(self, base):
        st, err = _post(base + "/jobs", {})
        assert (st, err["error"]) == (400, "bad_request")
        st, err = _post(base + "/jobs", {"fingerprint": "nope"})
        assert (st, err["error"]) == (404, "unknown_fingerprint")
        st, _ = _get(base + "/jobs/job-99999999")
        assert st == 404
        st, err = _post(base + "/jobs/job-99999999/cancel")
        assert st == 409 and err["cancelled"] is False

    def test_update_job_then_serve_new_fp(self, plane, base, g1):
        d = random_delta(g1, churn=0.02, seed=9)
        rec = plane.update_job(g1.fingerprint(), d)
        assert rec.state == JobState.DONE and rec.kind == "update"
        new_fp = rec.metrics["fingerprint"]
        assert rec.metrics["stats"]["path"] in ("splice", "bulk_sort")
        st, r2 = _post(base + "/jobs", {"fingerprint": new_fp,
                                        "app": "pagerank", "max_iters": 3})
        assert st == 201
        st, _ = _get(base + f"/jobs/{r2['id']}/result?timeout={WAIT}")
        assert st == 200

    def test_metrics_endpoints(self, base):
        st, snap = _get(base + "/metrics.json")
        assert st == 200
        assert {"service", "scheduler", "jobs"} <= snap.keys()
        with urllib.request.urlopen(base + "/metrics") as r:
            prom = r.read().decode()
        for needle in ("regraph_requests_total", "regraph_scheduler_depth",
                       'regraph_jobs{state="done"}',
                       'regraph_tenant_requests_total{tenant="alice"'):
            assert needle in prom, needle

    def test_rejected_jobs_are_recorded(self, g1):
        """An admission refusal raises AND leaves a queryable record."""
        with ControlPlane(workers=1, default_geom=GEOM, default_path="ref",
                          quotas={"s": TenantQuota(rate=0.001)}) as cp:
            fp = cp.register(g1)
            cp.submit_job(fingerprint=fp, app="wcc", tenant="s")
            with pytest.raises(QuotaExceeded):
                cp.submit_job(fingerprint=fp, app="pagerank", tenant="s",
                              max_iters=2)
            rejected = cp.jobs.list(state=JobState.REJECTED)
            assert len(rejected) == 1
            assert "quota" in rejected[0]["error"]


# ---------------------------------------------------------------------------
# prometheus exposition: merged families, escaping
# ---------------------------------------------------------------------------

def _parse_exposition(text):
    """Strict promtext round-trip parse: returns {family: (help, type,
    [sample lines])} and fails on malformed lines, duplicate metadata,
    or samples appearing before their family's headers."""
    import re as _re
    sample_re = _re.compile(
        r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'
        r'(\{(?:[a-zA-Z_][a-zA-Z0-9_]*="(?:\\.|[^"\\\n])*",?)*\})?'
        r' (NaN|[-+0-9.eE]+)$')
    fams = {}
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            _, kw, name, rest = line.split(None, 3)
            fam = fams.setdefault(name, [None, None, []])
            idx = 0 if kw == "HELP" else 1
            assert fam[idx] is None, f"duplicate # {kw} for {name}"
            fam[idx] = rest
        else:
            m = sample_re.match(line)
            assert m, f"malformed sample line: {line!r}"
            name = m.group(1)
            assert name in fams, f"sample before headers: {name}"
            float(m.group(3)) if m.group(3) != "NaN" else None
            fams[name][2].append(line)
    return fams


class TestPrometheusExposition:
    def test_merge_expositions_dedupes_headers(self):
        a = ("# HELP x_total Things.\n# TYPE x_total counter\n"
             'x_total{k="a"} 1\n')
        b = ("# HELP x_total Things again (dropped).\n"
             "# TYPE x_total counter\n"
             'x_total{k="b"} 2\n'
             "# HELP y_depth Depth.\n# TYPE y_depth gauge\ny_depth 3\n")
        merged = merge_expositions(a, b)
        fams = _parse_exposition(merged)
        assert fams["x_total"][0] == "Things."        # first header wins
        assert len(fams["x_total"][2]) == 2           # both samples kept
        assert fams["y_depth"][2] == ["y_depth 3"]
        # family order is first appearance
        assert list(fams) == ["x_total", "y_depth"]

    def test_control_plane_prometheus_roundtrips(self, g1):
        """The merged /metrics document must parse cleanly: one HELP +
        one TYPE per family, every sample under its family (regression:
        the old concatenation repeated nothing only by luck — a family
        emitted by both the service and the plane would have carried
        duplicate metadata)."""
        with ControlPlane(workers=1, default_geom=GEOM,
                          default_path="ref") as cp:
            fp = cp.register(g1)
            rec = cp.submit_job(fingerprint=fp, app="pagerank",
                                max_iters=2)
            cp.result(rec.id, timeout=WAIT)
            fams = _parse_exposition(cp.prometheus())
        for fam in ("regraph_requests_total", "regraph_latency_ms",
                    "regraph_scheduler_depth", "regraph_jobs",
                    "regraph_perf_model_drift"):
            help_, type_, samples = fams[fam]
            assert help_ and type_ and samples, fam

    def test_label_escaping_deterministic(self):
        m = ServiceMetrics()
        nasty = 'ten"ant\\with\nnewline'
        m.record_submit(False, tenant=nasty)
        text = m.render_prometheus()
        fams = _parse_exposition(text)      # no raw newline broke a line
        line = [ln for ln in fams["regraph_tenant_requests_total"][2]
                if "ten" in ln][0]
        assert '\\"' in line and "\\\\" in line and "\\n" in line

    def test_label_escaping_property(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        def unescape(s):
            out, i = [], 0
            while i < len(s):
                c = s[i]
                if c == "\\":
                    assert i + 1 < len(s), "dangling backslash"
                    n = s[i + 1]
                    assert n in ('\\', 'n', '"'), f"bad escape \\{n}"
                    out.append({'\\': '\\', 'n': '\n', '"': '"'}[n])
                    i += 2
                else:
                    assert c not in ('\n', '"'), f"unescaped {c!r}"
                    out.append(c)
                    i += 1
            return "".join(out)

        @settings(max_examples=200, deadline=None)
        @given(st.text(min_size=0, max_size=64))
        def check(value):
            esc = _escape_label(value)
            assert "\n" not in esc          # never breaks the line
            assert unescape(esc) == value   # lossless round-trip

        check()


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_estimates_learn_from_measurements(g1):
    """After one measured run the scheduler's cost for that (store, app)
    comes from the EWMA, not the plan model."""
    with _service() as svc:
        fp = svc.register(g1)
        svc.run(fingerprint=fp, app="pagerank", max_iters=3, timeout=WAIT)
        with svc._cost_lock:
            assert svc._cost_n >= 1 and len(svc._cost_ewma) == 1
            ewma = next(iter(svc._cost_ewma.values()))
        assert ewma > 0.0
        skey = next(iter(svc.cache.keys()))
        cost, model_est = svc._estimate_cost(skey, "pagerank", PlanConfig())
        assert cost == pytest.approx(ewma)
        # an app never run on this store falls back to the plan model,
        # scaled by the observed model/actual ratio
        cost2, est2 = svc._estimate_cost(skey, "wcc", PlanConfig())
        assert cost2 > 0.0
