"""Per-arch reduced-config smoke tests + model-level correctness
(prefill/decode consistency, flash-attention VJP, MoE dispatch oracle)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.kernels.ref import flash_attention_ref, moe_dispatch_ref
from repro.models import common as mc
from repro.models import moe as moe_mod
from repro.models.api import build_model

B, S = 2, 32


def make_batch(cfg, rng):
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"labels": tok}
    if cfg.frontend == "vision":
        batch["embeds"] = jnp.asarray(
            rng.randn(B, S, cfg.d_model), jnp.bfloat16)
    elif cfg.frontend == "audio":
        batch["enc_embeds"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = tok
    else:
        batch["tokens"] = tok
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch, rng):
    """One forward + train-grad step on a reduced config: shapes + finite."""
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(cfg, rng)
    logits = m.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_matches_forward(arch, rng):
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(cfg, rng)
    logits = m.forward(params, batch)
    cache, last = m.prefill(params, batch)
    np.testing.assert_allclose(
        np.asarray(last, np.float32), np.asarray(logits[:, -1:], np.float32),
        rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["qwen2_1p5b", "internlm2_1p8b",
                                  "chatglm3_6b", "command_r_35b",
                                  "mamba2_2p7b", "hymba_1p5b",
                                  "granite_moe_3b_a800m"])
def test_decode_matches_forward(arch, rng):
    """Teacher-forced decode step-by-step == full forward logits."""
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    full = m.forward(params, {"tokens": tok})
    half = S // 2
    cache, last = m.prefill(params, {"tokens": tok[:, :half]})
    # grow kv caches to S for attention archs
    def grow(x):
        if x.ndim >= 3 and x.shape[2] == half and cfg.family not in (
                "ssm", "hybrid"):
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, S - half)
            return jnp.pad(x, pad)
        return x
    cache = jax.tree.map(grow, cache)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full[:, half - 1:half], np.float32),
                               rtol=2e-2, atol=2e-2)
    logits = last
    # bf16 SSM state accumulates a little more drift over a long
    # teacher-forced decode than attention caches do (recurrent state vs
    # recomputed attention); the occasional outlier lands just past 5e-2
    tol = 8e-2 if cfg.family in ("ssm", "hybrid") else 5e-2
    for t in range(half, S):
        logits, cache = m.decode_step(params, cache, tok[:, t:t + 1],
                                      jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full[:, t], np.float32), rtol=tol, atol=tol)


def test_flash_attention_grad_matches_dense(rng):
    q = jnp.asarray(rng.randn(2, 50, 4, 16), jnp.float32)
    k = jnp.asarray(rng.randn(2, 50, 2, 16), jnp.float32)
    v = jnp.asarray(rng.randn(2, 50, 2, 16), jnp.float32)

    def dense(q, k, v):
        def one(qb, kb, vb):
            h = qb.transpose(1, 0, 2)
            rep = qb.shape[1] // kb.shape[1]
            kk = jnp.repeat(kb, rep, axis=1).transpose(1, 0, 2)
            vv = jnp.repeat(vb, rep, axis=1).transpose(1, 0, 2)
            return flash_attention_ref(h, kk, vv, causal=True) \
                .transpose(1, 0, 2)
        return jax.vmap(one)(q, k, v)

    f1 = lambda *a: (mc.blockwise_attention(*a, causal=True, q_block=16,
                                            kv_block=16) ** 2).sum()
    f2 = lambda *a: (dense(*a) ** 2).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_moe_dispatch_no_drop_equals_exact(rng):
    cfg = dataclasses.replace(reduced(get_config("granite_moe_3b_a800m")),
                              moe_dispatch="biglittle")
    lp = moe_mod.init_layer_params(cfg, jax.random.key(1))
    lp = {k: jax.tree.map(lambda a: a.astype(jnp.float32), lp[k])
          for k in ("router", "we_gate", "we_up", "we_down")}
    x = jnp.asarray(rng.randn(1, 64, cfg.d_model), jnp.float32) * 0.5
    out, _ = moe_mod.moe_ffn(cfg, lp, x, capacity_factor=50.0)
    logits = x[0] @ lp["router"]
    eid = jnp.arange(logits.shape[1])[None, :]
    logits = jnp.where(eid < cfg.num_experts, logits, -1e30)
    ref = moe_dispatch_ref(x[0], logits, lp["we_gate"], lp["we_up"],
                           lp["we_down"], cfg.top_k)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_moe_biglittle_buffer_savings():
    from repro.models.moe_schedule import padded_flops_ratio
    r = padded_flops_ratio(384, 8, 65536)
    # big-little: far smaller buffers than drop-matched uniform ...
    assert r["flops_ratio_vs_matched"] < 0.35
    assert r["n_hot"] < 384
    # ... at bounded drops, where cheap-uniform drops heavily under skew
    assert r["biglittle_drop_rate"] <= 0.02 < r["uniform_cheap_drop_rate"]


def test_rope_partial_rotates_half():
    inv = mc.rope_freqs(16, rotary_dim=8)
    x = jnp.ones((1, 4, 2, 16))
    pos = jnp.arange(4)[None, :]
    y = mc.apply_rope(x, pos, inv, rotary_dim=8)
    # last half untouched
    np.testing.assert_allclose(np.asarray(y[..., 8:]),
                               np.asarray(x[..., 8:]))
    assert not np.allclose(np.asarray(y[..., :8])[0, 1:],
                           np.asarray(x[..., :8])[0, 1:])


def test_cross_entropy_masks_padding(rng):
    logits = jnp.asarray(rng.randn(2, 4, 16), jnp.float32)
    labels = jnp.asarray([[1, 2, -1, 3], [0, -1, -1, 5]], jnp.int32)
    loss = mc.cross_entropy(logits, labels, vocab_real=12)
    # oracle
    lf = np.asarray(logits).copy()
    lf[:, :, 12:] = -1e30
    p = np.exp(lf - lf.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    nll, n = 0.0, 0
    for b in range(2):
        for t in range(4):
            if labels[b, t] >= 0:
                nll += -np.log(p[b, t, labels[b, t]])
                n += 1
    assert float(loss) == pytest.approx(nll / n, rel=1e-4)
