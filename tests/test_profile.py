"""Utilization profiler + perf ledger + readiness/dashboard tests.

Pure-Python pieces (accumulator, ledger, reservoir percentiles, log
stamps) run with no jax work; the footprint-vs-jaxpr parity and the
export path run one tiny RMAT graph on the ref path like the other
control-plane tests.
"""
import json
import re
import time
import urllib.request

import pytest

from repro import api, obs
from repro.control import ControlPlane, JobStore, WorkerPool
from repro.control.dashboard import DASHBOARD_HTML
from repro.control.jobs import JobState
from repro.core.types import Geometry
from repro.graphs.rmat import rmat
from repro.obs.ledger import PerfLedger, flatten_metrics, git_sha
from repro.obs.profile import UtilizationAccumulator
from repro.serve_graph import GraphService
from repro.serve_graph.metrics import ServiceMetrics, _Reservoir

from test_control_plane import _parse_exposition

GEOM = Geometry(U=512, W=512, T=512, E_BLK=128, big_batch=2)
WAIT = 300.0

# a job-log line: "[<epoch seconds>] <LEVEL> <message>"
LOG_LINE = re.compile(r"^\[\d+\.\d{3}\] (INFO|ERROR) .+")


@pytest.fixture(scope="module")
def g1():
    return rmat(8, 6, seed=1, weighted=True)


# ---------------------------------------------------------------------------
# lane footprints vs jaxpr ground truth
# ---------------------------------------------------------------------------

class TestLaneFootprints:
    @pytest.fixture(scope="class")
    def ex(self, g1):
        return api.compile(g1, "pagerank", geom=GEOM, n_lanes=2,
                           path="ref").executor

    def test_analytic_matches_jaxpr(self, ex):
        checked = 0
        for li, fp in enumerate(ex.footprints()):
            truth = obs.jaxpr_lane_bytes(ex, li)
            if fp is None or truth is None:
                continue
            checked += 1
            assert fp.total_bytes == pytest.approx(truth, rel=0.10)
        assert checked > 0

    def test_footprint_invariants(self, ex):
        for fp in ex.footprints():
            if fp is None:
                continue
            assert fp.hbm_bytes > 0 and fp.flops > 0
            assert fp.total_bytes >= fp.edge_bytes
            assert fp.intensity == fp.flops / fp.hbm_bytes
            d = fp.as_dict()
            assert d["hbm_bytes"] == fp.hbm_bytes
            assert d["kind"] in ("little", "big", "mixed")

    def test_traced_run_accumulates_utilization(self, ex):
        tr = obs.Tracer(lane_detail=True)
        root = tr.start_trace("t")
        with tr.activate(root.context):
            ex.run(max_iters=2)
        root.end()
        util = ex.stats()["utilization"]
        assert util["profile"] is True
        assert util["kinds"], "traced run must record samples"
        for rep in util["kinds"].values():
            assert rep["gbps"] > 0 and rep["n"] > 0
        assert util["peak_bandwidth_gbps"] > 0
        # exec.lane spans carry the footprint counters
        spans = [s for s in tr.export(root.trace_id)
                 if s["name"] == "executor.lane"]
        assert spans and all("hbm_bytes" in s["attrs"]
                             and "gbps" in s["attrs"] for s in spans)

    def test_profile_off_records_nothing(self, g1):
        from repro.core import gas
        from repro.core.executor import Executor
        store = api.GraphStore(g1, geom=GEOM)
        bundle = store.plan(api.PlanConfig(n_lanes=2))
        ex = Executor(store, bundle, gas.make_pagerank(max_iters=2),
                      path="ref", profile=False)
        tr = obs.Tracer(lane_detail=True)
        root = tr.start_trace("t")
        with tr.activate(root.context):
            ex.run(max_iters=2)
        root.end()
        util = ex.stats()["utilization"]
        assert util["profile"] is False
        assert util["kinds"] == {} and util["footprints"] == []


# ---------------------------------------------------------------------------
# UtilizationAccumulator (pure python)
# ---------------------------------------------------------------------------

class TestUtilizationAccumulator:
    def test_report_shape_and_math(self):
        acc = UtilizationAccumulator()
        acc.add("little", nbytes=2e9, flops=4e9, measured_s=1.0,
                peak_bps=4e9, lane=0)
        rep = acc.report()
        little = rep["kinds"]["little"]
        assert little["gbps"] == pytest.approx(2.0)
        assert little["utilization"] == pytest.approx(0.5)
        assert little["intensity"] == pytest.approx(2.0)
        assert rep["peak_bandwidth_gbps"] == pytest.approx(4.0)
        assert rep["lanes"][0]["kind"] == "little"

    def test_no_peak_means_none_utilization(self):
        acc = UtilizationAccumulator()
        acc.add("big", 1e9, 1e9, 0.5)
        rep = acc.report()
        assert rep["kinds"]["big"]["utilization"] is None
        assert rep["peak_bandwidth_gbps"] is None

    def test_parent_chaining(self):
        parent = UtilizationAccumulator()
        child = UtilizationAccumulator(parent=parent)
        child.add("little", 1e9, 1e9, 1.0, peak_bps=2e9, lane=3)
        assert parent.report()["kinds"]["little"]["n"] == 1
        assert parent.report()["lanes"][3]["gbps"] == pytest.approx(1.0)
        with pytest.raises(ValueError):
            parent.set_parent(parent)

    def test_clear(self):
        acc = UtilizationAccumulator()
        acc.add("little", 1e9, 1e9, 1.0, lane=0)
        acc.clear()
        rep = acc.report()
        assert rep["kinds"] == {} and rep["lanes"] == {}

    def test_lane_retention_bound(self):
        acc = UtilizationAccumulator()
        for lane in range(UtilizationAccumulator._MAX_LANES + 10):
            acc.add("little", 1.0, 1.0, 1.0, lane=lane)
        assert len(acc.report()["lanes"]) \
            == UtilizationAccumulator._MAX_LANES


# ---------------------------------------------------------------------------
# perf ledger
# ---------------------------------------------------------------------------

class TestPerfLedger:
    def test_flatten_metrics(self):
        doc = {"a": 1, "b": {"c": 2.5, "flag": True, "s": "txt"},
               "xs": [3, {"d": 4}]}
        flat = flatten_metrics(doc)
        assert flat == {"a": 1.0, "b.c": 2.5, "xs.0": 3.0, "xs.1.d": 4.0}
        assert len(flatten_metrics({str(i): i for i in range(500)},
                                   max_keys=16)) == 16

    def test_append_and_compare_roundtrip(self, tmp_path):
        led = PerfLedger(str(tmp_path / "ledger.jsonl"))
        rec = led.append("fused", {"p50_run_s": 1.0, "teps": 10.0},
                         sha="abc", geom_key="g", spec_version=2)
        assert rec["bench"] == "fused" and rec["spec_version"] == 2
        assert led.records("fused")[0]["metrics"]["teps"] == 10.0
        rep = led.compare()
        assert rep["benches"]["fused"]["n_prior"] == 0
        assert rep["regressions"] == 0

    def test_compare_flags_directions(self, tmp_path):
        led = PerfLedger(str(tmp_path / "l.jsonl"))
        for sha in ("a", "b", "c"):
            led.append("x", {"p50_run_s": 1.0, "teps": 10.0}, sha=sha)
        led.append("x", {"p50_run_s": 2.0, "teps": 20.0}, sha="d")
        rep = led.compare()
        flagged = {f["metric"]: f for f in rep["benches"]["x"]["flagged"]}
        assert flagged["p50_run_s"]["regression"] is True
        assert flagged["teps"]["regression"] is False      # improvement
        assert rep["regressions"] == 1 and rep["flagged"] == 2
        out = led.render_report(rep)
        assert "[REGRESSION] p50_run_s" in out
        assert "[improvement] teps" in out

    def test_lower_is_worse_direction(self, tmp_path):
        led = PerfLedger(str(tmp_path / "l.jsonl"))
        led.append("x", {"lane_gbps": 10.0}, sha="a")
        led.append("x", {"lane_gbps": 1.0}, sha="b")
        rep = led.compare()
        f = rep["benches"]["x"]["flagged"][0]
        assert f["direction"] == "lower_is_worse"
        assert f["regression"] is True

    def test_corrupt_lines_skipped(self, tmp_path):
        path = tmp_path / "l.jsonl"
        led = PerfLedger(str(path))
        led.append("x", {"v": 1.0}, sha="a")
        with open(path, "a") as f:
            f.write("{truncated\n\nnot json at all\n")
        led.append("x", {"v": 2.0}, sha="b")
        assert len(led.records()) == 2
        assert led.compare()["benches"]["x"]["checked"] == 1

    def test_missing_file_is_empty(self, tmp_path):
        led = PerfLedger(str(tmp_path / "absent.jsonl"))
        assert led.records() == []
        assert led.compare() == {"benches": {}, "flagged": 0,
                                 "regressions": 0, "tolerance": 0.25}

    def test_git_sha_never_raises(self):
        assert isinstance(git_sha(), str) and git_sha()


# ---------------------------------------------------------------------------
# reservoir percentile edge cases (satellite d)
# ---------------------------------------------------------------------------

class TestReservoir:
    def test_empty_is_none(self):
        r = _Reservoir()
        assert r.percentile(50) is None
        assert r.mean() is None
        assert len(r) == 0

    def test_single_sample_every_percentile(self):
        r = _Reservoir()
        r.add(7.5)
        for p in (0, 1, 50, 99, 100):
            assert r.percentile(p) == 7.5
        assert r.mean() == 7.5

    def test_p0_and_p100_are_extremes(self):
        r = _Reservoir()
        for x in (5.0, 1.0, 9.0, 3.0):
            r.add(x)
        assert r.percentile(0) == 1.0
        assert r.percentile(100) == 9.0
        assert r.percentile(50) == 5.0      # nearest-rank of sorted

    def test_bounded_keeps_most_recent(self):
        r = _Reservoir(maxlen=4)
        for x in range(10):
            r.add(float(x))
        assert r.percentile(0) == 6.0 and r.percentile(100) == 9.0


# ---------------------------------------------------------------------------
# prometheus exposition of the new gauges (satellite d)
# ---------------------------------------------------------------------------

class TestUtilizationExposition:
    def test_gauges_rendered_and_parse(self):
        m = ServiceMetrics()
        m.utilization.add("little", 2e9, 4e9, 1.0, peak_bps=4e9, lane=0)
        m.utilization.add("big", 8e9, 8e9, 2.0, peak_bps=4e9, lane=1)
        fams = _parse_exposition(m.render_prometheus())
        bw = fams["regraph_lane_bandwidth_gbps"]
        ut = fams["regraph_pipeline_utilization"]
        assert bw[1] == "gauge" and ut[1] == "gauge"
        assert any('kind="little"' in ln and ln.endswith(" 2.0")
                   for ln in bw[2])
        assert any('kind="big"' in ln for ln in ut[2])
        snap = m.snapshot()
        assert snap["utilization"]["kinds"]["big"]["utilization"] \
            == pytest.approx(1.0)

    def test_empty_accumulator_keeps_families_valid(self):
        fams = _parse_exposition(ServiceMetrics().render_prometheus())
        assert fams["regraph_lane_bandwidth_gbps"][2] == []
        assert fams["regraph_pipeline_utilization"][2] == []


# ---------------------------------------------------------------------------
# job-log stamps (satellite c)
# ---------------------------------------------------------------------------

class TestJobLogStamps:
    def test_epoch_and_level_on_every_line(self):
        js = JobStore()
        rec = js.create(kind="run", app="pagerank")
        js.transition(rec.id, JobState.QUEUED)
        js.append_log(rec.id, "custom note")
        js.transition(rec.id, JobState.FAILED, error="boom")
        lines = list(js.get(rec.id).logs)
        assert lines and all(LOG_LINE.match(ln) for ln in lines)
        assert any(" ERROR " in ln for ln in lines)       # failure line
        stamp = float(lines[0].split("]")[0][1:])
        assert abs(stamp - time.time()) < 60              # epoch seconds
        assert all(isinstance(ln, str) for ln in lines)

    def test_explicit_level(self):
        js = JobStore()
        rec = js.create(kind="run", app="wcc")
        js.append_log(rec.id, "scary", level="error")
        assert " ERROR scary" in list(js.get(rec.id).logs)[-1]


# ---------------------------------------------------------------------------
# readiness probes + dashboard (satellite b, tentpole 3)
# ---------------------------------------------------------------------------

def _get(url):
    import urllib.error
    try:
        with urllib.request.urlopen(url, timeout=30) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


class TestReadinessAndDashboard:
    def test_pool_alive_flips_on_close(self):
        pool = WorkerPool(workers=1)
        assert pool.alive() is True
        pool.close()
        assert pool.alive() is False

    def test_readyz_healthz_dashboard(self, g1):
        svc = GraphService(workers=1, default_geom=GEOM,
                           default_path="ref")
        cp = ControlPlane(service=svc)
        try:
            _, base = cp.serve_http()
            st, body = _get(base + "/healthz")
            assert st == 200 and json.loads(body)["status"] == "ok"
            st, body = _get(base + "/readyz")
            info = json.loads(body)
            assert st == 200 and info["ready"] is True
            assert info["scheduler_accepting"] is True
            assert "queue_depth" in info
            st, html = _get(base + "/dashboard")
            assert st == 200
            assert "/metrics.json" in html
            assert "Pipeline utilization" in html
            # closing the service must flip readiness while the HTTP
            # face stays up (liveness unchanged)
            svc.close()
            st, body = _get(base + "/readyz")
            info = json.loads(body)
            assert st == 503 and info["ready"] is False
            assert info["scheduler_accepting"] is False
            st, _ = _get(base + "/healthz")
            assert st == 200
        finally:
            cp.close()
            svc.close()

    def test_dashboard_html_self_contained(self):
        assert "<script src" not in DASHBOARD_HTML
        assert 'href="http' not in DASHBOARD_HTML
        for needle in ("util-kinds", "util-lanes", "latency", "drift",
                       "prefers-color-scheme: dark"):
            assert needle in DASHBOARD_HTML, needle


# ---------------------------------------------------------------------------
# service-level chaining: a traced job feeds the /metrics gauges
# ---------------------------------------------------------------------------

class TestServiceUtilizationChaining:
    def test_traced_job_surfaces_gauges(self, g1):
        with ControlPlane(workers=1, default_geom=GEOM,
                          default_path="ref",
                          tracer=obs.Tracer(lane_detail=True)) as cp:
            fp = cp.register(g1)
            rec = cp.submit_job(fingerprint=fp, app="pagerank",
                                max_iters=2)
            cp.result(rec.id, timeout=WAIT)
            fams = _parse_exposition(cp.prometheus())
            assert fams["regraph_lane_bandwidth_gbps"][2], \
                "no bandwidth samples after a lane-traced job"
            assert fams["regraph_pipeline_utilization"][2]
            snap = cp.metrics_snapshot()
            assert snap["service"]["utilization"]["kinds"]
