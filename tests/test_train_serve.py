"""Integration: training loop (loss decreases, checkpoint-restart
bit-exact resume), serving engine, microbatching equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.models.api import build_model
from repro.optim.adamw import adamw
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import Trainer
from repro.train.step import make_train_step


def _setup(tmp_path, steps_ckpt=5):
    cfg = reduced(get_config("qwen2_1p5b"))
    model = build_model(cfg)
    opt = adamw(lr=3e-3, weight_decay=0.0)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    tr = Trainer(model, opt, data, tmp_path, checkpoint_every=steps_ckpt)
    return cfg, model, opt, data, tr


def test_training_reduces_loss(tmp_path):
    _, _, _, _, tr = _setup(tmp_path)
    _, _, losses = tr.run(25, log_every=0)
    assert losses[-5:].mean() < losses[:5].mean()


def test_checkpoint_restart_exact(tmp_path):
    """Crash after step k, restart -> identical final params to an
    uninterrupted run (deterministic pipeline + checkpointing)."""
    cfg, model, opt, data, tr = _setup(tmp_path / "a", steps_ckpt=10)
    p_full, _, _ = tr.run(16, log_every=0)

    cfg2, model2, opt2, data2, tr2 = _setup(tmp_path / "b", steps_ckpt=10)
    tr2.run(11, log_every=0)        # "crash" right after the step-10 ckpt
    tr3 = Trainer(model2, opt2, data2, tmp_path / "b", checkpoint_every=10)
    p_resumed, _, _ = tr3.run(16, log_every=0)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_microbatch_equivalence(rng):
    """grad accumulation over 4 microbatches == single big batch."""
    cfg = reduced(get_config("internlm2_1p8b"))
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    opt = adamw(lr=0.0, weight_decay=0.0)   # lr 0: compare metrics only
    params = model.init(jax.random.key(0))
    st = opt.init(params)
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 16)), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    s1 = make_train_step(model, opt, micro_batches=1)
    s4 = make_train_step(model, opt, micro_batches=4)
    _, _, m1 = jax.jit(s1)(params, st, batch)
    _, _, m4 = jax.jit(s4)(params, st, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    assert float(m1["grad_norm"]) == pytest.approx(float(m4["grad_norm"]),
                                                   rel=1e-3)


def test_serve_engine_waves(rng):
    cfg = reduced(get_config("qwen2_1p5b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=3, max_seq=48)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab_size, 12)
                    .astype(np.int32), max_new_tokens=6) for _ in range(5)]
    stats = eng.serve(reqs)
    assert stats["requests"] == 5
    assert all(r.done and len(r.out) == 6 for r in reqs)
    assert stats["tokens_per_s"] > 0


def test_serve_greedy_matches_decode_path(rng):
    """Engine greedy output == manual prefill+decode loop."""
    cfg = reduced(get_config("internlm2_1p8b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = rng.randint(0, cfg.vocab_size, 10).astype(np.int32)
    eng = ServeEngine(model, params, max_batch=1, max_seq=32)
    [req] = eng.run_wave([Request(tokens=prompt, max_new_tokens=5)])
    # manual loop
    cache, logits = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]})
    cache = jax.tree.map(
        lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, 8)] + [(0, 0)] * (x.ndim - 3))
        if x.ndim >= 3 and x.shape[2] == 10 else x, cache)
    out = [int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))]
    for t in range(4):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(10 + t))
        out.append(int(jnp.argmax(logits[0, 0, :cfg.vocab_size])))
    assert req.out.tolist() == out
