"""Layered API (GraphStore → Planner → Executor): equivalence with the
legacy engine, PlanConfig validation, caching, and deprecation."""
import warnings

import numpy as np
import pytest

from repro import api
from repro.core import gas
from repro.core.engine import HeterogeneousEngine, run_app
from repro.core.types import Geometry
from repro.graphs.rmat import rmat

GEOM = Geometry(U=1024, W=512, T=512, E_BLK=128, big_batch=4)

FIVE_APPS = [
    ("pagerank", lambda: gas.make_pagerank(max_iters=8)),
    ("bfs", lambda: gas.make_bfs(root=7)),
    ("sssp", lambda: gas.make_sssp(root=3)),
    ("wcc", lambda: gas.make_wcc()),
    ("closeness", lambda: gas.make_closeness(sources=np.arange(4))),
]


@pytest.fixture(scope="module")
def weighted_graph():
    return rmat(10, 8, seed=3, weighted=True)


@pytest.fixture(scope="module")
def shared_store(weighted_graph):
    return api.GraphStore(weighted_graph, geom=GEOM)


# ------------------------------------------------------------------ (a)
@pytest.mark.parametrize("app_name,mk", FIVE_APPS)
def test_store_built_once_matches_legacy_engine(shared_store, weighted_graph,
                                                app_name, mk):
    """ONE GraphStore shared across all five apps must yield bit-identical
    results to a fresh per-app HeterogeneousEngine."""
    cfg = api.PlanConfig(n_lanes=4)
    p_new, m_new = shared_store.plan_and_run(mk(), cfg, path="ref",
                                             max_iters=8)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = HeterogeneousEngine(weighted_graph, mk(), geom=GEOM,
                                  n_lanes=4, path="ref")
    p_old, m_old = eng.run(max_iters=8)
    assert m_new["iterations"] == m_old["iterations"], app_name
    np.testing.assert_array_equal(p_new, p_old, err_msg=app_name)


@pytest.mark.parametrize("mode,kw", [
    ("monolithic", {}),
    ("fixed", {"forced_little": 2, "forced_big": 2}),
])
def test_plan_modes_match_legacy(shared_store, weighted_graph, mode, kw):
    cfg = api.PlanConfig(mode=mode, n_lanes=4, **kw)
    app = gas.make_pagerank(max_iters=4)
    p_new, _ = shared_store.plan_and_run(app, cfg, path="ref", max_iters=4)
    legacy_mode = (mode if mode == "monolithic"
                   else ("fixed", kw["forced_little"], kw["forced_big"]))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = HeterogeneousEngine(weighted_graph, app, geom=GEOM, n_lanes=4,
                                  path="ref", plan_mode=legacy_mode)
    p_old, _ = eng.run(max_iters=4)
    np.testing.assert_array_equal(p_new, p_old)


def test_store_memoizes_blocking_and_plans(shared_store):
    cfg = api.PlanConfig(n_lanes=4)
    b1 = shared_store.plan(cfg)
    b2 = shared_store.plan(api.PlanConfig(n_lanes=4))  # equal, new instance
    assert b1 is b2, "equal configs must hit the plan cache"
    b3 = shared_store.plan(api.PlanConfig(n_lanes=2))
    assert b3 is not b1
    # blockings are shared object-identically across plans
    for pid, w in b1.little_works.items():
        if pid in b3.little_works:
            assert b3.little_works[pid] is w


def test_planner_does_not_mutate_store_infos(shared_store):
    shared_store.plan(api.PlanConfig(n_lanes=4))
    assert all(i.is_dense is None for i in shared_store.infos), \
        "classification must happen on copies, not the pristine store stats"


def test_compile_convenience(weighted_graph):
    compiled = api.compile(weighted_graph, "pagerank", geom=GEOM,
                           n_lanes=4, path="ref")
    props, meta = compiled.run(max_iters=4)
    assert props.shape[0] >= weighted_graph.num_vertices
    assert meta["iterations"] >= 1
    assert compiled.plan.num_lanes == 4
    # reuse the store for a second app without re-preprocessing
    c2 = api.compile(None, "bfs", store=compiled.store, n_lanes=4,
                     path="ref")
    assert c2.store is compiled.store
    with pytest.raises(ValueError):
        api.compile(None, "bfs")  # no graph and no store
    with pytest.raises(ValueError):
        api.compile(weighted_graph, "bfs",
                    config=api.PlanConfig(), n_lanes=2)  # both config+kwargs
    with pytest.raises(ValueError):
        api.compile(None, "pagerankk", store=compiled.store)  # unknown app
    # a shared store fixes graph/geometry/DBG: contradicting asks are loud
    with pytest.raises(ValueError):
        api.compile(None, "bfs", store=compiled.store,
                    geom=Geometry(U=2048, W=512, T=512, E_BLK=128))
    with pytest.raises(ValueError):
        api.compile(None, "bfs", store=compiled.store, use_dbg=False)
    with pytest.raises(ValueError):
        api.compile(rmat(8, 6, seed=9), "bfs", store=compiled.store)
    # the store's own graph / matching geom are fine
    api.compile(weighted_graph, "bfs", store=compiled.store, geom=GEOM,
                n_lanes=4, path="ref")


def test_store_clear_plans(weighted_graph):
    store = api.GraphStore(weighted_graph, geom=GEOM)
    b1 = store.plan(api.PlanConfig(n_lanes=2))
    cleared = store.clear_plans()
    assert cleared["plans"] == 1
    assert cleared["freed_bytes"] >= 0
    b2 = store.plan(api.PlanConfig(n_lanes=2))
    assert b2 is not b1, "cleared plans must rebuild"
    # blockings survive the clear (re-planning stays cheap)
    assert store.stats()["cached_little_works"] > 0 or \
        store.stats()["cached_big_works"] > 0


def test_legacy_shim_rejects_store_mismatches(weighted_graph):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = HeterogeneousEngine(weighted_graph,
                                  gas.make_pagerank(max_iters=2),
                                  geom=GEOM, n_lanes=2, path="ref")
        for kw in (dict(graph=rmat(8, 6, seed=9)),
                   dict(graph=None,
                        geom=Geometry(U=2048, W=512, T=512, E_BLK=128)),
                   dict(graph=None, use_dbg=False)):
            with pytest.raises(ValueError):
                HeterogeneousEngine(app=gas.make_bfs(root=0), n_lanes=2,
                                    path="ref", store=eng.store, **kw)
        with pytest.raises(ValueError):
            HeterogeneousEngine(None, gas.make_bfs(root=0))  # no graph/store


# ------------------------------------------------------------------ (b)
@pytest.mark.parametrize("bad", [
    dict(mode="weird"),
    dict(n_lanes=0),
    dict(n_lanes=-3),
    dict(forced_little=2),                                    # not fixed
    dict(mode="fixed", forced_little=3, forced_big=3, n_lanes=4),
    dict(mode="fixed", forced_little=-1, forced_big=5, n_lanes=4),
    dict(mode="fixed", forced_little=0, forced_big=0, n_lanes=1),
])
def test_plan_config_rejects_bad_splits(bad):
    with pytest.raises(ValueError):
        api.PlanConfig(**bad)


def test_plan_config_accepts_valid_splits():
    api.PlanConfig(mode="fixed", forced_little=0, forced_big=4, n_lanes=4)
    api.PlanConfig(mode="fixed", forced_little=4, forced_big=0, n_lanes=4)
    api.PlanConfig(mode="monolithic", n_lanes=1)


def test_plan_config_from_legacy():
    cfg = api.PlanConfig.from_legacy(("fixed", 2, 6), n_lanes=4, hw=None)
    assert (cfg.mode, cfg.forced_little, cfg.forced_big, cfg.n_lanes) == \
        ("fixed", 2, 6, 8)  # tuple overrides n_lanes, legacy semantics
    assert api.PlanConfig.from_legacy("monolithic", 4).mode == "monolithic"
    with pytest.raises(ValueError):
        api.PlanConfig.from_legacy("mystery", 4)


# ------------------------------------------------------------------ (c)
def test_legacy_engine_emits_deprecation_warning(weighted_graph):
    with pytest.warns(DeprecationWarning, match="HeterogeneousEngine"):
        HeterogeneousEngine(weighted_graph, gas.make_pagerank(max_iters=2),
                            geom=GEOM, n_lanes=2, path="ref")


def test_legacy_shim_surface(weighted_graph):
    """The shim keeps the attribute surface tests/benchmarks rely on."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = HeterogeneousEngine(weighted_graph,
                                  gas.make_pagerank(max_iters=2),
                                  geom=GEOM, n_lanes=4, path="ref")
    assert eng.plan.num_lanes == 4
    assert len(eng.infos) == len(eng.store.infos)
    assert set(eng.edges) == {"src", "dst", "weights"}
    assert eng.V_pad % GEOM.U == 0
    s = eng.stats()
    for key in ("V", "E", "partitions", "dense", "sparse", "little_lanes",
                "big_lanes", "est_makespan", "t_dbg_ms",
                "t_partition_schedule_ms"):
        assert key in s
    # sharing a store across engines reuses plans and blockings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng2 = HeterogeneousEngine(weighted_graph, gas.make_bfs(root=0),
                                   geom=GEOM, n_lanes=4, path="ref",
                                   store=eng.store)
    assert eng2.plan is eng.plan
