"""Per-kernel allclose validation: Pallas (interpret=True) vs ref.py
oracle, swept over shapes/dtypes/modes with hypothesis."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, not a collection error
from hypothesis import given, settings, strategies as st

from repro.core import partition as part
from repro.core.types import Geometry
from repro.graphs.rmat import rmat
from repro.kernels import ops, ref as ref_mod
from repro.kernels.gas_kernel import gas_pallas_call

GEOM = Geometry(U=512, W=512, T=512, E_BLK=128, big_batch=2)


def _entry(graph, kind, geom=GEOM, pid=0):
    infos, edges = part.partition_graph(graph, geom)
    infos = [i for i in infos if i.num_edges > 0]
    if kind == "little":
        work = part.block_little(edges, infos[pid % len(infos)], geom)
    else:
        work = part.block_big(edges, infos[:2], geom)
    return ops.materialize_entry(work, 0, work.n_blocks)


@pytest.mark.parametrize("kind", ["little", "big"])
@pytest.mark.parametrize("mode", ["sum", "min", "max"])
def test_pallas_matches_ref_float(kind, mode, tiny_graph, rng):
    entry = _entry(tiny_graph, kind)
    V_pad = part.padded_num_vertices(tiny_graph.num_vertices, GEOM)
    vprops = jnp.asarray(rng.rand(V_pad).astype(np.float32))
    sc = (lambda p, w: p + w) if mode != "sum" else (lambda p, w: p)
    tr, _ = ops.run_entry(entry, vprops, sc, mode, "ref")
    tp, _ = ops.run_entry(entry, vprops, sc, mode, "pallas")
    np.testing.assert_allclose(np.asarray(tr), np.asarray(tp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", ["little", "big"])
def test_pallas_matches_ref_int_or(kind, tiny_graph, rng):
    entry = _entry(tiny_graph, kind)
    V_pad = part.padded_num_vertices(tiny_graph.num_vertices, GEOM)
    vprops = jnp.asarray(rng.randint(0, 2**30, V_pad).astype(np.int32))
    tr, _ = ops.run_entry(entry, vprops, lambda p, w: p, "or", "ref")
    tp, _ = ops.run_entry(entry, vprops, lambda p, w: p, "or", "pallas")
    assert np.array_equal(np.asarray(tr), np.asarray(tp))


def test_slice_merge_equals_full(tiny_graph, rng):
    """Tile-snapped slices merged = whole-work result."""
    geom = GEOM
    infos, edges = part.partition_graph(tiny_graph, geom)
    infos = [i for i in infos if i.num_edges > 0]
    work = part.block_little(edges, infos[0], geom)
    V_pad = part.padded_num_vertices(tiny_graph.num_vertices, geom)
    vprops = jnp.asarray(rng.rand(V_pad).astype(np.float32))
    sc = lambda p, w: p
    full_entry = ops.materialize_entry(work, 0, work.n_blocks)
    t_full, idx_full = ops.run_entry(full_entry, vprops, sc, "sum", "ref")
    accum_full = ops.merge_tiles(jnp.zeros(V_pad), t_full, idx_full, geom.T)
    accum_sliced = jnp.zeros(V_pad)
    mid = work.n_blocks // 2
    for lo, hi in [(0, mid), (mid, work.n_blocks)]:
        e = ops.materialize_entry(work, lo, hi)
        if e is None:
            continue
        t, idx = ops.run_entry(e, vprops, sc, "sum", "ref")
        accum_sliced = ops.merge_tiles(accum_sliced, t, idx, geom.T)
    np.testing.assert_allclose(np.asarray(accum_full),
                               np.asarray(accum_sliced), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(scale=st.integers(6, 8), ef=st.integers(2, 8),
       seed=st.integers(0, 99), mode=st.sampled_from(["sum", "min"]))
def test_property_kernel_vs_edge_oracle(scale, ef, seed, mode):
    """End-to-end property: blocked kernel == straight edge-list oracle
    for arbitrary graphs."""
    g = rmat(scale, ef, seed=seed)
    geom = Geometry(U=512, W=512, T=512, E_BLK=128, big_batch=2)
    infos, edges = part.partition_graph(g, geom)
    V_pad = part.padded_num_vertices(g.num_vertices, geom)
    rs = np.random.RandomState(seed)
    vprops = jnp.asarray(rs.rand(V_pad).astype(np.float32))
    sc = lambda p, w: p
    from repro.core.gas import GATHER_IDENTITY
    accum = jnp.full((V_pad,), GATHER_IDENTITY[mode], jnp.float32)
    for i in infos:
        if i.num_edges == 0:
            continue
        work = part.block_little(edges, i, geom)
        e = ops.materialize_entry(work, 0, work.n_blocks)
        t, idx = ops.run_entry(e, vprops, sc, mode, "ref")
        accum = ops.merge_tiles(accum, t, idx, geom.T)
    oracle = ref_mod.edge_ref(jnp.asarray(g.src), jnp.asarray(g.dst),
                              jnp.zeros(g.num_edges), vprops, sc, mode,
                              V_pad)
    np.testing.assert_allclose(np.asarray(accum), np.asarray(oracle),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("e_blk,w,t", [(128, 512, 512), (256, 512, 512),
                                       (128, 1024, 512), (128, 512, 1024)])
def test_kernel_geometry_sweep(e_blk, w, t, rng):
    """Direct pallas_call across block geometries."""
    n_blocks, n_win, n_tiles = 5, 3, 2
    vwin = jnp.asarray(rng.rand(n_win, w).astype(np.float32))
    src = jnp.asarray(rng.randint(0, w, (n_blocks, e_blk)).astype(np.int32))
    dst = jnp.asarray(rng.randint(0, t, (n_blocks, e_blk)).astype(np.int32))
    wts = jnp.asarray(rng.rand(n_blocks, e_blk).astype(np.float32))
    valid = jnp.asarray(rng.rand(n_blocks, e_blk) < 0.9, jnp.int32)
    wid = jnp.asarray(rng.randint(0, n_win, n_blocks).astype(np.int32))
    # every output tile must be touched (materialize_entry guarantees it)
    tid = jnp.asarray(np.sort(np.concatenate(
        [np.arange(n_tiles), rng.randint(0, n_tiles, n_blocks - n_tiles)]))
        .astype(np.int32))
    tf = np.ones(n_blocks, np.int32)
    tf[1:] = (np.asarray(tid)[1:] != np.asarray(tid)[:-1])
    tf = jnp.asarray(tf)
    sc = lambda p, wt: p * 2 + wt
    kw = dict(scatter_fn=sc, mode="sum", e_blk=e_blk, w=w, t=t,
              n_out_tiles=n_tiles)
    out_p = gas_pallas_call(vwin, src, dst, wts, valid, wid, tid, tf,
                            **kw, interpret=True)
    out_r = ref_mod.gas_ref(vwin, src, dst, wts, valid, wid, tid, tf,
                            scatter_fn=sc, mode="sum", t=t,
                            n_out_tiles=n_tiles)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)
