"""Partitioning, DBG and brick-blocking invariants (unit + property)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, not a collection error
from hypothesis import given, settings, strategies as st

from repro.core import partition as part
from repro.core.types import Geometry
from repro.graphs.formats import from_edges
from repro.graphs.rmat import rmat


def test_dbg_concentrates_high_degree(small_graph):
    g2, perm = part.apply_dbg(small_graph)
    ind = g2.in_degrees()
    # mean in-degree of the first quarter must dominate the last quarter
    q = g2.num_vertices // 4
    assert ind[:q].mean() > ind[-q:].mean() * 2


def test_dbg_preserves_graph(small_graph):
    g2, perm = part.apply_dbg(small_graph)
    assert g2.num_edges == small_graph.num_edges
    # edge set is preserved under the permutation
    orig = set(zip(small_graph.src.tolist(), small_graph.dst.tolist()))
    mapped = set(zip(perm[small_graph.src].tolist(),
                     perm[small_graph.dst].tolist()))
    new = set(zip(g2.src.tolist(), g2.dst.tolist()))
    assert mapped == new and len(orig) == len(new)


def test_partition_ranges(small_graph, small_geom):
    infos, edges = part.partition_graph(small_graph, small_geom)
    total = 0
    for i in infos:
        d = edges["dst"][i.edge_lo:i.edge_hi]
        assert ((d >= i.dst_lo) & (d < i.dst_lo + small_geom.U)).all()
        total += i.num_edges
    assert total == small_graph.num_edges


def _roundtrip_edges(blocked, geom):
    """Recover (src_global?, dst_global) pairs from a blocked layout."""
    out = []
    for b in range(blocked.n_blocks):
        for e in range(geom.E_BLK):
            if not blocked.valid[b, e]:
                continue
            dst = (blocked.tile_dst_start[blocked.tile_id[b]]
                   + blocked.dst_local[b, e])
            src_win = blocked.window_id[b]
            src = src_win * geom.W + blocked.src_local[b, e]
            out.append((src, dst))
    return out


def test_block_little_roundtrip(small_graph, small_geom):
    infos, edges = part.partition_graph(small_graph, small_geom)
    blocked = part.block_little(edges, infos[0], small_geom)
    got = sorted(_roundtrip_edges(blocked, small_geom))
    lo, hi = infos[0].edge_lo, infos[0].edge_hi
    want = sorted(zip(edges["src"][lo:hi].tolist(),
                      edges["dst"][lo:hi].tolist()))
    assert got == want


def test_block_big_roundtrip(small_graph, small_geom):
    infos, edges = part.partition_graph(small_graph, small_geom)
    blocked = part.block_big(edges, infos[:1], small_geom)
    # big uses compact indices: src = unique_src[win*W + local]
    got = []
    for b in range(blocked.n_blocks):
        for e in range(small_geom.E_BLK):
            if not blocked.valid[b, e]:
                continue
            cid = blocked.window_id[b] * small_geom.W \
                + blocked.src_local[b, e]
            src = blocked.unique_src[cid]
            dst = (blocked.tile_dst_start[blocked.tile_id[b]]
                   + blocked.dst_local[b, e])
            got.append((int(src), int(dst)))
    lo, hi = infos[0].edge_lo, infos[0].edge_hi
    want = sorted(zip(edges["src"][lo:hi].tolist(),
                      edges["dst"][lo:hi].tolist()))
    assert sorted(got) == want


def test_blocks_tile_sorted(small_graph, small_geom):
    infos, edges = part.partition_graph(small_graph, small_geom)
    blocked = part.block_little(edges, infos[0], small_geom)
    # output-tile revisits must be consecutive (TPU accumulation safety)
    tid = blocked.tile_id[:blocked.n_blocks]
    assert (np.diff(tid) >= 0).all()
    # tile_first marks exactly the changes
    tf = blocked.tile_first
    expect = np.ones_like(tid)
    expect[1:] = (tid[1:] != tid[:-1]).astype(np.int32)
    assert (tf == expect).all()


def test_blocks_homogeneous(small_graph, small_geom):
    """Every block holds edges of one (window, tile) brick."""
    infos, edges = part.partition_graph(small_graph, small_geom)
    blocked = part.block_little(edges, infos[0], small_geom)
    assert (blocked.src_local < small_geom.W).all()
    assert (blocked.dst_local < small_geom.T).all()


@settings(max_examples=20, deadline=None)
@given(scale=st.integers(6, 9), ef=st.integers(2, 12),
       seed=st.integers(0, 1000))
def test_property_blocking_preserves_edges(scale, ef, seed):
    """Property: blocking is lossless for any graph/geometry."""
    g = rmat(scale, ef, seed=seed)
    geom = Geometry(U=512, W=512, T=512, E_BLK=128, big_batch=2)
    infos, edges = part.partition_graph(g, geom)
    n_real = 0
    for i in infos:
        if i.num_edges == 0:
            continue
        bl = part.block_little(edges, i, geom)
        assert bl.num_real_edges == i.num_edges
        assert bl.valid.sum() == i.num_edges
        n_real += i.num_edges
    assert n_real == g.num_edges


def test_self_loop_free_and_dedup():
    g = from_edges([0, 0, 1, 1], [1, 1, 2, 2], num_vertices=4)
    assert g.num_edges == 2  # deduped
