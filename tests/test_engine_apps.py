"""End-to-end graph application correctness (vs networkx / numpy oracles)
across plan modes, and scheduler/perf-model behaviour."""
import networkx as nx
import numpy as np
import pytest

from repro.core import gas, perf_model, schedule
from repro.core.engine import HeterogeneousEngine
from repro.core.types import Geometry
from repro.graphs.rmat import rmat

GEOM = Geometry(U=1024, W=512, T=512, E_BLK=128, big_batch=4)


def _nx(graph):
    G = nx.DiGraph()
    G.add_nodes_from(range(graph.num_vertices))
    G.add_edges_from(zip(graph.src.tolist(), graph.dst.tolist()))
    return G


@pytest.mark.parametrize("plan_mode", ["model", "monolithic",
                                       ("fixed", 2, 2)])
def test_pagerank_vs_oracle(small_graph, plan_mode):
    app = gas.make_pagerank(max_iters=8)
    eng = HeterogeneousEngine(small_graph, app, geom=GEOM, n_lanes=4,
                              path="ref", plan_mode=plan_mode)
    props, meta = eng.run(max_iters=8)
    # numpy pull power-iteration oracle
    outdeg = np.maximum(small_graph.out_degrees(), 1).astype(np.float32)
    p = np.full(small_graph.num_vertices, 1 / small_graph.num_vertices,
                np.float32) / outdeg
    for _ in range(meta["iterations"]):
        acc = np.zeros(small_graph.num_vertices, np.float32)
        np.add.at(acc, small_graph.dst, p[small_graph.src])
        p = ((1 - 0.85) / small_graph.num_vertices + 0.85 * acc) / outdeg
    np.testing.assert_allclose(props[:small_graph.num_vertices], p,
                               rtol=1e-4, atol=1e-8)


def test_bfs_vs_networkx(small_graph):
    app = gas.make_bfs(root=7)
    eng = HeterogeneousEngine(small_graph, app, geom=GEOM, n_lanes=4,
                              path="ref")
    props, _ = eng.run()
    dist = nx.single_source_shortest_path_length(_nx(small_graph), 7)
    ref = np.full(small_graph.num_vertices, gas.INF)
    for k, v in dist.items():
        ref[k] = v
    assert np.array_equal(props[:small_graph.num_vertices], ref)


def test_wcc_vs_networkx(small_graph):
    app = gas.make_wcc(max_iters=64)
    # WCC needs symmetric edges: run on the union graph
    from repro.graphs.formats import from_edges
    src = np.concatenate([small_graph.src, small_graph.dst])
    dst = np.concatenate([small_graph.dst, small_graph.src])
    g = from_edges(src, dst, num_vertices=small_graph.num_vertices)
    eng = HeterogeneousEngine(g, app, geom=GEOM, n_lanes=4, path="ref")
    props, _ = eng.run()
    comps = list(nx.weakly_connected_components(_nx(g)))
    for comp in comps:
        vals = {props[v] for v in comp}
        assert len(vals) == 1, "component must share one label"


def test_sssp_vs_networkx():
    g = rmat(9, 8, seed=11, weighted=True)
    app = gas.make_sssp(root=3)
    eng = HeterogeneousEngine(g, app, geom=GEOM, n_lanes=4, path="ref")
    props, _ = eng.run(max_iters=64)
    G = nx.DiGraph()
    G.add_nodes_from(range(g.num_vertices))
    for s, d, w in zip(g.src.tolist(), g.dst.tolist(), g.weights.tolist()):
        G.add_edge(s, d, weight=w)
    dist = nx.single_source_dijkstra_path_length(G, 3)
    ref = np.full(g.num_vertices, gas.INF)
    for k, v in dist.items():
        ref[k] = v
    np.testing.assert_allclose(props[:g.num_vertices], ref, rtol=1e-5)


def test_closeness_bit_parallel(small_graph):
    app = gas.make_closeness(sources=np.arange(4), max_iters=16)
    eng = HeterogeneousEngine(small_graph, app, geom=GEOM, n_lanes=3,
                              path="ref")
    props, _ = eng.run()
    # bit b of vertex v set <=> v reachable from source b
    G = _nx(small_graph)
    for b in range(4):
        reach = nx.descendants(G, b) | {b}
        got = {v for v in range(small_graph.num_vertices)
               if props[v] & (1 << b)}
        assert got == reach


def test_scheduler_classifies_and_balances(small_graph):
    eng = HeterogeneousEngine(small_graph, gas.make_pagerank(), geom=GEOM,
                              n_lanes=4, path="ref")
    s = eng.stats()
    assert s["dense"] + s["sparse"] == sum(
        1 for i in eng.infos if i.num_edges > 0)
    assert eng.plan.num_lanes == 4
    # per-lane modelled load within 2x of each other (balanced)
    loads = [sum(e.est_time for e in lane) for lane in eng.plan.lanes
             if lane]
    if len(loads) > 1:
        assert max(loads) < 2.5 * (sum(loads) / len(loads)) + 1e-9


def test_perf_model_orders_dense_vs_sparse(small_graph):
    """Dense partitions should prefer Little; sparse prefer Big."""
    eng = HeterogeneousEngine(small_graph, gas.make_pagerank(), geom=GEOM,
                              n_lanes=4, path="ref")
    dense = [i for i in eng.infos if i.is_dense]
    sparse = [i for i in eng.infos if i.is_dense is False and i.num_edges]
    for i in dense:
        assert i.t_little <= i.t_big
    for i in sparse:
        assert i.t_big <= i.t_little
    # with DBG, the first partition is the densest
    if dense:
        assert min(d.pid for d in dense) == 0


def test_perf_model_calibration(small_graph):
    eng = HeterogeneousEngine(small_graph, gas.make_pagerank(), geom=GEOM,
                              n_lanes=2, path="ref")
    samples = []
    for i in eng.infos:
        if i.num_edges == 0:
            continue
        samples.append((i, GEOM, "little", i.t_little * 1.7))
    hw2 = perf_model.calibrate(samples, perf_model.TPU_V5E)
    # calibrated model should track the synthetic 1.7x-scaled times
    for i, g, kind, t in samples[:3]:
        est = perf_model.estimate(i, g, kind, hw2)
        assert est == pytest.approx(t, rel=0.5)


def test_monolithic_uses_only_big(small_graph):
    eng = HeterogeneousEngine(small_graph, gas.make_pagerank(), geom=GEOM,
                              n_lanes=4, path="ref", plan_mode="monolithic")
    assert eng.plan.num_little_lanes == 0
    kinds = {e.kind for lane in eng.plan.lanes for e in lane}
    assert kinds <= {"big"}
