"""Paper Table IV — preprocessing cost: DBG grouping and
partitioning+scheduling wall time per graph (single thread, like the
paper's one-CPU-thread measurement). Both are O(E)/O(V)."""
from __future__ import annotations

import time

from repro.core import gas
from repro.core.engine import HeterogeneousEngine
from repro.graphs import datasets

from .common import GEOM, emit


def run(graphs=("r16s", "g17s", "ggs", "ams", "hds", "tcs", "pks", "ljs")):
    out = {}
    for name in graphs:
        g = datasets.load(name)
        eng = HeterogeneousEngine(g, gas.make_pagerank(), geom=GEOM,
                                  n_lanes=8, path="ref")
        s = eng.stats()
        out[name] = (s["t_dbg_ms"], s["t_partition_schedule_ms"])
        emit(f"tab4.{name}.dbg_ms", s["t_dbg_ms"] * 1e3,
             f"V={g.num_vertices} E={g.num_edges}")
        emit(f"tab4.{name}.partition_schedule_ms",
             s["t_partition_schedule_ms"] * 1e3,
             f"partitions={s['partitions']}")
    return out


if __name__ == "__main__":
    run()
