"""Paper Table IV — preprocessing cost: DBG grouping and
partitioning+scheduling wall time per graph (single thread, like the
paper's one-CPU-thread measurement). Both are O(E)/O(V).

Also measures the layered API's amortization: building one GraphStore
and planning all five builtin apps from it vs. rebuilding the engine
per app (the pre-redesign behaviour of examples/graph_apps.py).
"""
from __future__ import annotations

import time

import numpy as np

from repro import api
from repro.core import gas
from repro.graphs import datasets

from .common import GEOM, emit, store_for

FIVE_APPS = ("pagerank", "bfs", "sssp", "wcc", "closeness")


def run(graphs=("r16s", "g17s", "ggs", "ams", "hds", "tcs", "pks", "ljs")):
    out = {}
    for name in graphs:
        g = datasets.load(name)
        store = store_for(g)
        bundle = store.plan(api.PlanConfig(n_lanes=8))
        # partition + blocking + classification/scheduling — the same
        # span the paper's Table IV (and the legacy engine) timed;
        # all terms in seconds
        t_prep = store.t_partition + bundle.t_block + bundle.t_plan
        out[name] = (store.t_dbg, t_prep)
        emit(f"tab4.{name}.dbg_ms", store.t_dbg * 1e6,
             f"V={g.num_vertices} E={g.num_edges}")
        emit(f"tab4.{name}.partition_schedule_ms", t_prep * 1e6,
             f"partitions={len(store.infos)}")
    return out


def run_amortization(graphs=("ggs", "g17s"), n_lanes=8):
    """Store-build-once amortization across the five builtin apps:
    shared-store planning cost vs per-app full preprocessing."""
    results = {}
    for name in graphs:
        g = datasets.load(name)
        if g.weights is None:
            g.weights = np.random.RandomState(42).uniform(
                0.1, 1.0, g.num_edges).astype(np.float32)
        cfg = api.PlanConfig(n_lanes=n_lanes)

        # untimed warmup: first-touch numpy/JAX costs hit neither path
        warm = store_for(g)
        warm.executor(gas.BUILTIN_APPS["pagerank"](), cfg, path="ref")

        # shared store: preprocessing once, then five cheap plans
        t0 = time.perf_counter()
        store = store_for(g)
        store.plan(cfg)
        t_shared_prep = time.perf_counter() - t0
        t_extra = []
        for app_name in FIVE_APPS:
            t0 = time.perf_counter()
            store.executor(gas.BUILTIN_APPS[app_name](), cfg, path="ref")
            t_extra.append(time.perf_counter() - t0)
        t_shared = t_shared_prep + sum(t_extra)

        # per-app rebuild: preprocessing five times (legacy behaviour)
        t0 = time.perf_counter()
        for app_name in FIVE_APPS:
            fresh = store_for(g)
            fresh.executor(gas.BUILTIN_APPS[app_name](), cfg, path="ref")
        t_rebuild = time.perf_counter() - t0

        speedup = t_rebuild / max(t_shared, 1e-12)
        results[name] = (t_shared, t_rebuild, speedup)
        emit(f"tab4.{name}.amortized_5apps_ms", t_shared * 1e3 * 1e3,
             f"prep_once={t_shared_prep*1e3:.1f}ms")
        emit(f"tab4.{name}.rebuild_5apps_ms", t_rebuild * 1e3 * 1e3,
             f"amortization_speedup={speedup:.2f}x")
    return results


if __name__ == "__main__":
    run()
    run_amortization()
