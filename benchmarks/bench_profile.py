"""Utilization-profiler gates — footprint truth, overhead, export, ledger.

The profiler (repro.obs.profile) is only worth shipping if its numbers
are *trustworthy* and its cost is *invisible*, so this suite gates:

  1. **byte parity** — every non-empty lane's analytic
     ``LaneFootprint.total_bytes`` within ±10% of the independent
     jaxpr-derived operand/result byte count of the same lane fn
     (they are exact today; the tolerance absorbs future traced
     constants).
  2. **overhead** — profile-on vs profile-off executors over the SAME
     cached plan, run interleaved (A/B per round) under per-lane
     tracing; profile-on p50 within 5%.
  3. **export** — a traced job through a ControlPlane surfaces
     ``regraph_lane_bandwidth_gbps`` / ``regraph_pipeline_utilization``
     samples on ``GET /metrics``, the ``/dashboard`` page serves, and
     ``/readyz`` reports ready.
  4. **ledger round-trip** — a PerfLedger append is read back by
     ``compare`` (first record: no history, nothing flagged; a planted
     regression on a second sha IS flagged).

Results go to stdout as CSV AND to ``BENCH_profile.json``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
import urllib.request

import numpy as np

from repro import api, obs
from repro.core import gas
from repro.core.executor import Executor
from repro.graphs import datasets
from repro.obs.ledger import PerfLedger

from .common import GEOM, cpu_calibrated_hw, emit, store_for

GATE_BYTES = 0.10        # |analytic/jaxpr - 1| per non-empty lane
GATE_OVERHEAD = 1.05     # profile-on p50 / profile-off p50


def _traced_run(compiled_or_ex, tracer, iters):
    run = getattr(compiled_or_ex, "run")
    root = tracer.start_trace("bench")
    with tracer.activate(root.context):
        t0 = time.perf_counter()
        run(max_iters=iters)
        dt = time.perf_counter() - t0
    root.end()
    return dt


def _gate_bytes(ex) -> list:
    rows = []
    for li, fp in enumerate(ex.footprints()):
        truth = obs.jaxpr_lane_bytes(ex, li)
        if fp is None or truth is None:
            continue
        ratio = fp.total_bytes / truth
        rows.append({"lane": li, "kind": fp.kind,
                     "analytic_bytes": fp.total_bytes,
                     "jaxpr_bytes": truth, "ratio": ratio,
                     "hbm_bytes": fp.hbm_bytes,
                     "intensity": fp.intensity})
        assert abs(ratio - 1.0) <= GATE_BYTES, (
            f"lane {li} analytic bytes {fp.total_bytes} vs jaxpr "
            f"{truth} (ratio {ratio:.4f}) outside the "
            f"±{GATE_BYTES:.0%} gate")
    assert rows, "no non-empty lanes to validate"
    return rows


def _gate_overhead(store, hw, rounds, iters):
    c_on = api.compile(None, "pagerank", store=store, n_lanes=4, hw=hw)
    ex_on = c_on.executor
    ex_off = Executor(store, ex_on.bundle, gas.make_pagerank(),
                      profile=False)
    tr_on = obs.Tracer(lane_detail=True)
    tr_off = obs.Tracer(lane_detail=True)
    _traced_run(c_on, tr_on, iters)          # warm both jit paths
    _traced_run(ex_off, tr_off, iters)
    ts = {"on": [], "off": []}
    for _ in range(rounds):                  # interleaved: drift cancels
        ts["on"].append(_traced_run(c_on, tr_on, iters))
        ts["off"].append(_traced_run(ex_off, tr_off, iters))
    p50 = {k: float(np.median(v)) for k, v in ts.items()}
    ratio = p50["on"] / max(p50["off"], 1e-12)
    assert ratio <= GATE_OVERHEAD, (
        f"profiler-on p50 regression {100 * (ratio - 1):.1f}% exceeds "
        f"the {100 * (GATE_OVERHEAD - 1):.0f}% gate")
    util = ex_on.utilization()
    assert util["kinds"], "profile-on run recorded no utilization samples"
    assert not ex_off.utilization()["kinds"], \
        "profile=False executor must not accumulate samples"
    return {"p50_on_s": p50["on"], "p50_off_s": p50["off"],
            "overhead": ratio, "rounds": rounds,
            "kinds": {k: {"n": r["n"], "gbps": r["gbps"],
                          "utilization": r["utilization"]}
                      for k, r in util["kinds"].items()}}


def _http(url: str):
    with urllib.request.urlopen(url, timeout=10) as r:
        return r.status, r.read().decode()


def _gate_export(g) -> dict:
    from repro.control import ControlPlane
    with ControlPlane(workers=1, default_geom=GEOM, default_path="ref",
                      tracer=obs.Tracer(lane_detail=True)) as cp:
        fp = cp.register(g)
        rec = cp.submit_job(fingerprint=fp, app="pagerank", max_iters=2)
        cp.result(rec.id, timeout=120)
        server, base = cp.serve_http()
        code, text = _http(base + "/metrics")
        assert code == 200
        bw = [ln for ln in text.splitlines()
              if ln.startswith("regraph_lane_bandwidth_gbps{")]
        ut = [ln for ln in text.splitlines()
              if ln.startswith("regraph_pipeline_utilization{")]
        assert bw and ut, (
            "utilization gauges missing from /metrics after a traced "
            f"job: bw={bw} util={ut}")
        dcode, dhtml = _http(base + "/dashboard")
        assert dcode == 200 and "Pipeline utilization" in dhtml
        rcode, rbody = _http(base + "/readyz")
        ready = json.loads(rbody)
        assert rcode == 200 and ready["ready"], ready
        return {"bandwidth_samples": len(bw),
                "utilization_samples": len(ut),
                "dashboard_bytes": len(dhtml), "readyz": ready}


def _gate_ledger() -> dict:
    fd, path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        led = PerfLedger(path)
        led.append("profile", {"p50_on_s": 0.010, "gbps": 5.0},
                   sha="aaaa", geom_key="g", spec_version=1)
        first = led.compare()
        assert first["benches"]["profile"]["n_prior"] == 0
        assert first["regressions"] == 0
        # a planted 2x latency regression on the next sha must flag
        led.append("profile", {"p50_on_s": 0.020, "gbps": 5.0},
                   sha="bbbb", geom_key="g", spec_version=1)
        second = led.compare()
        entry = second["benches"]["profile"]
        assert entry["n_prior"] == 1 and second["regressions"] == 1, second
        flagged = {f["metric"] for f in entry["flagged"]}
        assert "p50_on_s" in flagged and "gbps" not in flagged
        return {"records": len(led.records()),
                "regressions_flagged": second["regressions"]}
    finally:
        os.unlink(path)


def run(graphs=None, rounds=9, iters=2, out_json="BENCH_profile.json"):
    graphs = graphs or ["ggs"]
    records = []
    for name in graphs:
        g = datasets.load(name)
        store = store_for(g)
        hw, _ = cpu_calibrated_hw(store)
        c = api.compile(None, "pagerank", store=store, n_lanes=4, hw=hw)
        byte_rows = _gate_bytes(c.executor)
        worst = max(abs(r["ratio"] - 1.0) for r in byte_rows)
        emit(f"profile.{name}.bytes", 0.0,
             f"{len(byte_rows)} lanes, worst |ratio-1|={worst:.4f} "
             f"(gate <= {GATE_BYTES:.2f})")
        ov = _gate_overhead(store, hw, rounds, iters)
        emit(f"profile.{name}.overhead", ov["p50_on_s"] * 1e6,
             f"overhead={100 * (ov['overhead'] - 1):+.1f}% "
             f"(gate <= {100 * (GATE_OVERHEAD - 1):.0f}%)")
        records.append({"graph": name, "V": g.num_vertices,
                        "E": g.num_edges, "lanes": byte_rows,
                        "worst_byte_ratio_err": worst, **ov})
    export = _gate_export(datasets.load(graphs[0]))
    emit("profile.export", 0.0,
         f"{export['bandwidth_samples']} bandwidth samples on /metrics; "
         f"dashboard+readyz ok")
    ledger = _gate_ledger()
    emit("profile.ledger", 0.0,
         f"round-trip ok, {ledger['regressions_flagged']} planted "
         f"regression flagged")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"benchmark": "utilization_profiler",
                       "gate_bytes": GATE_BYTES,
                       "gate_overhead": GATE_OVERHEAD,
                       "records": records, "export": export,
                       "ledger": ledger}, f, indent=2)
        emit("profile.artifact", 0.0, out_json)
    emit("profile.gate", 0.0, "pass")
    return records


if __name__ == "__main__":
    run()
