"""Serving benchmark: throughput + latency under a mixed workload.

Several RMAT graphs × the five builtin apps are pushed through a
GraphService twice — a COLD pass (every store/plan built on demand)
and a WARM pass (everything cached) — plus a duplicate burst that
measures coalescing. Emits p50/p99 end-to-end latency, throughput,
and cache hit rates.

    PYTHONPATH=src python -m benchmarks.run --only serving [--smoke]
"""
from __future__ import annotations

import time

import numpy as np

from repro import api
from repro.core.types import Geometry
from repro.graphs.rmat import rmat

from .common import emit

APPS = [
    ("pagerank", {}),
    ("bfs", {"root": 0}),
    ("sssp", {"root": 0}),
    ("wcc", {}),
    ("closeness", {"sources": np.arange(4)}),
]


def _graphs(smoke: bool):
    if smoke:
        return [rmat(8, 6, seed=s, weighted=True) for s in (1, 2, 3)]
    return [rmat(sc, 8, seed=s, weighted=True)
            for s, sc in ((1, 10), (2, 11), (3, 12))]


def _drain(svc, graphs, n_lanes, max_iters, label):
    """Submit the full graph × app matrix, wait for all, emit stats."""
    t0 = time.perf_counter()
    handles = [svc.submit(g, name, app_kwargs=kw, n_lanes=n_lanes,
                          max_iters=max_iters)
               for g in graphs for name, kw in APPS]
    for h in handles:
        h.result(timeout=600)
    wall = time.perf_counter() - t0
    lat = sorted(h.metrics.t_total_ms for h in handles)
    p50 = lat[len(lat) // 2]
    p99 = lat[min(len(lat) - 1, int(round(0.99 * (len(lat) - 1))))]
    emit(f"serving.{label}.p50", p50 * 1e3, f"{len(handles)}req")
    emit(f"serving.{label}.p99", p99 * 1e3, f"{len(handles)}req")
    emit(f"serving.{label}.throughput",
         wall / len(handles) * 1e6, f"{len(handles) / wall:.2f}rps")
    return handles


def run(smoke: bool = False, n_lanes: int = 4, workers: int = 2,
        max_iters: int = 5):
    graphs = _graphs(smoke)
    geom = (Geometry(U=512, W=512, T=512, E_BLK=128, big_batch=2) if smoke
            else Geometry(U=2048, W=512, T=512, E_BLK=256, big_batch=8))
    if smoke:
        n_lanes, max_iters = 2, 3

    with api.GraphService(workers=workers, default_geom=geom,
                          default_path="ref" if smoke else None,
                          byte_budget=None) as svc:
        _drain(svc, graphs, n_lanes, max_iters, "cold")
        warm = _drain(svc, graphs, n_lanes, max_iters, "warm")
        assert all(h.metrics.store_hit for h in warm), \
            "warm pass must hit the store cache"

        # coalescing burst: N identical requests, executed once
        ex0 = svc.metrics.executions
        burst = [svc.submit(graphs[0], "pagerank", n_lanes=n_lanes,
                            max_iters=max_iters) for _ in range(16)]
        for h in burst:
            h.result(timeout=600)
        emit("serving.coalesce.executions",
             float(svc.metrics.executions - ex0), "of 16 submits")

        snap = svc.metrics.snapshot()
        emit("serving.store_hit_rate", snap["store_hit_rate"] * 100,
             f"{snap['store_hits']}/{snap['store_hits'] + snap['store_misses']}")
        emit("serving.plan_hit_rate", snap["plan_hit_rate"] * 100,
             f"{snap['plan_hits']}/{snap['plan_hits'] + snap['plan_misses']}")
        emit("serving.queue.p50_wait", (snap["p50_queue_ms"] or 0.0) * 1e3,
             f"depth={snap['queue_depth']}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke)
