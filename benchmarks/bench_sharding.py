"""Sharded (per-device lane ownership) vs single-device fused execution.

Runs in a SUBPROCESS with ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` (the parent process has already imported jax with one device;
device count is fixed at import). The inner run builds one store, plans
once, and compares the fused single-device executor against the sharded
one on the same cached plan:

  * parity gate: results bit-identical for pagerank (the acceptance
    criterion's 'sum' app — the mode where program-shape drift shows);
  * dispatch gates: per-device kernel dispatch counts must match the
    placement's per-device payload queues, their total must equal the
    fused path's dispatch count (sharding never adds launches), and the
    cross-device merge count must be exactly 1;
  * placement gate: the LPT balance bound (max load <= total/n + max);
  * streaming gate: after a 1% skewed-churn delta, at least half of the
    resident sharded lane payloads are reused without re-transfer
    (``shards_moved`` accounting);
  * timing (recorded, not gated — on forced-CPU devices the per-
    iteration broadcast/collect transfers dominate; the regime the
    placement targets is real multi-chip HBM): interleaved A/B
    per-iteration wall time.

Results go to stdout as usual AND to a ``BENCH_sharding.json`` artifact.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

N_DEVICES = 8
OUT_JSON = "BENCH_sharding.json"


def run(smoke: bool = False, out_json: str = OUT_JSON):
    """Spawn the forced-8-device inner run and pass its output through."""
    env = {**os.environ,
           "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "") +
                         " --xla_force_host_platform_device_count="
                         f"{N_DEVICES}").strip()}
    cmd = [sys.executable, "-m", "benchmarks.bench_sharding", "--inner",
           "--out-json", out_json]
    if smoke:
        cmd.append("--smoke")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=1200)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stderr.write(r.stderr)
        raise RuntimeError(
            f"bench_sharding inner run failed ({r.returncode})")
    with open(out_json) as f:
        return json.load(f)["records"]


def _inner(smoke: bool, out_json: str) -> None:
    import time

    import jax
    import numpy as np

    from repro import api
    from repro.core import gas
    from repro.core.types import Geometry
    from repro.graphs import datasets
    from repro.streaming import apply_delta, random_delta

    from .common import emit

    assert jax.device_count() == N_DEVICES, \
        f"inner run expected {N_DEVICES} devices, got {jax.device_count()}"

    # finer partitioning than the shared GEOM so there are enough lanes
    # to spread (same regime bench_fused measures dispatch scaling in)
    geom = Geometry(U=256, W=256, T=256, E_BLK=256, big_batch=4)
    graphs = ["ggs"] if smoke else ["ggs", "hws"]
    repeats = 3 if smoke else 5
    iters = 2
    records = []
    for name in graphs:
        g = datasets.load(name)
        app = gas.make_pagerank(max_iters=iters)
        store = api.GraphStore(g, geom=geom)
        cfg = api.PlanConfig(n_lanes=N_DEVICES)
        fused = store.executor(app, cfg, path="ref")
        sharded = store.executor(app, cfg, path="ref", shard=N_DEVICES)

        # -- parity gate (bit-identical) --------------------------------
        pf, mf = fused.run(max_iters=iters)
        ps, ms = sharded.run(max_iters=iters)
        assert mf["iterations"] == ms["iterations"]
        np.testing.assert_array_equal(pf, ps)

        # -- dispatch gates ---------------------------------------------
        df, ds = fused.dispatch_stats(), sharded.dispatch_stats()
        sh = sharded.sharded
        per_dev = ds["kernel_dispatches_per_device"]
        assert per_dev == [len(sh.payloads_of(d))
                           for d in range(N_DEVICES)], \
            "per-device dispatches do not match the placement queues"
        assert ds["kernel_dispatches"] == df["kernel_dispatches"], \
            "sharding changed the total kernel dispatch count"
        assert ds["cross_device_merges"] == 1, \
            "expected exactly one cross-device merge per iteration"
        # program-derived (not static-accounting) merge gate: the traced
        # merge+apply program must contain exactly ONE scatter op
        mt = sharded.merge_trace_stats()
        assert mt["merge_scatter_ops"] == 1, \
            (f"merge program contains {mt['merge_scatter_ops']} scatter "
             f"ops; the cross-device merge must be a single scatter-set")

        # -- placement gate ---------------------------------------------
        pl = sh.placement
        assert max(pl.loads) <= pl.lpt_bound() + 1e-12, \
            "placement exceeded the LPT balance bound"

        # -- timing (interleaved A/B; recorded, not gated) ---------------
        vf, vs = fused.init_props(), sharded.init_props()
        fused._iter_fn = fused._build_iteration()
        fused._iter_fn(vf, fused.aux, 0).block_until_ready()
        sharded._iterate(vs, 0).block_until_ready()
        ts_f, ts_s = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fused._iter_fn(vf, fused.aux, 0).block_until_ready()
            ts_f.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            sharded._iterate(vs, 0).block_until_ready()
            ts_s.append(time.perf_counter() - t0)
        t_f, t_s = float(np.median(ts_f)), float(np.median(ts_s))

        # -- streaming reuse gate ----------------------------------------
        # same degree-skew the streaming acceptance gate uses: hot 1% of
        # dsts absorb the churn, which DBG co-locates into few partitions
        delta = random_delta(g, churn=0.01, hot_frac=0.01,
                             base_fp=store.fingerprint())
        res = apply_delta(store, delta)
        st = res.stats
        assert st["shards_reused"] >= st["shards_moved"], \
            (f"expected >= half resident shard reuse at 1% churn, got "
             f"{st['shards_reused']} reused / {st['shards_moved']} moved")

        rec = {
            "graph": name, "V": g.num_vertices, "E": g.num_edges,
            "n_devices": N_DEVICES, "n_lanes": cfg.n_lanes,
            "t_iteration_fused_s": t_f,
            "t_iteration_sharded_s": t_s,
            "kernel_dispatches_per_device": per_dev,
            "cross_device_merges": ds["cross_device_merges"],
            "placement": sh.stats(),
            "delta": {k: st[k] for k in
                      ("dirty_partitions", "shards_moved",
                       "shard_bytes_moved", "shards_reused",
                       "shard_bytes_reused")},
        }
        records.append(rec)
        emit(f"sharding.{name}.iter", t_s * 1e6,
             f"fused={t_f * 1e6:.0f}us devices={N_DEVICES} "
             f"imbalance={pl.imbalance:.2f}")
        emit(f"sharding.{name}.dispatch", 0.0,
             f"per_device={per_dev} xmerges={ds['cross_device_merges']}")
        emit(f"sharding.{name}.reuse", 0.0,
             f"reused={st['shards_reused']} moved={st['shards_moved']} "
             f"bytes_reused={st['shard_bytes_reused']}")
        store.clear_plans()
    with open(out_json, "w") as f:
        json.dump({"benchmark": "sharded_vs_fused", "records": records},
                  f, indent=2)
    emit("sharding.artifact", 0.0, out_json)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out-json", default=OUT_JSON)
    args = ap.parse_args()
    if args.inner:
        _inner(args.smoke, args.out_json)
    else:
        run(smoke=args.smoke, out_json=args.out_json)
