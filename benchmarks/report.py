"""Generate the EXPERIMENTS.md roofline/dry-run tables from
results/dryrun/*.json. Run: PYTHONPATH=src python -m benchmarks.report"""
from __future__ import annotations

import glob
import json
from pathlib import Path


def fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def dryrun_table(mesh_filter: str) -> str:
    rows = []
    for f in sorted(glob.glob("results/dryrun/*.json")):
        r = json.load(open(f))
        mp = "multipod" if r.get("multi_pod") else "pod"
        if mp != mesh_filter:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | "
                        f"{r['reason'][:58]} | | | | |")
            continue
        m, t = r["memory"], r["roofline"]
        frac = (t["analytic"]["flops_model"] / r["chips"]
                / 197e12 / t["roofline_bound_s"]
                if t["roofline_bound_s"] else 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| args {fmt_bytes(m['argument_bytes'])} / "
            f"peak* {fmt_bytes(m['peak_tpu_estimate_bytes'])} GB"
            f"{'' if m['fits_16g_hbm'] else ' **OVER**'} "
            f"| {t['t_compute_s']:.4f} | {t['t_memory_s']:.4f} "
            f"| {t['t_collective_s']:.4f} | {t['dominant']} "
            f"| {frac:.2f} |")
    head = ("| arch | shape | status | memory/chip | t_comp s | t_mem s "
            "| t_coll s | dominant | MFU-bound |\n"
            "|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def collectives_summary(mesh_filter: str) -> str:
    rows = []
    for f in sorted(glob.glob("results/dryrun/*.json")):
        r = json.load(open(f))
        mp = "multipod" if r.get("multi_pod") else "pod"
        if mp != mesh_filter or r["status"] != "ok":
            continue
        c = r["collectives"]
        parts = [f"{k}={v/1e6:.0f}MB" for k, v in c.items()
                 if k not in ("count", "total") and v > 0]
        rows.append(f"| {r['arch']} | {r['shape']} | {c['count']:.0f} "
                    f"| {c['total']/1e6:.1f} | {' '.join(parts) or '-'} |")
    head = ("| arch | shape | #coll (trip-count x) | total MB/chip "
            "| breakdown |\n|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def main():
    print("## Single-pod (16x16 = 256 chips) baseline\n")
    print(dryrun_table("pod"))
    print("\n## Multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table("multipod"))
    print("\n## Collective payloads (single-pod)\n")
    print(collectives_summary("pod"))


if __name__ == "__main__":
    main()
