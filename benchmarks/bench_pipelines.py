"""Paper Fig. 9 — Big vs Little pipelines: measured vs modelled execution
time per partition, and the model's error ratio.

For each partition of each graph we time BOTH pipeline types (jitted,
ref path = the same math the kernels compute) and compare with the
CPU-calibrated perf model. The paper reports 4% (Big) / 6% (Little)
average error; we report ours the same way.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import gas, perf_model
from repro.core.executor import init_props
from repro.graphs import datasets
from repro.kernels import ops

from .common import GEOM, SMALL, cpu_calibrated_hw, emit, store_for


def run(graphs=None):
    graphs = graphs or SMALL
    all_err = {"little": [], "big": []}
    crossover = 0
    total = 0
    for name in graphs:
        g = datasets.load(name)
        app = gas.make_pagerank(max_iters=2)
        store = store_for(g)
        hw, _ = cpu_calibrated_hw(store, app)
        vprops = init_props(store, app)
        infos = sorted([i for i in store.infos if i.num_edges > 0],
                       key=lambda i: -i.num_edges)[:10]
        for i in infos:
            meas = {}
            for kind in ("little", "big"):
                work = (store.little_work(i.pid) if kind == "little"
                        else store.big_work((i.pid,)))
                entry = ops.materialize_entry(work, 0, work.n_blocks)
                f = jax.jit(lambda vp: ops.run_entry(
                    entry, vp, app.scatter, app.gather, "ref")[0])
                f(vprops).block_until_ready()
                f(vprops).block_until_ready()
                ts = []
                for _ in range(5):
                    t0 = time.perf_counter()
                    f(vprops).block_until_ready()
                    ts.append(time.perf_counter() - t0)
                meas[kind] = float(np.median(ts))
                est = perf_model.estimate(i, GEOM, kind, hw)
                err = abs(est - meas[kind]) / meas[kind]
                all_err[kind].append(err)
            # does the model pick the faster pipeline for this partition?
            model_pick = ("little" if perf_model.estimate(i, GEOM, "little",
                                                          hw)
                          < perf_model.estimate(i, GEOM, "big", hw)
                          else "big")
            real_pick = "little" if meas["little"] < meas["big"] else "big"
            crossover += int(model_pick == real_pick)
            total += 1
        emit(f"fig9.{name}.partitions", 0.0,
             f"n={len(infos)}")
    for kind in ("little", "big"):
        emit(f"fig9.model_error.{kind}",
             float(np.mean(all_err[kind])) * 1e6,
             f"mean_error_ratio={np.mean(all_err[kind]):.3f} "
             f"(paper: little 6% / big 4%)")
    emit("fig9.model_picks_faster_pipeline", 0.0,
         f"accuracy={crossover / max(total, 1):.2f} over {total} partitions")
    return all_err


if __name__ == "__main__":
    run()
