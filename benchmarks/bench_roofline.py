"""Paper Fig. 13 analogue — the resource-centric roofline: throughput per
resource. On TPU the scarce per-lane resources are VMEM bytes and issued
MACs/edge; we report TEPS per resource for heterogeneous vs monolithic,
plus the paper-technique MoE numbers (padded-FLOPs savings of big-little
expert dispatch) and the LM dry-run roofline summary."""
from __future__ import annotations

import glob
import json

import numpy as np

from repro import api
from repro.core import gas, perf_model
from repro.graphs import datasets
from repro.models.moe_schedule import padded_flops_ratio

from .common import GEOM, cpu_calibrated_hw, emit, mteps, store_for


def vmem_per_lane(geom, kind):
    """Working set a lane claims (window + tile accumulator + edge block)."""
    base = geom.W * 4 + geom.T * 4 + geom.E_BLK * 16
    if kind == "big":
        base += geom.W * 4           # compact table window
    return base


def macs_per_edge(geom):
    return geom.W + geom.T           # one-hot gather + router


def run(graphs=("r16s", "tcs"), n_lanes=8):
    for name in graphs:
        g = datasets.load(name)
        store = store_for(g)
        hw, _ = cpu_calibrated_hw(store)
        for mode in ("model", "monolithic"):
            ex = store.executor(gas.make_pagerank(max_iters=2),
                                api.PlanConfig(mode=mode, n_lanes=n_lanes,
                                               hw=hw), path="ref")
            lt = ex.time_lanes(repeats=2)
            t = max(lt) if lt else 1e-9
            n_little = ex.plan.num_little_lanes
            n_big = ex.plan.num_big_lanes
            vmem = (n_little * vmem_per_lane(GEOM, "little")
                    + n_big * vmem_per_lane(GEOM, "big"))
            teps = mteps(g, t) * 1e6
            emit(f"fig13.{name}.{mode}", t * 1e6,
                 f"teps_per_vmem_kb={teps / (vmem / 1024):.0f} "
                 f"lanes={n_little}L{n_big}B")

    # MoE big-little resource efficiency (the paper technique on LM side)
    for e, k, t in ((384, 8, 32768), (48, 8, 32768)):
        r = padded_flops_ratio(e, k, t)
        emit(f"fig13.moe_biglittle.E{e}", 0.0,
             f"padded_ratio_vs_drop_matched={r['flops_ratio_vs_matched']:.3f} "
             f"n_hot={r['n_hot']} drop={r['biglittle_drop_rate']:.3f}")

    # LM dry-run roofline summary (from results/dryrun)
    cells = sorted(glob.glob("results/dryrun/*.pod.json"))
    doms = {}
    for f in cells:
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        d = rec["roofline"]["dominant"]
        doms[d] = doms.get(d, 0) + 1
    emit("fig13.lm_dryrun_dominant_terms", 0.0,
         " ".join(f"{k}={v}" for k, v in sorted(doms.items())))


if __name__ == "__main__":
    run()
