"""Paper Fig. 12 — PR scaling with the number of lanes (pipelines).

Makespan = max over lanes of measured lane time. The paper observes
near-linear scaling on regular graphs and saturation on small/irregular
ones (partition-switch overhead) — we report the same speedup curve,
for BOTH execution paths: fused (one packed launch per lane) and
per-entry (one launch per materialized entry — the dispatch overhead
that grows with lane count, since more lanes means more entry splits).
One GraphStore per graph serves every lane count in the sweep.
"""
from __future__ import annotations

from repro import api
from repro.core import gas
from repro.graphs import datasets

from .common import GEOM, cpu_calibrated_hw, emit, mteps, store_for


def run(graphs=("r16s", "g17s", "ggs"), lane_counts=(1, 2, 4, 8, 16)):
    out = {}
    for name in graphs:
        g = datasets.load(name)
        app = gas.make_pagerank(max_iters=2)
        store = store_for(g)
        hw, _ = cpu_calibrated_hw(store, app)
        base = None
        for nl in lane_counts:
            cfg = api.PlanConfig(n_lanes=nl, hw=hw)
            ex = store.executor(app, cfg, path="ref")
            lt = ex.time_lanes(repeats=2)
            # drop the fused executor AND its plan before the per-entry
            # form materializes (and again after), so the sweep's peak
            # memory stays one payload-form deep — clear_plans() alone
            # can't free a bundle an executor still references; the plan
            # rebuild in between costs milliseconds
            ex = None
            store.clear_plans()
            ex_pe = store.executor(app, cfg, path="ref", fuse_lanes=False)
            lt_pe = ex_pe.time_lanes(repeats=2)
            ex_pe = None
            store.clear_plans()
            t = max(lt) if lt else 0.0
            t_pe = max(lt_pe) if lt_pe else 0.0
            base = base or t
            out[(name, nl)] = t
            emit(f"fig12.{name}.lanes{nl}", t * 1e6,
                 f"speedup={base / max(t, 1e-12):.2f}x "
                 f"mteps={mteps(g, max(t, 1e-12)):.0f} "
                 f"per_entry={t_pe * 1e6:.0f}us "
                 f"fused_gain={t_pe / max(t, 1e-12):.2f}x")
    return out


if __name__ == "__main__":
    run()
