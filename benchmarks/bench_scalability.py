"""Paper Fig. 12 — PR scaling with the number of lanes (pipelines).

Makespan = max over lanes of measured lane time. The paper observes
near-linear scaling on regular graphs and saturation on small/irregular
ones (partition-switch overhead) — we report the same speedup curve.
One GraphStore per graph serves every lane count in the sweep.
"""
from __future__ import annotations

from repro import api
from repro.core import gas
from repro.graphs import datasets

from .common import GEOM, cpu_calibrated_hw, emit, mteps, store_for


def run(graphs=("r16s", "g17s", "ggs"), lane_counts=(1, 2, 4, 8, 16)):
    out = {}
    for name in graphs:
        g = datasets.load(name)
        app = gas.make_pagerank(max_iters=2)
        store = store_for(g)
        hw, _ = cpu_calibrated_hw(store, app)
        base = None
        for nl in lane_counts:
            ex = store.executor(app, api.PlanConfig(n_lanes=nl, hw=hw),
                                path="ref")
            lt = ex.time_lanes(repeats=2)
            # each lane count materializes its own device entries; drop
            # them so the sweep's peak memory stays one-plan-deep
            store.clear_plans()
            t = max(lt) if lt else 0.0
            base = base or t
            out[(name, nl)] = t
            emit(f"fig12.{name}.lanes{nl}", t * 1e6,
                 f"speedup={base / max(t, 1e-12):.2f}x "
                 f"mteps={mteps(g, max(t, 1e-12)):.0f}")
    return out


if __name__ == "__main__":
    run()
