"""Paper Table V analogue — ReGraph (heterogeneous, model-guided) vs the
monolithic homogeneous baseline (ThunderGP-like: every partition through
the worst-case-provisioned Big pipeline), across PR / BFS / CC.

Speedup = monolithic makespan / heterogeneous makespan at equal lane
count — the paper's 1.6-5.9x claim is against exactly this kind of
baseline (plus platform differences we cannot reproduce on CPU).

The plan is app-independent, so each graph needs exactly TWO plans
(model + monolithic) from one shared GraphStore — the legacy harness
rebuilt the full engine 6x per graph.
"""
from __future__ import annotations

import numpy as np

from repro import api
from repro.core import gas, perf_model
from repro.graphs import datasets

from .common import GEOM, emit, mteps, store_for

APPS = {
    "pr": lambda: gas.make_pagerank(max_iters=2),
    "bfs": lambda: gas.make_bfs(root=0),
    "cc": lambda: gas.make_closeness(max_iters=4),
}


def run(graphs=("r16s", "g17s", "tcs", "pks", "hws"), n_lanes=8):
    def modeled(plan):
        return max((sum(e.est_time for e in lane)
                    for lane in plan.lanes), default=0.0)

    speedups = []
    for name in graphs:
        g = datasets.load(name)
        store = store_for(g)
        hw = perf_model.TPU_V5E_SCALED
        ts = {mode: modeled(store.plan(api.PlanConfig(
                  mode=mode, n_lanes=n_lanes, hw=hw)).plan)
              for mode in ("model", "monolithic")}
        sp = ts["monolithic"] / max(ts["model"], 1e-12)
        for app_name in APPS:
            speedups.append(sp)
            emit(f"tab5.{name}.{app_name}", ts["model"] * 1e6,
                 f"mteps={mteps(g, max(ts['model'], 1e-12)):.0f} "
                 f"speedup_vs_monolithic={sp:.2f}x (TPU-modelled)")
    emit("tab5.geomean_speedup", 0.0,
         f"{float(np.exp(np.mean(np.log(np.maximum(speedups, 1e-9))))):.2f}x"
         f" (paper: 1.6-5.9x vs SOTA FPGA frameworks)")
    return speedups


if __name__ == "__main__":
    run()
