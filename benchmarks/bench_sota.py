"""Paper Table V analogue — ReGraph (heterogeneous, model-guided) vs the
monolithic homogeneous baseline (ThunderGP-like: every partition through
the worst-case-provisioned Big pipeline), across PR / BFS / CC.

Speedup = monolithic makespan / heterogeneous makespan at equal lane
count — the paper's 1.6-5.9x claim is against exactly this kind of
baseline (plus platform differences we cannot reproduce on CPU).
"""
from __future__ import annotations

import numpy as np

from repro.core import gas
from repro.core.engine import HeterogeneousEngine
from repro.graphs import datasets

from .common import GEOM, cpu_calibrated_hw, emit, mteps

APPS = {
    "pr": lambda: gas.make_pagerank(max_iters=2),
    "bfs": lambda: gas.make_bfs(root=0),
    "cc": lambda: gas.make_closeness(max_iters=4),
}


def run(graphs=("r16s", "g17s", "tcs", "pks", "hws"), n_lanes=8):
    from repro.core import perf_model

    def modeled(eng):
        return max((sum(e.est_time for e in lane)
                    for lane in eng.plan.lanes), default=0.0)

    speedups = []
    for name in graphs:
        g = datasets.load(name)
        for app_name, mk in APPS.items():
            ts = {}
            for mode in ("model", "monolithic"):
                eng = HeterogeneousEngine(g, mk(), geom=GEOM,
                                          n_lanes=n_lanes, path="ref",
                                          hw=perf_model.TPU_V5E_SCALED,
                                          plan_mode=mode)
                ts[mode] = modeled(eng)
            sp = ts["monolithic"] / max(ts["model"], 1e-12)
            speedups.append(sp)
            emit(f"tab5.{name}.{app_name}", ts["model"] * 1e6,
                 f"mteps={mteps(g, max(ts['model'], 1e-12)):.0f} "
                 f"speedup_vs_monolithic={sp:.2f}x (TPU-modelled)")
    emit("tab5.geomean_speedup", 0.0,
         f"{float(np.exp(np.mean(np.log(np.maximum(speedups, 1e-9))))):.2f}x"
         f" (paper: 1.6-5.9x vs SOTA FPGA frameworks)")
    return speedups


if __name__ == "__main__":
    run()
