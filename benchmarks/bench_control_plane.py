"""Control-plane benchmark: p99 ``GraphService.update`` latency under
concurrent submit load, threads-only vs process-pool worker tier.

The scenario the pool exists for: a service keeps answering streaming
updates (latency-sensitive, caller-thread) while tenants ingest new
graph snapshots — ``register(prepare=True)``, a cold GraphStore build
each (DBG + lexsort + partitioning, ~75% GIL-holding numpy/python).
Threads-only, those builds run in the serving process and fight the
updater for the GIL; with ``pool=N`` they run in worker PROCESSES and
the parent pays only the (much smaller) result unpickle.

Both modes run the IDENTICAL workload:

  * ``hammer`` ingest threads register distinct pre-generated graphs
    (every one a cold store build) at a FIXED rate — open-loop, so
    both modes face the same offered load rather than the faster mode
    punishing itself with its own extra throughput;
  * the main thread chains ``n_updates`` deltas on the base snapshot,
    timing each ``update()`` call end-to-end.

Emits p50/p99 per mode and a ``pool_speedup_p99`` headline, gates
``p99(pool) <= p99(threads)``, and writes three artifacts:
``BENCH_control_plane.json`` (the numbers),
``BENCH_control_plane_metrics.json`` (full ServiceMetrics
snapshot_json of the pool-mode service) and
``BENCH_control_plane.prom`` (the same in Prometheus text form).

    PYTHONPATH=src python -m benchmarks.run --only control_plane [--smoke]
"""
from __future__ import annotations

import json
import threading
import time

import numpy as np

from repro import api
from repro.core.types import Geometry
from repro.graphs.rmat import rmat
from repro.serve_graph import GraphService
from repro.streaming import apply_delta_to_graph, random_delta

from .common import emit


def _percentile(sorted_vals, q: float) -> float:
    return sorted_vals[min(len(sorted_vals) - 1,
                           int(round(q * (len(sorted_vals) - 1))))]


def _measure(mode: str, base, hammer_graphs, geom, *, pool, hammer_threads,
             hammer_interval, n_updates, churn, max_iters) -> dict:
    """One full scenario run; returns latency + load stats."""
    with GraphService(workers=2, default_geom=geom, default_path="ref",
                      byte_budget=None, pool=pool) as svc:
        fp = svc.register(base)
        svc.run(fingerprint=fp, app="pagerank", max_iters=max_iters,
                timeout=600)                # warm base store + executor

        stop = threading.Event()
        hammer_done = [0] * hammer_threads

        def hammer(tid: int) -> None:
            # fixed-rate, open-loop ingest: one distinct graph per
            # tick, each register a cold store build — the CPU-heavy
            # job class the pool offloads. register() is synchronous,
            # so in threads mode the build's GIL time lands in this
            # process; in pool mode only the result unpickle does.
            i = tid
            while not stop.is_set():
                if i < len(hammer_graphs):
                    svc.register(hammer_graphs[i])
                    hammer_done[tid] += 1
                i += hammer_threads
                stop.wait(hammer_interval)

        threads = [threading.Thread(target=hammer, args=(t,), daemon=True)
                   for t in range(hammer_threads)]
        for t in threads:
            t.start()
        time.sleep(0.3)                     # let the hammer ramp up

        # the measured path: chained streaming updates on the hot base,
        # spread across the load window
        cur_fp, cur_g = fp, base
        lat_ms = []
        try:
            for k in range(n_updates):
                delta = random_delta(cur_g, churn=churn, seed=1000 + k,
                                     hot_frac=0.01, base_fp=cur_fp)
                cur_g = apply_delta_to_graph(cur_g, delta,
                                             check_fp=False)   # untimed
                t0 = time.perf_counter()
                res = svc.update(cur_fp, delta)
                lat_ms.append((time.perf_counter() - t0) * 1e3)
                cur_fp = res.fingerprint
                time.sleep(hammer_interval / 2)
        finally:
            stop.set()
            for t in threads:
                t.join(timeout=600)

        svc.run(fingerprint=cur_fp, app="pagerank", max_iters=max_iters,
                timeout=600)                # the final snapshot serves
        lat = sorted(lat_ms)
        out = {
            "mode": mode,
            "p50_update_ms": _percentile(lat, 0.50),
            "p99_update_ms": _percentile(lat, 0.99),
            "mean_update_ms": float(np.mean(lat)),
            "updates": len(lat),
            "hammer_jobs": int(sum(hammer_done)),
            "scheduler": svc.stats()["scheduler"],
            "pool": svc.stats()["pool"],
        }
        # the pool-mode service also donates the metrics artifacts
        if pool is not None:
            out["_snapshot_json"] = svc.metrics.snapshot_json(
                benchmark="control_plane")
            out["_prometheus"] = svc.metrics.render_prometheus()
        return out


def run(smoke: bool = False, n_updates: int = 24, hammer_threads: int = 3,
        pool_workers: int = 2, out_json: str = "BENCH_control_plane.json"):
    if smoke:
        n_updates = 20
    hammer_interval = 0.2                   # per-thread offered build rate
    base = rmat(11, 8, seed=19, weighted=True)
    # enough distinct graphs that the open loop never resubmits one (a
    # resubmit would hit the warm store cache and carry no build), big
    # enough that store builds are the dominant CPU term of the load
    window_s = n_updates * (hammer_interval / 2 + 0.05) + 2.0
    n_hammer = int(window_s / hammer_interval * hammer_threads) + 8
    hammer_graphs = [rmat(12, 12, seed=100 + s, weighted=True)
                     for s in range(n_hammer)]
    geom = Geometry(U=512, W=256, T=256, E_BLK=128, big_batch=4)
    churn, max_iters = 0.01, 2

    results = {}
    for mode, pool in (("threads", None), ("pool", pool_workers)):
        r = _measure(mode, base, hammer_graphs, geom, pool=pool,
                     hammer_threads=hammer_threads,
                     hammer_interval=hammer_interval, n_updates=n_updates,
                     churn=churn, max_iters=max_iters)
        results[mode] = r
        emit(f"control_plane.{mode}.update.p50",
             r["p50_update_ms"] * 1e3,
             f"{r['updates']}updates hammer={r['hammer_jobs']}")
        emit(f"control_plane.{mode}.update.p99",
             r["p99_update_ms"] * 1e3,
             f"mean={r['mean_update_ms']:.1f}ms")

    snapshot_json = results["pool"].pop("_snapshot_json")
    prometheus = results["pool"].pop("_prometheus")
    results["threads"].pop("_snapshot_json", None)
    results["threads"].pop("_prometheus", None)

    speedup = (results["threads"]["p99_update_ms"]
               / max(results["pool"]["p99_update_ms"], 1e-9))
    emit("control_plane.pool_speedup_p99", 0.0, f"{speedup:.2f}x")

    # acceptance: offloading builds to processes must not make the
    # latency-sensitive update path WORSE, and should improve its tail.
    # 1.05 absorbs timer noise on the small smoke run; the gate is on
    # the tail because the mean hides GIL convoys.
    assert (results["pool"]["p99_update_ms"]
            <= results["threads"]["p99_update_ms"] * 1.05), \
        (f"process-pool p99 update latency "
         f"{results['pool']['p99_update_ms']:.1f}ms worse than "
         f"threads-only {results['threads']['p99_update_ms']:.1f}ms")
    emit("control_plane.acceptance", 0.0,
         f"pool_p99={results['pool']['p99_update_ms']:.1f}ms <= "
         f"threads_p99={results['threads']['p99_update_ms']:.1f}ms")

    if out_json:
        with open(out_json, "w") as f:
            json.dump({"benchmark": "control_plane_update_tail_latency",
                       "pool_speedup_p99": speedup,
                       "modes": results}, f, indent=2, default=str)
        emit("control_plane.artifact", 0.0, out_json)
        metrics_path = out_json.replace(".json", "_metrics.json")
        with open(metrics_path, "w") as f:
            f.write(snapshot_json)
        prom_path = out_json.replace(".json", ".prom")
        with open(prom_path, "w") as f:
            f.write(prometheus)
        emit("control_plane.metrics_artifacts", 0.0,
             f"{metrics_path} {prom_path}")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke)
