"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines (see benchmarks/common.emit).

  Fig. 9   bench_pipelines      Big/Little measured vs modelled time
  Fig. 10  bench_heterogeneity  lane-combination sweep + model selection
  Fig. 12  bench_scalability    speedup vs number of lanes
  Tab. IV  bench_preprocessing  DBG / partition+schedule cost
  Tab. V   bench_sota           vs monolithic (ThunderGP-like) baseline
  Fig. 13  bench_roofline       resource-centric roofline analogue
  —        bench_serving        GraphService throughput/latency/caching
  —        bench_fused          fused vs per-entry execution (+ JSON)
  —        bench_streaming      delta apply vs full rebuild (+ JSON)
  —        bench_sharding       sharded vs single-device fused (+ JSON)
  —        bench_control_plane  p99 update latency, threads vs pool (+ JSON)
  —        bench_obs            tracing-off vs tracing-on overhead (+ JSON)
  —        bench_autotune       calibrate-and-replan gates (+ JSON)
  —        bench_profile        utilization profiler + ledger gates (+ JSON)

Every suite that writes a ``BENCH_*.json`` artifact also APPENDS its
flattened gate metrics to the perf ledger (``BENCH_ledger.jsonl``,
``--ledger`` to move, ``--ledger ''`` to disable), keyed by git sha /
geometry / bench device-spec version. ``run.py compare`` reports the
latest records against the rolling median of prior ones — a
non-blocking CI step (always exit 0; the report is the product).
"""
from __future__ import annotations

import argparse
import json
import os
import time

# suite name -> the JSON artifact its run() writes (ledger source)
ARTIFACTS = {
    "fused": "BENCH_fused.json",
    "streaming": "BENCH_streaming.json",
    "sharding": "BENCH_sharding.json",
    "control_plane": "BENCH_control_plane.json",
    "obs": "BENCH_obs.json",
    "autotune": "BENCH_autotune.json",
    "profile": "BENCH_profile.json",
}


def _ledger_context():
    """(geom_key, bench spec version | None) for ledger records — the
    same key the calibration cache uses, so records are comparable only
    within one device/geometry lineage."""
    from repro.autotune import (SpecRegistry, default_device_kind,
                                geometry_key)

    from .common import GEOM
    gkey = geometry_key(GEOM)
    spec = SpecRegistry().get("bench-" + default_device_kind(), GEOM)
    return gkey, (spec.version if spec is not None else None)


def _append_ledger(ledger, suite: str, artifact: str,
                   run_started: float, geom_key, spec_version) -> None:
    """Fold one suite's fresh artifact into the ledger (best-effort:
    a stale or unreadable artifact is skipped, never fatal)."""
    from repro.obs.ledger import flatten_metrics
    try:
        if os.path.getmtime(artifact) < run_started:
            return      # suite didn't (re)write it this run
        with open(artifact, "r", encoding="utf-8") as f:
            doc = json.load(f)
        ledger.append(suite, flatten_metrics(doc), geom_key=geom_key,
                      spec_version=spec_version,
                      meta={"artifact": artifact})
        print(f"ledger.{suite},0,appended to {ledger.path}", flush=True)
    except (OSError, ValueError) as exc:
        print(f"ledger.{suite},0,skipped ({exc})", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("command", nargs="?", default="bench",
                    choices=("bench", "compare"),
                    help="bench (default): run suites and append the "
                         "perf ledger; compare: report the latest "
                         "ledger records vs their rolling median")
    ap.add_argument("--only", default="all",
                    help="comma list: pipelines,heterogeneity,scalability,"
                         "preprocessing,amortization,sota,roofline,serving,"
                         "fused,streaming,sharding,control_plane,obs,"
                         "autotune,profile")
    ap.add_argument("--quick", action="store_true",
                    help="smaller graph set (CI-speed)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiniest graphs (implies --quick; CI smoke tier)")
    ap.add_argument("--ledger", default="BENCH_ledger.jsonl",
                    help="perf ledger JSONL path ('' disables)")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="compare: |relative change| that flags a metric "
                         "(default 0.25)")
    args = ap.parse_args()

    if args.command == "compare":
        from repro.obs.ledger import DEFAULT_TOLERANCE, PerfLedger
        ledger = PerfLedger(args.ledger or "BENCH_ledger.jsonl")
        report = ledger.compare(
            tolerance=(args.tolerance if args.tolerance is not None
                       else DEFAULT_TOLERANCE))
        print(ledger.render_report(report))
        return      # non-blocking by design: the report is the product

    if args.smoke:
        args.quick = True
    want = (None if args.only == "all"
            else set(args.only.split(",")))

    from . import (bench_autotune, bench_control_plane, bench_fused,
                   bench_heterogeneity, bench_obs, bench_pipelines,
                   bench_preprocessing, bench_profile, bench_roofline,
                   bench_scalability, bench_serving, bench_sharding,
                   bench_sota, bench_streaming)

    suites = [
        ("pipelines", lambda: bench_pipelines.run(
            graphs=["ggs", "hws"] if args.quick else None)),
        ("heterogeneity", lambda: bench_heterogeneity.run(
            graphs=["r16s", "unif16"] if args.quick else None,
            n_lanes=4 if args.quick else 8)),
        ("scalability", lambda: bench_scalability.run(
            graphs=("ggs",) if args.quick else ("r16s", "g17s", "ggs"),
            lane_counts=(1, 2, 4) if args.quick else (1, 2, 4, 8, 16))),
        ("preprocessing", lambda: bench_preprocessing.run(
            graphs=("ggs", "ams") if args.quick
            else ("r16s", "g17s", "ggs", "ams", "hds", "tcs", "pks",
                  "ljs"))),
        ("amortization", lambda: bench_preprocessing.run_amortization(
            graphs=("ggs",) if args.quick else ("ggs", "g17s"),
            n_lanes=4 if args.quick else 8)),
        ("sota", lambda: bench_sota.run(
            graphs=("r16s",) if args.quick
            else ("r16s", "g17s", "tcs", "pks", "hws"),
            n_lanes=4 if args.quick else 8)),
        ("roofline", lambda: bench_roofline.run(
            graphs=("r16s",) if args.quick else ("r16s", "tcs"),
            n_lanes=4 if args.quick else 8)),
        # --quick has no mid tier for serving; it gets the smoke sizes
        ("serving", lambda: bench_serving.run(smoke=args.quick)),
        # acceptance target: >= 8 lanes even on the quick graph set (the
        # dispatch wall only shows at high entry counts)
        ("fused", lambda: bench_fused.run(
            graphs=["ggs"] if args.quick else ["ggs", "hws", "r16s"],
            lane_counts=(8,) if args.quick else (8, 16),
            repeats=3 if args.quick else 5)),
        # the >=5x acceptance gate runs at every tier (the quick tier
        # IS the acceptance graph; --smoke shrinks it further for CI
        # and loosens the gate — see bench_streaming). Always 5 repeats:
        # the gate is a median ratio and 3 samples is too noisy to gate.
        ("streaming", lambda: bench_streaming.run(smoke=args.smoke,
                                                  repeats=5)),
        # forced 8-device CPU subprocess (device count is fixed at jax
        # import, so the parent process can't host it); gates parity,
        # per-device dispatch counts, the single cross-device merge,
        # and streaming shard reuse at every tier
        ("sharding", lambda: bench_sharding.run(smoke=args.smoke)),
        # gates p99 update latency with a process pool <= threads-only
        # at every tier, and dumps the full ServiceMetrics snapshot
        # (JSON + Prometheus text) as artifacts
        ("control_plane", lambda: bench_control_plane.run(
            smoke=args.quick)),
        # gates the unconditional obs instrumentation: tracing-on
        # (coarse) p50 within 5% of tracing-off at every tier
        ("obs", lambda: bench_obs.run(
            graphs=["ggs"] if args.quick else ["ggs", "hws"],
            rounds=9 if args.smoke else 15)),
        # gates the model-guided loop: post-retune drift ratio_p50 in
        # [0.5, 2.0], retuned-vs-analytic measured makespan (interleaved
        # A/B), bit-identical results across the plan swap
        ("autotune", lambda: bench_autotune.run(
            graphs=["ggs"] if args.quick else ["ggs", "hws"],
            n_lanes=4 if args.quick else 8,
            rounds=3 if args.smoke else 5)),
        # gates the utilization profiler: analytic lane bytes within
        # ±10% of the jaxpr-derived count, profile-on p50 within 5%,
        # gauges on /metrics, dashboard/readyz up, ledger round-trip
        ("profile", lambda: bench_profile.run(
            graphs=["ggs"] if args.quick else ["ggs", "hws"],
            rounds=5 if args.smoke else 9)),
    ]
    ledger = None
    geom_key = spec_version = None
    if args.ledger:
        from repro.obs.ledger import PerfLedger
        ledger = PerfLedger(args.ledger)
    run_started = time.time()
    print("name,us_per_call,derived")
    for name, fn in suites:
        if want and name not in want:
            continue
        t0 = time.time()
        fn()
        print(f"suite.{name},{(time.time() - t0) * 1e6:.0f},done",
              flush=True)
        if ledger is not None and name in ARTIFACTS:
            if geom_key is None:
                geom_key, spec_version = _ledger_context()
            _append_ledger(ledger, name, ARTIFACTS[name], run_started,
                           geom_key, spec_version)


if __name__ == "__main__":
    main()
