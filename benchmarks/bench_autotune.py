"""Autotune gate — does closing the model-guided loop actually help?

Three hard gates, all on the quick/smoke tier (CPU-feasible graphs):

1. **Model accuracy after retune**: a forced calibrate-and-replan cycle
   must leave every observed drift kind's windowed ``ratio_p50``
   (measured / estimated) inside ``[0.5, 2.0]``. The analytic TPU
   constants are orders of magnitude off on a CPU host — this gate
   proves the fitted constants actually describe the machine the lanes
   run on.

2. **End-to-end win**: the retuned plan's measured makespan analogue
   (max per-lane wall time, lanes timed one by one on the host — the
   same quantity the LPT scheduler balances) must not exceed the
   analytic plan's. A/B rounds are interleaved so host drift cancels.
   When the retuned plan's lane structure is identical to the analytic
   one (the model already chose right; recalibration only rescales
   estimates), the makespans are definitionally equal and the ratio is
   reported as 1.0 without timing.

3. **Bit-identical results**: the retuned plan must produce exactly the
   same pagerank output as the analytic plan — re-planning changes
   scheduling, never semantics.

Results go to stdout as CSV and to ``BENCH_autotune.json``.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro import api
from repro.autotune import AutoTuner, RetunePolicy
from repro.core import gas
from repro.core.executor import Executor
from repro.core.planner import PlanConfig
from repro.graphs import datasets

from .common import GEOM, emit, store_for

GATE_DRIFT_LO = 0.5
GATE_DRIFT_HI = 2.0
# the retuned plan may not be measurably WORSE; a little noise headroom
GATE_MAKESPAN = 1.10


def _lane_shape(plan):
    """Structural identity of a plan's lane assignment (what execution
    order/grouping actually depends on — estimates excluded)."""
    return tuple(tuple((e.kind, e.work_id, e.block_lo, e.block_hi)
                       for e in lane) for lane in plan.lanes)


def _measured_makespan(ex) -> float:
    return max(ex.time_lanes(repeats=1) or [0.0])


def run(graphs=None, n_lanes=4, rounds=5, iters=3,
        out_json="BENCH_autotune.json"):
    graphs = graphs or ["ggs"]
    records = []
    worst_drift = (1.0, "none")
    worst_ratio = 0.0
    for name in graphs:
        g = datasets.load(name)
        store = store_for(g)
        app = gas.make_pagerank(max_iters=iters)
        cfg_a = PlanConfig(mode="model", n_lanes=n_lanes)   # analytic HW
        bundle_a = store.plan(cfg_a)
        tuner = AutoTuner(policy=RetunePolicy(drift_threshold=1.2,
                                              min_samples=4,
                                              cooldown_s=0.0),
                          registry=False)
        ex_a = Executor(store, bundle_a, app,
                        calibrator=tuner.calibrator)
        res_a, _ = ex_a.run(max_iters=iters)

        t0 = time.time()
        event = tuner.retune(store, ex_a, cfg_a, force=True)
        t_retune = time.time() - t0
        assert event.get("applied"), (
            f"forced retune did not apply on {name}: "
            f"{event.get('rejected') or event.get('error')}")

        cfg_b = tuner.resolve_config(PlanConfig(mode="model",
                                                n_lanes=n_lanes))
        assert cfg_b.hw is tuner.hw, "resolve_config kept analytic HW"
        bundle_b = store.plan(cfg_b)   # adopted by the retune: cache hit
        ex_b = Executor(store, bundle_b, app)

        # gate 3: bit-identical results across the plan swap
        res_b, _ = ex_b.run(max_iters=iters)
        identical = bool(np.array_equal(res_a, res_b))
        assert identical, f"retuned plan changed results on {name}"

        # gate 1: post-retune drift (tuner window was cleared at the
        # retune; refill it from the RETUNED plan's estimates)
        ex_b.drift.set_parent(tuner.drift)
        for _ in range(3):            # p50 over a real window, not n=1
            ex_b.time_lanes(repeats=2)
        ex_b.run(max_iters=iters)
        drift = tuner.drift.report()
        for kind, rep in drift.items():
            p50 = rep.get("ratio_p50")
            if not p50 or p50 <= 0:
                continue
            sev = max(p50, 1.0 / p50)   # symmetric distance from 1.0
            if sev > worst_drift[0]:
                worst_drift = (sev, f"{name}.{kind}")

        # gate 2: interleaved A/B on the measured makespan analogue
        same_shape = _lane_shape(bundle_a.plan) == _lane_shape(bundle_b.plan)
        if same_shape:
            ratio = 1.0
            mk_a = mk_b = None
        else:
            _measured_makespan(ex_a)     # warm both lane-jit sets
            _measured_makespan(ex_b)
            as_, bs_ = [], []
            for _ in range(rounds):
                as_.append(_measured_makespan(ex_a))
                bs_.append(_measured_makespan(ex_b))
            mk_a = float(np.median(as_))
            mk_b = float(np.median(bs_))
            ratio = mk_b / max(mk_a, 1e-12)
        worst_ratio = max(worst_ratio, ratio)

        rec = {
            "graph": name, "V": g.num_vertices, "E": g.num_edges,
            "n_lanes": n_lanes, "t_retune_s": t_retune,
            "fit": event.get("fit"), "chosen": event.get("chosen"),
            "candidates": event.get("candidates"),
            "same_lane_shape": same_shape,
            "makespan_analytic_s": mk_a, "makespan_retuned_s": mk_b,
            "makespan_ratio": ratio, "bit_identical": identical,
            "post_retune_drift": {
                k: {kk: r.get(kk) for kk in ("n", "ratio", "ratio_p50")}
                for k, r in drift.items()},
        }
        records.append(rec)
        emit(f"autotune.{name}.retune", t_retune * 1e6,
             f"applied chosen={event['chosen']['mode']}")
        emit(f"autotune.{name}.makespan_ratio", ratio * 1e6,
             "identical lane shape" if same_shape
             else f"retuned/analytic={ratio:.3f}")
        for k, r in sorted(drift.items()):
            emit(f"autotune.{name}.drift.{k}",
                 (r.get("ratio_p50") or 0.0) * 1e6,
                 f"n={r['n']} gate [{GATE_DRIFT_LO}, {GATE_DRIFT_HI}]")

    if out_json:
        with open(out_json, "w") as f:
            json.dump({"benchmark": "autotune",
                       "gate_drift": [GATE_DRIFT_LO, GATE_DRIFT_HI],
                       "gate_makespan": GATE_MAKESPAN,
                       "records": records}, f, indent=2)
        emit("autotune.artifact", 0.0, out_json)

    assert worst_drift[0] <= 1.0 / GATE_DRIFT_LO, (
        f"post-retune drift ratio_p50 {worst_drift[0]:.2f} "
        f"({worst_drift[1]}) outside [{GATE_DRIFT_LO}, {GATE_DRIFT_HI}] "
        f"— the calibrated model does not describe this host")
    assert worst_ratio <= GATE_MAKESPAN, (
        f"retuned plan is {worst_ratio:.2f}x the analytic plan's measured "
        f"makespan (gate {GATE_MAKESPAN}) — re-planning made things worse")
    emit("autotune.gate", 0.0,
         f"pass drift<={worst_drift[0]:.2f} makespan_ratio<="
         f"{worst_ratio:.3f}")
    return records
