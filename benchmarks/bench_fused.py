"""Fused (packed-lane) vs per-entry execution — the dispatch-overhead
scaling wall (ROADMAP perf item; GraphScale/ScalaBFS attribute the same
wall to per-PE dispatch rather than bandwidth).

For each graph and lane count we build BOTH executors on the SAME
cached plan and compare:

  * per-iteration wall time, timed INTERLEAVED (A/B/A/B) so slow host
    drift (CPU contention, thermal) hits both paths equally;
  * jit trace time + jaxpr size (``trace_stats``) and first-call
    compile time — the cost the GraphService cold path pays (per-entry
    is measured first, so any warm-cache bias favours it: the reported
    fused win is conservative);
  * kernel/merge dispatch counts (``dispatch_stats``).

Results go to stdout as usual AND to a ``BENCH_fused.json`` artifact
(one record per (graph, lane count), both paths + derived speedups).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro import api
from repro.core import gas
from repro.core.types import Geometry
from repro.graphs import datasets

from .common import emit, store_for

LANE_COUNTS = (8, 16)
# Much finer partitioning than the shared benchmark GEOM: the dispatch
# wall only shows when entries >> lanes (ggs at U=256 is 64 partitions
# vs 4 at the default U=4096), exactly the regime the ROADMAP item and
# GraphScale's scaling analysis describe.
FUSED_GEOM = Geometry(U=256, W=256, T=256, E_BLK=256, big_batch=4)


def _prepare(store, app, cfg, fused: bool) -> tuple:
    """Build + warm one executor; returns (executor, static metrics)."""
    ex = store.executor(app, cfg, path="ref", fuse_lanes=fused)
    ex.trace_stats()        # warm tracing-machinery caches (order fairness)
    tr = ex.trace_stats()
    t0 = time.perf_counter()
    ex._iter_fn = ex._build_iteration()
    vp = ex.init_props()
    ex._iter_fn(vp, ex.aux, 0).block_until_ready()
    t_compile = time.perf_counter() - t0
    d = ex.dispatch_stats()
    return ex, {
        "t_trace_ms": tr["t_trace_ms"],
        "jaxpr_eqns": tr["jaxpr_eqns"],
        "t_compile_s": t_compile,
        "kernel_dispatches": d["kernel_dispatches"],
        "merge_dispatches": d["merge_dispatches"],
        "num_entries": d["num_entries"],
        "payload_bytes": d["payload_bytes"],
    }


def _time_interleaved(ex_a, ex_b, repeats: int) -> tuple:
    """Median per-iteration wall time of two warmed executors, sampled
    alternately so host-speed drift cancels out of the comparison."""
    vp_a, vp_b = ex_a.init_props(), ex_b.init_props()
    ts_a, ts_b = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        ex_a._iter_fn(vp_a, ex_a.aux, 0).block_until_ready()
        ts_a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        ex_b._iter_fn(vp_b, ex_b.aux, 0).block_until_ready()
        ts_b.append(time.perf_counter() - t0)
    return float(np.median(ts_a)), float(np.median(ts_b))


def run(graphs=None, lane_counts=LANE_COUNTS, repeats=5,
        out_json="BENCH_fused.json"):
    graphs = graphs or ["ggs", "hws"]
    records = []
    for name in graphs:
        g = datasets.load(name)
        app = gas.make_pagerank(max_iters=2)
        store = store_for(g, FUSED_GEOM)
        for nl in lane_counts:
            cfg = api.PlanConfig(n_lanes=nl)
            ex_pe, per_entry = _prepare(store, app, cfg, fused=False)
            ex_f, fused = _prepare(store, app, cfg, fused=True)
            t_pe, t_f = _time_interleaved(ex_pe, ex_f, repeats)
            per_entry["t_iteration_s"] = t_pe
            fused["t_iteration_s"] = t_f
            # one plan deep: drop this config's device payloads (both
            # forms) before the next lane count materializes its own
            ex_pe = ex_f = None
            store.clear_plans()
            rec = {
                "graph": name, "V": g.num_vertices, "E": g.num_edges,
                "n_lanes": nl,
                "fused": fused, "per_entry": per_entry,
                "iteration_speedup": t_pe / max(t_f, 1e-12),
                "trace_speedup":
                    per_entry["t_trace_ms"]
                    / max(fused["t_trace_ms"], 1e-12),
                "compile_speedup":
                    per_entry["t_compile_s"]
                    / max(fused["t_compile_s"], 1e-12),
                "dispatch_reduction":
                    per_entry["kernel_dispatches"]
                    / max(fused["kernel_dispatches"], 1),
            }
            records.append(rec)
            emit(f"fused.{name}.lanes{nl}.iter", t_f * 1e6,
                 f"speedup={rec['iteration_speedup']:.2f}x "
                 f"(per_entry={t_pe * 1e6:.0f}us)")
            emit(f"fused.{name}.lanes{nl}.trace",
                 fused["t_trace_ms"] * 1e3,
                 f"eqns={fused['jaxpr_eqns']} vs "
                 f"{per_entry['jaxpr_eqns']} "
                 f"trace_speedup={rec['trace_speedup']:.2f}x "
                 f"compile_speedup={rec['compile_speedup']:.2f}x")
            emit(f"fused.{name}.lanes{nl}.dispatch", 0.0,
                 f"kernel={fused['kernel_dispatches']} vs "
                 f"{per_entry['kernel_dispatches']} "
                 f"(entries={per_entry['num_entries']})")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"benchmark": "fused_vs_per_entry",
                       "records": records}, f, indent=2)
        emit("fused.artifact", 0.0, out_json)
    return records


if __name__ == "__main__":
    run()
