"""Paper Fig. 10 — PR throughput across Little:Big lane combinations,
including the homogeneous ends (0L:NB, NL:0B).

Two views:
  * modelled TPU makespan per combination (TPU analytic constants) — the
    deployment predictor, where the paper's phenomenon (mixed beats
    homogeneous) lives; bandwidth asymmetry between streamed and random
    access is a TPU/FPGA property;
  * measured CPU makespan for the homogeneous ends and the selected
    combination — on a cache-based CPU random access is as cheap as
    streaming, so Big-everywhere tends to win; that inversion is itself
    the hardware-adaptation finding (DESIGN.md §2) and is reported.

The whole sweep (n_lanes+1 combinations × 2 hardware models) shares ONE
GraphStore per graph — only planning reruns per combination.
"""
from __future__ import annotations

import numpy as np

from repro import api
from repro.core import gas, perf_model
from repro.graphs import datasets

from .common import GEOM, MEDIUM, cpu_calibrated_hw, emit, mteps, store_for


def _modeled_makespan(plan):
    return max((sum(e.est_time for e in lane) for lane in plan.lanes),
               default=0.0)


def run(graphs=None, n_lanes=8):
    graphs = graphs or MEDIUM
    results = {}
    for name in graphs:
        g = datasets.load(name)
        app = gas.make_pagerank(max_iters=2)
        store = store_for(g)
        tpu = perf_model.TPU_V5E_SCALED
        model_times = {}
        for m in range(0, n_lanes + 1):
            n = n_lanes - m
            bundle = store.plan(api.PlanConfig(
                mode="fixed", forced_little=m, forced_big=n,
                n_lanes=n_lanes, hw=tpu))
            model_times[(m, n)] = _modeled_makespan(bundle.plan)
        best = min(model_times, key=model_times.get)
        homog = min(model_times[(0, n_lanes)], model_times[(n_lanes, 0)])
        sel_bundle = store.plan(api.PlanConfig(mode="model",
                                               n_lanes=n_lanes, hw=tpu))
        sel = (sel_bundle.plan.num_little_lanes,
               sel_bundle.plan.num_big_lanes)
        t_sel = _modeled_makespan(sel_bundle.plan)
        emit(f"fig10.{name}.tpu_best_combo", model_times[best] * 1e6,
             f"{best[0]}L{best[1]}B mteps={mteps(g, max(model_times[best], 1e-12)):.0f}")
        emit(f"fig10.{name}.tpu_homogeneous", homog * 1e6,
             f"speedup_het={homog / max(model_times[best], 1e-12):.2f}x")
        emit(f"fig10.{name}.tpu_model_selected", t_sel * 1e6,
             f"{sel[0]}L{sel[1]}B frac_of_best="
             f"{model_times[best] / max(t_sel, 1e-12):.2f} (paper: ~0.92)")
        # CPU-measured ends (hardware-adaptation check) — same store
        hw_cpu, _ = cpu_calibrated_hw(store, app)
        meas = {}
        for m, n in [(0, n_lanes), (n_lanes, 0)]:
            ex = store.executor(app, api.PlanConfig(
                mode="fixed", forced_little=m, forced_big=n,
                n_lanes=n_lanes, hw=hw_cpu), path="ref")
            lt = ex.time_lanes(repeats=2)
            meas[(m, n)] = max(lt) if lt else 0.0
        emit(f"fig10.{name}.cpu_measured_ends", 0.0,
             f"allBig={meas[(0, n_lanes)]*1e3:.2f}ms "
             f"allLittle={meas[(n_lanes, 0)]*1e3:.2f}ms "
             "(CPU: no streamed-vs-random asymmetry)")
        results[name] = (model_times, best, sel, t_sel)
    return results


if __name__ == "__main__":
    run()
