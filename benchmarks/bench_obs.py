"""Tracing overhead gate — tracing-off vs tracing-on end-to-end runs.

The obs instrumentation is unconditional in library code (store,
planner, executor call ``obs.span`` on every build/iteration), so its
cost must be provably negligible in BOTH states:

  * **off** — no tracer bound to the thread: every ``obs.span`` call is
    one thread-local lookup returning the shared no-op. This is the
    production default and the state the ≤5% p50 gate protects.
  * **coarse** — tracer active, ``lane_detail=False``: real spans for
    store/plan/iteration but the fused single-jit iteration keeps
    running (no extra dispatches).
  * **lane** — tracer active with per-lane detail: the executor
    switches to per-lane jits + one merge/apply jit for per-lane
    timing visibility. Extra dispatch boundaries per iteration are the
    price of the calibration data; reported, and gated only loosely
    (it is an opt-out knob, not the default cost).

All three variants run INTERLEAVED (A/B/C per round) on warmed
executors over the same cached plan, so host drift cancels out of the
comparison. Results go to stdout as usual AND to ``BENCH_obs.json``.
The hard gate: tracing-on (coarse) p50 within 5% of tracing-off p50.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro import api
from repro.graphs import datasets
from repro.obs import Tracer

from .common import GEOM, cpu_calibrated_hw, emit, store_for

# coarse spans must be invisible at request granularity
GATE_COARSE = 1.05
# per-lane detail pays real dispatches; keep it bounded, not invisible
GATE_LANE = 1.50


def _run_off(compiled, iters):
    t0 = time.perf_counter()
    compiled.run(max_iters=iters)
    return time.perf_counter() - t0


def _run_traced(compiled, tracer, iters):
    root = tracer.start_trace("bench")
    with tracer.activate(root.context):
        t0 = time.perf_counter()
        compiled.run(max_iters=iters)
        dt = time.perf_counter() - t0
    root.end()
    return dt


def run(graphs=None, rounds=15, iters=2, out_json="BENCH_obs.json"):
    graphs = graphs or ["ggs"]
    records = []
    worst_coarse = worst_lane = 0.0
    for name in graphs:
        g = datasets.load(name)
        store = store_for(g)
        # calibrated constants, not the analytic TPU defaults: the
        # drift_kinds block this artifact reports is meaningless (and
        # alarming — thousands-of-x "drift") when the estimates come
        # from a device profile this host doesn't have
        hw, _ = cpu_calibrated_hw(store)
        # three executors over the SAME cached plan: the comparison is
        # about the run path, not plan/build work
        c_off = api.compile(None, "pagerank", store=store, n_lanes=4,
                            hw=hw)
        c_coarse = api.compile(None, "pagerank", store=store, n_lanes=4,
                               hw=hw)
        c_lane = api.compile(None, "pagerank", store=store, n_lanes=4,
                             hw=hw)
        tr_coarse = Tracer(lane_detail=False)
        tr_lane = Tracer(lane_detail=True)
        # warm every path (compiles its jits) before any timed round
        _run_off(c_off, iters)
        _run_traced(c_coarse, tr_coarse, iters)
        _run_traced(c_lane, tr_lane, iters)
        ts = {"off": [], "coarse": [], "lane": []}
        for _ in range(rounds):
            ts["off"].append(_run_off(c_off, iters))
            ts["coarse"].append(_run_traced(c_coarse, tr_coarse, iters))
            ts["lane"].append(_run_traced(c_lane, tr_lane, iters))
        p50 = {k: float(np.median(v)) for k, v in ts.items()}
        ratio_coarse = p50["coarse"] / max(p50["off"], 1e-12)
        ratio_lane = p50["lane"] / max(p50["off"], 1e-12)
        worst_coarse = max(worst_coarse, ratio_coarse)
        worst_lane = max(worst_lane, ratio_lane)
        spans_per_run = (len(tr_lane.export(tr_lane.trace_ids()[-1]))
                         if tr_lane.trace_ids() else 0)
        drift = c_lane.executor.stats()["drift"]
        rec = {
            "graph": name, "V": g.num_vertices, "E": g.num_edges,
            "n_lanes": 4, "iters_per_run": iters, "rounds": rounds,
            "p50_off_s": p50["off"], "p50_coarse_s": p50["coarse"],
            "p50_lane_s": p50["lane"],
            "overhead_coarse": ratio_coarse, "overhead_lane": ratio_lane,
            "spans_per_lane_run": spans_per_run,
            "drift_kinds": {k: {"n": r["n"], "ratio": r["ratio"]}
                            for k, r in drift.items()},
        }
        records.append(rec)
        emit(f"obs.{name}.off", p50["off"] * 1e6, "tracing off (no-op)")
        emit(f"obs.{name}.coarse", p50["coarse"] * 1e6,
             f"overhead={100 * (ratio_coarse - 1):+.1f}% "
             f"(gate <= {100 * (GATE_COARSE - 1):.0f}%)")
        emit(f"obs.{name}.lane", p50["lane"] * 1e6,
             f"overhead={100 * (ratio_lane - 1):+.1f}% "
             f"spans/run={spans_per_run}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"benchmark": "tracing_overhead",
                       "gate_coarse": GATE_COARSE, "gate_lane": GATE_LANE,
                       "records": records}, f, indent=2)
        emit("obs.artifact", 0.0, out_json)
    assert worst_coarse <= GATE_COARSE, (
        f"tracing-on (coarse) p50 regression {100 * (worst_coarse - 1):.1f}%"
        f" exceeds the {100 * (GATE_COARSE - 1):.0f}% gate")
    assert worst_lane <= GATE_LANE, (
        f"per-lane tracing p50 regression {100 * (worst_lane - 1):.1f}% "
        f"exceeds the {100 * (GATE_LANE - 1):.0f}% bound")
    emit("obs.gate", 0.0,
         f"pass coarse={100 * (worst_coarse - 1):+.1f}% "
         f"lane={100 * (worst_lane - 1):+.1f}%")
    return records


if __name__ == "__main__":
    run()
