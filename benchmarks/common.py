"""Shared benchmark utilities: CPU-calibrated perf model + CSV output."""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import gas, perf_model
from repro.core.engine import HeterogeneousEngine
from repro.core.types import Geometry
from repro.graphs import datasets

GEOM = Geometry(U=4096, W=512, T=512, E_BLK=256, big_batch=8)

# Datasets per benchmark tier (CPU wall-time budget)
SMALL = ["ggs", "ams", "g17s", "hws"]
MEDIUM = ["r16s", "tcs", "pks", "unif16"]
LARGE = ["r18s", "hds", "bbs", "ljs"]


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def cpu_calibrated_hw(graph, app=None, geom=GEOM, n_samples=12):
    """Calibrate the perf model's coefficients on this host by timing a
    few partitions on both pipeline types (the paper benchmarks memory
    latency to fit Eq. 4's a and b; we least-squares all four terms)."""
    app = app or gas.make_pagerank(max_iters=2)
    eng = HeterogeneousEngine(graph, app, geom=geom, n_lanes=1, path="ref",
                              plan_mode="model",
                              hw=perf_model.TPU_V5E.clone(combine="sum"))
    from repro.kernels import ops
    import jax
    import jax.numpy as jnp
    vprops = eng.init_props()
    samples = []
    infos = sorted([i for i in eng.infos if i.num_edges > 0],
                   key=lambda i: -i.num_edges)
    for i in infos[:n_samples]:
        from repro.core import partition as part
        for kind, work in (
                ("little", part.block_little(eng.edges, i, geom)),
                ("big", part.block_big(eng.edges, [i], geom))):
            entry = ops.materialize_entry(work, 0, work.n_blocks)
            if entry is None:
                continue
            f = jax.jit(lambda vp: ops.run_entry(
                entry, vp, app.scatter, app.gather, "ref")[0])
            f(vprops).block_until_ready()
            f(vprops).block_until_ready()
            ts = []
            for _ in range(5):
                t0 = time.perf_counter()
                f(vprops).block_until_ready()
                ts.append(time.perf_counter() - t0)
            samples.append((i, geom, kind, float(np.median(ts))))
    return perf_model.calibrate(samples, perf_model.TPU_V5E), samples


def mteps(graph, seconds_per_iter: float) -> float:
    return graph.num_edges / seconds_per_iter / 1e6
