"""Shared benchmark utilities: CPU-calibrated perf model + CSV output."""
from __future__ import annotations

import sys
import time

import numpy as np

from repro import api
from repro.core import gas, perf_model
from repro.core.types import Geometry
from repro.graphs import datasets

GEOM = Geometry(U=4096, W=512, T=512, E_BLK=256, big_batch=8)

# Datasets per benchmark tier (CPU wall-time budget)
SMALL = ["ggs", "ams", "g17s", "hws"]
MEDIUM = ["r16s", "tcs", "pks", "unif16"]
LARGE = ["r18s", "hds", "bbs", "ljs"]


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def store_for(graph, geom=GEOM) -> api.GraphStore:
    """Construct a fresh GraphStore (NOT memoized — run_amortization's
    rebuild baseline relies on that). Benchmarks hold onto the returned
    store and share it across every plan mode / lane count they sweep —
    the amortization the layered API exists for."""
    return api.GraphStore(graph, geom=geom)


def cpu_calibrated_hw(graph_or_store, app=None, geom=GEOM, n_samples=12,
                      use_cache=True):
    """Calibrate the perf model's coefficients on this host by timing a
    few partitions on both pipeline types (the paper benchmarks memory
    latency to fit Eq. 4's a and b; we least-squares all four terms).

    Results are cached as a device spec per (host, geometry) in the
    autotune SpecRegistry (REGRAPH_SPEC_DIR, default .regraph_specs/),
    so a multi-benchmark run calibrates once; the cached path returns
    ``(hw, [])``. ``use_cache=False`` forces a fresh calibration (and
    refreshes the spec)."""
    from repro.autotune import DeviceSpec, SpecRegistry, \
        default_device_kind, geometry_key
    registry = SpecRegistry()
    kind = "bench-" + default_device_kind()
    if use_cache:
        spec = registry.get(kind, geom)
        if spec is not None and spec.source == "bench":
            return spec.hw, []
    app = app or gas.make_pagerank(max_iters=2)
    store = (graph_or_store if isinstance(graph_or_store, api.GraphStore)
             else store_for(graph_or_store, geom))
    from repro.core.executor import init_props
    from repro.kernels import ops
    import jax
    vprops = init_props(store, app)
    samples = []
    infos = sorted([i for i in store.infos if i.num_edges > 0],
                   key=lambda i: -i.num_edges)
    for i in infos[:n_samples]:
        for kind, work in (("little", store.little_work(i.pid)),
                           ("big", store.big_work((i.pid,)))):
            entry = ops.materialize_entry(work, 0, work.n_blocks)
            if entry is None:
                continue
            f = jax.jit(lambda vp: ops.run_entry(
                entry, vp, app.scatter, app.gather, "ref")[0])
            f(vprops).block_until_ready()
            f(vprops).block_until_ready()
            ts = []
            for _ in range(5):
                t0 = time.perf_counter()
                f(vprops).block_until_ready()
                ts.append(time.perf_counter() - t0)
            samples.append((i, store.geom, kind, float(np.median(ts))))
    hw, diag = perf_model.calibrate_full(samples, perf_model.TPU_V5E)
    # pin the utilization profiler's %-of-peak denominator to what this
    # calibration believes the host can stream, so it persists with the
    # spec instead of being re-derived from analytic defaults
    hw = hw.clone(peak_bandwidth_gbps=(
        perf_model.effective_peak_bandwidth_bps(hw) / 1e9))
    try:
        registry.put(DeviceSpec(
            device_kind=kind, geom_key=geometry_key(geom), hw=hw,
            version=1, created_at=time.time(), source="bench", fit=diag))
    except OSError:
        pass   # read-only checkout: caching is best-effort
    return hw, samples


def mteps(graph, seconds_per_iter: float) -> float:
    return graph.num_edges / seconds_per_iter / 1e6
