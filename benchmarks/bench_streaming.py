"""Streaming delta-apply vs full store rebuild (ROADMAP streaming item).

For each churn level a delta is applied two ways to the SAME prepared
state (store + cached plan + packed device payloads):

  * ``apply_delta`` — dirty-partition splice + plan rebuild with
    packed-lane carry-over (the GraphService.update path);
  * cold rebuild — GraphStore on the post-delta graph + plan + pack
    (what serving would pay without streaming). The oracle graph
    construction itself is NOT timed for either side.

Both sides are medianed over ``repeats`` interleaved runs. Deltas come
in two dst distributions: degree-skewed churn (``hot_frac`` —
preferential attachment, the realistic evolving-graph case DBG
localizes into few partitions) and uniform churn (the no-locality worst
case). Acceptance target: >= 5x apply speedup at <= 1% skewed churn on
the quick-tier RMAT graph, with untouched lanes' packed payloads reused
(asserted from the apply stats).

A third tier benchmarks grow-the-graph deltas (``grow_frac`` — new
vertices appended to the tail of the frozen DBG id space): growth must
never cost more than the cold rebuild it replaces (>= 1x at <= 1%
growth) and the incrementally-grown store must be bit-identical to a
cold build of the post-growth graph under the extended permutation.

Results go to stdout AND a ``BENCH_streaming.json`` artifact.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro import api
from repro.core.store import GraphStore
from repro.core.types import Geometry
from repro.graphs.rmat import rmat
from repro.streaming import apply_delta, apply_delta_to_graph, random_delta

from .common import emit

# finer partitioning than the default geometry: streaming locality is a
# partition-count effect (hot vertices -> few dirty partitions of many)
STREAM_GEOM = Geometry(U=512, W=256, T=256, E_BLK=256, big_batch=4)
CHURN_LEVELS = (0.001, 0.01, 0.05)
GROWTH_LEVELS = (0.001, 0.01)


def run(smoke: bool = False, churn_levels=CHURN_LEVELS, repeats: int = 3,
        n_lanes: int = 8, out_json: str = "BENCH_streaming.json",
        growth_levels=GROWTH_LEVELS):
    scale, ef = (12, 8) if smoke else (14, 16)
    g = rmat(scale, ef, seed=19, weighted=True)
    geom = STREAM_GEOM if not smoke else Geometry(
        U=256, W=128, T=128, E_BLK=128, big_batch=4)
    cfg = api.PlanConfig(n_lanes=n_lanes)

    store = GraphStore(g, geom=geom)
    store.plan(cfg).packed_lanes()      # serving-warm state to update
    emit("streaming.base", 0.0,
         f"V={g.num_vertices} E={g.num_edges} "
         f"partitions={len(store.infos)}")

    records = []
    for churn in churn_levels:
        for dist, hot in (("skewed", 0.01), ("uniform", None)):
            delta = random_delta(g, churn=churn, seed=int(churn * 1e5),
                                 hot_frac=hot, update_frac=churn / 4)
            post = apply_delta_to_graph(g, delta)    # oracle (untimed)

            # interleave A/B so host drift cancels
            ta, tc = [], []
            res = None
            for _ in range(repeats):
                t0 = time.perf_counter()
                res = apply_delta(store, delta)
                ta.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                cold = GraphStore(post, geom=geom)
                cold.plan(cfg).packed_lanes()
                tc.append(time.perf_counter() - t0)
            t_apply, t_cold = float(np.median(ta)), float(np.median(tc))

            s = res.stats
            speedup = t_cold / max(t_apply, 1e-12)
            rec = {
                "graph": g.name, "V": g.num_vertices, "E": g.num_edges,
                "churn": churn, "distribution": dist,
                "changes": delta.num_changes,
                "path": s["path"],
                "dirty_fraction": s["dirty_fraction"],
                "t_apply_ms": t_apply * 1e3,
                "t_cold_rebuild_ms": t_cold * 1e3,
                "speedup": speedup,
                "dirty_partitions": s["dirty_partitions"],
                "partitions": s["partitions"],
                "packed_lanes_reused": s["packed_lanes_reused"],
                "packed_lanes_repacked": s["packed_lanes_repacked"],
                "packed_bytes_reused": s["packed_bytes_reused"],
                "little_blockings_reused": s["little_blockings_reused"],
            }
            records.append(rec)
            emit(f"streaming.{dist}.churn{churn:g}.apply", t_apply * 1e6,
                 f"speedup={speedup:.1f}x "
                 f"(cold={t_cold * 1e3:.0f}ms "
                 f"dirty={s['dirty_partitions']}/{s['partitions']})")
            emit(f"streaming.{dist}.churn{churn:g}.reuse",
                 float(s["packed_bytes_reused"]),
                 f"lanes={s['packed_lanes_reused']}/"
                 f"{s['packed_lanes_reused'] + s['packed_lanes_repacked']} "
                 f"blockings={s['little_blockings_reused']}")

    # acceptance: >= 5x at <= 1% skewed churn on the quick-tier graph,
    # with payload reuse. The smoke graph is too small for the ratio to
    # be meaningful (cold rebuild is ~20 ms, fixed overheads dominate),
    # so CI smoke gates at a looser 2x + the same reuse requirement.
    need = 2.0 if smoke else 5.0
    gate = [r for r in records
            if r["distribution"] == "skewed" and r["churn"] <= 0.01]
    assert gate, "no skewed churn level <= 1% measured"
    best = max(r["speedup"] for r in gate)
    assert best >= need, \
        f"delta apply speedup {best:.1f}x < {need:g}x at <=1% skewed churn"
    assert any(r["packed_lanes_reused"] >= 1 for r in gate), \
        "no packed payloads carried over at <=1% skewed churn"
    emit("streaming.acceptance", 0.0,
         f"best_speedup={best:.1f}x (>={need:g}x ok)")

    # uniform-churn gate: the no-locality worst case must never regress
    # below a cold rebuild (it used to: per-partition splices across
    # ~every partition paid per-segment overhead with zero reuse, down
    # to 0.41x). The adaptive bulk fallback caps that cost; the chosen
    # path is recorded per record. The smoke graph again measures fixed
    # overheads more than merge cost, hence the looser floor.
    need_u = 0.7 if smoke else 1.0
    uni = [r for r in records if r["distribution"] == "uniform"]
    assert uni, "no uniform churn level measured"
    worst = min(uni, key=lambda r: r["speedup"])
    assert worst["speedup"] >= need_u, \
        (f"uniform-churn apply regressed: {worst['speedup']:.2f}x < "
         f"{need_u:g}x vs cold rebuild at churn={worst['churn']:g} "
         f"(path={worst['path']})")
    assert all(r["path"] in ("splice", "bulk_sort") for r in uni)
    emit("streaming.acceptance_uniform", 0.0,
         f"worst_speedup={worst['speedup']:.2f}x (>={need_u:g}x ok, "
         f"path={worst['path']})")

    # growth tier: vertex growth (tail-appended under the frozen perm)
    # plus mild skewed churn — the evolving-graph arrival pattern. The
    # gate is intentionally modest: growth dirties the LAST partition
    # (plus any partition the churn touches) and allocates fresh tail
    # partitions, so the locality win shrinks, but applying a growth
    # delta must never cost more than the cold rebuild it replaces.
    for gf in growth_levels:
        delta = random_delta(g, churn=gf / 2, seed=int(gf * 1e6) + 7,
                             hot_frac=0.01, grow_frac=gf)
        post = apply_delta_to_graph(g, delta)        # oracle (untimed)

        ta, tc = [], []
        res = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = apply_delta(store, delta)
            ta.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            cold = GraphStore(post, geom=geom)
            cold.plan(cfg).packed_lanes()
            tc.append(time.perf_counter() - t0)
        t_apply, t_cold = float(np.median(ta)), float(np.median(tc))

        # correctness gate rides along with the timing: the grown store
        # is bit-identical to a cold build of the post-growth graph
        # under the extended frozen permutation
        ext_perm = np.concatenate([
            np.asarray(store.perm),
            np.arange(g.num_vertices, post.num_vertices, dtype=np.int32)])
        ext = GraphStore(post, geom=geom, perm=ext_perm)
        for k in ("src", "dst", "weights"):
            assert np.array_equal(res.store.edges[k], ext.edges[k]), \
                f"grown store diverged from cold rebuild ({k})"
        assert res.store.infos == ext.infos and res.store.V_pad == ext.V_pad

        s = res.stats
        speedup = t_cold / max(t_apply, 1e-12)
        rec = {
            "graph": g.name, "V": g.num_vertices, "E": g.num_edges,
            "churn": gf / 2, "distribution": "growth",
            "grow_frac": gf,
            "grown_vertices": s["grown_vertices"],
            "new_partitions": s["new_partitions"],
            "changes": delta.num_changes,
            "path": s["path"],
            "dirty_fraction": s["dirty_fraction"],
            "t_apply_ms": t_apply * 1e3,
            "t_cold_rebuild_ms": t_cold * 1e3,
            "speedup": speedup,
            "dirty_partitions": s["dirty_partitions"],
            "partitions": s["partitions"],
            "packed_lanes_reused": s["packed_lanes_reused"],
            "packed_lanes_repacked": s["packed_lanes_repacked"],
            "packed_bytes_reused": s["packed_bytes_reused"],
            "little_blockings_reused": s["little_blockings_reused"],
        }
        records.append(rec)
        emit(f"streaming.growth.frac{gf:g}.apply", t_apply * 1e6,
             f"speedup={speedup:.1f}x grown={s['grown_vertices']}V "
             f"(+{s['new_partitions']}p, cold={t_cold * 1e3:.0f}ms)")

    grow = [r for r in records
            if r["distribution"] == "growth" and r["grow_frac"] <= 0.01]
    assert grow, "no growth level <= 1% measured"
    worst_g = min(grow, key=lambda r: r["speedup"])
    assert worst_g["speedup"] >= 1.0, \
        (f"growth apply regressed below cold rebuild: "
         f"{worst_g['speedup']:.2f}x at grow_frac="
         f"{worst_g['grow_frac']:g}")
    assert all(r["grown_vertices"] > 0 for r in grow)
    emit("streaming.acceptance_growth", 0.0,
         f"worst_speedup={worst_g['speedup']:.2f}x (>=1x ok, "
         f"bit-identical to cold rebuild)")

    if out_json:
        with open(out_json, "w") as f:
            json.dump({"benchmark": "streaming_delta_vs_rebuild",
                       "records": records}, f, indent=2)
        emit("streaming.artifact", 0.0, out_json)
    return records


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke)
