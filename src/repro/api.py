"""Layered public API for the heterogeneous graph engine.

Three composable layers (paper §IV–§V: the push-button flow separates
app-independent graph preparation from model-guided scheduling):

    GraphStore  — app-independent; DBG relabeling, dst-range
                  partitioning, Little/Big brick blockings. Built once
                  per (graph, Geometry), memoizes blockings and plans.
    Planner     — per PlanConfig (typed: mode/forced split/n_lanes/hw);
                  classifies partitions with the perf model and builds
                  the lane schedule. Cheap; cached on the store.
    Executor    — per (plan, app); device-resident lane entries and the
                  jit'd iteration loop (run / time_iteration /
                  time_lanes).

Quickstart::

    from repro import api
    from repro.graphs.rmat import rmat

    compiled = api.compile(rmat(12, 16, seed=7), "pagerank", n_lanes=8)
    props, meta = compiled.run()

Amortized multi-app use (build the store once, plan each app)::

    store = api.GraphStore(graph, geom=geom)
    for name in ("pagerank", "bfs", "wcc"):
        props, meta = store.plan_and_run(api.BUILTIN_APPS[name]())

Serving (multi-tenant: LRU of stores + request queue + coalescing —
see repro/serve_graph/)::

    with api.GraphService(byte_budget=512 << 20, workers=2) as svc:
        handles = [svc.submit(g, name) for name in api.BUILTIN_APPS]
        results = [h.result(timeout=120) for h in handles]

Streaming updates flow through :class:`GraphDelta` / :func:`apply_delta`
(see repro/streaming/); multi-device execution through
``compile(shard=...)`` / ``GraphStore.shard()`` (see repro/sharding/).

Serving at scale layers the control plane on top (see repro/control/):
``GraphService(pool=N)`` moves store builds and delta splices into
worker processes (:class:`WorkerPool`), submits carry ``priority`` /
``deadline`` / ``tenant`` through the model-guided scheduler with
:class:`TenantQuota` admission (typed :class:`QueueFull` /
:class:`QuotaExceeded` / :class:`DeadlineExpired` rejections), and
:class:`ControlPlane` + :func:`serve_jobs` expose persistent job
records over an HTTP JSON API::

    plane = api.ControlPlane(svc, job_store=api.JobStore("jobs.jsonl"))
    server, url = api.serve_jobs(plane)        # POST {url}/jobs, ...

Every job carries an end-to-end trace (:class:`Tracer`): spans cross
the scheduler queue and the worker-process boundary, per-lane spans
record measured-vs-estimated drift against the perf model, and
``GET {url}/jobs/{id}/trace`` returns Chrome-trace JSON for Perfetto
(see docs/OBSERVABILITY.md).

docs/ARCHITECTURE.md maps the whole system.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from .autotune import (AutoTuner, Calibrator, DeviceSpec, RetunePolicy,
                       SpecRegistry)
from .control import (ControlPlane, DeadlineExpired, JobRecord,
                      JobScheduler, JobStore, QueueFull, QuotaExceeded,
                      RejectedJob, TenantQuota, WorkerCrashed, WorkerPool,
                      serve_jobs)
from .core.executor import Executor
from .core.gas import (BUILTIN_APPS, GASApp, make_bfs, make_closeness,
                       make_pagerank, make_sssp, make_wcc)
from .core.perf_model import HW, TPU_V5E, TPU_V5E_SCALED
from .core.planner import PlanBundle, PlanConfig, Planner
from .core.store import GraphStore
from .core.types import Geometry, SchedulePlan
from .graphs.formats import Graph, fingerprint as graph_fingerprint
from .obs import (DriftAccumulator, LaneFootprint, PerfLedger, Span,
                  SpanContext, Tracer, UtilizationAccumulator)
from .serve_graph import (GraphService, GraphStoreCache, RequestHandle,
                          ServiceMetrics, UpdateResult)
from .sharding import (LanePlacement, ShardedExecutor, ShardedLanes,
                       place_lanes)
from .streaming import (GraphDelta, RegroupPolicy, apply_delta,
                        apply_delta_to_graph, chain_fingerprint,
                        compact_deltas, compose_deltas, grouping_drift,
                        grown_num_vertices, make_delta, random_delta,
                        rebuild_plans, reregister, splice_delta)

__all__ = [
    "AutoTuner", "BUILTIN_APPS", "Calibrator", "CompiledApp",
    "ControlPlane", "DeadlineExpired", "DeviceSpec",
    "DriftAccumulator", "Executor", "GASApp", "Geometry", "GraphDelta",
    "GraphService", "GraphStore", "GraphStoreCache", "HW", "JobRecord",
    "JobScheduler", "JobStore", "LaneFootprint", "LanePlacement",
    "PerfLedger", "PlanBundle",
    "PlanConfig", "Planner", "QueueFull", "QuotaExceeded",
    "RegroupPolicy", "RejectedJob",
    "RequestHandle", "RetunePolicy", "SchedulePlan", "ServiceMetrics",
    "ShardedExecutor", "SpecRegistry",
    "ShardedLanes", "Span", "SpanContext", "TPU_V5E", "TPU_V5E_SCALED",
    "TenantQuota", "Tracer", "UpdateResult",
    "UtilizationAccumulator", "WorkerCrashed",
    "WorkerPool", "apply_delta", "apply_delta_to_graph",
    "chain_fingerprint", "compact_deltas", "compile", "compose_deltas",
    "graph_fingerprint", "grouping_drift", "grown_num_vertices",
    "make_bfs", "make_closeness", "make_delta", "make_pagerank",
    "make_sssp", "make_wcc", "place_lanes", "random_delta",
    "rebuild_plans", "reregister", "serve_jobs", "splice_delta",
]


@dataclasses.dataclass
class CompiledApp:
    """The result of :func:`compile`: one app bound to a (possibly
    shared) GraphStore and a cached plan, ready to run. ``executor``
    is an :class:`Executor` or — under ``compile(shard=...)`` — a
    :class:`ShardedExecutor` (same run/time_iteration/stats surface;
    ``time_lanes`` exists only on the single-device form)."""

    store: GraphStore
    executor: Union[Executor, ShardedExecutor]

    @property
    def app(self) -> GASApp:
        return self.executor.app

    @property
    def config(self) -> PlanConfig:
        return self.executor.bundle.config

    @property
    def plan(self) -> SchedulePlan:
        return self.executor.plan

    def run(self, max_iters: Optional[int] = None, collect_history=False):
        return self.executor.run(max_iters=max_iters,
                                 collect_history=collect_history)

    def time_iteration(self, repeats: int = 5) -> float:
        return self.executor.time_iteration(repeats=repeats)

    def time_lanes(self, repeats: int = 3):
        return self.executor.time_lanes(repeats=repeats)

    def stats(self) -> dict:
        return self.executor.stats()


def compile(
    graph: Optional[Graph],
    app: Union[GASApp, str],
    *,
    geom: Optional[Geometry] = None,
    config: Optional[PlanConfig] = None,
    store: Optional[GraphStore] = None,
    path: Optional[str] = None,
    use_dbg: Optional[bool] = None,
    fuse_lanes: bool = True,
    shard=None,
    **cfg,
) -> CompiledApp:
    """Push-button entry point: prepare (or reuse) a GraphStore, plan,
    and materialize an executor for one app.

    ``app`` may be a :class:`GASApp` or a builtin name ("pagerank",
    "bfs", "sssp", "wcc", "closeness"). Extra keyword arguments become
    :class:`PlanConfig` fields (``n_lanes``, ``mode``, ``hw``,
    ``forced_little``, ``forced_big``). Pass ``store=`` to amortize
    preprocessing across apps; ``graph`` may then be None.
    ``fuse_lanes=False`` disables the packed-lane execution path (one
    kernel launch per plan entry instead of one per lane; bit-identical
    results — see README §Performance). ``shard`` switches to
    multi-device execution with per-device lane ownership (``True`` =
    every local device, int = first n, or an explicit device sequence;
    bit-identical to the single-device fused path — see README
    §Sharding); the returned :class:`CompiledApp` then wraps a
    :class:`ShardedExecutor`.

    Returns a :class:`CompiledApp` (run / time_iteration / stats).
    """
    if isinstance(app, str):
        if app not in BUILTIN_APPS:
            raise ValueError(f"unknown builtin app {app!r}; available: "
                             f"{sorted(BUILTIN_APPS)}")
        app = BUILTIN_APPS[app]()
    if config is not None and cfg:
        raise ValueError("pass either config= or PlanConfig kwargs, not both")
    if config is None:
        config = PlanConfig(**cfg)
    if store is None:
        if graph is None:
            raise ValueError("compile() needs a graph when no store= given")
        store = GraphStore(graph, geom=geom or Geometry(),
                           use_dbg=use_dbg if use_dbg is not None else True)
    else:
        # a shared store fixes graph/geometry/DBG — reject contradictions
        store.validate_compatible(graph=graph, geom=geom, use_dbg=use_dbg)
    return CompiledApp(store=store,
                       executor=store.executor(app, config, path=path,
                                               fuse_lanes=fuse_lanes,
                                               shard=shard))
