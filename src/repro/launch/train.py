"""Training launcher: ``python -m repro.launch.train --arch qwen2_1p5b
--steps 200 --scale reduced``.

``--scale reduced`` trains the CPU-feasible config (the examples use
this); ``--scale full`` expects real accelerators and applies the mesh +
sharding rules from sharding/specs.py.
"""
from __future__ import annotations

import argparse
from pathlib import Path

import jax

from ..configs import get_config, reduced
from ..data.pipeline import DataConfig
from ..models.api import build_model
from ..optim.adafactor import adafactor
from ..optim.adamw import adamw
from ..optim.schedule import warmup_cosine
from ..train.loop import Trainer


def build_optimizer(cfg, steps: int):
    lr = warmup_cosine(peak=3e-4, warmup=min(100, steps // 10 + 1),
                       total=steps)
    if cfg.optimizer == "adafactor":
        return adafactor(lr=lr)
    return adamw(lr=lr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1p5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--scale", choices=["reduced", "full"],
                    default="reduced")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--run-dir", default="runs/train")
    ap.add_argument("--micro-batches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "reduced":
        cfg = reduced(cfg, layers=args.layers)
        if args.d_model:
            import dataclasses
            cfg = dataclasses.replace(cfg, d_model=args.d_model)
    model = build_model(cfg)
    opt = build_optimizer(cfg, args.steps)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)

    def transform(b):
        if cfg.frontend == "vision":
            import numpy as np
            d = np.random.default_rng(0).normal(
                size=(b["tokens"].shape[0], args.seq, cfg.d_model))
            return {"embeds": d.astype("float32"), "labels": b["labels"]}
        if cfg.frontend == "audio":
            import numpy as np
            d = np.random.default_rng(0).normal(
                size=(b["tokens"].shape[0], cfg.encoder_seq, cfg.d_model))
            return {"enc_embeds": d.astype("float32"),
                    "tokens": b["tokens"], "labels": b["labels"]}
        return b

    trainer = Trainer(model, opt, data_cfg, args.run_dir,
                      micro_batches=args.micro_batches,
                      batch_transform=transform)
    params, _, losses = trainer.run(args.steps)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}) over "
          f"{len(losses)} steps")


if __name__ == "__main__":
    main()
