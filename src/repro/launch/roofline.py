"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per EXPERIMENTS.md §Roofline, v5e-like targets):
  compute    = FLOPs_per_chip / peak_flops
  memory     = bytes_per_chip / hbm_bw
  collective = collective_bytes_per_chip / link_bw

``cost_analysis()`` on the SPMD-partitioned module reports PER-CHIP flops
and bytes (the module is the per-device program). collective bytes are
parsed from the HLO text: operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async -start variants
counted once, -done skipped).
"""
from __future__ import annotations

import re
from typing import Dict

HW = {
    "peak_flops": 197e12,     # bf16 / chip
    "hbm_bw": 819e9,          # B/s / chip
    "link_bw": 50e9,          # B/s / ICI link
}

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(tok_dtype, 4)


def _split_computations(hlo_text: str):
    """Return ({computation_name: body_lines}, entry_name)."""
    comps = {}
    entry = None
    name, body = None, []
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->.*{",
                     line)
        if m:
            name, body = m.group(2), []
            if m.group(1):
                entry = name
            continue
        if name is not None:
            if line.strip() == "}":
                comps[name] = body
                name = None
            else:
                body.append(line)
    return comps, entry


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s+[\w\-]+\(")


def _type_bytes(type_str: str) -> int:
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(type_str))


def _build_shape_map(hlo_text: str) -> Dict[str, int]:
    """instruction name -> output bytes (operand shapes are not inlined
    at call sites in compiled HLO text)."""
    out = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            out[m.group(1)] = _type_bytes(m.group(2))
    return out


def _line_collective_bytes(line: str, shape_map: Dict[str, int]):
    m = re.search(r"=\s*(.*?)\s+([a-z\-]+)\(", line)
    if not m:
        return None
    op = m.group(2)
    base = None
    for c in _COLLECTIVES:
        if op == c or op == c + "-start":
            base = c
            break
    if base is None:
        return None
    lp = line.index("(")
    rp = line.index(")", lp)
    operands = re.findall(r"%([\w\.\-]+)", line[lp + 1:rp])
    b = sum(shape_map.get(o, 0) for o in operands)
    if b == 0:  # entry-style HLO inlines operand shapes
        b = sum(_shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(line[lp + 1:rp]))
    if b == 0:  # last resort: output type (== operand for all-reduce)
        b = _type_bytes(m.group(1))
    return base, b


def _loop_trip_count(cond_lines) -> int:
    """XLA scan loops compare the induction var against a constant."""
    consts = []
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def cpu_upcast_estimate(cfg, chips: int) -> int:
    """XLA:CPU has no native bf16 dot, so it hoists f32 copies of every
    bf16 weight out of the layer loop (visible as convert(param) ops in
    the HLO) — a backend artifact absent on TPU (native bf16 MXU). The
    hoisted copies are ~2x the per-chip bf16 param bytes. Used to derive
    peak_tpu_estimate_bytes; instruction-level summing is wrong because
    XLA reuses buffers (liveness != sum of outputs)."""
    return int(2 * param_count(cfg) * 2 / chips)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective operand bytes, with while-loop bodies multiplied by
    their trip count (XLA cost analysis counts loop bodies ONCE — a 61x
    undercount for per-layer collectives inside the layer scan)."""
    comps, entry = _split_computations(hlo_text)
    shape_map = _build_shape_map(hlo_text)
    # map body computation -> trip count (from the loop's condition comp)
    trip: Dict[str, int] = {}
    for name, lines in comps.items():
        for line in lines:
            if "while(" not in line:
                continue
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            if mc and mb:
                trip[mb.group(1)] = _loop_trip_count(
                    comps.get(mc.group(1), []))

    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0

    def walk(comp_name, multiplier, seen):
        if comp_name in seen or comp_name not in comps:
            return
        seen = seen | {comp_name}
        for line in comps[comp_name]:
            got = _line_collective_bytes(line, shape_map)
            if got:
                c, b = got
                out[c] += b * multiplier
                out["count"] += multiplier
            if "while(" in line:
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                if mb:
                    walk(mb.group(1),
                         multiplier * trip.get(mb.group(1), 1), seen)
            elif "call(" in line or "conditional(" in line:
                for mc in re.finditer(
                        r"(?:to_apply|true_computation|false_computation|"
                        r"branch_computations=\{)[=]?%?([\w\.\-]+)", line):
                    walk(mc.group(1), multiplier, seen)

    if entry is None and comps:
        entry = list(comps)[-1]
    walk(entry, 1, frozenset())
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def roofline_terms(cost: dict, coll: dict, chips: int, cfg=None, shape=None,
                   hw: dict = HW) -> dict:
    """Three-term roofline. compute/memory use the ANALYTIC workload model
    (XLA cost_analysis counts scan bodies once — useless for L-layer
    models; its raw numbers are recorded for reference with that caveat).
    collective uses the trip-count-corrected HLO parse (per-chip program
    payloads)."""
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.get("total", 0.0))
    an = analytic_costs(cfg, shape) if cfg is not None else None
    flops_chip = (an["flops_exec"] / chips) if an else hlo_flops
    bytes_chip = (an["hbm_bytes"] / chips) if an else hlo_bytes
    t_compute = flops_chip / hw["peak_flops"]
    t_memory = bytes_chip / hw["hbm_bw"]
    t_collective = cb / hw["link_bw"]
    dominant = max(
        [("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)], key=lambda kv: kv[1])[0]
    tot = max(t_compute, t_memory, t_collective)
    out = {
        "flops_per_chip": flops_chip,
        "bytes_per_chip": bytes_chip,
        "collective_bytes_per_chip": cb,
        "hlo_flops_per_chip_raw": hlo_flops,
        "hlo_bytes_per_chip_raw": hlo_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "roofline_bound_s": tot,
    }
    if an:
        out["analytic"] = an
        # useful fraction: model (6N D) flops vs executed (remat, padding)
        out["mfu_upper_bound"] = (an["flops_model"] / chips
                                  / hw["peak_flops"]) / tot if tot else 0.0
    return out


def analytic_costs(cfg, shape) -> dict:
    """Global FLOPs and HBM bytes from the workload's structure.

    flops_model — the 'useful' count (6·N_active·tokens train,
                  2·N_active·tokens inference) + exact attention term.
    flops_exec  — what actually executes: remat multiplies the forward
                  by ~2x in train (fwd + bwd(2x fwd) + remat fwd = 8N·T),
                  MoE padding multiplies expert FFN flops by
                  padded/used capacity.
    hbm_bytes   — params read/written (+optimizer state traffic in train),
                  activations through HBM between remat blocks, KV-cache
                  traffic for decode.
    """
    B, S = shape.batch, shape.seq
    train = shape.kind == "train"
    tokens = B * S if shape.kind != "decode" else B
    n_active = param_count(cfg, active_only=True)
    n_total = param_count(cfg, active_only=False)
    p_bytes = 2.0  # bf16

    # attention flops (fwd): 4·B·S·ctx·H·hd x 0.5 causal
    H, hd, L = cfg.num_heads, cfg.hd, cfg.num_layers
    if shape.kind == "decode":
        ctx = S
        attn_fwd = 4.0 * B * 1 * min(ctx, cfg.sliding_window or ctx) \
            * H * hd * L
    else:
        eff_ctx = min(S, cfg.sliding_window or S)
        attn_fwd = 4.0 * B * S * eff_ctx * 0.5 * H * hd * L
    if cfg.family == "ssm":
        attn_fwd = 0.0

    mm_fwd = 2.0 * n_active * tokens
    fwd = mm_fwd + attn_fwd
    if train:
        flops_model = 3.0 * fwd                      # fwd + 2x bwd
        flops_exec = (4.0 if cfg.remat else 3.0) * fwd
    else:
        flops_model = fwd
        flops_exec = fwd
    # MoE capacity padding overhead on the expert-FFN share
    if cfg.family == "moe":
        from ..models.moe_schedule import biglittle_split
        E, K = cfg.num_experts_padded, cfg.top_k
        Fm = cfg.moe_d_ff or cfg.d_ff
        used = tokens * K
        if cfg.moe_dispatch == "biglittle":
            n_hot, c_hot, c_cold = biglittle_split(E, K, max(tokens, 1),
                                                   round_to=16)
            padded = n_hot * c_hot + (E - n_hot) * c_cold
        else:
            padded = E * max(8, int(used / E * 1.25))
        ffn_share = 6.0 * cfg.d_model * Fm * K * tokens  # 3 mats x 2
        overhead = ffn_share * max(padded / max(used, 1) - 1.0, 0.0)
        flops_exec += overhead * (3.0 if train else 1.0)

    # HBM bytes (global)
    if train:
        opt_mult = {"adamw": 3.0, "adafactor": 1.1}.get(cfg.optimizer, 3.0)
        # params: read fwd + read bwd + grad write + opt read/write
        param_traffic = n_total * p_bytes * (3.0 + opt_mult)
        act_bytes = tokens * cfg.d_model * p_bytes * L * 2.0  # remat edges
        hbm = param_traffic + act_bytes
    elif shape.kind == "prefill":
        hbm = n_active * p_bytes + tokens * cfg.d_model * p_bytes * L * 2.0
    else:  # decode: weights + full KV cache read per token
        kvb = 0.0
        if cfg.num_kv_heads:
            ctx = min(S, cfg.sliding_window or S)
            kv_bytes = 1.0 if "8" in (cfg.kv_cache_dtype or "") else p_bytes
            kvb = 2.0 * B * L * ctx * cfg.num_kv_heads * cfg.hd * kv_bytes
        if cfg.family in ("ssm", "hybrid"):
            din = cfg.din
            Hs = din // cfg.ssm_head_dim
            kvb += B * L * Hs * cfg.ssm_head_dim * cfg.ssm_state * 4.0 * 2
        hbm = n_active * p_bytes + kvb
    return {
        "flops_model": flops_model,
        "flops_exec": flops_exec,
        "hbm_bytes": hbm,
        "tokens": tokens,
        "n_active": n_active,
        "n_total": n_total,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens.
    Decode counts one token per sequence."""
    tokens = (shape.batch * shape.seq if shape.kind != "decode"
              else shape.batch)
    n = param_count(cfg, active_only=True)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def param_count(cfg, active_only: bool = False) -> float:
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_padded
    L = cfg.num_layers
    n = V * D                           # lm_head matmul (embed is a gather)
    if cfg.family in ("ssm",):
        din, N = cfg.din, cfg.ssm_state
        H = din // cfg.ssm_head_dim
        per = D * (2 * din + 2 * N + H) + din * D
        return n + L * per
    hd, Hh, KH = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    attn = D * Hh * hd + 2 * D * KH * hd + Hh * hd * D
    if cfg.family == "moe":
        Fm = cfg.moe_d_ff or F
        e = cfg.top_k if active_only else cfg.num_experts
        ffn = 3 * D * Fm * e + D * cfg.num_experts  # experts + router
    elif cfg.mlp == "gelu":
        ffn = 2 * D * F
    else:
        ffn = 3 * D * F
    per = attn + ffn
    if cfg.family == "hybrid":
        din, N = cfg.din, cfg.ssm_state
        H = din // cfg.ssm_head_dim
        per += D * (2 * din + 2 * N + H) + din * D
    total = n + L * per
    if cfg.is_encoder_decoder:
        total += cfg.encoder_layers * (attn + ffn) + L * (attn)  # cross attn
    return total
