"""Serving launcher: ``python -m repro.launch.serve --arch qwen2_1p5b``
— spins up the wave-batched engine on a reduced config and runs a
synthetic request burst, printing throughput/TTFT stats."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_config, reduced
from ..models.api import build_model
from ..serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1p5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("serve launcher demo targets token-input archs")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    eng = ServeEngine(model, params, max_batch=args.batch,
                      max_seq=args.prompt_len + args.max_new,
                      temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [Request(tokens=rng.integers(
        0, cfg.vocab_size, args.prompt_len).astype(np.int32),
        max_new_tokens=args.max_new) for _ in range(args.requests)]
    stats = eng.serve(reqs)
    print({k: (round(v, 4) if isinstance(v, float) else v)
           for k, v in stats.items()})


if __name__ == "__main__":
    main()
