"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — device count is locked on first
jax init, and only launch/dryrun.py may set the 512-device XLA flag.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
