import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count on first init). Everything else follows.
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, cache_specs, get_config,  # noqa: E402
                           input_specs, supports)
from repro.launch import roofline as rl                      # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models.api import build_model                     # noqa: E402
from repro.optim.adafactor import adafactor                  # noqa: E402
from repro.optim.adamw import adamw                          # noqa: E402
from repro.sharding.specs import (batch_shardings,           # noqa: E402
                                  cache_shardings, replicated,
                                  tree_shardings)
from repro.train.step import make_train_step                 # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _save_hlo(arch, shape_name, multi_pod, hlo_text):
    """Compressed HLO next to the JSON so measurement improvements can
    reprocess offline without recompiling."""
    try:
        import zstandard as zstd
        m = "multipod" if multi_pod else "pod"
        out = RESULTS / f"{arch}.{shape_name}.{m}.hlo.zst"
        out.write_bytes(zstd.ZstdCompressor(level=9).compress(
            hlo_text.encode()))
    except Exception as e:  # noqa: BLE001 — HLO capture is best-effort
        print(f"[warn] hlo save failed: {e}")


def make_optimizer(cfg):
    if cfg.optimizer == "adafactor":
        return adafactor(lr=1e-3)
    return adamw(lr=3e-4, state_dtype="bfloat16")


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = supports(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    param_sds = model.param_specs()
    param_sh = tree_shardings(param_sds, mesh)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt = make_optimizer(cfg)
            opt_sds = jax.eval_shape(opt.init, param_sds)
            opt_sh = tree_shardings(opt_sds, mesh)
            batch_sh = batch_shardings(specs["batch"], mesh)
            import jax.numpy as jnp
            step = make_train_step(
                model, opt, micro_batches=cfg.micro_batches,
                accum_dtype=jnp.dtype(cfg.grad_accum_dtype))
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),   # params/opt update in place
            ).lower(param_sds, opt_sds, specs["batch"])
        elif shape.kind == "prefill":
            batch_sh = batch_shardings(specs["batch"], mesh)

            def serve_prefill(params, batch):
                return model.prefill(params, batch)

            lowered = jax.jit(
                serve_prefill, in_shardings=(param_sh, batch_sh),
            ).lower(param_sds, specs["batch"])
        else:  # decode
            cache_sds = specs["cache"]
            cache_sh = cache_shardings(cache_sds, mesh)
            tok_sh = batch_shardings(specs["token"], mesh)

            def serve_decode(params, cache, token, length):
                return model.decode_step(params, cache, token, length)

            lowered = jax.jit(
                serve_decode,
                in_shardings=(param_sh, cache_sh, tok_sh, replicated(mesh)),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),     # KV cache updates in place
            ).lower(param_sds, cache_sds, specs["token"], specs["length"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(mem)                      # proves it fits
    cost = compiled.cost_analysis()
    print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
    hlo = compiled.as_text()
    _save_hlo(arch, shape_name, multi_pod, hlo)
    coll = rl.collective_bytes(hlo)
    chips = int(np.prod(mesh.devices.shape))
    upcast = rl.cpu_upcast_estimate(cfg, chips)
    terms = rl.roofline_terms(cost, coll, chips, cfg, shape)
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": peak,
            "cpu_bf16_upcast_estimate_bytes": upcast,
            "peak_tpu_estimate_bytes": max(peak - upcast, 0),
            "fits_16g_hbm": bool(max(peak - upcast, 0) <= 16 * 1024**3),
        },
        "collectives": coll,
        "roofline": terms,
    }


def cell_path(arch, shape_name, multi_pod, tag=""):
    m = "multipod" if multi_pod else "pod"
    t = f".{tag}" if tag else ""
    return RESULTS / f"{arch}.{shape_name}.{m}{t}.json"


def run_cell(arch, shape_name, multi_pod, force=False, tag=""):
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = cell_path(arch, shape_name, multi_pod, tag)
    if out.exists() and not force:
        rec = json.loads(out.read_text())
        print(f"[cached] {out.name}: {rec.get('status')}")
        return rec
    print(f"=== {arch} x {shape_name} x "
          f"{'multipod' if multi_pod else 'singlepod'} ===", flush=True)
    try:
        rec = lower_cell(arch, shape_name, multi_pod)
    except Exception as e:  # noqa: BLE001 — recorded, dry-run must continue
        rec = {"status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    rec.setdefault("arch", arch)
    rec.setdefault("shape", shape_name)
    rec["multi_pod"] = multi_pod
    out.write_text(json.dumps(rec, indent=1))
    print(f"[{rec['status']}] {out.name}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    summary = {"ok": 0, "skipped": 0, "error": 0}
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, force=args.force,
                               tag=args.tag)
                summary[rec["status"]] = summary.get(rec["status"], 0) + 1
    print("SUMMARY:", summary)
    if summary.get("error"):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
