"""Dense decoder-only transformer (qwen2 / internlm2 / chatglm3 /
command-r / llava-mistral backbone).

Covers: GQA with arbitrary H:KH ratios, optional QKV bias, full/partial
RoPE, sliding-window attention, command-r parallel attn+FFN blocks,
RMSNorm/LayerNorm, gated-SiLU or GELU MLPs, tied or untied LM head.
Layers are scanned (stacked params). Exposes init/forward/loss/prefill/
decode_step used by train and serve steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import common as c


def _norm(cfg, x, lp, name):
    if cfg.norm == "layernorm":
        return c.layernorm(x, lp[name + "_g"], lp[name + "_b"], cfg.norm_eps)
    return c.rmsnorm(x, lp[name + "_g"], cfg.norm_eps)


def _norm_params(cfg, key, shape_prefix=()):
    g = jnp.ones(shape_prefix + (cfg.d_model,), c.dtype_of(cfg))
    out = {"_g": g}
    if cfg.norm == "layernorm":
        out["_b"] = jnp.zeros(shape_prefix + (cfg.d_model,), c.dtype_of(cfg))
    return out


def init_layer_params(cfg, key):
    dt = c.dtype_of(cfg)
    D, H, KH, hd, F = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
                       cfg.d_ff)
    ks = jax.random.split(key, 8)
    p = {
        "wq": c.dense_init(ks[0], D, H * hd, dt),
        "wk": c.dense_init(ks[1], D, KH * hd, dt),
        "wv": c.dense_init(ks[2], D, KH * hd, dt),
        "wo": c.dense_init(ks[3], H * hd, D, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KH * hd,), dt)
        p["bv"] = jnp.zeros((KH * hd,), dt)
    if cfg.mlp == "gelu":
        p["w_up"] = c.dense_init(ks[4], D, F, dt)
        p["b_up"] = jnp.zeros((F,), dt)
        p["w_down"] = c.dense_init(ks[5], F, D, dt)
        p["b_down"] = jnp.zeros((D,), dt)
    else:
        p["w_gate"] = c.dense_init(ks[4], D, F, dt)
        p["w_up"] = c.dense_init(ks[5], D, F, dt)
        p["w_down"] = c.dense_init(ks[6], F, D, dt)
    for nm, k2 in [("ln1", ks[7])]:
        for suffix, v in _norm_params(cfg, k2).items():
            p[nm + suffix] = v
    if not cfg.parallel_block:
        for suffix, v in _norm_params(cfg, ks[7]).items():
            p["ln2" + suffix] = v
    return p


def init_params(cfg, key):
    dt = c.dtype_of(cfg)
    k1, k2, k3, kl = jax.random.split(key, 4)
    layers = jax.vmap(lambda k: init_layer_params(cfg, k))(
        jax.random.split(kl, cfg.num_layers))
    p = {
        "embed": c.embed_init(k1, cfg.vocab_padded, cfg.d_model, dt),
        "lm_head": c.dense_init(k2, cfg.d_model, cfg.vocab_padded, dt),
        "layers": layers,
    }
    for suffix, v in _norm_params(cfg, k3).items():
        p["ln_f" + suffix] = v
    return p


def _rotary_dim(cfg):
    rd = int(cfg.hd * cfg.rotary_pct)
    return rd - (rd % 2)


def _qkv(cfg, lp, h, positions, inv_freq):
    B, S, D = h.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KH, hd)
    v = v.reshape(B, S, KH, hd)
    rd = _rotary_dim(cfg)
    if rd:
        q = c.apply_rope(q, positions, inv_freq, rd)
        k = c.apply_rope(k, positions, inv_freq, rd)
    return q, k, v


def _mlp(cfg, lp, h):
    if cfg.mlp == "gelu":
        return c.gelu_mlp(h, lp["w_up"], lp["b_up"], lp["w_down"],
                          lp["b_down"])
    return c.gated_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"])


def make_layer_fn(cfg, collect_kv: bool):
    inv_freq = c.rope_freqs(cfg.hd, cfg.rope_base, _rotary_dim(cfg) or None)
    window = cfg.sliding_window or None

    def layer(x, lp, positions):
        h = _norm(cfg, x, lp, "ln1")
        q, k, v = _qkv(cfg, lp, h, positions, inv_freq)
        attn = c.blockwise_attention(q, k, v, causal=True, window=window)
        B, S = x.shape[:2]
        attn_out = attn.reshape(B, S, -1) @ lp["wo"]
        if cfg.parallel_block:        # command-r: attn & FFN from same norm
            x = x + attn_out + _mlp(cfg, lp, h)
        else:
            x = x + attn_out
            h2 = _norm(cfg, x, lp, "ln2")
            x = x + _mlp(cfg, lp, h2)
        return (x, (k, v)) if collect_kv else (x, None)

    return layer


def backbone(cfg, params, x, positions, collect_kv=False):
    layer = make_layer_fn(cfg, collect_kv)

    def body(xc, lp):
        return layer(xc, lp, positions)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, kv = jax.lax.scan(body, x, params["layers"])
    x = _norm(cfg, x, params, "ln_f")
    return x, kv


def embed_input(cfg, params, batch):
    if "embeds" in batch:
        return c.constrain_act(batch["embeds"].astype(c.dtype_of(cfg)))
    return c.constrain_act(params["embed"][batch["tokens"]])


def forward(cfg, params, batch):
    x = embed_input(cfg, params, batch)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, _ = backbone(cfg, params, x, positions)
    return c.constrain_logits(x @ params["lm_head"])


def loss_fn(cfg, params, batch):
    logits = forward(cfg, params, batch)
    return c.cross_entropy(logits, batch["labels"], cfg.vocab_size)


def prefill(cfg, params, batch):
    """Full-sequence pass collecting the KV cache."""
    x = embed_input(cfg, params, batch)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, kv = backbone(cfg, params, x, positions, collect_kv=True)
    k, v = kv                      # (L, B, S, KH, hd)
    cdt = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
    logits_last = c.constrain_logits(x[:, -1:] @ params["lm_head"])
    return {"k": k.astype(cdt), "v": v.astype(cdt)}, logits_last


def decode_step(cfg, params, cache, token, length):
    """One token with a KV cache (written at position ``length``)."""
    inv_freq = c.rope_freqs(cfg.hd, cfg.rope_base, _rotary_dim(cfg) or None)
    window = cfg.sliding_window or None
    x = params["embed"][token]                       # (B, 1, D)
    B = x.shape[0]
    pos = jnp.full((B, 1), length, jnp.int32)

    def body(xc, scans):
        lp, kc, vc = scans
        h = _norm(cfg, xc, lp, "ln1")
        q, k, v = _qkv(cfg, lp, h, pos, inv_freq)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                 length, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                 length, axis=1)
        attn = c.decode_attention(q, kc, vc, length + 1, window=window)
        attn_out = attn.reshape(B, 1, -1) @ lp["wo"]
        if cfg.parallel_block:
            xc = xc + attn_out + _mlp(cfg, lp, h)
        else:
            xc = xc + attn_out
            h2 = _norm(cfg, xc, lp, "ln2")
            xc = xc + _mlp(cfg, lp, h2)
        return xc, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                               cache["v"]))
    x = _norm(cfg, x, params, "ln_f")
    logits = c.constrain_logits(x @ params["lm_head"])
    return logits, {"k": k_new, "v": v_new}
