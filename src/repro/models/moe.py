"""Mixture-of-Experts transformer (kimi-k2 / granite-moe).

Attention blocks are shared with models.transformer; the FFN is a top-k
routed expert layer with sort-based (one-hot-free) dispatch.

Two dispatch modes:
  * "dense"     — uniform capacity per expert (GShard/Switch style).
  * "biglittle" — the paper's heterogeneous-pipeline idea applied to
    experts: expert load under top-k routing is power-law (same skew the
    paper exploits in graph partitions). Experts are offline-relabelled
    by historical load (the DBG analogue), the first n_hot experts get
    Little treatment (large capacity, long regular batches) and the tail
    gets Big treatment (small capacity, compacted batch), cutting padded
    FLOPs/memory vs. provisioning every expert for the worst case. The
    split (n_hot, C_hot, C_cold) comes from models.moe_schedule — the
    model-guided scheduling analogue.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import common as c
from . import transformer as tfm


def init_layer_params(cfg, key):
    dt = c.dtype_of(cfg)
    D, E, F = cfg.d_model, cfg.num_experts_padded, cfg.moe_d_ff or cfg.d_ff
    p = tfm.init_layer_params(cfg, key)
    for nm in ("w_gate", "w_up", "w_down", "b_up", "b_down"):
        p.pop(nm, None)
    ks = jax.random.split(jax.random.fold_in(key, 17), 4)
    p["router"] = c.dense_init(ks[0], D, E, jnp.float32)
    p["we_gate"] = c.dense_init(ks[1], D, F, dt) * jnp.ones((E, 1, 1), dt)
    p["we_up"] = c.dense_init(ks[2], D, F, dt) * jnp.ones((E, 1, 1), dt)
    p["we_down"] = c.dense_init(ks[3], F, D, dt) * jnp.ones((E, 1, 1), dt)
    return p


def init_params(cfg, key):
    p = tfm.init_params(cfg, key)
    kl = jax.random.fold_in(key, 3)
    p["layers"] = jax.vmap(lambda k: init_layer_params(cfg, k))(
        jax.random.split(kl, cfg.num_layers))
    return p


# ---------------------------------------------------------------------------
# sort-based dispatch
# ---------------------------------------------------------------------------

def _ranks_in_expert(sorted_e):
    """rank of each sorted element within its expert segment."""
    n = sorted_e.shape[0]
    ar = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_e[1:] != sorted_e[:-1]])
    start = jnp.where(is_start, ar, 0)
    start = jax.lax.associative_scan(jnp.maximum, start)
    return ar - start


def _route(x, router_w, top_k, e_real=None):
    logits = (x.astype(jnp.float32) @ router_w)          # (T, E_pad)
    if e_real is not None and e_real < router_w.shape[1]:
        eid = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(eid < e_real, logits, -1e30)
    gw, gi = jax.lax.top_k(logits, top_k)
    gw = jax.nn.softmax(gw, axis=-1)
    # aux load-balance loss (Switch): E * mean(frac_tokens * frac_router)
    probs = jax.nn.softmax(logits, axis=-1)
    E = router_w.shape[1]
    frac_router = probs.mean(axis=0)
    hard = jnp.zeros((E,)).at[gi.reshape(-1)].add(1.0) / gi.size
    aux = E * jnp.sum(hard * frac_router)
    return gw, gi, aux


def _expert_ffn(buf, wg, wu, wd):
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) \
        * jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _dispatch_group(x, tok_id, sorted_e, rank, gatew, group_lo, group_hi,
                    cap, wg, wu, wd):
    """Dispatch+compute+combine for experts in [group_lo, group_hi) with
    uniform capacity ``cap``. The weight slices wg/wu/wd cover EXACTLY the
    group (pre-sliced — under shard_map they are the local shard).
    Returns (T, D) contribution."""
    T, D = x.shape
    n_exp = group_hi - group_lo
    in_group = (sorted_e >= group_lo) & (sorted_e < group_hi)
    keep = in_group & (rank < cap)
    slot = jnp.where(keep, (sorted_e - group_lo) * cap + rank, n_exp * cap)
    buf = jnp.zeros((n_exp * cap, D), x.dtype).at[slot].set(
        x[tok_id], mode="drop")
    y = _expert_ffn(buf.reshape(n_exp, cap, D), wg, wu, wd) \
        .reshape(n_exp * cap, D)
    gathered = jnp.where(keep[:, None], y[jnp.minimum(slot, n_exp * cap - 1)],
                         0.0)
    return jnp.zeros((T, D), x.dtype).at[tok_id].add(
        gathered * gatew[:, None], mode="drop")


def _moe_ffn_tokens(cfg, router, wg, wu, wd, x, r, e_per, n_model,
                    capacity_factor):
    """Dispatch a (T, D) token block against this rank's experts.

    Storage order is the offline load-based relabel (the DBG analogue)
    INTERLEAVED across ranks: rank r's local expert j has global load
    rank j*n_model + r, so with n_hot a multiple of n_model every rank
    owns exactly h_per = n_hot/n_model hot experts — a static, identical
    (hot block, cold block) buffer layout on every rank:

        [ h_per experts x C_hot | (e_per - h_per) experts x C_cold ]

    Hot experts ("Little": few, long regular batches) and cold experts
    ("Big": many, compact batches) each get their own einsum — the
    paper's two pipeline types at the expert level.
    """
    T, D = x.shape
    E, K = cfg.num_experts_padded, cfg.top_k
    gw, gi, aux = _route(x, router, K, cfg.num_experts)
    flat_e = gi.reshape(-1).astype(jnp.int32)            # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    rank = _ranks_in_expert(sorted_e)
    tok_id = (order // K).astype(jnp.int32)
    gatew = gw.reshape(-1)[order].astype(x.dtype)

    if cfg.moe_dispatch == "biglittle":
        from .moe_schedule import biglittle_split
        n_hot, c_hot, c_cold = biglittle_split(
            E, K, T, capacity_factor, round_to=n_model)
    else:
        n_hot, c_hot = 0, 8
        c_cold = max(8, int(T * K / E * capacity_factor))
    h_per = n_hot // n_model
    e_lo = r * e_per
    j = sorted_e - e_lo                      # local expert index
    in_rank = (j >= 0) & (j < e_per)
    is_hot = j < h_per
    cap_j = jnp.where(is_hot, c_hot, c_cold)
    off_j = jnp.where(is_hot, j * c_hot,
                      h_per * c_hot + (j - h_per) * c_cold)
    keep = in_rank & (rank < cap_j)
    bufsize = h_per * c_hot + (e_per - h_per) * c_cold
    slot = jnp.where(keep, off_j + rank, bufsize)
    buf = jnp.zeros((bufsize, D), x.dtype).at[slot].set(
        x[tok_id], mode="drop")
    y = jnp.zeros((bufsize, D), x.dtype)
    hb = h_per * c_hot
    if h_per > 0:                            # Little: hot experts
        y = y.at[:hb].set(_expert_ffn(
            buf[:hb].reshape(h_per, c_hot, D),
            wg[:h_per], wu[:h_per], wd[:h_per]).reshape(hb, D))
    if e_per > h_per:                        # Big: cold experts
        y = y.at[hb:].set(_expert_ffn(
            buf[hb:].reshape(e_per - h_per, c_cold, D),
            wg[h_per:], wu[h_per:], wd[h_per:]).reshape(bufsize - hb, D))
    gathered = jnp.where(keep[:, None], y[jnp.minimum(slot, bufsize - 1)],
                         0.0)
    out = jnp.zeros((T, D), x.dtype).at[tok_id].add(
        gathered * gatew[:, None], mode="drop")
    return out, aux


def _moe_ffn_tokens_fsharded(cfg, router, wg, wu, wd, x, capacity_factor):
    """Fallback when the model axis does not divide E_pad: every rank
    dispatches all experts, FFN dim sharded, partial outputs psum'd."""
    return _moe_ffn_tokens(cfg, router, wg, wu, wd, x,
                           jnp.int32(0), cfg.num_experts_padded, 1,
                           capacity_factor)


def moe_ffn(cfg, lp, h, capacity_factor=None):
    """h: (B, S, D) -> (out, aux_loss).

    Distribution: dispatch runs PER DATA SHARD inside shard_map (sort,
    ranks, scatter stay local — the global-token form materialises
    E*cap_global buffers, observed +100 GB/chip at 1M tokens). Experts
    shard on the "model" axis (each rank computes its expert slice for
    its local tokens, then psum over "model" — the Megatron-FFN combine;
    no all-to-all because activations are model-replicated).
    """
    capacity_factor = (cfg.capacity_factor if capacity_factor is None
                       else capacity_factor)
    B, S, D = h.shape
    E = cfg.num_experts_padded
    mesh = c._context_mesh()
    dp = tuple(a for a in ("pod", "data") if mesh and a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    n_dp = int(np.prod([sizes[a] for a in dp])) if dp else 1
    n_model = sizes.get("model", 1)
    expert_sharded = n_model > 1 and E % n_model == 0
    F = cfg.moe_d_ff or cfg.d_ff
    ffn_sharded = (not expert_sharded) and n_model > 1 and F % n_model == 0
    if mesh is None or B % max(n_dp, 1) != 0 or n_model == 1:
        x = h.reshape(B * S, D)
        out, aux = _moe_ffn_tokens(cfg, lp["router"], lp["we_gate"],
                                   lp["we_up"], lp["we_down"], x,
                                   jnp.int32(0), E, 1, capacity_factor)
        return out.reshape(B, S, D), aux

    from jax.sharding import PartitionSpec as P
    dp_spec = dp if len(dp) > 1 else dp[0]
    if expert_sharded:
        w_spec, wd_spec = P("model", None, None), P("model", None, None)
    elif ffn_sharded:
        w_spec, wd_spec = P(None, None, "model"), P(None, "model", None)
    else:
        w_spec, wd_spec = P(None, None, None), P(None, None, None)

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(P(dp_spec, None, None), P(None, None),
                       w_spec, w_spec, wd_spec),
             out_specs=(P(dp_spec, None, None), P()))
    def inner(h_loc, router, wg, wu, wd):
        bl, sl, _ = h_loc.shape
        x = h_loc.reshape(bl * sl, D)
        if expert_sharded:
            r = jax.lax.axis_index("model")
            out, aux = _moe_ffn_tokens(cfg, router, wg, wu, wd, x, r,
                                       E // n_model, n_model,
                                       capacity_factor)
        else:
            out, aux = _moe_ffn_tokens_fsharded(cfg, router, wg, wu, wd, x,
                                                capacity_factor)
        out = jax.lax.psum(out, "model")
        if dp:
            aux = jax.lax.pmean(aux, dp)
        aux = jax.lax.pmean(aux, "model")
        return out.reshape(bl, sl, D), aux

    return inner(h, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"])


def make_layer_fn(cfg, collect_kv: bool):
    inv_freq = c.rope_freqs(cfg.hd, cfg.rope_base,
                            tfm._rotary_dim(cfg) or None)
    window = cfg.sliding_window or None

    def layer(carry, lp, positions):
        x, aux_acc = carry
        h = _n = tfm._norm(cfg, x, lp, "ln1")
        q, k, v = tfm._qkv(cfg, lp, h, positions, inv_freq)
        attn = c.blockwise_attention(q, k, v, causal=True, window=window)
        B, S = x.shape[:2]
        x = x + attn.reshape(B, S, -1) @ lp["wo"]
        h2 = tfm._norm(cfg, x, lp, "ln2")
        y, aux = moe_ffn(cfg, lp, h2)
        x = x + y
        return (x, aux_acc + aux), ((k, v) if collect_kv else None)

    return layer


def backbone(cfg, params, x, positions, collect_kv=False):
    layer = make_layer_fn(cfg, collect_kv)

    def body(carry, lp):
        return layer(carry, lp, positions)

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                params["layers"])
    x = tfm._norm(cfg, x, params, "ln_f")
    return x, aux, kv


def forward(cfg, params, batch):
    x = tfm.embed_input(cfg, params, batch)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux, _ = backbone(cfg, params, x, positions)
    return c.constrain_logits(x @ params["lm_head"]), aux


def loss_fn(cfg, params, batch, aux_weight=0.01):
    logits, aux = forward(cfg, params, batch)
    return c.cross_entropy(logits, batch["labels"], cfg.vocab_size) \
        + aux_weight * aux / cfg.num_layers


def prefill(cfg, params, batch):
    x = tfm.embed_input(cfg, params, batch)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, _, kv = backbone(cfg, params, x, positions, collect_kv=True)
    k, v = kv
    cdt = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
    return ({"k": k.astype(cdt), "v": v.astype(cdt)},
            c.constrain_logits(x[:, -1:] @ params["lm_head"]))


def decode_step(cfg, params, cache, token, length):
    inv_freq = c.rope_freqs(cfg.hd, cfg.rope_base,
                            tfm._rotary_dim(cfg) or None)
    x = params["embed"][token]
    B = x.shape[0]
    pos = jnp.full((B, 1), length, jnp.int32)

    def body(xc, scans):
        lp, kc, vc = scans
        h = tfm._norm(cfg, xc, lp, "ln1")
        q, k, v = tfm._qkv(cfg, lp, h, pos, inv_freq)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                 length, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                 length, axis=1)
        attn = c.decode_attention(q, kc, vc, length + 1)
        xc = xc + attn.reshape(B, 1, -1) @ lp["wo"]
        h2 = tfm._norm(cfg, xc, lp, "ln2")
        y, _ = moe_ffn(cfg, lp, h2)
        xc = xc + y
        return xc, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                               cache["v"]))
    x = tfm._norm(cfg, x, params, "ln_f")
    return c.constrain_logits(x @ params["lm_head"]), {"k": k_new, "v": v_new}
