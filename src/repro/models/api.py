"""Unified model API: build_model(cfg) → Model(init, forward, loss,
prefill, decode_step). Family dispatch:
  dense, vlm      → transformer (vlm consumes stubbed patch embeds)
  moe             → moe
  ssm             → mamba2
  hybrid          → hymba
  audio           → whisper (enc-dec; stubbed frame embeds)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from . import hymba, mamba2, moe, transformer, whisper

_FAMILY = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "ssm": mamba2,
    "hybrid": hymba,
    "audio": whisper,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable          # (rng) -> params
    forward: Callable       # (params, batch) -> logits
    loss: Callable          # (params, batch) -> scalar
    prefill: Callable       # (params, batch) -> (cache, last_logits)
    decode_step: Callable   # (params, cache, token, length) -> (logits, cache)

    def param_specs(self):
        """ShapeDtypeStruct pytree of params (no allocation)."""
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))


def build_model(cfg) -> Model:
    mod = _FAMILY[cfg.family]
    return Model(
        cfg=cfg,
        init=lambda rng: mod.init_params(cfg, rng),
        forward=lambda params, batch: _fwd(mod, cfg, params, batch),
        loss=lambda params, batch: mod.loss_fn(cfg, params, batch),
        prefill=lambda params, batch: mod.prefill(cfg, params, batch),
        decode_step=lambda params, cache, token, length:
            mod.decode_step(cfg, params, cache, token, length),
    )


def _fwd(mod, cfg, params, batch):
    out = mod.forward(cfg, params, batch)
    # moe.forward returns (logits, aux)
    return out[0] if isinstance(out, tuple) else out
