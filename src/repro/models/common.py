"""Shared model building blocks (pure functions over param pytrees).

Conventions:
  * params are nested dicts of jnp arrays; layer-stacked leaves carry a
    leading L axis and are consumed via jax.lax.scan (keeps HLO size and
    compile time flat in depth — essential for the 512-device dry-run).
  * dtype policy: params/activations in cfg.dtype (bf16 default), softmax
    and reductions in f32.
  * attention uses an online-softmax blockwise implementation (pure jnp
    scan — the same math as the Pallas flash kernel, used as its oracle
    and as the CPU/dry-run path).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# sharding constraints (no-ops outside a mesh context)
# ---------------------------------------------------------------------------

def _context_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def constrain(x, *spec):
    """with_sharding_constraint if a mesh is active and dims divide.
    Critically, its transpose constrains the *cotangent* too — GSPMD
    otherwise materialises unsharded logits cotangents in the backward
    (observed +28 GB/chip on the 256-chip mesh)."""
    mesh = _context_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for d, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in sizes)
        n = int(np.prod([sizes[a] for a in axes])) if axes else 1
        if axes and x.shape[d] % n == 0 and x.shape[d] >= n:
            fixed.append(axes if len(axes) > 1 else axes[0])
        else:
            fixed.append(None)
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*fixed)))


def constrain_logits(x):
    """(B, S, V) or (B, 1, V): batch over ("pod","data"), vocab on model."""
    return constrain(x, ("pod", "data"), None, "model")


def constrain_act(x):
    """(B, S, D): batch over ("pod","data")."""
    return constrain(x, ("pod", "data"), *([None] * (x.ndim - 1)))


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, v, d, dtype):
    return (jax.random.normal(key, (v, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, gamma, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * gamma


def layernorm(x, gamma, beta, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma + beta


# ---------------------------------------------------------------------------
# RoPE (standard / partial a.k.a. chatglm "2d" / none)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, base=10000.0, rotary_dim=None):
    rd = rotary_dim or head_dim
    inv = 1.0 / (base ** (np.arange(0, rd, 2, dtype=np.float32) / rd))
    return jnp.asarray(inv)  # (rd/2,)


def apply_rope(x, positions, inv_freq, rotary_dim=None):
    """x: (..., S, H, hd); positions: (..., S) int32. Rotates the first
    rotary_dim dims (partial rotary = chatglm3's 2D RoPE on half dims)."""
    hd = x.shape[-1]
    rd = rotary_dim or hd
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # (...,S,rd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rd].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    rot = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    if rd == hd:
        return rot
    return jnp.concatenate([rot, x[..., rd:]], axis=-1)


# ---------------------------------------------------------------------------
# blockwise causal attention (online softmax) — jnp flash
# ---------------------------------------------------------------------------

def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, hd)) \
        .reshape(b, s, kh * n_rep, hd)


def blockwise_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                        q_block=512, kv_block=512):
    """q,k,v: (B, S, H, hd) / (B, S, KH, hd) with H % KH == 0.
    Online-softmax over KV blocks: O(S·block) memory instead of O(S²).
    Sliding ``window`` (in tokens) skips KV blocks wholly outside range.

    Uses a flash-attention custom_vjp: the forward saves only (q,k,v,out,
    lse); the backward re-derives each P block inside its own scan step.
    Plain autodiff (even under jax.checkpoint) stacks every (q_block x
    kv_block) P matrix across BOTH block loops — O(S^2) residuals,
    observed +16 GB/chip on the 4k train cells of the 256-chip dry-run.
    """
    h = q.shape[2]
    kh = k.shape[2]
    k = _repeat_kv(k, h // kh)   # autodiff of the repeat sums dk over groups
    v = _repeat_kv(v, h // kh)
    return _flash(q, k, v, causal, window, q_block, kv_block)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, q_block, kv_block):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block)
    return out


def _block_mask(qi_ids, kj_ids, causal, window, sq, skv):
    mask = (kj_ids < skv) & (qi_ids < sq)
    if causal:
        mask &= kj_ids <= qi_ids
    if window is not None:
        mask &= kj_ids > qi_ids - window
    return mask


def _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block):
    b, sq, h, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    nq = -(-sq // q_block)
    skv = k.shape[1]
    nk = -(-skv // kv_block)
    pq, pk = nq * q_block - sq, nk * kv_block - skv
    qb = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) \
        .reshape(b, nq, q_block, h, hd).transpose(1, 0, 3, 2, 4)
    kb = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) \
        .reshape(b, nk, kv_block, h, hd).transpose(1, 0, 3, 2, 4)
    vb = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) \
        .reshape(b, nk, kv_block, h, hd).transpose(1, 0, 3, 2, 4)
    q_ids = jnp.arange(nq * q_block).reshape(nq, q_block)
    k_ids = jnp.arange(nk * kv_block).reshape(nk, kv_block)

    def per_qblock(qi, qblk):
        m0 = jnp.full((b, h, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        o0 = jnp.zeros((b, h, q_block, hd), jnp.float32)

        def body(carry, inp):
            m, l, o = carry
            kj, kblk, vblk = inp
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            mask = _block_mask(q_ids[qi][:, None], k_ids[kj][None, :],
                               causal, window, sq, skv)
            s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(mask[None, None], jnp.exp(s - m_safe[..., None]),
                          0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0),
                                    (jnp.arange(nk), kb, vb))
        o = o / jnp.maximum(l[..., None], 1e-20)
        lse = jnp.where(l > 0, jnp.where(jnp.isfinite(m), m, 0.0)
                        + jnp.log(jnp.maximum(l, 1e-20)), -jnp.inf)
        return o, lse

    out, lse = jax.lax.map(lambda t: per_qblock(t[0], t[1]),
                           (jnp.arange(nq), qb))
    # out: (nq, B, H, q_block, hd); lse: (nq, B, H, q_block)
    o_final = out.transpose(1, 0, 3, 2, 4).reshape(b, nq * q_block, h, hd)
    return o_final[:, :sq].astype(q.dtype), lse


def _flash_fwd(q, k, v, causal, window, q_block, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_block, kv_block, res, dout):
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    nq = -(-sq // q_block)
    nk = -(-skv // kv_block)
    pq, pk = nq * q_block - sq, nk * kv_block - skv

    def blockq(x):
        return jnp.pad(x, ((0, 0), (0, pq), (0, 0), (0, 0))) \
            .reshape(b, nq, q_block, h, hd).transpose(1, 0, 3, 2, 4)

    def blockk(x):
        return jnp.pad(x, ((0, 0), (0, pk), (0, 0), (0, 0))) \
            .reshape(b, nk, kv_block, h, hd).transpose(1, 0, 3, 2, 4)

    qb, dob, ob = blockq(q), blockq(dout), blockq(out)
    kb, vb = blockk(k), blockk(v)
    q_ids = jnp.arange(nq * q_block).reshape(nq, q_block)
    k_ids = jnp.arange(nk * kv_block).reshape(nk, kv_block)
    # D_i = rowsum(dout * out)
    Db = jnp.sum(dob.astype(jnp.float32) * ob.astype(jnp.float32), axis=-1)
    f32 = jnp.float32

    # Nested scans: one (q_block x kv_block) panel live at a time.
    # A single kv scan over ALL q blocks holds (nq,B,H,qb,kb) panels —
    # that is the full S x kv_block stripe (observed +17 GB/chip on the
    # hymba train cell). Operands stay bf16; f32 only via accumulation.
    def kv_body(dq_acc, kv_inp):
        kj, kblk, vblk = kv_inp

        def q_body(carry, q_inp):
            dk_j, dv_j = carry
            qi, qblk, doblk, D_i, lse_i = q_inp
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk,
                           preferred_element_type=f32) * scale
            mask = _block_mask(q_ids[qi][:, None], k_ids[kj][None, :],
                               causal, window, sq, skv)
            p = jnp.where(mask[None, None],
                          jnp.exp(s - lse_i[..., None]), 0.0)
            pb = p.astype(qblk.dtype)
            dv_j = dv_j + jnp.einsum("bhqk,bhqd->bhkd", pb, doblk,
                                     preferred_element_type=f32)
            dp = jnp.einsum("bhqd,bhkd->bhqk", doblk, vblk,
                            preferred_element_type=f32)
            ds = p * (dp - D_i[..., None]) * scale
            dsb = ds.astype(qblk.dtype)
            dq_i = jnp.einsum("bhqk,bhkd->bhqd", dsb, kblk,
                              preferred_element_type=f32)
            dk_j = dk_j + jnp.einsum("bhqk,bhqd->bhkd", dsb, qblk,
                                     preferred_element_type=f32)
            return (dk_j, dv_j), dq_i

        zero_kv = jnp.zeros((b, h, kv_block, hd), f32)
        (dk_j, dv_j), dq_contrib = jax.lax.scan(
            q_body, (zero_kv, zero_kv),
            (jnp.arange(nq), qb, dob, Db, lse))
        return dq_acc + dq_contrib, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, b, h, q_block, hd), f32)
    dq, (dk, dv) = jax.lax.scan(kv_body, dq0, (jnp.arange(nk), kb, vb))

    def unblockq(x):
        return x.transpose(1, 0, 3, 2, 4).reshape(b, nq * q_block, h, hd)[
            :, :sq]

    def unblockk(x):
        return x.transpose(1, 0, 3, 2, 4).reshape(b, nk * kv_block, h, hd)[
            :, :skv]

    return (unblockq(dq).astype(q.dtype), unblockk(dk).astype(k.dtype),
            unblockk(dv).astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)



def decode_attention(q, k_cache, v_cache, length, *, window=None):
    """Single-token attention against a cache.
    q: (B, 1, H, hd); caches: (B, S_max, KH, hd); length: current length
    (int32 scalar or (B,) vector) — positions >= length are masked.

    GQA is computed with grouped einsums directly against the (KH)-headed
    cache: materialising the H-repeated (or f32-upcast) cache costs
    2 x (H/KH) x cache bytes of temp — observed +25 GB/chip on the
    kimi decode_32k cell. preferred_element_type keeps the f32 accumulate
    without an f32 copy of the cache."""
    b, one, h, hd = q.shape
    kh = k_cache.shape[2]
    rep = h // kh
    qg = q.reshape(b, one, kh, rep, hd)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqkrd,bskd->bkrqs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[1])
    ln = jnp.asarray(length)
    ln = ln[:, None, None, None, None] if ln.ndim else ln
    mask = pos[None, None, None, None, :] < ln
    if window is not None:
        mask &= pos[None, None, None, None, :] >= (ln - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrqs,bskd->bqkrd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, one, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def gated_mlp(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = jax.nn.gelu(x @ w_up + b_up)
    return h @ w_down + b_down


def cross_entropy(logits, labels, vocab_real: Optional[int] = None):
    """Mean CE in f32; labels < 0 masked; vocab padding masked.

    Sharding-preserving formulation: the label log-prob is extracted with
    a masked one-hot reduction (elementwise compare + sum) instead of
    take_along_axis — a vocab-dim gather would force GSPMD to all-gather
    the full-vocab logits on every chip (observed: +13 GB/chip temp on a
    256-way mesh). Elementwise + reduce keeps the vocab axis sharded.
    """
    lf = logits.astype(jnp.float32)
    vid = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    if vocab_real is not None and vocab_real < lf.shape[-1]:
        lf = jnp.where(vid < vocab_real, lf, -1e30)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.sum(jnp.where(vid == jnp.maximum(labels, 0)[..., None], lf, 0.0),
                 axis=-1)
    mask = labels >= 0
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
