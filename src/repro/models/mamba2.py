"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060).

Chunked SSD forward: the sequence is split into chunks; within a chunk
the quadratic dual form runs on the MXU, between chunks the SSM state
(B, H, P, N) is passed through a lax.scan — O(S) memory, O(S·Q) compute.
Decode is the O(1) recurrent step. Attention-free (no KV cache); the
long_500k cell runs on this family.

Shapes: d_inner = expansion (cfg.din), P = ssm_head_dim, H = din/P heads,
N = ssm_state. B/C are shared across heads (ngroups=1, as in the paper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import common as c

CONV_K = 4
CHUNK = 128


def _dims(cfg):
    din = cfg.din
    H = din // cfg.ssm_head_dim
    return din, H, cfg.ssm_head_dim, cfg.ssm_state


def init_layer_params(cfg, key):
    dt = c.dtype_of(cfg)
    D = cfg.d_model
    din, H, P, N = _dims(cfg)
    conv_dim = din + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": c.dense_init(ks[0], D, 2 * din + 2 * N + H, dt),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, conv_dim)) * 0.2
                   ).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_g": jnp.ones((din,), dt),
        "ln_g": jnp.ones((D,), dt),
        "out_proj": c.dense_init(ks[2], din, D, dt),
    }


def init_params(cfg, key):
    dt = c.dtype_of(cfg)
    k1, k2, k3, kl = jax.random.split(key, 4)
    return {
        "embed": c.embed_init(k1, cfg.vocab_padded, cfg.d_model, dt),
        "lm_head": c.dense_init(k2, cfg.d_model, cfg.vocab_padded, dt),
        "ln_f_g": jnp.ones((cfg.d_model,), dt),
        "layers": jax.vmap(lambda k: init_layer_params(cfg, k))(
            jax.random.split(kl, cfg.num_layers)),
    }


def _split_proj(cfg, zxbcdt):
    din, H, P, N = _dims(cfg)
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din:2 * din + 2 * N]
    dt_raw = zxbcdt[..., 2 * din + 2 * N:]
    return z, xBC, dt_raw


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, kernel CONV_K. xBC: (B, S, C)."""
    pads = jnp.pad(xBC, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + xBC.shape[1]] * w[i] for i in range(CONV_K))
    return jax.nn.silu(out + b)


def ssd_chunked(cfg, x, Bm, Cm, dt, A, D, h0=None):
    """Chunked SSD scan.
    x: (B,S,H,P); Bm,Cm: (B,S,N); dt: (B,S,H) (post-softplus); A: (H,)<0.
    Returns y (B,S,H,P), final state (B,H,P,N)."""
    b, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(CHUNK, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(b, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    Bc = Bm.reshape(b, nc, Q, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(b, nc, Q, N).transpose(1, 0, 2, 3)
    dtc = dt.reshape(b, nc, Q, H).transpose(1, 0, 2, 3)
    h_init = (jnp.zeros((b, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def chunk_body(h, inp):
        xq, Bq, Cq, dtq = inp                     # (B,Q,...)
        dA = dtq * A                              # (B,Q,H) negative
        a_cum = jnp.cumsum(dA, axis=1)            # (B,Q,H)
        # intra-chunk dual (quadratic) form
        G = jnp.einsum("bqn,bkn->bqk", Cq.astype(jnp.float32),
                       Bq.astype(jnp.float32))    # (B,Q,Q)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        # mask the exponent BEFORE exp: the i>j half would overflow to inf
        # and poison the backward via inf*0=NaN cotangents
        delta = a_cum[:, :, None, :] - a_cum[:, None, :, :]
        delta = jnp.where(mask[None, :, :, None], delta, -1e30)
        decay = jnp.exp(delta)
        M = G[..., None] * decay * dtq[:, None, :, :]  # (B,Q,K,H)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", M,
                             xq.astype(jnp.float32))
        # inter-chunk from carried state
        y_inter = jnp.einsum("bqn,bhpn->bqhp", Cq.astype(jnp.float32), h) \
            * jnp.exp(a_cum)[..., None]
        # state update
        w = dtq * jnp.exp(a_cum[:, -1:, :] - a_cum)      # (B,Q,H)
        h_new = h * jnp.exp(a_cum[:, -1])[:, :, None, None] \
            + jnp.einsum("bkh,bkn,bkhp->bhpn", w,
                         Bq.astype(jnp.float32), xq.astype(jnp.float32))
        return h_new, (y_intra + y_inter)

    h_fin, ys = jax.lax.scan(chunk_body, h_init, (xc, Bc, Cc, dtc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * Q, H, P)[:, :S]
    y = y + D[None, None, :, None] * x[:, :S].astype(jnp.float32)
    return y, h_fin


def layer_forward(cfg, lp, x, h0=None, conv0=None, return_state=False):
    """One mamba2 block. x: (B,S,D)."""
    din, H, P, N = _dims(cfg)
    B, S, D = x.shape
    hid = c.rmsnorm(x, lp["ln_g"], cfg.norm_eps)
    zxbcdt = hid @ lp["in_proj"]
    z, xBC_raw, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC_raw, lp["conv_w"], lp["conv_b"])
    xs = xBC[..., :din].reshape(B, S, H, P)
    Bm = xBC[..., din:din + N]
    Cm = xBC[..., din + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])
    y, h_fin = ssd_chunked(cfg, xs, Bm, Cm, dt, A, lp["D"], h0)
    y = y.reshape(B, S, din).astype(x.dtype)
    y = c.rmsnorm(y, lp["norm_g"], cfg.norm_eps) * jax.nn.silu(z)
    out = x + y @ lp["out_proj"]
    if return_state:
        tail = jnp.zeros((B, CONV_K, din + 2 * N), x.dtype)
        take = min(CONV_K, S)
        tail = tail.at[:, -take:].set(xBC_raw[:, -take:])
        return out, h_fin, tail
    return out


def backbone(cfg, params, x, collect_state=False):
    def body(xc, lp):
        if collect_state:
            out, h, conv = layer_forward(cfg, lp, xc, return_state=True)
            return out, (h, conv)
        return layer_forward(cfg, lp, xc), None

    f = jax.checkpoint(body) if cfg.remat else body
    x, states = jax.lax.scan(f, x, params["layers"])
    x = c.rmsnorm(x, params["ln_f_g"], cfg.norm_eps)
    return x, states


def forward(cfg, params, batch):
    x = c.constrain_act(params["embed"][batch["tokens"]])
    x, _ = backbone(cfg, params, x)
    return c.constrain_logits(x @ params["lm_head"])


def loss_fn(cfg, params, batch):
    return c.cross_entropy(forward(cfg, params, batch), batch["labels"],
                           cfg.vocab_size)


def prefill(cfg, params, batch):
    x = params["embed"][batch["tokens"]]
    x, (h, conv) = backbone(cfg, params, x, collect_state=True)
    logits = c.constrain_logits(x[:, -1:] @ params["lm_head"])
    return {"ssm_state": h, "conv_state": conv}, logits


def decode_step(cfg, params, cache, token, length):
    """O(1) recurrent step. cache: ssm_state (L,B,H,P,N),
    conv_state (L,B,CONV_K,conv_dim) holding the last raw xBC inputs."""
    del length
    din, H, P, N = _dims(cfg)
    x = params["embed"][token]                  # (B,1,D)
    B = x.shape[0]

    def body(xc, scans):
        lp, h, conv = scans
        hid = c.rmsnorm(xc, lp["ln_g"], cfg.norm_eps)
        zxbcdt = hid @ lp["in_proj"]
        z, xBC_raw, dt_raw = _split_proj(cfg, zxbcdt)
        conv = jnp.concatenate([conv[:, 1:], xBC_raw], axis=1)
        xBC = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv, lp["conv_w"])
                          + lp["conv_b"])[:, None]
        xs = xBC[..., :din].reshape(B, H, P)
        Bm = xBC[..., din:din + N][:, 0]
        Cm = xBC[..., din + N:][:, 0]
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + lp["dt_bias"])   # (B,H)
        A = -jnp.exp(lp["A_log"])
        dA = jnp.exp(dt * A)                    # (B,H)
        h = h * dA[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32),
            xs.astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h) \
            + lp["D"][None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(B, 1, din).astype(xc.dtype)
        y = c.rmsnorm(y, lp["norm_g"], cfg.norm_eps) * jax.nn.silu(z)
        return xc + y @ lp["out_proj"], (h, conv)

    x, (h_new, conv_new) = jax.lax.scan(
        body, x, (params["layers"], cache["ssm_state"], cache["conv_state"]))
    x = c.rmsnorm(x, params["ln_f_g"], cfg.norm_eps)
    return c.constrain_logits(x @ params["lm_head"]), {"ssm_state": h_new,
                                   "conv_state": conv_new}
