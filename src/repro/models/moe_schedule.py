"""Model-guided big-little expert scheduling (the paper's §IV for MoE).

Expert load under learned top-k routing is empirically Zipf-like — the
same skew ReGraph exploits in graph partitions. Given the expert count,
top-k, token count and a Zipf exponent (measurable online; default from
published MoE load traces), choose (n_hot, C_hot, C_cold) minimising the
padded-token compute volume subject to an expected-drop-rate budget —
the analogue of minimising the worst cluster time in Eq. (5)-(6).

The split is *static* per deployment (experts are offline-relabelled by
historical load — the DBG analogue), so the dispatch stays shape-static
and TPU-friendly.
"""
from __future__ import annotations

import functools

import numpy as np


@functools.lru_cache(maxsize=None)
def zipf_loads(num_experts: int, exponent: float = 0.8) -> tuple:
    """Expected per-expert load fractions, descending (relabelled)."""
    r = np.arange(1, num_experts + 1, dtype=np.float64)
    w = r ** (-exponent)
    return tuple(w / w.sum())


@functools.lru_cache(maxsize=None)
def biglittle_split(num_experts: int, top_k: int, tokens: int,
                    capacity_factor: float = 1.25,
                    zipf_exponent: float = 0.8,
                    drop_budget: float = 0.02,
                    round_to: int = 1) -> tuple:
    """Return (n_hot, C_hot, C_cold).

    Uniform dispatch pads every expert to C_uni = T*k/E * cf. With skewed
    load, hot experts need MORE than C_uni (they drop tokens) while cold
    experts waste padding. We pick the split minimising total buffer size
    n_hot*C_hot + (E-n_hot)*C_cold with C_hot sized for the max hot load
    and C_cold for the max cold load (both + cf headroom), subject to the
    expected drop fraction staying under budget.

    ``round_to``: n_hot is rounded UP to a multiple (the model-axis size)
    so hot experts interleave evenly across expert-sharded ranks and every
    rank gets an identical static (hot, cold) buffer layout.
    """
    loads = np.asarray(zipf_loads(num_experts, zipf_exponent))
    total_assign = tokens * top_k
    best = None
    c_uni = max(1, int(total_assign / num_experts * capacity_factor))
    for n_hot in range(round_to, num_experts, round_to):
        c_hot = int(np.ceil(loads[0] * total_assign * capacity_factor))
        c_cold = max(1, int(np.ceil(loads[n_hot] * total_assign
                                    * capacity_factor)))
        # expected drops: load beyond capacity
        exp_tok = loads * total_assign
        cap = np.where(np.arange(num_experts) < n_hot, c_hot, c_cold)
        dropped = np.maximum(exp_tok - cap, 0.0).sum() / total_assign
        if dropped > drop_budget:
            continue
        size = n_hot * c_hot + (num_experts - n_hot) * c_cold
        if best is None or size < best[0]:
            best = (size, n_hot, c_hot, c_cold)
    if best is None:  # fall back to uniform
        return num_experts, c_uni, c_uni
    _, n_hot, c_hot, c_cold = best
    # round capacities to MXU-friendly multiples of 8
    rnd = lambda c: max(8, int(-(-c // 8) * 8))
    return n_hot, rnd(c_hot), rnd(c_cold)


def padded_flops_ratio(num_experts: int, top_k: int, tokens: int,
                       capacity_factor: float = 1.25,
                       zipf_exponent: float = 0.8,
                       drop_budget: float = 0.02) -> dict:
    """Napkin-math comparison used by benchmarks and EXPERIMENTS.md.

    The fair baseline is uniform capacity sized for the SAME drop budget
    (i.e. every expert provisioned like the hottest one — exactly the
    paper's monolithic-pipeline over-provisioning argument). The cheap
    uniform (cf * mean load) is also reported with its drop rate.
    """
    loads = np.asarray(zipf_loads(num_experts, zipf_exponent))
    total = tokens * top_k
    n_hot, c_hot, c_cold = biglittle_split(
        num_experts, top_k, tokens, capacity_factor, zipf_exponent,
        drop_budget)
    uni_cheap = max(1, int(total / num_experts * capacity_factor))
    drop_cheap = float(np.maximum(loads * total - uni_cheap, 0).sum()
                       / total)
    uni_matched = int(np.ceil(loads[0] * total * capacity_factor))
    size_uni_matched = num_experts * uni_matched
    size_bl = n_hot * c_hot + (num_experts - n_hot) * c_cold
    drop_bl = float(np.maximum(
        loads * total - np.where(np.arange(num_experts) < n_hot,
                                 c_hot, c_cold), 0).sum() / total)
    return {
        "n_hot": n_hot, "c_hot": c_hot, "c_cold": c_cold,
        "uniform_capacity_cheap": uni_cheap,
        "uniform_cheap_drop_rate": drop_cheap,
        "uniform_capacity_drop_matched": uni_matched,
        "padded_tokens_uniform_matched": size_uni_matched,
        "padded_tokens_biglittle": size_bl,
        "biglittle_drop_rate": drop_bl,
        "flops_ratio_vs_matched": size_bl / size_uni_matched,
    }
