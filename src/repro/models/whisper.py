"""Whisper (enc-dec, arXiv:2212.04356) — transformer backbone only.

The conv frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S_enc, d_model); the encoder is
non-causal self-attention over them, the decoder is causal self-attention
+ cross-attention. LayerNorm + GELU MLPs, sinusoidal positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import common as c
from . import transformer as tfm


def sinusoid_pos(S, D, dtype):
    pos = np.arange(S)[:, None]
    dim = np.arange(0, D, 2)[None, :]
    ang = pos / np.power(10000.0, dim / D)
    out = np.zeros((S, D), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang[:, : out[:, 1::2].shape[1]])
    return jnp.asarray(out, dtype)


def init_enc_layer(cfg, key):
    # reuse dense layer params (self-attn + mlp)
    return tfm.init_layer_params(cfg, key)


def init_dec_layer(cfg, key):
    dt = c.dtype_of(cfg)
    D, H, KH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    p = tfm.init_layer_params(cfg, key)
    ks = jax.random.split(jax.random.fold_in(key, 31), 4)
    p.update({
        "xq": c.dense_init(ks[0], D, H * hd, dt),
        "xk": c.dense_init(ks[1], D, KH * hd, dt),
        "xv": c.dense_init(ks[2], D, KH * hd, dt),
        "xo": c.dense_init(ks[3], H * hd, D, dt),
        "lnx_g": jnp.ones((D,), dt),
        "lnx_b": jnp.zeros((D,), dt),
    })
    return p


def init_params(cfg, key):
    dt = c.dtype_of(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "embed": c.embed_init(k1, cfg.vocab_padded, cfg.d_model, dt),
        "lm_head": c.dense_init(k2, cfg.d_model, cfg.vocab_padded, dt),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(cfg, k))(
            jax.random.split(k3, cfg.encoder_layers)),
        "layers": jax.vmap(lambda k: init_dec_layer(cfg, k))(
            jax.random.split(k4, cfg.num_layers)),
    }
    for nm in ("ln_enc", "ln_f"):
        p[nm + "_g"] = jnp.ones((cfg.d_model,), dt)
        p[nm + "_b"] = jnp.zeros((cfg.d_model,), dt)
    return p


def _self_attn(cfg, lp, h, causal, positions=None):
    B, S, D = h.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = (h @ lp["wq"]).reshape(B, S, H, hd)
    k = (h @ lp["wk"]).reshape(B, S, KH, hd)
    v = (h @ lp["wv"]).reshape(B, S, KH, hd)
    o = c.blockwise_attention(q, k, v, causal=causal)
    return o.reshape(B, S, -1) @ lp["wo"], (k, v)


def encode(cfg, params, enc_embeds):
    dt = c.dtype_of(cfg)
    B, S, D = enc_embeds.shape
    x = enc_embeds.astype(dt) + sinusoid_pos(S, D, dt)

    def body(xc, lp):
        h = c.layernorm(xc, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        a, _ = _self_attn(cfg, lp, h, causal=False)
        xc = xc + a
        h2 = c.layernorm(xc, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
        xc = xc + c.gelu_mlp(h2, lp["w_up"], lp["b_up"], lp["w_down"],
                             lp["b_down"])
        return xc, None

    f = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(f, x, params["enc_layers"])
    return c.layernorm(x, params["ln_enc_g"], params["ln_enc_b"],
                       cfg.norm_eps)


def _cross_kv(cfg, lp, enc_out):
    B, Se, D = enc_out.shape
    KH, hd = cfg.num_kv_heads, cfg.hd
    xk = (enc_out @ lp["xk"]).reshape(B, Se, KH, hd)
    xv = (enc_out @ lp["xv"]).reshape(B, Se, KH, hd)
    return xk, xv


def decode_stack(cfg, params, tokens, enc_out, collect_kv=False):
    dt = c.dtype_of(cfg)
    B, S = tokens.shape
    x = params["embed"][tokens] + sinusoid_pos(S, cfg.d_model, dt)
    H, hd = cfg.num_heads, cfg.hd

    def body(xc, lp):
        h = c.layernorm(xc, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        a, kv = _self_attn(cfg, lp, h, causal=True)
        xc = xc + a
        hx = c.layernorm(xc, lp["lnx_g"], lp["lnx_b"], cfg.norm_eps)
        q = (hx @ lp["xq"]).reshape(B, S, H, hd)
        xk, xv = _cross_kv(cfg, lp, enc_out)
        o = c.blockwise_attention(q, xk, xv, causal=False)
        xc = xc + o.reshape(B, S, -1) @ lp["xo"]
        h2 = c.layernorm(xc, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
        xc = xc + c.gelu_mlp(h2, lp["w_up"], lp["b_up"], lp["w_down"],
                             lp["b_down"])
        return xc, ((kv[0], kv[1], xk, xv) if collect_kv else None)

    f = jax.checkpoint(body) if cfg.remat else body
    x, kv = jax.lax.scan(f, x, params["layers"])
    return c.layernorm(x, params["ln_f_g"], params["ln_f_b"],
                       cfg.norm_eps), kv


def forward(cfg, params, batch):
    enc_out = encode(cfg, params, batch["enc_embeds"])
    x, _ = decode_stack(cfg, params, batch["tokens"], enc_out)
    return c.constrain_logits(x @ params["lm_head"])


def loss_fn(cfg, params, batch):
    return c.cross_entropy(forward(cfg, params, batch), batch["labels"],
                           cfg.vocab_size)


def prefill(cfg, params, batch):
    enc_out = encode(cfg, params, batch["enc_embeds"])
    x, kv = decode_stack(cfg, params, batch["tokens"], enc_out,
                         collect_kv=True)
    k, v, xk, xv = kv
    cache = {"k": k, "v": v, "cross_k": xk, "cross_v": xv}
    return cache, c.constrain_logits(x[:, -1:] @ params["lm_head"])


def decode_step(cfg, params, cache, token, length):
    dt = c.dtype_of(cfg)
    B = token.shape[0]
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    pos_tab = sinusoid_pos(cache["k"].shape[2] + 1, cfg.d_model, dt)
    x = params["embed"][token] + jax.lax.dynamic_slice_in_dim(
        pos_tab, length, 1, axis=0)[None]

    def body(xc, scans):
        lp, kc, vc, xk, xv = scans
        h = c.layernorm(xc, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        q = (h @ lp["wq"]).reshape(B, 1, H, hd)
        k = (h @ lp["wk"]).reshape(B, 1, KH, hd)
        v = (h @ lp["wv"]).reshape(B, 1, KH, hd)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                 length, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                 length, axis=1)
        a = c.decode_attention(q, kc, vc, length + 1)
        xc = xc + a.reshape(B, 1, -1) @ lp["wo"]
        hx = c.layernorm(xc, lp["lnx_g"], lp["lnx_b"], cfg.norm_eps)
        qx = (hx @ lp["xq"]).reshape(B, 1, H, hd)
        ox = c.decode_attention(qx, xk, xv, xk.shape[1])
        xc = xc + ox.reshape(B, 1, -1) @ lp["xo"]
        h2 = c.layernorm(xc, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
        xc = xc + c.gelu_mlp(h2, lp["w_up"], lp["b_up"], lp["w_down"],
                             lp["b_down"])
        return xc, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = c.layernorm(x, params["ln_f_g"], params["ln_f_b"], cfg.norm_eps)
    return c.constrain_logits(x @ params["lm_head"]), {"k": k_new, "v": v_new,
                                   "cross_k": cache["cross_k"],
                                   "cross_v": cache["cross_v"]}
