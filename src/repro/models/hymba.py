"""Hymba — hybrid-head architecture (arXiv:2411.13676).

Each layer runs attention heads and SSM (mamba2-style) heads in
*parallel* on the same normed input and fuses their outputs (here: mean
of the two projected streams — the paper fuses with learned per-head
scaling; documented simplification). Attention is sliding-window (the
paper keeps a few global layers; we use SWA everywhere, which is what
makes the long_500k cell sub-quadratic), SSM path is a conv-free SSD.

Decode state: right-aligned sliding KV window (pre-rotated keys) + SSM
state per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import common as c
from . import mamba2
from . import transformer as tfm


def _dims(cfg):
    din = cfg.din
    return din, din // cfg.ssm_head_dim, cfg.ssm_head_dim, cfg.ssm_state


def init_layer_params(cfg, key):
    dt = c.dtype_of(cfg)
    D = cfg.d_model
    din, H, P, N = _dims(cfg)
    p = tfm.init_layer_params(cfg, key)   # attn + mlp + norms
    ks = jax.random.split(jax.random.fold_in(key, 29), 3)
    p.update({
        "ssm_in": c.dense_init(ks[0], D, 2 * din + 2 * N + H, dt),
        "ssm_out": c.dense_init(ks[1], din, D, dt),
        "ssm_norm_g": jnp.ones((din,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "Dd": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
    })
    return p


def init_params(cfg, key):
    p = tfm.init_params(cfg, key)
    kl = jax.random.fold_in(key, 5)
    p["layers"] = jax.vmap(lambda k: init_layer_params(cfg, k))(
        jax.random.split(kl, cfg.num_layers))
    return p


def _ssm_branch(cfg, lp, h, h0=None, return_state=False):
    din, H, P, N = _dims(cfg)
    B, S, _ = h.shape
    zxbcdt = h @ lp["ssm_in"]
    z = zxbcdt[..., :din]
    xBC = jax.nn.silu(zxbcdt[..., din:2 * din + 2 * N])
    dt_raw = zxbcdt[..., 2 * din + 2 * N:]
    xs = xBC[..., :din].reshape(B, S, H, P)
    Bm = xBC[..., din:din + N]
    Cm = xBC[..., din + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])
    y, h_fin = mamba2.ssd_chunked(cfg, xs, Bm, Cm, dt, A, lp["Dd"], h0)
    y = y.reshape(B, S, din).astype(h.dtype)
    y = c.rmsnorm(y, lp["ssm_norm_g"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ lp["ssm_out"]
    return (out, h_fin) if return_state else out


def make_layer_fn(cfg, collect_state: bool):
    inv_freq = c.rope_freqs(cfg.hd, cfg.rope_base)
    W = cfg.sliding_window

    def layer(x, lp, positions):
        h = tfm._norm(cfg, x, lp, "ln1")
        q, k, v = tfm._qkv(cfg, lp, h, positions, inv_freq)
        attn = c.blockwise_attention(q, k, v, causal=True, window=W)
        B, S = x.shape[:2]
        attn_out = attn.reshape(B, S, -1) @ lp["wo"]
        if collect_state:
            ssm_out, h_fin = _ssm_branch(cfg, lp, h, return_state=True)
        else:
            ssm_out = _ssm_branch(cfg, lp, h)
        x = x + 0.5 * (attn_out + ssm_out)     # parallel-head fusion
        h2 = tfm._norm(cfg, x, lp, "ln2")
        x = x + tfm._mlp(cfg, lp, h2)
        if collect_state:
            kw = k[:, -W:] if S >= W else jnp.pad(
                k, ((0, 0), (W - S, 0), (0, 0), (0, 0)))
            vw = v[:, -W:] if S >= W else jnp.pad(
                v, ((0, 0), (W - S, 0), (0, 0), (0, 0)))
            return x, (kw, vw, h_fin)
        return x, None

    return layer


def backbone(cfg, params, x, positions, collect_state=False):
    layer = make_layer_fn(cfg, collect_state)

    def body(xc, lp):
        return layer(xc, lp, positions)

    f = jax.checkpoint(body) if cfg.remat else body
    x, st = jax.lax.scan(f, x, params["layers"])
    return tfm._norm(cfg, x, params, "ln_f"), st


def forward(cfg, params, batch):
    x = params["embed"][batch["tokens"]]
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, _ = backbone(cfg, params, x, positions)
    return c.constrain_logits(x @ params["lm_head"])


def loss_fn(cfg, params, batch):
    return c.cross_entropy(forward(cfg, params, batch), batch["labels"],
                           cfg.vocab_size)


def prefill(cfg, params, batch):
    x = params["embed"][batch["tokens"]]
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, (k, v, h) = backbone(cfg, params, x, positions, collect_state=True)
    return ({"k": k, "v": v, "ssm_state": h},
            c.constrain_logits(x[:, -1:] @ params["lm_head"]))


def decode_step(cfg, params, cache, token, length):
    """Sliding-window KV (right-aligned, newest last) + O(1) SSM step."""
    din, H, P, N = _dims(cfg)
    inv_freq = c.rope_freqs(cfg.hd, cfg.rope_base)
    W = cfg.sliding_window
    x = params["embed"][token]
    B = x.shape[0]
    pos = jnp.full((B, 1), length, jnp.int32)

    def body(xc, scans):
        lp, kc, vc, h = scans
        hn = tfm._norm(cfg, xc, lp, "ln1")
        q, k, v = tfm._qkv(cfg, lp, hn, pos, inv_freq)
        kc = jnp.concatenate([kc[:, 1:], k.astype(kc.dtype)], axis=1)
        vc = jnp.concatenate([vc[:, 1:], v.astype(vc.dtype)], axis=1)
        # entries at index i hold absolute position length-(W-1-i); valid >=0
        idx = jnp.arange(W)
        valid = idx >= (W - 1 - length)
        kk = c._repeat_kv(kc, cfg.num_heads // cfg.num_kv_heads)
        vv = c._repeat_kv(vc, cfg.num_heads // cfg.num_kv_heads)
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       kk.astype(jnp.float32)) / np.sqrt(cfg.hd)
        s = jnp.where(valid[None, None, None, :], s, -1e30)
        attn = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, axis=-1),
                          vv.astype(jnp.float32)).astype(xc.dtype)
        attn_out = attn.reshape(B, 1, -1) @ lp["wo"]
        # SSM single step (conv-free)
        zxbcdt = hn @ lp["ssm_in"]
        z = zxbcdt[..., :din]
        xBC = jax.nn.silu(zxbcdt[..., din:2 * din + 2 * N])
        dt_raw = zxbcdt[..., 2 * din + 2 * N:]
        xs = xBC[..., :din].reshape(B, H, P)
        Bm = xBC[:, 0, din:din + N]
        Cm = xBC[:, 0, din + N:]
        dtv = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                              + lp["dt_bias"])
        A = -jnp.exp(lp["A_log"])
        dA = jnp.exp(dtv * A)
        h = h * dA[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtv, Bm.astype(jnp.float32),
            xs.astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h) \
            + lp["Dd"][None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(B, 1, din).astype(xc.dtype)
        y = c.rmsnorm(y, lp["ssm_norm_g"], cfg.norm_eps) * jax.nn.silu(z)
        ssm_out = y @ lp["ssm_out"]
        xc = xc + 0.5 * (attn_out + ssm_out)
        h2 = tfm._norm(cfg, xc, lp, "ln2")
        xc = xc + tfm._mlp(cfg, lp, h2)
        return xc, (kc, vc, h)

    x, (k_new, v_new, h_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["ssm_state"]))
    x = tfm._norm(cfg, x, params, "ln_f")
    return c.constrain_logits(x @ params["lm_head"]), {"k": k_new, "v": v_new,
                                   "ssm_state": h_new}
