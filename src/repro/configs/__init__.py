from .base import (ARCH_IDS, SHAPES, ArchConfig, ShapeSpec, cache_specs,
                   get_config, input_specs, reduced, supports)
