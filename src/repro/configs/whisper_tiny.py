"""Whisper-tiny — enc-dec, conv frontend stubbed to frame embeddings
[arXiv:2212.04356; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    is_encoder_decoder=True, encoder_layers=4, encoder_seq=1500,
    frontend="audio", norm="layernorm", mlp="gelu",
    source="arXiv:2212.04356; unverified",
)
