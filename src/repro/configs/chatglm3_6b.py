"""ChatGLM3-6B — 2D (partial) RoPE, GQA, QKV bias [arXiv:2406.12793; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    qkv_bias=True, rotary_pct=0.5,
    source="arXiv:2406.12793; hf",
)
