"""Cohere Command-R 35B — GQA, no bias, parallel attn+FFN, LayerNorm
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000,
    parallel_block=True, norm="layernorm",
    micro_batches=4,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
