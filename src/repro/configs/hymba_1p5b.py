"""Hymba-1.5B — parallel attn+mamba heads, SWA [arXiv:2411.13676; hf].
d_inner=1600 so the SSM path has 25 heads of 64 — mirroring the 25
attention heads running in parallel."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, d_inner=1600, ssm_head_dim=64,
    sliding_window=1024,
    micro_batches=2,
    source="arXiv:2411.13676; hf",
)
