"""Kimi K2 — trillion-param MoE (paper-table) [arXiv:2501.kimi2; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    num_experts=384, top_k=8, moe_d_ff=2048,
    optimizer="adafactor",          # bf16 Adam m+v for 1T params won't fit 512xv5e
    moe_dispatch="biglittle",       # the paper's technique, first-class (DESIGN.md §5)
    micro_batches=8,
    grad_accum_dtype="bfloat16",   # f32 accum alone would be 16 GB/chip
    kv_cache_dtype="float8_e4m3fn",  # halves the decode memory term
    source="arXiv:2501.kimi2; unverified",
)
