"""LLaVA-NeXT (Mistral-7B backbone) — anyres tiling frontend is a STUB:
input_specs() provides precomputed patch embeddings
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    frontend="vision",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
