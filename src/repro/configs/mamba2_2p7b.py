"""Mamba2-2.7B — SSD, attention-free [arXiv:2405.21060; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, d_inner=5120, ssm_head_dim=64,
    attn_free=True,
    source="arXiv:2405.21060; unverified",
)
