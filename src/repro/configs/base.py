"""Architecture config schema, shape registry and input_specs().

Every assigned architecture is a frozen ArchConfig in its own module
(src/repro/configs/<id>.py) registered here. input_specs() returns
jax.ShapeDtypeStruct stand-ins for every model input of a given
(arch, shape) cell — weak-type-correct, shardable, no device allocation —
consumed by the launch/dryrun.py AOT lowering.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

VOCAB_ALIGN = 2048  # pad vocab so (model=16) x (lane=128) sharding divides
EXPERT_ALIGN = 16   # pad expert count so the model axis divides it


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    d_inner: int = 0               # 0 -> 2*d_model (mamba expansion)
    ssm_head_dim: int = 64
    attn_free: bool = False
    sliding_window: int = 0        # 0 = full attention
    # attention details
    qkv_bias: bool = False
    rotary_pct: float = 1.0        # chatglm3 2D RoPE = 0.5
    rope_base: float = 10000.0
    parallel_block: bool = False   # command-r style parallel attn+FFN
    mlp: str = "gated_silu"        # gated_silu | gelu
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0
    # modality frontend stub
    frontend: str = "none"         # none | vision | audio
    norm: str = "rmsnorm"
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    optimizer: str = "adamw"       # adamw | adafactor
    remat: bool = True
    moe_dispatch: str = "dense"    # dense | biglittle (the paper's technique)
    capacity_factor: float = 1.25  # MoE dispatch headroom
    micro_batches: int = 1         # grad-accumulation microbatches (train)
    grad_accum_dtype: str = "float32"  # bf16 halves the accumulator
    kv_cache_dtype: str = ""       # "" -> activation dtype; f8 halves KV
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab_size // VOCAB_ALIGN) * VOCAB_ALIGN

    @property
    def num_experts_padded(self) -> int:
        if not self.num_experts:
            return 0
        return -(-self.num_experts // EXPERT_ALIGN) * EXPERT_ALIGN

    @property
    def din(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def sub_quadratic(self) -> bool:
        return self.family == "ssm" or (self.family == "hybrid"
                                        and self.sliding_window > 0)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "kimi_k2_1t_a32b", "granite_moe_3b_a800m", "qwen2_1p5b", "internlm2_1p8b",
    "chatglm3_6b", "command_r_35b", "hymba_1p5b", "llava_next_mistral_7b",
    "mamba2_2p7b", "whisper_tiny",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def reduced(cfg: ArchConfig, layers: int = 2) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (small widths, few
    experts, tiny vocab) — the FULL config is exercised only by the
    dry-run (ShapeDtypeStruct, no allocation)."""
    kw = dict(
        num_layers=layers, d_model=64, d_ff=128, vocab_size=128,
        head_dim=16, remat=False,
    )
    if cfg.num_heads:
        kw["num_heads"] = 4
        kw["num_kv_heads"] = 2 if cfg.num_kv_heads < cfg.num_heads else 4
    else:
        kw["num_heads"] = 0
        kw["num_kv_heads"] = 0
    if cfg.family == "moe":
        kw.update(num_experts=8, top_k=2, moe_d_ff=64,
                  capacity_factor=100.0)  # drop-free at toy scale
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=8, d_inner=64, ssm_head_dim=16)
    if cfg.sliding_window:
        kw["sliding_window"] = 32
    if cfg.is_encoder_decoder:
        kw.update(encoder_layers=layers, encoder_seq=24)
    return dataclasses.replace(cfg, **kw)


def get_config(arch: str) -> ArchConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def supports(cfg: ArchConfig, shape: ShapeSpec) -> tuple:
    """(ok, reason) — which cells run. long_500k needs sub-quadratic
    attention (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention at 512k context — skipped "
                       "per assignment; see DESIGN.md §5")
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the step function."""
    B, S = shape.batch, shape.seq
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    tok = jax.ShapeDtypeStruct((B, S), i32)

    def embeds(seq):
        return jax.ShapeDtypeStruct((B, seq, cfg.d_model), dt)

    if shape.kind == "train":
        batch = {"labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "vision":
            batch["embeds"] = embeds(S)      # anyres patch embeddings (stub)
        elif cfg.frontend == "audio":
            batch["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), dt)
            batch["tokens"] = tok
        else:
            batch["tokens"] = tok
        return {"batch": batch}

    if shape.kind == "prefill":
        if cfg.frontend == "vision":
            return {"batch": {"embeds": embeds(S)}}
        if cfg.frontend == "audio":
            return {"batch": {
                "enc_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), dt),
                "tokens": tok}}
        return {"batch": {"tokens": tok}}

    # decode: one new token against a cache of length S
    cache = cache_specs(cfg, B, S)
    return {
        "cache": cache,
        "token": jax.ShapeDtypeStruct((B, 1), i32),
        "length": jax.ShapeDtypeStruct((), i32),
    }


def cache_specs(cfg: ArchConfig, B: int, S: int):
    """Decode-state ShapeDtypeStructs per family."""
    dt = jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
    L = cfg.num_layers
    kv = lambda: jax.ShapeDtypeStruct((L, B, S, cfg.num_kv_heads, cfg.hd), dt)
    out = {}
    if cfg.family == "ssm":
        H = cfg.din // cfg.ssm_head_dim
        out["ssm_state"] = jax.ShapeDtypeStruct(
            (L, B, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        out["conv_state"] = jax.ShapeDtypeStruct(
            (L, B, 4, cfg.din + 2 * cfg.ssm_state), dt)
    elif cfg.family == "hybrid":
        W = min(cfg.sliding_window or S, S)
        out["k"] = jax.ShapeDtypeStruct((L, B, W, cfg.num_kv_heads, cfg.hd), dt)
        out["v"] = jax.ShapeDtypeStruct((L, B, W, cfg.num_kv_heads, cfg.hd), dt)
        H = cfg.din // cfg.ssm_head_dim
        out["ssm_state"] = jax.ShapeDtypeStruct(
            (L, B, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    elif cfg.is_encoder_decoder:
        out["k"] = kv()
        out["v"] = kv()
        out["cross_k"] = jax.ShapeDtypeStruct(
            (L, B, cfg.encoder_seq, cfg.num_kv_heads, cfg.hd), dt)
        out["cross_v"] = jax.ShapeDtypeStruct(
            (L, B, cfg.encoder_seq, cfg.num_kv_heads, cfg.hd), dt)
    else:
        out["k"] = kv()
        out["v"] = kv()
    return out
