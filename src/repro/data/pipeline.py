"""Deterministic sharded token pipeline with skip-to-step resume.

Production data loading for LM training without external deps:
  * a seeded synthetic corpus (mixture of Zipf unigrams + repeated spans,
    enough structure for a LM to show decreasing loss) OR a binary token
    file (np.memmap) when a real corpus is available;
  * deterministic (seed, step) -> batch mapping: any host can materialise
    any step's global batch slice — this is what makes checkpoint-restart
    and elastic rescaling exact (no data repeated or skipped after a
    failure, regardless of the new host count);
  * per-host sharding: host h of H draws rows [h*B/H, (h+1)*B/H) of the
    global batch.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_exponent: float = 1.2
    span_repeat_p: float = 0.3     # chance a span is a repeat (learnable)
    token_file: Optional[str] = None


class TokenPipeline:
    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        self._mm = (np.memmap(cfg.token_file, dtype=np.int32, mode="r")
                    if cfg.token_file else None)
        # Zipf unigram table (stable across hosts)
        r = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        w = r ** (-cfg.zipf_exponent)
        self._probs = w / w.sum()

    def _row(self, step: int, row: int) -> np.ndarray:
        """Deterministic tokens for (step, global row)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, row]))
        if self._mm is not None:
            n = self._mm.shape[0] - cfg.seq_len - 1
            off = int(rng.integers(0, max(n, 1)))
            return np.asarray(self._mm[off:off + cfg.seq_len + 1],
                              dtype=np.int32)
        out = np.empty(cfg.seq_len + 1, np.int32)
        pos = 0
        while pos < out.shape[0]:
            span = int(rng.integers(8, 64))
            span = min(span, out.shape[0] - pos)
            if pos > span and rng.random() < cfg.span_repeat_p:
                back = int(rng.integers(1, pos - span + 1))
                out[pos:pos + span] = out[pos - back - span:pos - back]
            else:
                out[pos:pos + span] = rng.choice(
                    cfg.vocab_size, size=span, p=self._probs)
            pos += span
        return out

    def batch(self, step: int) -> dict:
        """Local shard of the global batch for ``step``."""
        lo = self.host_id * self.local_batch
        rows = [self._row(step, lo + i) for i in range(self.local_batch)]
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1].copy(), "labels": arr[:, 1:].copy()}

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        """Resume-aware iterator — start_step comes from the checkpoint."""
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
