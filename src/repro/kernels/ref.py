"""Pure-jnp oracles for every kernel in this package.

Each oracle computes the same function as its Pallas kernel from the same
blocked inputs, using only plain jnp ops (gather / scatter-at / segmented
scan). Tests assert_allclose kernels (interpret=True) against these.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.gas import GATHER_IDENTITY


def _segment_or(flat_idx, vals, size):
    """OR-scatter via sort + segmented inclusive scan (no lax.scatter-or)."""
    order = jnp.argsort(flat_idx)
    idx = flat_idx[order]
    v = vals[order]

    def combine(a, b):
        va, sa = a
        vb, sb = b
        return jnp.where(sa == sb, va | vb, vb), sb

    scanned, segs = jax.lax.associative_scan(combine, (v, idx))
    is_last = jnp.concatenate([segs[1:] != segs[:-1],
                               jnp.ones((1,), bool)])
    out = jnp.zeros((size,), v.dtype)
    safe_idx = jnp.where(is_last, segs, size)  # dump non-last to OOB (dropped)
    return out.at[safe_idx].set(jnp.where(is_last, scanned, 0), mode="drop")


@functools.partial(
    jax.jit,
    static_argnames=("scatter_fn", "mode", "t", "n_out_tiles"))
def gas_ref(vwin, src_local, dst_local, weights, valid, window_id, tile_id,
            tile_first, *, scatter_fn, mode, t, n_out_tiles):
    """Oracle for kernels.gas_kernel.gas_pallas_call."""
    del tile_first
    win = vwin[window_id]                                   # (n_blocks, W)
    props = jnp.take_along_axis(win, src_local, axis=1)     # (n_blocks, E)
    vals = scatter_fn(props, weights)
    ident = GATHER_IDENTITY[mode]
    flat = tile_id[:, None] * t + dst_local                 # (n_blocks, E)
    flat = jnp.where(valid != 0, flat, n_out_tiles * t)     # pads -> OOB drop
    flat = flat.reshape(-1)
    v = vals.reshape(-1)
    size = n_out_tiles * t
    if mode == "sum":
        out = jnp.zeros((size,), vals.dtype).at[flat].add(v, mode="drop")
    elif mode == "min":
        out = jnp.full((size,), ident, vals.dtype).at[flat].min(v, mode="drop")
    elif mode == "max":
        out = jnp.full((size,), ident, vals.dtype).at[flat].max(v, mode="drop")
    elif mode == "or":
        # append one dummy OOB element so every segment id is valid for sort
        out = _segment_or(flat, v, size)
    else:
        raise ValueError(mode)
    return out.reshape(n_out_tiles, t)


def edge_ref(graph_src, graph_dst, graph_w, vprops, scatter_fn, mode,
             num_vertices):
    """Ground-truth straight from the edge list (no blocking) — the
    end-to-end oracle used by engine tests."""
    props = vprops[graph_src]
    vals = scatter_fn(props, graph_w)
    ident = GATHER_IDENTITY[mode]
    if mode == "sum":
        out = jnp.zeros((num_vertices,), vals.dtype).at[graph_dst].add(vals)
    elif mode == "min":
        out = jnp.full((num_vertices,), ident, vals.dtype).at[graph_dst].min(vals)
    elif mode == "max":
        out = jnp.full((num_vertices,), ident, vals.dtype).at[graph_dst].max(vals)
    elif mode == "or":
        out = _segment_or(graph_dst, vals, num_vertices)
    else:
        raise ValueError(mode)
    return out


def moe_dispatch_ref(tokens, router_logits, w_gate, w_up, w_down, top_k):
    """Oracle for the heterogeneous MoE dispatch: exact top-k gated
    mixture-of-experts FFN (no capacity drop)."""
    weights, idx = jax.lax.top_k(router_logits, top_k)        # (n_tok, k)
    weights = jax.nn.softmax(weights, axis=-1)
    out = jnp.zeros_like(tokens)
    for k in range(top_k):
        e = idx[:, k]                                          # (n_tok,)
        h = jax.nn.silu(jnp.einsum("td,tdf->tf", tokens, w_gate[e])) \
            * jnp.einsum("td,tdf->tf", tokens, w_up[e])
        y = jnp.einsum("tf,tfd->td", h, w_down[e])
        out = out + weights[:, k:k + 1] * y
    return out


def flash_attention_ref(q, k, v, causal=True, window=None):
    """Oracle for the blockwise attention kernel: exact softmax attention.
    q,k,v: (heads, seq, head_dim). Optional sliding window."""
    h, s, d = q.shape
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v)
