"""Big pipeline — sparse-partition batched GAS kernel (paper §III-B).

Sparse partitions have terrible locality: streaming whole vprops windows
would waste nearly all fetched bytes. The Vertex Loader's two tricks map
to TPU as:
  * request dedup  → offline unique-source compaction (partition.block_big)
  * latency-tolerant fetch → one XLA gather of the compact table, which
    the hardware pipelines against compute (execute/access decoupling).
Many sparse partitions are batched per invocation (the Data Router let
N_gpe Gather PEs hold N_gpe partitions; here the whole batch shares one
launch), amortising partition-switch overhead exactly as in the paper.
"""
from __future__ import annotations

from .gas_kernel import gas_pallas_call, gas_pallas_call_segmented


def big_pipeline(vprops_padded, unique_src, src_local, dst_local, weights,
                 valid, window_id, tile_id, tile_first, *, scatter_fn, mode,
                 geom, n_out_tiles, interpret=True):
    """Run one sparse-batch slice.

    unique_src: (n_unique_pad,) int32 global ids (the dedup'd request set).
    Returns (n_out_tiles, T) accumulator tiles.
    """
    # The Vertex Loader: a single deduplicated gather of unique sources.
    compact = vprops_padded[unique_src]
    vwin = compact.reshape(-1, geom.W)
    return gas_pallas_call(
        vwin, src_local, dst_local, weights, valid,
        window_id, tile_id, tile_first,
        scatter_fn=scatter_fn, mode=mode,
        e_blk=geom.E_BLK, w=geom.W, t=geom.T, n_out_tiles=n_out_tiles,
        interpret=interpret)


def big_pipeline_packed(vprops_padded, unique_src, src_local, dst_local,
                        weights, valid, window_id, tile_id, tile_first, *,
                        scatter_fn, mode, geom, n_out_tiles, n_segments,
                        interpret=True):
    """Run a whole packed Big lane (all sparse entries of one lane) as
    ONE segmented grid.

    unique_src here is the lane's PACKED compaction table — the distinct
    per-work unique-source tables concatenated by ops.pack_lane, with
    each segment's window_id rebased to its table's window offset. The
    Vertex Loader gather therefore runs once per LANE per iteration
    instead of once per entry.
    Returns (n_out_tiles, T) accumulator tiles for the whole lane.
    """
    compact = vprops_padded[unique_src]
    vwin = compact.reshape(-1, geom.W)
    return gas_pallas_call_segmented(
        vwin, src_local, dst_local, weights, valid,
        window_id, tile_id, tile_first,
        scatter_fn=scatter_fn, mode=mode,
        e_blk=geom.E_BLK, w=geom.W, t=geom.T, n_out_tiles=n_out_tiles,
        n_segments=n_segments, interpret=interpret)
