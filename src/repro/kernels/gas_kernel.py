"""Shared Pallas TPU kernel body for the Big/Little GAS pipelines.

One grid step processes one E_BLK edge block that is homogeneous in
(source window, destination tile):

  * the source-vertex window (W props) arrives in VMEM via BlockSpec —
    Pallas grid pipelining double-buffers consecutive windows, which IS
    the Little pipeline's ping-pong buffer;
  * source properties are gathered with a one-hot (E_BLK x W) product —
    MXU work replacing per-lane random loads;
  * the update values are routed into the (T,) destination tile
    accumulator with a one-hot (T x E_BLK) product for 'sum' (MXU) or a
    masked reduce for 'min'/'max'/'or' (VPU) — the TPU analogue of the
    paper's butterfly Data Router;
  * blocks are sorted by tile, so output revisits are consecutive and the
    accumulator tile stays resident in VMEM between steps.

The same body serves both pipelines; they differ only in what the window
input *is* (raw vprops windows for Little, compacted unique-source windows
for Big) — exactly the paper's division of labour.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.gas import GATHER_IDENTITY

INT_MODES = ("or",)


def _gather_src(window, src_local, e_blk, w, is_int):
    """props[e] = window[src_local[e]] via one-hot product (MXU/VPU)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (e_blk, w), 1)
    onehot = src_local[:, None] == iota
    if is_int:
        return jnp.sum(jnp.where(onehot, window[None, :], 0), axis=1)
    return jnp.dot(onehot.astype(window.dtype), window,
                   preferred_element_type=window.dtype)


def _route_dst(vals, dst_local, valid, mode, t, e_blk, acc_dtype):
    """tile_contrib[t] = gather-combine of vals routed to dst tile slots."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (t, e_blk), 0)
    onehot = (dst_local[None, :] == iota) & (valid[None, :] != 0)
    if mode == "sum":
        return jnp.dot(onehot.astype(acc_dtype), vals.astype(acc_dtype),
                       preferred_element_type=acc_dtype)
    ident = GATHER_IDENTITY[mode]
    cand = jnp.where(onehot, vals[None, :].astype(acc_dtype),
                     jnp.asarray(ident, acc_dtype))
    if mode == "min":
        return jnp.min(cand, axis=1)
    if mode == "max":
        return jnp.max(cand, axis=1)
    if mode == "or":
        return jax.lax.reduce(cand, np.int32(0), jax.lax.bitwise_or, (1,))
    raise ValueError(mode)


def make_gas_kernel(scatter_fn: Callable, mode: str, e_blk: int, w: int,
                    t: int, acc_dtype, n_blocks: int):
    """Build the kernel body (closes over the Scatter UDF — the paper's
    accScatter runs inside the pipeline).

    The running tile accumulator lives in VMEM *scratch* (persists across
    grid steps — the Gather-PE destination buffer of the paper) and is
    flushed to the output block on the last edge block of each tile.
    """
    ident = GATHER_IDENTITY[mode]
    is_int = mode in INT_MODES

    def kernel(wid_ref, tid_ref, tfirst_ref, vwin_ref, src_ref, dst_ref,
               w_ref, valid_ref, out_ref, acc_ref):
        b = pl.program_id(0)
        window = vwin_ref[0]          # (W,) source props in VMEM
        src_local = src_ref[0]        # (E_BLK,) int32
        dst_local = dst_ref[0]
        wts = w_ref[0]
        valid = valid_ref[0]

        @pl.when(tfirst_ref[b] == 1)
        def _init():
            acc_ref[...] = jnp.full((t,), ident, acc_dtype)

        props = _gather_src(window, src_local, e_blk, w, is_int)
        vals = scatter_fn(props, wts)
        contrib = _route_dst(vals, dst_local, valid, mode, t, e_blk, acc_dtype)
        if mode == "sum":
            acc_ref[...] += contrib
        elif mode == "min":
            acc_ref[...] = jnp.minimum(acc_ref[...], contrib)
        elif mode == "max":
            acc_ref[...] = jnp.maximum(acc_ref[...], contrib)
        else:  # or
            acc_ref[...] = acc_ref[...] | contrib

        # flush on the last block of this tile
        nxt = jnp.where(b + 1 < n_blocks,
                        tfirst_ref[jnp.minimum(b + 1, n_blocks - 1)], 1)
        @pl.when(nxt == 1)
        def _flush():
            out_ref[0] = acc_ref[...]

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("scatter_fn", "mode", "e_blk", "w", "t", "n_out_tiles",
                     "interpret"),
)
def gas_pallas_call(vwin, src_local, dst_local, weights, valid,
                    window_id, tile_id, tile_first, *,
                    scatter_fn, mode, e_blk, w, t, n_out_tiles,
                    interpret=True):
    """Run the blocked GAS kernel. All shape args static.

    vwin:      (n_windows, W) property windows (raw or compacted)
    src_local: (n_blocks, E_BLK) int32 — offsets within the block's window
    dst_local: (n_blocks, E_BLK) int32 — offsets within the block's tile
    returns (n_out_tiles, T) accumulator tiles.
    """
    n_blocks = src_local.shape[0]
    acc_dtype = vwin.dtype
    kernel = make_gas_kernel(scatter_fn, mode, e_blk, w, t, acc_dtype,
                             n_blocks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, w), lambda b, wid, tid, tf: (wid[b], 0)),
            pl.BlockSpec((1, e_blk), lambda b, wid, tid, tf: (b, 0)),
            pl.BlockSpec((1, e_blk), lambda b, wid, tid, tf: (b, 0)),
            pl.BlockSpec((1, e_blk), lambda b, wid, tid, tf: (b, 0)),
            pl.BlockSpec((1, e_blk), lambda b, wid, tid, tf: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda b, wid, tid, tf: (tid[b], 0)),
        scratch_shapes=[pltpu.VMEM((t,), acc_dtype)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out_tiles, t), acc_dtype),
        interpret=interpret,
    )(window_id, tile_id, tile_first, vwin, src_local, dst_local,
      weights, valid)


@functools.partial(
    jax.jit,
    static_argnames=("scatter_fn", "mode", "e_blk", "w", "t", "n_out_tiles",
                     "n_segments", "interpret"),
)
def gas_pallas_call_segmented(vwin, src_local, dst_local, weights, valid,
                              window_id, tile_id, tile_first, *,
                              scatter_fn, mode, e_blk, w, t, n_out_tiles,
                              n_segments, interpret=True):
    """One grid over the concatenation of ``n_segments`` tile-disjoint
    block ranges (a packed lane) — the fused alternative to issuing one
    :func:`gas_pallas_call` per plan entry.

    The kernel body is shared with the per-entry call; the segment
    structure is carried entirely by the prefetch maps, which packing
    (``ops.pack_lane``) establishes and validates host-side:

      * each segment's first block has ``tile_first == 1``, so the VMEM
        accumulator re-initializes exactly at segment boundaries;
      * local tile ids are rebased to be strictly increasing across
        segments (globally disjoint output rows), so the flush check
        (next block's ``tile_first``) closes a segment's last tile
        precisely when the next segment begins;
      * ``window_id`` is rebased against the packed window table (raw
        vprops windows for Little; the concatenated unique-source
        compaction tables for Big).

    ``n_segments`` is static so fused and per-entry launches of the same
    shape trace separately (dispatch accounting stays honest); the body
    itself only depends on the total block count.
    """
    del n_segments  # static trace identity only — see docstring
    return gas_pallas_call(
        vwin, src_local, dst_local, weights, valid,
        window_id, tile_id, tile_first,
        scatter_fn=scatter_fn, mode=mode, e_blk=e_blk, w=w, t=t,
        n_out_tiles=n_out_tiles, interpret=interpret)
