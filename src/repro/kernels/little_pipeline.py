"""Little pipeline — dense-partition GAS kernel (paper §III-C).

Dense partitions touch most source windows, so the kernel streams raw
vprops windows HBM→VMEM via BlockSpec (Pallas grid pipelining
double-buffers consecutive steps: the ping-pong buffer). No dedup, no
compaction — the paper's argument that locality makes those techniques
dead weight for dense partitions. The "jump access mechanism" (skipping
unread buffer ranges) falls out of the window_id prefetch map: untouched
windows are never fetched.
"""
from __future__ import annotations

from .gas_kernel import gas_pallas_call, gas_pallas_call_segmented


def little_pipeline(vprops_padded, src_local, dst_local, weights, valid,
                    window_id, tile_id, tile_first, *, scatter_fn, mode,
                    geom, n_out_tiles, interpret=True):
    """Run one dense-partition slice.

    vprops_padded: (V_pad,) current vertex properties, V_pad % W == 0.
    Blocked arrays as produced by partition.block_little (possibly a
    tile-aligned slice rebased by ops.materialize_entry).
    Returns (n_out_tiles, T) accumulator tiles.
    """
    vwin = vprops_padded.reshape(-1, geom.W)
    return gas_pallas_call(
        vwin, src_local, dst_local, weights, valid,
        window_id, tile_id, tile_first,
        scatter_fn=scatter_fn, mode=mode,
        e_blk=geom.E_BLK, w=geom.W, t=geom.T, n_out_tiles=n_out_tiles,
        interpret=interpret)


def little_pipeline_packed(vprops_padded, src_local, dst_local, weights,
                           valid, window_id, tile_id, tile_first, *,
                           scatter_fn, mode, geom, n_out_tiles, n_segments,
                           interpret=True):
    """Run a whole packed Little lane (all dense entries of one lane,
    concatenated by ops.pack_lane) as ONE segmented grid. Window ids
    index the raw vprops windows, so packing needs no rebase here —
    every segment streams from the same source array.
    Returns (n_out_tiles, T) accumulator tiles for the whole lane.
    """
    vwin = vprops_padded.reshape(-1, geom.W)
    return gas_pallas_call_segmented(
        vwin, src_local, dst_local, weights, valid,
        window_id, tile_id, tile_first,
        scatter_fn=scatter_fn, mode=mode,
        e_blk=geom.E_BLK, w=geom.W, t=geom.T, n_out_tiles=n_out_tiles,
        n_segments=n_segments, interpret=interpret)
