"""Jit'd wrappers and dispatch for the GAS pipeline kernels.

``materialize_entry`` turns a (work, block-range) plan entry into
device-resident arrays with tile indices rebased to the slice, after
snapping the range to tile boundaries — so every destination tile is
written by exactly one entry and the engine can merge with a plain
scatter-set regardless of gather mode.

``run_entry`` dispatches to the Pallas kernel (interpret=True on CPU,
compiled on TPU) or the pure-jnp reference path — identical math, used
both as the CPU fast path and as the oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.types import BlockedEdges, Geometry
from . import ref as ref_mod
from .big_pipeline import big_pipeline
from .little_pipeline import little_pipeline


def default_path() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def snap_down(blocked: BlockedEdges, x: int) -> int:
    """Largest tile boundary <= x (x == n_blocks allowed). Applying this
    one rule to both endpoints keeps adjacent slices exactly abutting."""
    n = blocked.n_blocks
    x = max(0, min(x, n))
    if x >= n:
        return n
    tf = blocked.tile_first
    while x > 0 and tf[x] != 1:
        x -= 1
    return x


def snap_to_tiles(blocked: BlockedEdges, lo: int, hi: int):
    """Snap [lo, hi) to tile boundaries; may return an empty range, which
    the engine drops (the work is covered by the neighbouring slice)."""
    return snap_down(blocked, lo), snap_down(blocked, hi)


def materialize_entry(blocked: BlockedEdges, lo: int, hi: int):
    """Build the device payload for one plan entry (tile-snapped).
    Returns None when the snapped range is empty."""
    lo, hi = snap_to_tiles(blocked, lo, hi)
    if hi <= lo:
        return None
    t0 = int(blocked.tile_id[lo])
    t1 = int(blocked.tile_id[hi - 1]) + 1 if hi > lo else t0
    tile_id = blocked.tile_id[lo:hi] - t0
    tf = blocked.tile_first[lo:hi].copy()
    if tf.shape[0]:
        tf[0] = 1
    payload = {
        "kind": blocked.kind,
        "geom": blocked.geom,
        "n_out_tiles": t1 - t0,
        "src_local": jnp.asarray(blocked.src_local[lo:hi]),
        "dst_local": jnp.asarray(blocked.dst_local[lo:hi]),
        "weights": jnp.asarray(blocked.weights[lo:hi]),
        "valid": jnp.asarray(blocked.valid[lo:hi], jnp.int32),
        "window_id": jnp.asarray(blocked.window_id[lo:hi]),
        "tile_id": jnp.asarray(tile_id),
        "tile_first": jnp.asarray(tf),
        "tile_idx": jnp.asarray(blocked.tile_dst_start[t0:t1]
                                // blocked.geom.T),
        "unique_src": (None if blocked.unique_src is None
                       else jnp.asarray(blocked.unique_src)),
        "n_blocks": hi - lo,
        "num_real_edges": int(blocked.valid[lo:hi].sum()),
    }
    return payload


def materialize_lanes(plan, little_works, big_works):
    """Materialize every plan entry, preserving the plan's lane structure.
    Empty (fully snapped-away) entries are dropped — their tiles are
    covered by the neighbouring slice. Shared by the Executor and any
    harness that replays a SchedulePlan."""
    lanes = []
    for lane in plan.lanes:
        mat = []
        for e in lane:
            work = (little_works[e.work_id] if e.kind == "little"
                    else big_works[e.work_id])
            p = materialize_entry(work, e.block_lo, e.block_hi)
            if p is not None:
                mat.append(p)
        lanes.append(mat)
    return lanes


def run_entry(entry: dict, vprops_padded, scatter_fn, mode: str,
              path: Optional[str] = None):
    """Returns (tiles (n_out_tiles, T), tile_idx (n_out_tiles,))."""
    path = path or default_path()
    geom: Geometry = entry["geom"]
    args = (entry["src_local"], entry["dst_local"], entry["weights"],
            entry["valid"], entry["window_id"], entry["tile_id"],
            entry["tile_first"])
    if path == "ref":
        if entry["kind"] == "big":
            vwin = vprops_padded[entry["unique_src"]].reshape(-1, geom.W)
        else:
            vwin = vprops_padded.reshape(-1, geom.W)
        tiles = ref_mod.gas_ref(vwin, *args, scatter_fn=scatter_fn, mode=mode,
                                t=geom.T, n_out_tiles=entry["n_out_tiles"])
    else:
        interpret = jax.default_backend() != "tpu"
        if entry["kind"] == "big":
            tiles = big_pipeline(vprops_padded, entry["unique_src"], *args,
                                 scatter_fn=scatter_fn, mode=mode, geom=geom,
                                 n_out_tiles=entry["n_out_tiles"],
                                 interpret=interpret)
        else:
            tiles = little_pipeline(vprops_padded, *args,
                                    scatter_fn=scatter_fn, mode=mode,
                                    geom=geom,
                                    n_out_tiles=entry["n_out_tiles"],
                                    interpret=interpret)
    return tiles, entry["tile_idx"]


def merge_tiles(accum_padded, tiles, tile_idx, t: int):
    """Scatter-set entry results into the global accumulator. Tiles are
    disjoint across entries by construction (snap_to_tiles)."""
    acc = accum_padded.reshape(-1, t)
    acc = acc.at[tile_idx].set(tiles.astype(acc.dtype))
    return acc.reshape(-1)
