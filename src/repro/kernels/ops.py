"""Jit'd wrappers and dispatch for the GAS pipeline kernels.

``materialize_entry`` turns a (work, block-range) plan entry into
device-resident arrays with tile indices rebased to the slice, after
snapping the range to tile boundaries — so every destination tile is
written by exactly one entry and the engine can merge with a plain
scatter-set regardless of gather mode.

``pack_lane`` / ``pack_lanes`` build the FUSED representation: all
same-kind entries of a lane concatenated host-side into one contiguous
payload (per-segment tile ids rebased to a global tile map, Big window
ids rebased against the packed unique-source tables), uploaded in one
shot. ``run_lane`` then executes an entire lane as ONE ``pallas_call``
(one ref-path call on CPU) instead of one launch per entry, so kernel
dispatches and trace size scale with the number of lanes, not the
number of materialized plan entries.

``run_entry`` dispatches to the Pallas kernel (interpret=True on CPU,
compiled on TPU) or the pure-jnp reference path — identical math, used
both as the CPU fast path and as the oracle.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.types import BlockedEdges, Geometry
from . import ref as ref_mod
from .big_pipeline import big_pipeline, big_pipeline_packed
from .little_pipeline import little_pipeline, little_pipeline_packed

# payload keys that hold per-block / per-tile arrays and concatenate
# along axis 0 when packing a lane
_CONCAT_KEYS = ("src_local", "dst_local", "weights", "valid",
                "window_id", "tile_id", "tile_first", "tile_idx")
# payload keys uploaded to the device by _upload_payload
_DEVICE_KEYS = _CONCAT_KEYS + ("unique_src",)


def default_path() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def snap_down(blocked: BlockedEdges, x: int) -> int:
    """Largest tile boundary <= x (x == n_blocks allowed). Applying this
    one rule to both endpoints keeps adjacent slices exactly abutting."""
    n = blocked.n_blocks
    x = max(0, min(x, n))
    if x >= n:
        return n
    tf = blocked.tile_first
    while x > 0 and tf[x] != 1:
        x -= 1
    return x


def snap_to_tiles(blocked: BlockedEdges, lo: int, hi: int):
    """Snap [lo, hi) to tile boundaries; may return an empty range, which
    the engine drops (the work is covered by the neighbouring slice)."""
    return snap_down(blocked, lo), snap_down(blocked, hi)


def _entry_np(blocked: BlockedEdges, lo: int, hi: int) -> Optional[dict]:
    """Host-side payload for one plan entry (tile-snapped). Returns None
    when the snapped range is empty. ``unique_src`` stays a reference to
    the work's shared compaction table so packing can deduplicate tables
    across entries of the same Big work."""
    lo, hi = snap_to_tiles(blocked, lo, hi)
    if hi <= lo:
        return None
    t0 = int(blocked.tile_id[lo])
    t1 = int(blocked.tile_id[hi - 1]) + 1
    tf = blocked.tile_first[lo:hi].copy()
    tf[0] = 1
    return {
        "kind": blocked.kind,
        "geom": blocked.geom,
        "n_out_tiles": t1 - t0,
        "n_blocks": hi - lo,
        "n_entries": 1,
        "src_local": blocked.src_local[lo:hi],
        "dst_local": blocked.dst_local[lo:hi],
        "weights": blocked.weights[lo:hi],
        "valid": blocked.valid[lo:hi].astype(np.int32),
        "window_id": blocked.window_id[lo:hi],
        "tile_id": blocked.tile_id[lo:hi] - t0,
        "tile_first": tf,
        "tile_idx": (blocked.tile_dst_start[t0:t1]
                     // blocked.geom.T).astype(np.int32),
        "unique_src": blocked.unique_src,
        "num_real_edges": int(blocked.valid[lo:hi].sum()),
    }


def _upload_payload(p: dict, device=None) -> dict:
    """Move a host payload's array fields to the device (jnp).
    ``device=None`` targets the default device; the sharded path passes
    each lane's OWNER device so payloads land committed where they will
    execute (committed inputs pin the jit'd lane fn to that device)."""
    out = dict(p)
    for k in _DEVICE_KEYS:
        if out.get(k) is not None:
            out[k] = (jnp.asarray(out[k]) if device is None
                      else jax.device_put(np.asarray(out[k]), device))
    return out


def materialize_entry(blocked: BlockedEdges, lo: int, hi: int):
    """Build the device payload for one plan entry (tile-snapped).
    Returns None when the snapped range is empty."""
    p = _entry_np(blocked, lo, hi)
    return None if p is None else _upload_payload(p)


def materialize_lanes(plan, little_works, big_works):
    """Materialize every plan entry, preserving the plan's lane structure.
    Empty (fully snapped-away) entries are dropped — their tiles are
    covered by the neighbouring slice. Shared by the Executor and any
    harness that replays a SchedulePlan."""
    lanes = []
    for lane in plan.lanes:
        mat = []
        for e in lane:
            work = (little_works[e.work_id] if e.kind == "little"
                    else big_works[e.work_id])
            p = materialize_entry(work, e.block_lo, e.block_hi)
            if p is not None:
                mat.append(p)
        lanes.append(mat)
    return lanes


# ---------------------------------------------------------------------------
# Packed (fused) lane payloads
# ---------------------------------------------------------------------------

def _pack_group(entries: List[dict]) -> dict:
    """Concatenate same-kind host entry payloads into one packed payload.

    Per-segment rebasing:
      * ``tile_id`` shifts by the running tile count, so packed local
        tile ids are strictly increasing across segments and the global
        ``tile_idx`` map is a plain concatenation;
      * Big ``window_id`` shifts by its work's offset in the packed
        unique-source table (tables shared by split entries of the same
        work are packed once); Little window ids index raw vprops
        windows and need no rebase.
    """
    kind, geom = entries[0]["kind"], entries[0]["geom"]
    tile_off = 0
    win_parts, tid_parts = [], []
    tables: List[np.ndarray] = []        # distinct tables, first-use order
    table_off: dict = {}                 # id(table) -> window offset
    n_windows = 0
    for e in entries:
        assert e["kind"] == kind and e["geom"] == geom
        tid_parts.append(e["tile_id"] + tile_off)
        tile_off += e["n_out_tiles"]
        if kind == "big":
            tab = e["unique_src"]
            off = table_off.get(id(tab))
            if off is None:
                off = n_windows
                table_off[id(tab)] = off
                tables.append(tab)
                n_windows += tab.shape[0] // geom.W
            win_parts.append(e["window_id"] + off)
        else:
            win_parts.append(e["window_id"])
    packed = {
        "kind": kind,
        "geom": geom,
        "n_out_tiles": tile_off,
        "n_blocks": int(sum(e["n_blocks"] for e in entries)),
        "n_entries": len(entries),
        "segment_starts": np.cumsum(
            [0] + [e["n_blocks"] for e in entries])[:-1].astype(np.int64),
        "tile_id": np.concatenate(tid_parts).astype(np.int32),
        "window_id": np.concatenate(win_parts).astype(np.int32),
        "unique_src": (np.concatenate(tables) if kind == "big" else None),
        "num_real_edges": int(sum(e["num_real_edges"] for e in entries)),
    }
    for k in ("src_local", "dst_local", "weights", "valid", "tile_first",
              "tile_idx"):
        packed[k] = np.concatenate([e[k] for e in entries])
    _validate_packed(packed)
    return packed


def _validate_packed(p: dict) -> None:
    """Pack-time invariants the segmented grid relies on (host numpy —
    zero device cost). Violations mean a scheduling/packing bug, not bad
    user input, hence asserts."""
    starts = p["segment_starts"]
    # every segment opens a fresh tile -> the VMEM accumulator re-inits
    assert np.all(p["tile_first"][starts] == 1), \
        "packed segment does not start on a tile boundary"
    # local tile ids are a 0..n_out_tiles-1 relabeling, non-decreasing
    tid = p["tile_id"]
    assert tid.shape[0] == 0 or (
        tid[0] == 0 and np.all(np.diff(tid) >= 0)
        and int(tid[-1]) + 1 == p["n_out_tiles"]), \
        "packed tile ids are not a dense non-decreasing relabeling"
    # entries write disjoint output tiles -> one scatter-set merge is safe
    idx = p["tile_idx"]
    assert np.unique(idx).shape[0] == idx.shape[0], \
        "packed entries write overlapping destination tiles"


def estimate_working_set(entries: List[dict], geom: Geometry) -> int:
    """Estimated on-chip (VMEM) working set, in bytes, of packing these
    same-kind host entries into ONE payload: the full output-tile
    accumulator, the gathered unique-source table (Big; distinct tables
    counted once, matching :func:`_pack_group`'s dedup) or one streamed
    source window (Little), plus one edge-block slab. The HBM-resident
    edge stream itself is excluded — it is streamed block-by-block."""
    ws = geom.E_BLK * 16                     # src+dst+weights+valid slab
    ws += sum(e["n_out_tiles"] for e in entries) * geom.T * 4
    if entries and entries[0]["kind"] == "big":
        seen, tot = set(), 0
        for e in entries:
            tab = e["unique_src"]
            if id(tab) not in seen:
                seen.add(id(tab))
                tot += int(tab.shape[0])
        ws += tot * 4
    else:
        ws += geom.W * 4
    return int(ws)


def payload_footprint(p: dict) -> dict:
    """Byte/FLOP accounting of ONE (packed or single-entry) payload, by
    traffic class — the per-payload half of
    :class:`repro.obs.profile.LaneFootprint`. All byte counts come from
    the actual arrays (``.nbytes``), not re-derived shapes, so they are
    exact for whatever this payload holds:

    ``edge_bytes``     the streamed edge slab (src/dst/weights/valid)
    ``index_bytes``    per-block routing metadata (window/tile ids,
                       tile_first flags, the global tile_idx map)
    ``table_bytes``    the deduped unique-source compaction table
                       (Big only; :func:`_pack_group` packs shared
                       tables once and this reads the packed array)
    ``vertex_bytes``   property values the kernel actually reads: the
                       gathered unique sources (Big) or the touched
                       source windows (Little — W values per distinct
                       window id)
    ``tile_bytes``     the merge scatter traffic: output tiles plus the
                       tile_idx scatter indices
    ``flops``          one-hot gather (E·W) + router (E·T) MACs over
                       padded edges, ×2 (multiply+add) — the numerator
                       of arithmetic intensity
    """
    geom: Geometry = p["geom"]
    nb = {k: (int(p[k].nbytes) if p.get(k) is not None
              and hasattr(p[k], "nbytes") else 0)
          for k in _DEVICE_KEYS}
    edge = nb["src_local"] + nb["dst_local"] + nb["weights"] + nb["valid"]
    index = (nb["window_id"] + nb["tile_id"] + nb["tile_first"]
             + nb["tile_idx"])
    table = nb["unique_src"]
    if p["kind"] == "big":
        # vwin = vprops[unique_src]: one property per table slot
        vertex = (int(p["unique_src"].shape[0]) * 4
                  if p.get("unique_src") is not None else 0)
    else:
        # Little streams whole windows; count each touched window once
        wids = np.asarray(p["window_id"])
        vertex = int(np.unique(wids).shape[0]) * geom.W * 4
    tiles = int(p["n_out_tiles"]) * geom.T * 4 + nb["tile_idx"]
    padded_e = int(p["n_blocks"]) * geom.E_BLK
    return {
        "kind": p["kind"],
        "edge_bytes": edge,
        "index_bytes": index,
        "table_bytes": table,
        "vertex_bytes": vertex,
        "tile_bytes": tiles,
        "flops": 2 * padded_e * (geom.W + geom.T),
        "padded_edges": padded_e,
        "real_edges": int(p["num_real_edges"]),
    }


def _chunk_entries(entries: List[dict], geom: Geometry,
                   budget: float) -> List[List[dict]]:
    """Greedily split a same-kind entry list so each chunk's estimated
    working set stays under ``budget`` bytes (0/negative = no limit).
    Chunk boundaries fall on ENTRY boundaries, which are tile-snapped
    already — each chunk is a valid packed payload and the lane's
    results stay bit-identical (the merge is one scatter-set over
    globally disjoint tiles either way; only launch count changes).
    A single entry over budget still forms its own chunk — entry
    granularity is the floor (the scheduler's block splits control it)."""
    if budget <= 0 or not entries:
        return [entries] if entries else []
    chunks, cur = [], []
    for e in entries:
        if cur and estimate_working_set(cur + [e], geom) > budget:
            chunks.append(cur)
            cur = []
        cur.append(e)
    if cur:
        chunks.append(cur)
    return chunks


def _pack_lane_np(lane, little_works, big_works,
                  max_working_set: float = 0.0) -> List[dict]:
    """Host-side packed payloads for one lane: at most one per kind (a
    lane may mix Little and Big entries when there are fewer lanes than
    pipeline classes), more when ``max_working_set`` (bytes) forces
    VMEM-pressure chunking. Returns [] for a fully snapped-away lane."""
    groups = {"little": [], "big": []}
    geom = None
    for e in lane:
        work = (little_works[e.work_id] if e.kind == "little"
                else big_works[e.work_id])
        geom = work.geom
        p = _entry_np(work, e.block_lo, e.block_hi)
        if p is not None:
            groups[e.kind].append(p)
    return [_pack_group(chunk)
            for g in (groups["little"], groups["big"]) if g
            for chunk in _chunk_entries(g, geom, max_working_set)]


def pack_lane(lane, little_works, big_works,
              max_working_set: float = 0.0) -> List[dict]:
    """Pack one lane's plan entries into at most two device payloads
    (more under VMEM chunking): materialized host-side, concatenated,
    validated, uploaded once."""
    return [_upload_payload(p)
            for p in _pack_lane_np(lane, little_works, big_works,
                                   max_working_set)]


def pack_lanes(plan, little_works, big_works,
               reuse: Optional[dict] = None,
               max_working_set: float = 0.0) -> List[List[dict]]:
    """Fused counterpart of :func:`materialize_lanes`: one packed payload
    per (lane, kind) instead of one payload per entry.

    ``reuse`` maps lane index -> already-packed device payload list (the
    streaming layer seeds it with payloads carried over from a
    pre-delta bundle whose lane is structurally unchanged). Reused lanes
    skip host-side packing AND the device upload entirely; they still
    participate in the global tile-disjointness check below.

    ``max_working_set`` (bytes; 0 = off) chunks a lane's packed segments
    when their estimated VMEM working set exceeds the device spec's
    per-lane budget (``HW.vmem_lane_budget``) — bit-identical results,
    just more launches on that lane."""
    reuse = reuse or {}
    host = [None if i in reuse
            else _pack_lane_np(lane, little_works, big_works,
                               max_working_set)
            for i, lane in enumerate(plan.lanes)]
    _check_lanes_disjoint(host, reuse)
    return [reuse[i] if lane is None else [_upload_payload(p) for p in lane]
            for i, lane in enumerate(host)]


def _check_lanes_disjoint(host, reuse) -> None:
    """Global tile disjointness ACROSS lanes: merge_all's single
    scatter-set (and the sharded path's single psum-style merge) rely on
    every destination tile being written by exactly one payload —
    duplicate scatter indices have an unspecified winner in XLA.
    ``_validate_packed`` only covers within-payload; this checks across.
    Runs on host copies (reused payloads' tile_idx pulled back — tiny
    per-tile arrays), before anything new is uploaded."""
    idx = []
    for i, lane in enumerate(host):
        if lane is None:
            idx += [np.asarray(p["tile_idx"]) for p in reuse[i]]
        else:
            idx += [p["tile_idx"] for p in lane]
    all_idx = np.concatenate(idx) if idx else np.zeros(0, np.int32)
    assert np.unique(all_idx).shape[0] == all_idx.shape[0], \
        "plan assigns the same destination tile to multiple lanes"


def pack_lanes_sharded(plan, little_works, big_works, owners, devices,
                       reuse: Optional[dict] = None,
                       max_working_set: float = 0.0):
    """Sharded counterpart of :func:`pack_lanes`: pack each lane
    host-side and upload its payloads to the OWNER device
    (``devices[owners[i]]`` for lane ``i``) instead of the default one.

    ``reuse`` maps lane index -> payload list already RESIDENT on the
    right device (streaming carry-over of clean, placement-stable
    lanes); reused lanes skip packing and the transfer entirely but
    still participate in the global disjointness check.

    Returns ``(lanes, moved, bytes_moved)`` where ``moved`` counts the
    non-empty lanes actually uploaded this call and ``bytes_moved``
    their device bytes — the ``shards_moved`` accounting streaming
    updates surface.
    """
    reuse = reuse or {}
    host = [None if i in reuse
            else _pack_lane_np(lane, little_works, big_works,
                               max_working_set)
            for i, lane in enumerate(plan.lanes)]
    _check_lanes_disjoint(host, reuse)
    lanes, moved, bytes_moved = [], 0, 0
    for i, lane in enumerate(host):
        if lane is None:
            lanes.append(reuse[i])
            continue
        up = [_upload_payload(p, device=devices[owners[i]]) for p in lane]
        if up:
            moved += 1
            bytes_moved += sum(payload_nbytes(p) for p in up)
        lanes.append(up)
    return lanes, moved, bytes_moved


def payload_nbytes(payload: dict) -> int:
    """Device bytes pinned by one (entry or packed) payload."""
    total = 0
    for k in _DEVICE_KEYS:
        v = payload.get(k)
        if v is not None and hasattr(v, "nbytes"):
            total += int(v.nbytes)
    return total


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

def run_entry(entry: dict, vprops_padded, scatter_fn, mode: str,
              path: Optional[str] = None):
    """Returns (tiles (n_out_tiles, T), tile_idx (n_out_tiles,))."""
    path = path or default_path()
    geom: Geometry = entry["geom"]
    args = (entry["src_local"], entry["dst_local"], entry["weights"],
            entry["valid"], entry["window_id"], entry["tile_id"],
            entry["tile_first"])
    if path == "ref":
        if entry["kind"] == "big":
            vwin = vprops_padded[entry["unique_src"]].reshape(-1, geom.W)
        else:
            vwin = vprops_padded.reshape(-1, geom.W)
        tiles = ref_mod.gas_ref(vwin, *args, scatter_fn=scatter_fn, mode=mode,
                                t=geom.T, n_out_tiles=entry["n_out_tiles"])
    else:
        interpret = jax.default_backend() != "tpu"
        if entry["kind"] == "big":
            tiles = big_pipeline(vprops_padded, entry["unique_src"], *args,
                                 scatter_fn=scatter_fn, mode=mode, geom=geom,
                                 n_out_tiles=entry["n_out_tiles"],
                                 interpret=interpret)
        else:
            tiles = little_pipeline(vprops_padded, *args,
                                    scatter_fn=scatter_fn, mode=mode,
                                    geom=geom,
                                    n_out_tiles=entry["n_out_tiles"],
                                    interpret=interpret)
    return tiles, entry["tile_idx"]


def run_lane(packed: dict, vprops_padded, scatter_fn, mode: str,
             path: Optional[str] = None):
    """Execute one packed lane payload (all same-kind entries of a lane)
    as a single kernel launch. Same contract as :func:`run_entry`:
    returns (tiles (n_out_tiles, T), tile_idx (n_out_tiles,))."""
    path = path or default_path()
    geom: Geometry = packed["geom"]
    args = (packed["src_local"], packed["dst_local"], packed["weights"],
            packed["valid"], packed["window_id"], packed["tile_id"],
            packed["tile_first"])
    if path == "ref":
        if packed["kind"] == "big":
            vwin = vprops_padded[packed["unique_src"]].reshape(-1, geom.W)
        else:
            vwin = vprops_padded.reshape(-1, geom.W)
        tiles = ref_mod.gas_ref(vwin, *args, scatter_fn=scatter_fn, mode=mode,
                                t=geom.T, n_out_tiles=packed["n_out_tiles"])
    else:
        interpret = jax.default_backend() != "tpu"
        kw = dict(scatter_fn=scatter_fn, mode=mode, geom=geom,
                  n_out_tiles=packed["n_out_tiles"],
                  n_segments=packed["n_entries"], interpret=interpret)
        if packed["kind"] == "big":
            tiles = big_pipeline_packed(vprops_padded, packed["unique_src"],
                                        *args, **kw)
        else:
            tiles = little_pipeline_packed(vprops_padded, *args, **kw)
    return tiles, packed["tile_idx"]


def merge_tiles(accum_padded, tiles, tile_idx, t: int):
    """Scatter-set entry results into the global accumulator. Tiles are
    disjoint across entries by construction (snap_to_tiles)."""
    acc = accum_padded.reshape(-1, t)
    acc = acc.at[tile_idx].set(tiles.astype(acc.dtype))
    return acc.reshape(-1)


def merge_all(accum_padded, outputs, t: int):
    """Fused merge: one tile-indexed scatter-set over ALL lanes' output
    tiles (``outputs`` is a list of (tiles, tile_idx) pairs, globally
    tile-disjoint by construction)."""
    if not outputs:
        return accum_padded
    tiles = jnp.concatenate([o[0] for o in outputs])
    idx = jnp.concatenate([o[1] for o in outputs])
    return merge_tiles(accum_padded, tiles, idx, t)
