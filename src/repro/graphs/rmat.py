"""R-MAT / Kronecker graph generator (Leskovec et al., JMLR'10).

The paper's synthetic datasets (rmat-19-32 etc.) use the Graph500 R-MAT
parameters (a,b,c,d) = (0.57, 0.19, 0.19, 0.05). We generate the same
family at CPU-feasible scales. Fully vectorised with numpy; O(E log V).
"""
from __future__ import annotations

import numpy as np

from .formats import Graph, from_edges

G500 = (0.57, 0.19, 0.19, 0.05)


def rmat(
    scale: int,
    edge_factor: int,
    params=G500,
    seed: int = 0,
    weighted: bool = False,
    name: str | None = None,
) -> Graph:
    """Generate an R-MAT graph with 2**scale vertices, edge_factor*V edges."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    num_edges = n * edge_factor
    a, b, c, d = params
    # Per-bit quadrant draws, vectorised over all edges at once.
    src = np.zeros(num_edges, dtype=np.int64)
    dst = np.zeros(num_edges, dtype=np.int64)
    ab = a + b
    a_norm = a / ab
    c_norm = c / (c + d)
    for bit in range(scale):
        r_row = rng.random(num_edges)
        go_down = r_row >= ab  # lower half of the adjacency quadrant
        r_col = rng.random(num_edges)
        right_top = r_col >= a_norm
        right_bot = r_col >= c_norm
        go_right = np.where(go_down, right_bot, right_top)
        src = (src << 1) | go_down
        dst = (dst << 1) | go_right
    # Random permutation of vertex labels to avoid artificial id-locality
    # beyond what DBG later re-creates deliberately.
    perm = rng.permutation(n)
    src = perm[src]
    dst = perm[dst]
    # Drop self loops.
    keep = src != dst
    src, dst = src[keep], dst[keep]
    weights = rng.random(src.shape[0]).astype(np.float32) if weighted else None
    gname = name or f"rmat-{scale}-{edge_factor}"
    return from_edges(src, dst, num_vertices=n, weights=weights, name=gname)


def uniform_random(scale: int, edge_factor: int, seed: int = 0,
                   name: str | None = None) -> Graph:
    """Erdos-Renyi-ish uniform graph — the 'no skew' control."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    return from_edges(src[keep], dst[keep], num_vertices=n,
                      name=name or f"uniform-{scale}-{edge_factor}")
