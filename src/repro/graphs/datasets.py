"""Dataset registry mirroring the paper's Table III at CPU-feasible scale.

The paper evaluates 16 graphs (0.5M-18M vertices, 5M-268M edges). This
container is CPU-only with limited RAM, so each dataset keeps the paper's
*shape* (degree distribution family, average degree, directedness) at a
reduced scale. Names keep the paper's initials with an `s` (scaled) suffix.
Real-world web/social graphs are emulated with R-MAT at matched average
degree plus a power-law exponent tweak — the workload-diversity phenomenon
the paper exploits (Fig. 2) is a function of the degree skew, which R-MAT
reproduces.
"""
from __future__ import annotations

from typing import Callable, Dict

from .formats import Graph
from .rmat import rmat, uniform_random

# name -> (factory, paper_counterpart, note)
_REGISTRY: Dict[str, tuple] = {}


def register(name: str, paper: str, note: str):
    def deco(fn: Callable[[], Graph]):
        _REGISTRY[name] = (fn, paper, note)
        return fn
    return deco


@register("r16s", "rmat-19-32 (R19)", "synthetic RMAT, deg 32")
def _r16s() -> Graph:
    return rmat(14, 32, seed=19, name="r16s")


@register("r18s", "rmat-21-32 (R21)", "synthetic RMAT, deg 32")
def _r18s() -> Graph:
    return rmat(15, 32, seed=21, name="r18s")


@register("r20s", "rmat-24-16 (R24)", "synthetic RMAT, deg 16")
def _r20s() -> Graph:
    return rmat(17, 16, seed=24, name="r20s")


@register("g17s", "graph500-scale23 (G23)", "graph500 RMAT, deg 56")
def _g17s() -> Graph:
    return rmat(13, 56, seed=23, name="g17s")


@register("ggs", "web-google (GG)", "web graph, deg 6")
def _ggs() -> Graph:
    return rmat(14, 6, seed=101, name="ggs")


@register("ams", "amazon-2008 (AM)", "social, deg 7")
def _ams() -> Graph:
    return rmat(14, 7, seed=102, name="ams")


@register("hds", "web-hudong (HD)", "web, deg 7")
def _hds() -> Graph:
    return rmat(15, 7, seed=103, name="hds")


@register("bbs", "web-baidu-baike (BB)", "web, deg 8")
def _bbs() -> Graph:
    return rmat(15, 8, seed=104, name="bbs")


@register("tcs", "wiki-topcats (TC)", "web, deg 16")
def _tcs() -> Graph:
    return rmat(14, 16, seed=105, name="tcs")


@register("pks", "pokec (PK)", "social, deg 19")
def _pks() -> Graph:
    return rmat(14, 19, seed=106, name="pks")


@register("ljs", "liveJournal (LJ)", "social, deg 14")
def _ljs() -> Graph:
    return rmat(15, 14, seed=107, name="ljs")


@register("hws", "hollywood-2009 (HW)", "collab, deg 53")
def _hws() -> Graph:
    return rmat(13, 53, seed=108, name="hws")


@register("ors", "orkut (OR)", "social, deg 38")
def _ors() -> Graph:
    return rmat(14, 38, seed=109, name="ors")


@register("unif16", "(control)", "uniform degree — no skew control")
def _unif16() -> Graph:
    return uniform_random(14, 16, seed=7, name="unif16")


def names() -> list:
    return list(_REGISTRY)


def info(name: str) -> dict:
    fn, paper, note = _REGISTRY[name]
    return {"name": name, "paper": paper, "note": note}


def load(name: str) -> Graph:
    fn, _, _ = _REGISTRY[name]
    return fn()
