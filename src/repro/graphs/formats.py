"""COO graph container and basic format utilities.

The paper (ReGraph §II-A) uses the standard COO representation with row
indices (source vertices) in ascending order. We keep the same canonical
form and add the degree statistics that drive degree-based grouping (DBG)
and the performance model.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Graph:
    """A directed graph in COO format.

    Invariants (enforced by :func:`canonicalize`):
      * ``src``/``dst`` are int32 arrays of equal length E.
      * edges sorted by (src, dst).
      * ``num_vertices`` >= max(src.max(), dst.max()) + 1.
    """

    num_vertices: int
    src: np.ndarray
    dst: np.ndarray
    weights: Optional[np.ndarray] = None
    name: str = "graph"

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def avg_degree(self) -> float:
        return self.num_edges / max(1, self.num_vertices)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.num_vertices).astype(np.int32)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.num_vertices).astype(np.int32)

    def fingerprint(self, refresh: bool = False) -> str:
        """Stable content hash of the graph (see :func:`fingerprint`).

        The digest is cached on the instance; rebinding ``weights`` (or
        any array attribute) to a *new* array invalidates it. Canonical
        graphs carry read-only arrays (see :func:`canonicalize`), so the
        cached digest can never go silently stale via in-place edits —
        structural change flows through :mod:`repro.streaming` deltas,
        which produce a new Graph (and a new fingerprint) instead.
        ``refresh=True`` forces a re-hash anyway (escape hatch for
        hand-built, still-writable Graphs).
        """
        cached = getattr(self, "_fp_cache", None)
        if (not refresh and cached is not None
                and cached[0] == self.num_vertices
                and cached[1] is self.src and cached[2] is self.dst
                and cached[3] is self.weights):
            return cached[4]
        fp = fingerprint(self)
        object.__setattr__(
            self, "_fp_cache",
            (self.num_vertices, self.src, self.dst, self.weights, fp))
        return fp

    def reversed(self) -> "Graph":
        """Transpose (used by pull-based execution: edges point dst->src)."""
        g = Graph(
            num_vertices=self.num_vertices,
            src=self.dst.copy(),
            dst=self.src.copy(),
            weights=None if self.weights is None else self.weights.copy(),
            name=self.name + "_T",
        )
        return canonicalize(g)


def fingerprint(g: Graph) -> str:
    """Stable content hash of a graph: vertex count + edge arrays (+
    weights when present). The ``name`` field is cosmetic and excluded,
    so the same edges loaded under two names share one fingerprint —
    this is the identity the serving layer keys GraphStores on.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(f"V={g.num_vertices};E={g.num_edges};".encode())
    h.update(np.ascontiguousarray(g.src, dtype=np.int32).tobytes())
    h.update(np.ascontiguousarray(g.dst, dtype=np.int32).tobytes())
    if g.weights is None:
        h.update(b";w=none")
    else:
        h.update(b";w=f32;")
        h.update(np.ascontiguousarray(g.weights, dtype=np.float32).tobytes())
    return h.hexdigest()


def freeze(g: Graph) -> Graph:
    """Mark the graph's arrays read-only. Every canonical Graph is
    frozen: the cached :meth:`Graph.fingerprint` (and every store /
    plan / packed-payload cache keyed on it) relies on edge arrays
    never mutating in place. Structural change goes through
    :mod:`repro.streaming` deltas, the only sanctioned mutation path.
    The arrays here are always fresh copies (fancy indexing), so this
    never freezes caller-owned buffers."""
    g.src.setflags(write=False)
    g.dst.setflags(write=False)
    if g.weights is not None:
        g.weights.setflags(write=False)
    return g


def canonicalize(g: Graph) -> Graph:
    """Sort edges by (src, dst) — the paper's ascending-row COO form.
    The sorted arrays are frozen (see :func:`freeze`)."""
    order = np.lexsort((g.dst, g.src))
    g.src = np.ascontiguousarray(g.src[order], dtype=np.int32)
    g.dst = np.ascontiguousarray(g.dst[order], dtype=np.int32)
    if g.weights is not None:
        g.weights = np.ascontiguousarray(g.weights[order], dtype=np.float32)
    return freeze(g)


def from_edges(
    src, dst, num_vertices: Optional[int] = None, weights=None, name: str = "graph",
    dedup: bool = True,
) -> Graph:
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if num_vertices is None:
        num_vertices = int(max(src.max(initial=-1), dst.max(initial=-1)) + 1)
    if dedup and src.size:
        key = src.astype(np.int64) * num_vertices + dst.astype(np.int64)
        _, idx = np.unique(key, return_index=True)
        src, dst = src[idx], dst[idx]
        if weights is not None:
            weights = np.asarray(weights, dtype=np.float32)[idx]
    g = Graph(num_vertices=num_vertices, src=src, dst=dst,
              weights=None if weights is None else np.asarray(weights, np.float32),
              name=name)
    return canonicalize(g)


def to_csr(g: Graph):
    """Return (indptr, indices[, weights]) CSR of the canonical COO."""
    indptr = np.zeros(g.num_vertices + 1, dtype=np.int64)
    np.add.at(indptr, g.src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, g.dst.copy(), (None if g.weights is None else g.weights.copy())


def relabel(g: Graph, perm: np.ndarray, name_suffix: str = "_dbg") -> Graph:
    """Relabel vertices: new_id = perm[old_id]; re-canonicalize."""
    assert perm.shape[0] == g.num_vertices
    g2 = Graph(
        num_vertices=g.num_vertices,
        src=perm[g.src].astype(np.int32),
        dst=perm[g.dst].astype(np.int32),
        weights=None if g.weights is None else g.weights.copy(),
        name=g.name + name_suffix,
    )
    return canonicalize(g2)


def degree_stats(g: Graph) -> dict:
    ind = g.in_degrees()
    outd = g.out_degrees()
    return {
        "V": g.num_vertices,
        "E": g.num_edges,
        "avg_deg": g.avg_degree,
        "max_in": int(ind.max(initial=0)),
        "max_out": int(outd.max(initial=0)),
        "p99_in": int(np.percentile(ind, 99)) if g.num_vertices else 0,
        "zero_in_frac": float((ind == 0).mean()) if g.num_vertices else 0.0,
    }
