"""GraphStore — the app-independent preparation layer (paper §IV-A).

Everything that depends only on ``(graph, Geometry)`` lives here and is
computed exactly once: the DBG permutation, dst-range partitioning (the
pristine :class:`PartitionInfo` stats plus partition-sorted edge arrays),
and the Little/Big brick blockings. Blockings are built lazily and
memoized — the first plan that needs a partition's Little layout (or a
batch's Big layout) pays for it, every later plan reuses it — so running
all five builtin apps against one store incurs the preprocessing cost
once. Plans themselves are cached per :class:`~.planner.PlanConfig`.

Layering (see repro/api.py and docs/ARCHITECTURE.md):

    GraphStore  — per (graph, geometry); owns edges + blockings
      Planner   — per PlanConfig; classification + lane schedule (cheap)
        Executor — per (plan, app); device arrays + jit'd iteration
        ShardedExecutor — per (plan, app, devices); lane-sharded
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .. import obs
from ..graphs.formats import Graph, relabel
from . import partition as part
from .types import BlockedEdges, Geometry, PartitionInfo


class GraphStore:
    """App-independent graph state, built once and shared by many plans.

    Parameters
    ----------
    graph:   input COO graph (original vertex ids).
    geom:    blocking geometry; one store serves exactly one geometry.
    use_dbg: apply degree-based grouping before partitioning (paper §II-A).
    max_plans: bound on the per-store plan LRU. Cached PlanBundles pin
             their materialized device-side lane entries, so an unbounded
             cache grows device memory with every distinct PlanConfig
             swept; the least-recently-used bundle is dropped once the
             bound is hit. Executors already holding an evicted bundle
             keep working — they own a reference; eviction only stops
             NEW plan() calls from reusing it.
    perm:    explicit vertex relabeling (``perm[old_id] = new_id``),
             overriding the DBG computation. Streaming equivalence
             checks use this to rebuild a cold store under a delta
             chain's frozen permutation; it also admits precomputed
             orderings (e.g. Gorder/RCM) in place of DBG.
    fingerprint: identity override. ``fingerprint()`` normally hashes
             the source graph lazily; the serving layer passes its own
             key here so stores rebuilt from a delta chain keep the
             CHAINED identity (which differs from the content hash of
             the materialized graph).
    """

    DEFAULT_MAX_PLANS = 32

    def __init__(self, graph: Graph, geom: Geometry = Geometry(),
                 use_dbg: bool = True, max_plans: Optional[int] = None,
                 perm: Optional[np.ndarray] = None,
                 fingerprint: Optional[str] = None):
        self.geom = geom
        self.use_dbg = use_dbg
        self.max_plans = (self.DEFAULT_MAX_PLANS if max_plans is None
                          else int(max_plans))
        if self.max_plans < 1:
            raise ValueError(f"max_plans must be >= 1, got {max_plans}")
        self.source = graph   # pre-DBG input, for sharing-mismatch checks
        self._fp = fingerprint

        t0 = time.perf_counter()
        with obs.span("store.dbg", "store", V=graph.num_vertices,
                      E=graph.num_edges, use_dbg=use_dbg):
            if perm is not None:
                perm = np.asarray(perm, dtype=np.int32)
                if perm.shape[0] != graph.num_vertices:
                    raise ValueError(
                        f"perm has {perm.shape[0]} entries for a graph of "
                        f"{graph.num_vertices} vertices")
                self.graph = relabel(graph, perm, name_suffix="_perm")
                self.perm = perm
            elif use_dbg:
                self.graph, self.perm = part.apply_dbg(graph)
            else:
                self.graph = graph
                self.perm = np.arange(graph.num_vertices, dtype=np.int32)
        self.t_dbg = time.perf_counter() - t0

        t0 = time.perf_counter()
        with obs.span("store.partition", "store") as sp:
            self._infos, self.edges = part.partition_graph(self.graph, geom)
            sp.set(partitions=len(self._infos))
        self.V_pad = part.padded_num_vertices(self.graph.num_vertices, geom)
        self.t_partition = time.perf_counter() - t0

        # lazy, memoized blockings (the expensive app-independent work)
        self._little_cache: Dict[int, BlockedEdges] = {}
        self._big_cache: Dict[Tuple[int, ...], BlockedEdges] = {}
        self.t_block = 0.0

        # plan LRU: PlanConfig.cache_key() -> PlanBundle (bounded by
        # max_plans; most-recently-used last)
        self._plan_cache: "collections.OrderedDict[tuple, object]" = \
            collections.OrderedDict()
        self._plan_lock = threading.RLock()
        self.plan_evictions = 0
        self._aux = None

    @classmethod
    def _derived(cls, base: "GraphStore", *, graph: Graph,
                 infos: List[PartitionInfo], edges: dict,
                 little_cache: Dict[int, BlockedEdges],
                 big_cache: Dict[Tuple[int, ...], BlockedEdges],
                 fingerprint: str, t_partition: float = 0.0,
                 perm: Optional[np.ndarray] = None,
                 V_pad: Optional[int] = None) -> "GraphStore":
        """Build a store by splicing delta-updated state into a base
        store's layout (used by :func:`repro.streaming.apply_delta`).
        Shares the base's frozen permutation and the untouched
        blockings; carries no source graph (``source is None`` — the
        chained ``fingerprint`` is its identity) and starts with an
        empty plan cache (the streaming layer rebuilds plans
        surgically). Vertex-growth deltas pass ``perm``/``V_pad``
        overrides: the permutation extended identity-wise over the new
        tail ids, and the padding recomputed for the grown vertex
        count (the lazy ``aux`` rebuilds against it). NOTE: while base
        and derived snapshots are BOTH alive (the old one draining out
        of the serving cache), shared state — perm, carried blockings,
        reused packed payloads — is counted in both stores'
        ``memory_footprint()``; like executor byte budgeting,
        footprints are conservative attribution, not exclusive
        ownership."""
        self = cls.__new__(cls)
        self.geom = base.geom
        self.use_dbg = base.use_dbg
        self.max_plans = base.max_plans
        self.source = None
        self._fp = fingerprint
        self.graph = graph
        self.perm = base.perm if perm is None else perm
        self.t_dbg = 0.0
        self._infos = infos
        self.edges = edges
        self.V_pad = base.V_pad if V_pad is None else int(V_pad)
        self.t_partition = t_partition
        self._little_cache = dict(little_cache)
        self._big_cache = dict(big_cache)
        self.t_block = 0.0
        self._plan_cache = collections.OrderedDict()
        self._plan_lock = threading.RLock()
        self.plan_evictions = 0
        self._aux = None
        return self

    # -- pickling (control-plane process pool) -------------------------
    def __getstate__(self) -> dict:
        """Ship the app-independent state only: locks don't pickle, the
        plan cache holds locks and device arrays (the receiving side
        re-plans — blockings make that cheap), and the jax aux rebuilds
        lazily. Used by ``repro.control.pool`` to move store builds and
        delta applies into worker processes."""
        state = self.__dict__.copy()
        # force the identity to a concrete string BEFORE dropping caches:
        # a derived store with a lazy fingerprint must not cross the
        # process boundary unresolved (its source may be None there)
        state["_fp"] = self.fingerprint()
        state["_plan_cache"] = None
        state["_plan_lock"] = None
        state["_aux"] = None
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._plan_cache = collections.OrderedDict()
        self._plan_lock = threading.RLock()
        self._aux = None

    def fingerprint(self) -> str:
        """Identity of the graph this store was built from: the source
        graph's content hash, or — for delta-derived stores — the
        chained ``(base_fp, delta_fp)`` fingerprint set at derivation.
        This is what :func:`repro.streaming.apply_delta` validates a
        delta's ``base_fp`` against."""
        if self._fp is None:
            if self.source is None:
                raise RuntimeError("derived store carries no source graph "
                                   "and was given no fingerprint")
            self._fp = self.source.fingerprint()
        return self._fp

    def validate_compatible(self, graph=None, geom=None, use_dbg=None):
        """Reject asks that contradict what this store was built with.
        ``None`` means "use the store's setting" and always passes."""
        if graph is not None and graph is not self.source:
            raise ValueError("store= was built from a different graph than "
                             "the one passed; pass graph=None or the "
                             "store's own graph")
        if geom is not None and geom != self.geom:
            raise ValueError(f"store was built with {self.geom}, but "
                             f"geom={geom} was requested")
        if use_dbg is not None and use_dbg != self.use_dbg:
            raise ValueError(f"store was built with use_dbg={self.use_dbg},"
                             f" but use_dbg={use_dbg} was requested")

    # -- partition stats ------------------------------------------------
    @property
    def infos(self) -> List[PartitionInfo]:
        """Pristine (unclassified) partition stats. Planners work on
        copies (see :meth:`copy_infos`) so one store serves plans with
        different hardware models or forced modes concurrently."""
        return self._infos

    def copy_infos(self) -> List[PartitionInfo]:
        return [dataclasses.replace(i) for i in self._infos]

    # -- memoized blocking ---------------------------------------------
    def little_work(self, pid: int) -> BlockedEdges:
        """Little-pipeline brick layout of one partition (memoized)."""
        w = self._little_cache.get(pid)
        if w is None:
            t0 = time.perf_counter()
            w = part.block_little(self.edges, self._infos[pid], self.geom)
            self.t_block += time.perf_counter() - t0
            self._little_cache[pid] = w
        return w

    def big_work(self, pids: Tuple[int, ...]) -> BlockedEdges:
        """Big-pipeline layout of one batch of partitions (memoized)."""
        pids = tuple(int(p) for p in pids)
        w = self._big_cache.get(pids)
        if w is None:
            t0 = time.perf_counter()
            w = part.block_big(self.edges, [self._infos[p] for p in pids],
                               self.geom)
            self.t_block += time.perf_counter() - t0
            self._big_cache[pids] = w
        return w

    # -- shared device-side aux ----------------------------------------
    @property
    def aux(self) -> dict:
        """Apply/init auxiliary data (device-resident out-degrees etc.),
        built once and shared by every Executor on this store (the lock
        keeps concurrent service workers from uploading it twice)."""
        if self._aux is None:
            with self._plan_lock:
                if self._aux is None:
                    outdeg = np.zeros(self.V_pad, np.float32)
                    outdeg[:self.graph.num_vertices] = \
                        self.graph.out_degrees()
                    self._aux = {
                        "outdeg": jnp.asarray(outdeg),
                        "num_v": float(self.graph.num_vertices),
                        "num_v_pad": self.V_pad,
                    }
        return self._aux

    # -- planning / execution ------------------------------------------
    def plan(self, config=None):
        """Build (or fetch the cached) :class:`~.planner.PlanBundle` for a
        :class:`~.planner.PlanConfig`. The cache is a bounded LRU (see
        ``max_plans``) and this method is thread-safe: concurrent callers
        asking for the same config get one build and one shared bundle."""
        from .planner import PlanConfig, Planner
        config = config or PlanConfig()
        key = config.cache_key()
        with self._plan_lock:
            bundle = self._plan_cache.get(key)
            if bundle is not None:
                self._plan_cache.move_to_end(key)
                return bundle
            with obs.span("plan.build", "planner",
                          n_lanes=config.n_lanes) as sp:
                bundle = Planner(self, config).build()
                sp.set(est_makespan=bundle.plan.est_makespan)
            self._plan_cache[key] = bundle
            while len(self._plan_cache) > self.max_plans:
                self._plan_cache.popitem(last=False)
                self.plan_evictions += 1
        return bundle

    def adopt_plan(self, bundle) -> None:
        """Insert a pre-built :class:`PlanBundle` into the plan LRU under
        its config's cache key (replacing any cached bundle for that
        key). This is the autotuner's atomic plan swap: the retuner
        builds + scores candidates OUTSIDE the cache (via Planner), then
        publishes only the winner here — one dict assignment under the
        plan lock, so concurrent ``plan()`` callers see either the old
        bundle or the new one, never a partial build."""
        key = bundle.config.cache_key()
        with self._plan_lock:
            self._plan_cache[key] = bundle
            self._plan_cache.move_to_end(key)
            while len(self._plan_cache) > self.max_plans:
                self._plan_cache.popitem(last=False)
                self.plan_evictions += 1

    def peek_plan(self, config=None):
        """Return the cached :class:`PlanBundle` for ``config`` WITHOUT
        building on a miss and without touching LRU recency (a pure
        peek). The control-plane scheduler uses this to read
        ``plan.est_makespan`` as a queued job's cost estimate — an
        estimate must never mutate cache state or trigger a build."""
        from .planner import PlanConfig
        config = config or PlanConfig()
        with self._plan_lock:
            return self._plan_cache.get(config.cache_key())

    def has_plan(self, config=None) -> bool:
        """True when ``plan(config)`` would hit the cache (does NOT touch
        LRU recency — a pure peek, used by serving metrics)."""
        from .planner import PlanConfig
        config = config or PlanConfig()
        with self._plan_lock:
            return config.cache_key() in self._plan_cache

    def clear_plans(self) -> dict:
        """Drop every cached PlanBundle (and the device-resident lane
        entries memoized on them). Blockings stay cached, so re-planning
        costs milliseconds. Use when sweeping many configs whose
        materialized entries would otherwise accumulate on device.

        Returns ``{"plans": evicted bundle count, "freed_bytes": device
        bytes those bundles pinned (per-entry + packed payloads)}`` —
        the same accounting the streaming layer uses to report what a
        partial invalidation did and did not carry over."""
        with self._plan_lock:
            n = len(self._plan_cache)
            freed = sum(_bundle_nbytes(b) for b in self._plan_cache.values())
            self._plan_cache.clear()
        return {"plans": n, "freed_bytes": int(freed)}

    def shard(self, config=None, devices=None):
        """Place and upload the (cached) plan's lanes across devices.

        The shard unit is the packed lane payload: lanes are
        LPT-assigned to devices from the perf model's per-lane
        estimates (Little and Big interleaved per device) and each
        lane's packed arrays are ``device_put`` to the owner. Returns
        the memoized :class:`~repro.sharding.executor.ShardedLanes`
        (placement + resident payloads + move/reuse accounting);
        ``devices`` is anything
        :func:`~repro.sharding.executor.resolve_devices` accepts
        (None = all local devices, int n = first n, or an explicit
        device sequence)."""
        from ..sharding.executor import resolve_devices
        return self.plan(config).sharded_lanes(resolve_devices(devices))

    def executor(self, app, config=None, path: Optional[str] = None,
                 fuse_lanes: bool = True, shard=None):
        """Materialize an executor for one app on the (cached) plan for
        ``config``.

        ``fuse_lanes=False`` falls back to one kernel launch per
        materialized plan entry (debug/AB path). ``shard`` switches to
        the multi-device :class:`~repro.sharding.executor.ShardedExecutor`
        (per-device lane ownership, one cross-device merge per
        iteration): ``True`` shards over every local device, an int
        over the first n, a device sequence over exactly those;
        ``None``/``False`` keeps the single-device Executor."""
        if shard is not None and shard is not False:
            from ..sharding.executor import ShardedExecutor
            return ShardedExecutor(self, self.plan(config), app,
                                   devices=shard, path=path)
        from .executor import Executor
        return Executor(self, self.plan(config), app, path=path,
                        fuse_lanes=fuse_lanes)

    def plan_and_run(self, app, config=None, path: Optional[str] = None,
                     max_iters: Optional[int] = None,
                     collect_history: bool = False):
        """One-call convenience: plan (cached) + execute one app."""
        ex = self.executor(app, config, path=path)
        return ex.run(max_iters=max_iters, collect_history=collect_history)

    # -- reporting ------------------------------------------------------
    def memory_footprint(self) -> dict:
        """Byte accounting of everything this store keeps alive: the
        (DBG'd) graph arrays, partition-sorted edge arrays, memoized
        Little/Big blockings, cached plans' device-resident lane entries,
        and the shared aux. Feeds the serving layer's byte-budgeted
        store LRU and metrics."""
        # delta-derived stores alias their graph arrays INTO the
        # partition-sorted edge arrays (zero-copy splice) — count shared
        # memory once, under edge_bytes
        shared = {id(a) for a in self.edges.values()}
        graph_bytes = sum(
            int(a.nbytes) for a in (self.graph.src, self.graph.dst,
                                    self.graph.weights)
            if a is not None and id(a) not in shared)
        graph_bytes += self.perm.nbytes
        edge_bytes = sum(int(a.nbytes) for a in self.edges.values())
        with self._plan_lock:
            blocking_bytes = sum(
                _blocked_nbytes(w) for w in self._little_cache.values())
            blocking_bytes += sum(
                _blocked_nbytes(w) for w in self._big_cache.values())
            plan_bytes = sum(_bundle_nbytes(b)
                             for b in self._plan_cache.values())
        aux_bytes = 0
        if self._aux is not None:
            aux_bytes = int(self._aux["outdeg"].nbytes)
        return {
            "graph_bytes": int(graph_bytes),
            "edge_bytes": int(edge_bytes),
            "blocking_bytes": int(blocking_bytes),
            "plan_bytes": int(plan_bytes),
            "aux_bytes": int(aux_bytes),
            "total_bytes": int(graph_bytes + edge_bytes + blocking_bytes
                               + plan_bytes + aux_bytes),
        }

    def placement_stats(self) -> dict:
        """Per-device placement section: lanes and payload bytes per
        device plus the worst imbalance ratio, aggregated over every
        cached plan's sharded materializations (empty-shaped —
        ``devices == 0`` — when nothing is sharded). Benchmarks and
        serving metrics read this instead of recomputing placement."""
        with self._plan_lock:
            bundles = list(self._plan_cache.values())
        # aggregate each form's own stats() — one definition of
        # "occupied lane" / per-device bytes, owned by ShardedLanes
        forms = [s.stats() for b in bundles
                 for s in list((getattr(b, "_sharded", None) or {})
                               .values())]
        n_dev = max((s["n_devices"] for s in forms), default=0)
        lanes = [0] * n_dev
        nbytes = [0] * n_dev
        for s in forms:
            for d in range(s["n_devices"]):
                lanes[d] += s["lanes_per_device"][d]
                nbytes[d] += s["bytes_per_device"][d]
        return {
            "devices": n_dev,
            "sharded_plans": len(forms),
            "lanes_per_device": lanes,
            "bytes_per_device": nbytes,
            "imbalance": max((s["imbalance"] for s in forms),
                             default=1.0),
        }

    def stats(self) -> dict:
        return {
            "V": self.graph.num_vertices,
            "E": self.graph.num_edges,
            "partitions": len(self._infos),
            "t_dbg_ms": self.t_dbg * 1e3,
            "t_partition_ms": self.t_partition * 1e3,
            "t_block_ms": self.t_block * 1e3,
            "cached_little_works": len(self._little_cache),
            "cached_big_works": len(self._big_cache),
            "cached_plans": len(self._plan_cache),
            "plan_evictions": self.plan_evictions,
            "placement": self.placement_stats(),
            **self.memory_footprint(),
        }


def _blocked_nbytes(w) -> int:
    """Host bytes held by one BlockedEdges (numpy brick arrays)."""
    total = 0
    for f in dataclasses.fields(w):
        v = getattr(w, f.name)
        if isinstance(v, np.ndarray):
            total += int(v.nbytes)
    return total


def _bundle_nbytes(bundle) -> int:
    """Bytes a cached PlanBundle pins BEYOND the store's own caches:
    its materialized device-side payloads, per-entry AND packed (the
    blockings it references are the store's memoized ones, counted once
    there). Un-materialized bundles pin ~nothing."""
    device_bytes = getattr(bundle, "device_bytes", None)
    if device_bytes is None:
        return 0
    return int(device_bytes()["total_bytes"])
