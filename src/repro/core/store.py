"""GraphStore — the app-independent preparation layer (paper §IV-A).

Everything that depends only on ``(graph, Geometry)`` lives here and is
computed exactly once: the DBG permutation, dst-range partitioning (the
pristine :class:`PartitionInfo` stats plus partition-sorted edge arrays),
and the Little/Big brick blockings. Blockings are built lazily and
memoized — the first plan that needs a partition's Little layout (or a
batch's Big layout) pays for it, every later plan reuses it — so running
all five builtin apps against one store incurs the preprocessing cost
once. Plans themselves are cached per :class:`~.planner.PlanConfig`.

Layering (see repro/api.py):

    GraphStore  — per (graph, geometry); owns edges + blockings
      Planner   — per PlanConfig; classification + lane schedule (cheap)
        Executor — per (plan, app); device arrays + jit'd iteration
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..graphs.formats import Graph
from . import partition as part
from .types import BlockedEdges, Geometry, PartitionInfo


class GraphStore:
    """App-independent graph state, built once and shared by many plans.

    Parameters
    ----------
    graph:   input COO graph (original vertex ids).
    geom:    blocking geometry; one store serves exactly one geometry.
    use_dbg: apply degree-based grouping before partitioning (paper §II-A).
    """

    def __init__(self, graph: Graph, geom: Geometry = Geometry(),
                 use_dbg: bool = True):
        self.geom = geom
        self.use_dbg = use_dbg
        self.source = graph   # pre-DBG input, for sharing-mismatch checks

        t0 = time.perf_counter()
        if use_dbg:
            self.graph, self.perm = part.apply_dbg(graph)
        else:
            self.graph = graph
            self.perm = np.arange(graph.num_vertices, dtype=np.int32)
        self.t_dbg = time.perf_counter() - t0

        t0 = time.perf_counter()
        self._infos, self.edges = part.partition_graph(self.graph, geom)
        self.V_pad = part.padded_num_vertices(self.graph.num_vertices, geom)
        self.t_partition = time.perf_counter() - t0

        # lazy, memoized blockings (the expensive app-independent work)
        self._little_cache: Dict[int, BlockedEdges] = {}
        self._big_cache: Dict[Tuple[int, ...], BlockedEdges] = {}
        self.t_block = 0.0

        # plan cache: PlanConfig.cache_key() -> PlanBundle
        self._plan_cache: Dict[tuple, "object"] = {}
        self._aux = None

    def validate_compatible(self, graph=None, geom=None, use_dbg=None):
        """Reject asks that contradict what this store was built with.
        ``None`` means "use the store's setting" and always passes."""
        if graph is not None and graph is not self.source:
            raise ValueError("store= was built from a different graph than "
                             "the one passed; pass graph=None or the "
                             "store's own graph")
        if geom is not None and geom != self.geom:
            raise ValueError(f"store was built with {self.geom}, but "
                             f"geom={geom} was requested")
        if use_dbg is not None and use_dbg != self.use_dbg:
            raise ValueError(f"store was built with use_dbg={self.use_dbg},"
                             f" but use_dbg={use_dbg} was requested")

    # -- partition stats ------------------------------------------------
    @property
    def infos(self) -> List[PartitionInfo]:
        """Pristine (unclassified) partition stats. Planners work on
        copies (see :meth:`copy_infos`) so one store serves plans with
        different hardware models or forced modes concurrently."""
        return self._infos

    def copy_infos(self) -> List[PartitionInfo]:
        return [dataclasses.replace(i) for i in self._infos]

    # -- memoized blocking ---------------------------------------------
    def little_work(self, pid: int) -> BlockedEdges:
        """Little-pipeline brick layout of one partition (memoized)."""
        w = self._little_cache.get(pid)
        if w is None:
            t0 = time.perf_counter()
            w = part.block_little(self.edges, self._infos[pid], self.geom)
            self.t_block += time.perf_counter() - t0
            self._little_cache[pid] = w
        return w

    def big_work(self, pids: Tuple[int, ...]) -> BlockedEdges:
        """Big-pipeline layout of one batch of partitions (memoized)."""
        pids = tuple(int(p) for p in pids)
        w = self._big_cache.get(pids)
        if w is None:
            t0 = time.perf_counter()
            w = part.block_big(self.edges, [self._infos[p] for p in pids],
                               self.geom)
            self.t_block += time.perf_counter() - t0
            self._big_cache[pids] = w
        return w

    # -- shared device-side aux ----------------------------------------
    @property
    def aux(self) -> dict:
        """Apply/init auxiliary data (device-resident out-degrees etc.),
        built once and shared by every Executor on this store."""
        if self._aux is None:
            outdeg = np.zeros(self.V_pad, np.float32)
            outdeg[:self.graph.num_vertices] = self.graph.out_degrees()
            self._aux = {
                "outdeg": jnp.asarray(outdeg),
                "num_v": float(self.graph.num_vertices),
                "num_v_pad": self.V_pad,
            }
        return self._aux

    # -- planning / execution ------------------------------------------
    def plan(self, config=None):
        """Build (or fetch the cached) :class:`~.planner.PlanBundle` for a
        :class:`~.planner.PlanConfig`."""
        from .planner import PlanConfig, Planner
        config = config or PlanConfig()
        key = config.cache_key()
        bundle = self._plan_cache.get(key)
        if bundle is None:
            bundle = Planner(self, config).build()
            self._plan_cache[key] = bundle
        return bundle

    def clear_plans(self) -> int:
        """Drop every cached PlanBundle (and the device-resident lane
        entries memoized on them). Blockings stay cached, so re-planning
        costs milliseconds. Use when sweeping many configs whose
        materialized entries would otherwise accumulate on device."""
        n = len(self._plan_cache)
        self._plan_cache.clear()
        return n

    def executor(self, app, config=None, path: Optional[str] = None):
        """Materialize an :class:`~.executor.Executor` for one app on the
        (cached) plan for ``config``."""
        from .executor import Executor
        return Executor(self, self.plan(config), app, path=path)

    def plan_and_run(self, app, config=None, path: Optional[str] = None,
                     max_iters: Optional[int] = None,
                     collect_history: bool = False):
        """One-call convenience: plan (cached) + execute one app."""
        ex = self.executor(app, config, path=path)
        return ex.run(max_iters=max_iters, collect_history=collect_history)

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        return {
            "V": self.graph.num_vertices,
            "E": self.graph.num_edges,
            "partitions": len(self._infos),
            "t_dbg_ms": self.t_dbg * 1e3,
            "t_partition_ms": self.t_partition * 1e3,
            "t_block_ms": self.t_block * 1e3,
            "cached_little_works": len(self._little_cache),
            "cached_big_works": len(self._big_cache),
            "cached_plans": len(self._plan_cache),
        }
