"""Model-guided task scheduling (paper §IV-B).

Inter-cluster: classify partitions dense/sparse by the perf model, then
choose the Little:Big lane split M:N minimising the worst cluster
finishing time. Intra-cluster: split work into equal-*time* chunks at
block granularity (the windowed equal-time cutting of the paper; our
"window" is the E_BLK block whose modelled time is uniform within a
partition), then LPT-pack chunks onto lanes.

Also provides the *monolithic* plan (ThunderGP-like homogeneous baseline:
every partition through the Big-style full pipeline) used by the
benchmarks as the state-of-the-art comparison point.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from . import perf_model
from .types import BlockedEdges, Geometry, PartitionInfo, PlanEntry, SchedulePlan


def batch_sparse(sparse: Sequence[PartitionInfo],
                 big_batch: int) -> List[List[PartitionInfo]]:
    """Group sparse partitions into Big-execution batches (paper: the Big
    pipelines process N_gpe partitions per execution)."""
    return [list(sparse[j:j + big_batch])
            for j in range(0, len(sparse), big_batch)]


def plan_from_config(
    infos: Sequence[PartitionInfo],
    little_works: Dict[int, BlockedEdges],
    big_works: List[BlockedEdges],
    big_work_ests: List[float],
    geom: Geometry,
    config,
) -> SchedulePlan:
    """Dispatch on a :class:`~.planner.PlanConfig` — the single entry
    point the Planner uses (replaces the engine's inline union switch)."""
    if config.mode == "model":
        return build_plan(infos, little_works, big_works, big_work_ests,
                          geom, config.n_lanes, config.hw)
    if config.mode == "monolithic":
        return monolithic_plan(infos, big_works, big_work_ests, geom,
                               config.n_lanes)
    return forced_split_plan(infos, little_works, big_works, big_work_ests,
                             geom, config.forced_little, config.forced_big,
                             config.hw)


def _lpt(items: List[Tuple[float, PlanEntry]], lanes: int) -> Tuple[List[List[PlanEntry]], float]:
    """Longest-processing-time-first packing; returns queues + makespan."""
    queues: List[List[PlanEntry]] = [[] for _ in range(lanes)]
    loads = np.zeros(lanes)
    for t, e in sorted(items, key=lambda x: -x[0]):
        k = int(np.argmin(loads))
        queues[k].append(e)
        loads[k] += t
    return queues, float(loads.max(initial=0.0))


def _split_entry(work: BlockedEdges, work_id: int, est: float,
                 n_chunks: int) -> List[Tuple[float, PlanEntry]]:
    """Equal-time splitting at block granularity (intra-cluster cutting)."""
    n_chunks = max(1, min(n_chunks, work.n_blocks or 1))
    bounds = np.linspace(0, work.n_blocks, n_chunks + 1).astype(int)
    out = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi > lo:
            frac = (hi - lo) / max(1, work.n_blocks)
            out.append((est * frac, PlanEntry(
                kind=work.kind, work_id=work_id,
                block_lo=int(lo), block_hi=int(hi), est_time=est * frac)))
    return out


def build_plan(
    infos: Sequence[PartitionInfo],
    little_works: Dict[int, BlockedEdges],   # pid -> blocked (dense partitions)
    big_works: List[BlockedEdges],           # batched sparse partitions
    big_work_ests: List[float],
    geom: Geometry,
    n_lanes: int,
    hw: perf_model.HW = perf_model.TPU_V5E,
) -> SchedulePlan:
    """Inter+intra cluster scheduling given pre-blocked works."""
    dense = [i for i in infos if i.is_dense and i.num_edges > 0]
    sparse = [i for i in infos if not i.is_dense and i.num_edges > 0]
    t_dense = sum(i.t_little for i in dense)
    t_sparse = float(sum(big_work_ests))

    # --- inter-cluster: choose M (little lanes) in [0..n_lanes] -------------
    best = None
    for m in range(0, n_lanes + 1):
        n = n_lanes - m
        if (t_dense > 0 and m == 0) or (t_sparse > 0 and n == 0):
            continue
        tl = t_dense / m if m else 0.0
        tb = t_sparse / n if n else 0.0
        worst = max(tl, tb)
        if best is None or worst < best[0]:
            best = (worst, m, n)
    if best is None:
        # fewer lanes than pipeline classes: lanes run BOTH kinds
        # sequentially (a queue may mix Little and Big entries)
        items = []
        for i in dense:
            items += _split_entry(little_works[i.pid], i.pid, i.t_little, 1)
        for wid, (w, est) in enumerate(zip(big_works, big_work_ests)):
            items += _split_entry(w, wid, est, 1)
        q, mk = _lpt(items, n_lanes)
        return SchedulePlan(
            geometry=geom, num_little_lanes=n_lanes, num_big_lanes=0,
            lanes=q, dense_pids=[i.pid for i in dense],
            sparse_pids=[i.pid for i in sparse], est_makespan=mk)
    _, M, N = best

    # --- intra-cluster: equal-time splitting + LPT --------------------------
    little_items: List[Tuple[float, PlanEntry]] = []
    if M:
        per_lane = t_dense / M
        for i in dense:
            w = little_works[i.pid]
            # split partitions whose modelled time exceeds a lane share
            n_chunks = max(1, int(np.ceil(i.t_little / max(per_lane, 1e-12))))
            little_items += _split_entry(w, i.pid, i.t_little, n_chunks)
    big_items: List[Tuple[float, PlanEntry]] = []
    if N:
        per_lane = t_sparse / N if t_sparse else 0.0
        for wid, (w, est) in enumerate(zip(big_works, big_work_ests)):
            n_chunks = max(1, int(np.ceil(est / max(per_lane, 1e-12))))
            big_items += _split_entry(w, wid, est, n_chunks)

    lq, lmax = _lpt(little_items, M) if M else ([], 0.0)
    bq, bmax = _lpt(big_items, N) if N else ([], 0.0)
    return SchedulePlan(
        geometry=geom, num_little_lanes=M, num_big_lanes=N,
        lanes=list(lq) + list(bq),
        dense_pids=[i.pid for i in dense],
        sparse_pids=[i.pid for i in sparse],
        est_makespan=max(lmax, bmax),
    )


def monolithic_plan(
    infos: Sequence[PartitionInfo],
    big_works: List[BlockedEdges],
    big_work_ests: List[float],
    geom: Geometry,
    n_lanes: int,
) -> SchedulePlan:
    """Homogeneous baseline: ALL partitions on Big-style pipelines (the
    monolithic, worst-case-provisioned design of prior work)."""
    items: List[Tuple[float, PlanEntry]] = []
    tot = float(sum(big_work_ests))
    per_lane = tot / max(n_lanes, 1)
    for wid, (w, est) in enumerate(zip(big_works, big_work_ests)):
        n_chunks = max(1, int(np.ceil(est / max(per_lane, 1e-12))))
        items += _split_entry(w, wid, est, n_chunks)
    q, mk = _lpt(items, n_lanes)
    return SchedulePlan(
        geometry=geom, num_little_lanes=0, num_big_lanes=n_lanes, lanes=q,
        dense_pids=[], sparse_pids=[i.pid for i in infos if i.num_edges > 0],
        est_makespan=mk,
    )


def forced_split_plan(
    infos: Sequence[PartitionInfo],
    little_works: Dict[int, BlockedEdges],
    big_works: List[BlockedEdges],
    big_work_ests: List[float],
    geom: Geometry,
    m: int,
    n: int,
    hw: perf_model.HW = perf_model.TPU_V5E,
) -> SchedulePlan:
    """Fix M:N (paper Fig. 10 sweep). M==0 → all partitions via Big;
    N==0 → all via Little."""
    if m == 0:
        return monolithic_plan(infos, big_works, big_work_ests, geom, n)
    if n == 0:
        items = []
        for i in infos:
            if i.num_edges == 0 or i.pid not in little_works:
                continue
            w = little_works[i.pid]
            items += _split_entry(w, i.pid, i.t_little, 1)
        tot = sum(t for t, _ in items)
        per_lane = tot / m if m else 0.0
        items2 = []
        for t, e in items:
            n_chunks = max(1, int(np.ceil(t / max(per_lane, 1e-12))))
            w = little_works[e.work_id]
            items2 += _split_entry(w, e.work_id, t, n_chunks)
        q, mk = _lpt(items2, m)
        return SchedulePlan(geometry=geom, num_little_lanes=m, num_big_lanes=0,
                            lanes=q, dense_pids=[i.pid for i in infos],
                            sparse_pids=[], est_makespan=mk)
    # fixed mixed split: keep model classification, override lane counts
    dense = [i for i in infos if i.is_dense and i.num_edges > 0]
    plan = build_plan(infos, little_works, big_works, big_work_ests, geom,
                      m + n, hw)
    # rebuild with forced M:N
    t_dense = sum(i.t_little for i in dense)
    little_items = []
    per_lane = t_dense / m if m else 0.0
    for i in dense:
        w = little_works[i.pid]
        n_chunks = max(1, int(np.ceil(i.t_little / max(per_lane, 1e-12))))
        little_items += _split_entry(w, i.pid, i.t_little, n_chunks)
    t_sparse = float(sum(big_work_ests))
    big_items = []
    per_lane_b = t_sparse / n if n else 0.0
    for wid, (w, est) in enumerate(zip(big_works, big_work_ests)):
        n_chunks = max(1, int(np.ceil(est / max(per_lane_b, 1e-12))))
        big_items += _split_entry(w, wid, est, n_chunks)
    lq, lmax = _lpt(little_items, m)
    bq, bmax = _lpt(big_items, n)
    return SchedulePlan(geometry=geom, num_little_lanes=m, num_big_lanes=n,
                        lanes=list(lq) + list(bq),
                        dense_pids=[i.pid for i in dense],
                        sparse_pids=plan.sparse_pids,
                        est_makespan=max(lmax, bmax))
