"""Heterogeneous Big/Little graph engine (paper §III + §IV end-to-end).

Pipeline: DBG relabel → dst-range partitioning → perf-model classification
→ brick blocking (Little per dense partition, Big per sparse batch) →
model-guided scheduling → iterate (Scatter+Gather kernels → merge → Apply)
until the app converges.

``plan_mode``:
  "model"       — paper's model-guided heterogeneous plan (default)
  ("fixed",M,N) — forced lane split (paper Fig. 10 sweep)
  "monolithic"  — homogeneous Big-only baseline (ThunderGP-like SOTA)
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.formats import Graph
from ..kernels import ops
from . import partition as part
from . import perf_model, schedule
from .gas import GASApp, GATHER_IDENTITY
from .types import Geometry, SchedulePlan


class HeterogeneousEngine:
    def __init__(
        self,
        graph: Graph,
        app: GASApp,
        geom: Geometry = Geometry(),
        n_lanes: int = 8,
        hw: perf_model.HW = perf_model.TPU_V5E,
        path: Optional[str] = None,
        use_dbg: bool = True,
        plan_mode="model",
    ):
        self.app = app
        self.geom = geom
        self.n_lanes = n_lanes
        self.hw = hw
        self.path = path or ops.default_path()

        t0 = time.perf_counter()
        if use_dbg:
            self.graph, self.perm = part.apply_dbg(graph)
        else:
            self.graph = graph
            self.perm = np.arange(graph.num_vertices, dtype=np.int32)
        self.t_dbg = time.perf_counter() - t0

        t0 = time.perf_counter()
        self.infos, self.edges = part.partition_graph(self.graph, geom)
        perf_model.classify(self.infos, geom, hw)
        if plan_mode == "monolithic":
            for i in self.infos:
                i.is_dense = False
        elif isinstance(plan_mode, tuple):
            _, m_forced, n_forced = plan_mode
            if m_forced == 0:      # all work through Big pipelines
                for i in self.infos:
                    i.is_dense = False
            elif n_forced == 0:    # all work through Little pipelines
                for i in self.infos:
                    i.is_dense = True
        self.V_pad = part.padded_num_vertices(self.graph.num_vertices, geom)

        # --- blocking -------------------------------------------------------
        self.little_works = {}
        dense = [i for i in self.infos if i.is_dense and i.num_edges > 0]
        sparse = [i for i in self.infos if not i.is_dense and i.num_edges > 0]
        for i in dense:
            self.little_works[i.pid] = part.block_little(self.edges, i, geom)
        self.big_works, self.big_ests = [], []
        for j in range(0, len(sparse), geom.big_batch):
            batch = sparse[j:j + geom.big_batch]
            self.big_works.append(part.block_big(self.edges, batch, geom))
            self.big_ests.append(perf_model.estimate_big_batch(batch, geom, hw))

        # --- scheduling -------------------------------------------------------
        if plan_mode == "model":
            self.plan = schedule.build_plan(
                self.infos, self.little_works, self.big_works, self.big_ests,
                geom, n_lanes, hw)
        elif plan_mode == "monolithic":
            self.plan = schedule.monolithic_plan(
                self.infos, self.big_works, self.big_ests, geom, n_lanes)
        else:
            _, m, n = plan_mode
            self.plan = schedule.forced_split_plan(
                self.infos, self.little_works, self.big_works, self.big_ests,
                geom, m, n, hw)
        self.t_schedule = time.perf_counter() - t0

        # --- materialization --------------------------------------------------
        self.lane_entries: List[List[Tuple[tuple, dict]]] = []
        for lane in self.plan.lanes:
            mat = []
            for e in lane:
                work = (self.little_works[e.work_id] if e.kind == "little"
                        else self.big_works[e.work_id])
                p = ops.materialize_entry(work, e.block_lo, e.block_hi)
                if p is not None:
                    mat.append(p)
            self.lane_entries.append(mat)

        # aux for apply/init
        outdeg = np.zeros(self.V_pad, np.float32)
        outdeg[:self.graph.num_vertices] = self.graph.out_degrees()
        self.aux = {
            "outdeg": jnp.asarray(outdeg),
            "num_v": float(self.graph.num_vertices),
            "num_v_pad": self.V_pad,
        }
        self._iter_fn = None

    # ------------------------------------------------------------------
    @property
    def accum_dtype(self):
        return jnp.int32 if self.app.gather == "or" else jnp.float32

    def _build_iteration(self):
        app, geom, path = self.app, self.geom, self.path
        entries = [p for lane in self.lane_entries for p in lane]
        ident = GATHER_IDENTITY[app.gather]
        dt = self.accum_dtype

        def iteration(vprops, aux, it):
            accum = jnp.full((self.V_pad,), ident, dt)
            for p in entries:
                tiles, idx = ops.run_entry(p, vprops, app.scatter, app.gather,
                                           path)
                accum = ops.merge_tiles(accum, tiles, idx, geom.T)
            return app.apply(accum, vprops, aux, it)

        return jax.jit(iteration)

    def init_props(self):
        p = self.app.init(self.aux | {
            "outdeg": np.asarray(self.aux["outdeg"]),
            "perm": self.perm,
        })
        full = np.full(self.V_pad, GATHER_IDENTITY[self.app.gather],
                       np.int32 if self.app.gather == "or" else np.float32)
        full[:p.shape[0]] = p[:self.V_pad]
        if self.app.name == "pagerank":
            full[self.graph.num_vertices:] = 0.0
        return jnp.asarray(full)

    def run(self, max_iters: Optional[int] = None, collect_history=False):
        """Run to convergence; returns props in ORIGINAL vertex ids."""
        if self._iter_fn is None:
            self._iter_fn = self._build_iteration()
        vprops = self.init_props()
        iters = max_iters or self.app.max_iters
        history = []
        it_done = 0
        for it in range(iters):
            new = self._iter_fn(vprops, self.aux, it)
            new.block_until_ready()
            it_done = it + 1
            if collect_history:
                history.append(np.asarray(new))
            if self.app.converged(vprops, new, it):
                vprops = new
                break
            vprops = new
        out = np.asarray(vprops)[self.perm]  # back to original ids
        return out, {"iterations": it_done, "history": history}

    # ------------------------------------------------------------------
    def time_iteration(self, repeats: int = 5) -> float:
        """Median wall time of one full iteration (all lanes, serialised —
        single host device). Used by benchmarks."""
        if self._iter_fn is None:
            self._iter_fn = self._build_iteration()
        vprops = self.init_props()
        self._iter_fn(vprops, self.aux, 0).block_until_ready()  # warmup
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            self._iter_fn(vprops, self.aux, 0).block_until_ready()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    def time_lanes(self, repeats: int = 3):
        """Per-lane wall times — the quantity the scheduler balances.
        On real hardware lanes run concurrently; on the host we time them
        one by one and report max() as the modelled makespan analogue."""
        app, geom, path = self.app, self.geom, self.path
        ident = GATHER_IDENTITY[app.gather]
        dt = self.accum_dtype
        vprops = self.init_props()
        out = []
        for lane in self.lane_entries:
            if not lane:
                out.append(0.0)
                continue

            def lane_fn(vp, entries=tuple(range(len(lane))), lane=lane):
                accum = jnp.full((self.V_pad,), ident, dt)
                for p in lane:
                    tiles, idx = ops.run_entry(p, vp, app.scatter, app.gather,
                                               path)
                    accum = ops.merge_tiles(accum, tiles, idx, geom.T)
                return accum

            f = jax.jit(lane_fn)
            f(vprops).block_until_ready()
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                f(vprops).block_until_ready()
                ts.append(time.perf_counter() - t0)
            out.append(float(np.median(ts)))
        return out

    def stats(self) -> dict:
        dense = [i for i in self.infos if i.is_dense]
        sparse = [i for i in self.infos if i.is_dense is False and i.num_edges]
        return {
            "V": self.graph.num_vertices, "E": self.graph.num_edges,
            "partitions": len(self.infos),
            "dense": len(dense), "sparse": len(sparse),
            "little_lanes": self.plan.num_little_lanes,
            "big_lanes": self.plan.num_big_lanes,
            "est_makespan": self.plan.est_makespan,
            "t_dbg_ms": self.t_dbg * 1e3,
            "t_partition_schedule_ms": self.t_schedule * 1e3,
        }


def run_app(graph: Graph, app: GASApp, **kw):
    eng = HeterogeneousEngine(graph, app, **kw)
    return eng.run()
