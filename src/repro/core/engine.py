"""DEPRECATED monolithic facade over the layered API.

``HeterogeneousEngine`` used to fuse app-independent preparation,
scheduling, and execution into one eager constructor. It is now a thin
shim over the three layers in ``repro.api``:

    GraphStore (graph prep, built once)  →  Planner (PlanConfig → plan)
        →  Executor (materialization + jit'd run loop)

New code should use ``repro.api`` directly::

    from repro import api
    store = api.GraphStore(graph, geom=geom)
    props, meta = store.plan_and_run(app)           # plan cached per config

The shim keeps every legacy attribute (``infos``, ``edges``, ``plan``,
``little_works`` …) so existing tests and benchmarks keep working, and
accepts the legacy ``plan_mode: str | tuple`` union (converted via
``PlanConfig.from_legacy``). Pass ``store=`` to share one GraphStore
across several engines (the plan cache then amortizes preprocessing).
``DistributedEngine`` no longer consumes the shim — it takes a
``GraphStore`` directly (see core/distributed.py).
"""
from __future__ import annotations

import warnings
from typing import Optional

from ..graphs.formats import Graph
from . import perf_model
from .executor import Executor
from .gas import GASApp
from .planner import PlanConfig
from .store import GraphStore
from .types import Geometry


class HeterogeneousEngine:
    def __init__(
        self,
        graph: Optional[Graph],
        app: GASApp,
        geom: Optional[Geometry] = None,
        n_lanes: int = 8,
        hw: perf_model.HW = perf_model.TPU_V5E,
        path: Optional[str] = None,
        use_dbg: Optional[bool] = None,
        plan_mode="model",
        store: Optional[GraphStore] = None,
    ):
        warnings.warn(
            "HeterogeneousEngine is deprecated; use the layered API in "
            "repro.api (GraphStore → Planner → Executor, or "
            "repro.api.compile).", DeprecationWarning, stacklevel=2)
        self.app = app
        self.n_lanes = n_lanes
        self.hw = hw
        if store is not None:
            # a shared store fixes graph/geometry/DBG — reject mismatches
            store.validate_compatible(graph=graph, geom=geom,
                                      use_dbg=use_dbg)
        else:
            if graph is None:
                raise ValueError("HeterogeneousEngine needs a graph when "
                                 "no store= is given")
            store = GraphStore(graph, geom=geom or Geometry(),
                               use_dbg=use_dbg if use_dbg is not None
                               else True)
        self.store = store
        self.geom = self.store.geom
        self.config = PlanConfig.from_legacy(plan_mode, n_lanes, hw)
        self.bundle = self.store.plan(self.config)
        self.executor = Executor(self.store, self.bundle, app, path=path)
        self.path = self.executor.path

    # --- legacy attribute surface (delegation) -------------------------
    @property
    def graph(self):
        return self.store.graph

    @property
    def perm(self):
        return self.store.perm

    @property
    def edges(self):
        return self.store.edges

    @property
    def V_pad(self):
        return self.store.V_pad

    @property
    def t_dbg(self):
        return self.store.t_dbg

    @property
    def t_schedule(self):
        # legacy: one timer over partition + classify + block + schedule.
        # Plan-local blocking time keeps this reproducible when a store
        # is shared across engines (a fresh store pays it all here).
        return (self.store.t_partition + self.bundle.t_block
                + self.bundle.t_plan)

    @property
    def infos(self):
        return self.bundle.infos

    @property
    def little_works(self):
        return self.bundle.little_works

    @property
    def big_works(self):
        return self.bundle.big_works

    @property
    def big_ests(self):
        return self.bundle.big_ests

    @property
    def plan(self):
        return self.bundle.plan

    @property
    def lane_entries(self):
        return self.executor.lane_entries

    @property
    def aux(self):
        return self.executor.aux

    @property
    def accum_dtype(self):
        return self.executor.accum_dtype

    # --- legacy methods ------------------------------------------------
    def _build_iteration(self):
        return self.executor._build_iteration()

    def init_props(self):
        return self.executor.init_props()

    def run(self, max_iters: Optional[int] = None, collect_history=False):
        return self.executor.run(max_iters=max_iters,
                                 collect_history=collect_history)

    def time_iteration(self, repeats: int = 5) -> float:
        return self.executor.time_iteration(repeats=repeats)

    def time_lanes(self, repeats: int = 3):
        return self.executor.time_lanes(repeats=repeats)

    def stats(self) -> dict:
        return self.executor.stats()


def run_app(graph: Graph, app: GASApp, **kw):
    eng = HeterogeneousEngine(graph, app, **kw)
    return eng.run()
