"""Chunk-granular SPMD execution over a GraphStore plan via shard_map.

One of the repo's two multi-device paths, built directly on the layered
GraphStore → Planner → Executor API: ``DistributedEngine(store, app)``
plans on the store (cached per :class:`~.planner.PlanConfig`), re-chunks
the plan's blocked works into fixed-shape units (tile-snapped, so chunks
never share a destination tile), LPT-balances chunks across the mesh
with a uniform per-block cost model, and runs ONE ``shard_map`` program
in which every device scans its stacked chunk queue — Little chunks and
Big chunks — accumulating a device-local property delta. The
cross-device merge is a collective psum/pmin/pmax (tiles are
device-disjoint, so 'or' merges exactly via psum).

The other path is ``repro.sharding`` (lane-granular: the packed lane
payload is the shard unit, per-device jit'd fns instead of one SPMD
program, native payload shapes, streaming payload-residency reuse).
This module trades that flexibility for a single fixed-shape SPMD
program — padding chunks to a uniform (depth, B, E_BLK) stack — which
is the shape collective-offload compilers want; it also serves as the
shard_map reference the lane-granular path is tested against.

At real scale the vertex property array would be window-sharded with a
halo exchange; on a multi-pod mesh the graph engine runs as a
per-pod-replica service, so vprops stays replicated here (it is the
small array; edges dominate and are fully sharded).
"""
from __future__ import annotations

from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops
from ..kernels import ref as ref_mod
from .executor import init_props
from .gas import GATHER_IDENTITY
from .types import BlockedEdges, Geometry

# --- jax version compat ----------------------------------------------------
# jax >= 0.6 promotes shard_map to jax.shard_map and replaces the old
# replication checker with varying-manual-axes (pcast marks an array
# varying). On the pinned 0.4.x line, shard_map lives in experimental and
# check_rep=False plays the role of the explicit pcast.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _exp_shard_map
    _shard_map = partial(_exp_shard_map, check_rep=False)


def _mark_varying(x, axis: str):
    """Tell the manual-axes checker the accumulator diverges across
    devices once sharded chunks land (no-op where pcast is absent and
    check_rep is off)."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, (axis,), to="varying")


def _chunk_work(work: BlockedEdges, blocks_per_chunk: int) -> List[tuple]:
    """Split a work into tile-snapped chunks of <= blocks_per_chunk."""
    chunks = []
    lo = 0
    while lo < work.n_blocks:
        hi = ops.snap_down(work, min(lo + blocks_per_chunk, work.n_blocks))
        if hi <= lo:  # giant tile: overflow a chunk (rare; keep correctness)
            nxt = lo + blocks_per_chunk
            while nxt < work.n_blocks and work.tile_first[nxt] != 1:
                nxt += 1
            hi = min(nxt, work.n_blocks)
        chunks.append((work, lo, hi))
        lo = hi
    return chunks


def _stack_chunks(chunks, B, geom: Geometry, umax: int, kind: str):
    """Pad each chunk to B blocks / B tiles and stack into numpy arrays."""
    E = geom.E_BLK
    n = len(chunks)
    out = {
        "src_local": np.zeros((n, B, E), np.int32),
        "dst_local": np.zeros((n, B, E), np.int32),
        "weights": np.zeros((n, B, E), np.float32),
        "valid": np.zeros((n, B, E), np.int32),
        "window_id": np.zeros((n, B), np.int32),
        "tile_id": np.zeros((n, B), np.int32),
        "tile_first": np.zeros((n, B), np.int32),
        "tile_idx": np.full((n, B), 2**30, np.int32),  # OOB -> dropped
    }
    if kind == "big":
        out["unique_src"] = np.zeros((n, umax), np.int32)
    for ci, (work, lo, hi) in enumerate(chunks):
        nb = hi - lo
        t0 = int(work.tile_id[lo])
        t1 = int(work.tile_id[hi - 1]) + 1
        out["src_local"][ci, :nb] = work.src_local[lo:hi]
        out["dst_local"][ci, :nb] = work.dst_local[lo:hi]
        out["weights"][ci, :nb] = work.weights[lo:hi]
        out["valid"][ci, :nb] = work.valid[lo:hi]
        out["window_id"][ci, :nb] = work.window_id[lo:hi]
        out["window_id"][ci, nb:] = work.window_id[hi - 1] if nb else 0
        tid = work.tile_id[lo:hi] - t0
        out["tile_id"][ci, :nb] = tid
        out["tile_id"][ci, nb:] = tid[-1] if nb else 0
        tf = work.tile_first[lo:hi].copy()
        if nb:
            tf[0] = 1
        out["tile_first"][ci, :nb] = tf
        out["tile_idx"][ci, :t1 - t0] = work.tile_dst_start[t0:t1] // geom.T
        if kind == "big":
            u = work.unique_src
            out["unique_src"][ci, :u.shape[0]] = u
    return out


class DistributedEngine:
    """Chunk-granular SPMD runner for one app on a GraphStore.

    Parameters
    ----------
    store:  a prepared :class:`~.store.GraphStore`.
    app:    the :class:`~.gas.GASApp` to execute.
    config: :class:`~.planner.PlanConfig` for the (cached) plan whose
            blocked works are chunked; defaults to ``PlanConfig()``.
    mesh:   jax mesh to run on (defaults to a 1-D mesh over every
            local device).
    blocks_per_chunk: chunk size in E_BLK blocks before tile-snapping
            (the fixed shape every chunk is padded to).
    axis:   mesh axis name the chunk queues are sharded over.

    ``run`` matches ``Executor.run``'s contract: returns props in
    ORIGINAL vertex ids plus an iteration count, numerically matching
    the single-device paths up to reduction order (the collective merge
    is exact for min/max/or; 'sum' apps may differ by 1 ULP).
    """

    def __init__(self, store, app, config=None, mesh: Optional[Mesh] = None,
                 blocks_per_chunk: int = 32, axis: str = "pipe"):
        from .planner import PlanConfig
        self.store = store
        self.app = app
        self.bundle = store.plan(config or PlanConfig())
        self.axis = axis
        self.geom = store.geom
        self.V_pad = store.V_pad
        if mesh is None:
            devs = np.array(jax.devices())
            mesh = Mesh(devs, (axis,))
        self.mesh = mesh
        self.n_dev = mesh.devices.size
        B = blocks_per_chunk

        little = [c for w in self.bundle.little_works.values()
                  for c in _chunk_work(w, B)]
        big = [c for w in self.bundle.big_works for c in _chunk_work(w, B)]
        self.Bl = max([hi - lo for _, lo, hi in little], default=1)
        self.Bb = max([hi - lo for _, lo, hi in big], default=1)
        umax = max([w.unique_src.shape[0]
                    for w in self.bundle.big_works], default=0)
        umax = max(umax, self.geom.W)

        # LPT-balance chunks over devices (est ~ #blocks; uniform-cost model)
        def balance(chunks):
            queues = [[] for _ in range(self.n_dev)]
            loads = np.zeros(self.n_dev)
            for c in sorted(chunks, key=lambda c: -(c[2] - c[1])):
                k = int(np.argmin(loads))
                queues[k].append(c)
                loads[k] += c[2] - c[1]
            depth = max((len(q) for q in queues), default=0)
            return queues, depth

        lq, ld = balance(little)
        bq, bd = balance(big)
        self.ld, self.bd = max(ld, 1), max(bd, 1)

        def stack_all(queues, depth, Bpad, kind):
            per_dev = []
            for q in queues:
                s = _stack_chunks(q, Bpad, self.geom, umax, kind)
                pad = depth - len(q)
                if pad:
                    for k, v in s.items():
                        shape = (pad,) + v.shape[1:]
                        fill = np.full(shape, 2**30, np.int32) \
                            if k == "tile_idx" else np.zeros(shape, v.dtype)
                        s[k] = np.concatenate([v, fill], 0)
                per_dev.append(s)
            return {k: np.stack([d[k] for d in per_dev])
                    for k in per_dev[0]} if per_dev else None

        self.little_stack = stack_all(lq, self.ld, self.Bl, "little")
        self.big_stack = stack_all(bq, self.bd, self.Bb, "big")
        self._iter_fn = None

    def _build(self):
        app, geom = self.app, self.geom
        ident = GATHER_IDENTITY[app.gather]
        dt = jnp.int32 if app.gather == "or" else jnp.float32
        V_pad, T, axis = self.V_pad, geom.T, self.axis
        n_rows = V_pad // T

        def run_chunk(vwin, c, n_tiles):
            return ref_mod.gas_ref(
                vwin, c["src_local"], c["dst_local"], c["weights"], c["valid"],
                c["window_id"], c["tile_id"], c["tile_first"],
                scatter_fn=app.scatter, mode=app.gather, t=T,
                n_out_tiles=n_tiles)

        def scan_queue(accum, vprops, stack, kind, n_tiles):
            def body(acc, c):
                if kind == "big":
                    vwin = vprops[c["unique_src"]].reshape(-1, geom.W)
                else:
                    vwin = vprops.reshape(-1, geom.W)
                tiles = run_chunk(vwin, c, n_tiles)
                a = acc.reshape(n_rows, T)
                a = a.at[c["tile_idx"][:n_tiles]].set(
                    tiles.astype(a.dtype), mode="drop")
                return a.reshape(-1), None
            accum, _ = jax.lax.scan(body, accum, stack)
            return accum

        combine = {"sum": jax.lax.psum, "or": jax.lax.psum,
                   "min": jax.lax.pmin, "max": jax.lax.pmax}[app.gather]

        @partial(_shard_map, mesh=self.mesh,
                 in_specs=(P(), P(axis), P(axis)), out_specs=P())
        def gather_phase(vprops, little_stack, big_stack):
            # local shard keeps a leading device axis of size 1 — drop it
            squeeze = lambda s: (None if s is None else
                                 jax.tree.map(lambda x: x[0], s))
            little_stack, big_stack = squeeze(little_stack), squeeze(big_stack)
            accum = jnp.full((V_pad,), ident, dt)
            accum = _mark_varying(accum, axis)
            if little_stack is not None:
                accum = scan_queue(accum, vprops, little_stack, "little",
                                   self.Bl)
            if big_stack is not None:
                accum = scan_queue(accum, vprops, big_stack, "big", self.Bb)
            return combine(accum, axis)

        def iteration(vprops, aux, it, ls, bs):
            accum = gather_phase(vprops, ls, bs)
            return app.apply(accum, vprops, aux, it)

        return jax.jit(iteration)

    def run(self, max_iters: Optional[int] = None):
        """Run to convergence; returns ``(props, meta)`` with props in
        ORIGINAL vertex ids (the chunk queues are uploaded sharded over
        the mesh axis once, on first call)."""
        if self._iter_fn is None:
            self._iter_fn = self._build()
        vprops = init_props(self.store, self.app)
        ls = (None if self.little_stack is None else
              jax.device_put(self.little_stack,
                             NamedSharding(self.mesh, P(self.axis))))
        bs = (None if self.big_stack is None else
              jax.device_put(self.big_stack,
                             NamedSharding(self.mesh, P(self.axis))))
        iters = max_iters or self.app.max_iters
        it_done = 0
        for it in range(iters):
            new = self._iter_fn(vprops, self.store.aux, it, ls, bs)
            new.block_until_ready()
            it_done = it + 1
            if self.app.converged(vprops, new, it):
                vprops = new
                break
            vprops = new
        return np.asarray(vprops)[self.store.perm], {"iterations": it_done}
