"""Analytic performance model of the Big and Little pipelines.

Paper Eqs. (1)-(4) estimate per-partition execution cycles as
  C_p = sum_i max(C_acs_v, C_acs_e, C_proc) + C_store + C_const
with pipeline-specific vertex-access terms. On TPU the same skeleton
holds with bandwidth/issue-rate terms (DESIGN.md §6):

  T(p) = combine(T_edges, T_vertices, T_compute) + T_store + T_const

where combine = max(...) on TPU (pipelined, overlapped stages — the
FPGA/TPU dataflow case) and combine = sum(...) on CPU (serial execution,
no overlap — used when validating the model against measured CPU times).
The Big vertex term keeps the paper's linear a*x+b law with x = number of
unique sources (request-dedup moved the independent variable from stride
to unique count; the law is unchanged).

Constants are either analytic TPU targets (v5e-like) or calibrated on the
host by ``calibrate()`` (least squares on measured lane timings), mirroring
the paper's approach of benchmarking memory latency to fit a and b.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

import numpy as np

from .types import Geometry, PartitionInfo


@dataclasses.dataclass
class HW:
    """Hardware constants. Defaults: TPU v5e-like target."""

    bw_hbm: float = 819e9          # B/s sequential stream
    mac_rate: float = 98.5e12      # MAC/s bf16 MXU (197 TFLOP/s / 2)
    vpu_rate: float = 2.5e12       # elementwise ops/s
    gather_a: float = 64.0 / 819e9  # s per unique vertex (transaction-granular)
    gather_b: float = 2e-6         # base gather latency
    t_const: float = 5e-6          # kernel launch / partition switch
    combine: str = "max"           # "max" (TPU overlap) | "sum" (CPU serial)
    # calibrated multipliers (unity for analytic mode)
    c_edges: float = 1.0
    c_edges_big: float = 0.0       # 0 -> share c_edges (big's indirection
    c_vertices: float = 1.0        # costs differ per padded edge on hosts)
    c_compute: float = 1.0
    c_store: float = 1.0
    # per-lane VMEM working-set budget in bytes; 0 = unlimited. A packed
    # lane whose estimated working set exceeds this is chunked into
    # several payloads at entry boundaries (kernels.ops.pack_lanes) —
    # bit-identical, just more launches. Device specs set this (guide:
    # ~16 MB VMEM per TPU core); the analytic default leaves it off.
    vmem_lane_budget: float = 0.0
    # achievable device bandwidth in GB/s, the denominator of the
    # utilization profiler's %-of-peak (repro.obs.profile). 0 = derive
    # from the stream terms via effective_peak_bandwidth_bps(); set
    # explicitly by calibration (bench specs, retuner) so it persists
    # through the autotune spec registry.
    peak_bandwidth_gbps: float = 0.0

    def clone(self, **kw) -> "HW":
        return dataclasses.replace(self, **kw)


TPU_V5E = HW()
# Scale-model profile: CPU-feasible graphs are ~100x smaller than the
# paper's; scaling bandwidth/compute down 100x (t_const fixed) puts them
# in the same operating regime (edge-bound, not switch-bound) as the
# paper's graphs on the real machine. Used by the Fig.10/Tab.V model-space
# sweeps; absolute TPU projections always use TPU_V5E.
TPU_V5E_SCALED = HW(bw_hbm=819e9 / 100, mac_rate=98.5e12 / 100,
                    vpu_rate=2.5e12 / 100, gather_a=64.0 / 819e9 * 100)
S_EDGE = 12          # src + dst + weight, 4 B each
S_PROP = 4           # scalar f32/int32 property


def effective_peak_bandwidth_bps(hw: HW) -> float:
    """The bandwidth ceiling (bytes/s) the utilization profiler divides
    achieved GB/s by. An explicitly calibrated ``peak_bandwidth_gbps``
    wins; otherwise the base stream rate deflated by the calibrated
    edge-stream multiplier — ``c_edges`` scales modelled *time*, so the
    bandwidth the model believes this device sustains on the dominant
    (edge) stream is ``bw_hbm / c_edges``."""
    if hw.peak_bandwidth_gbps > 0:
        return hw.peak_bandwidth_gbps * 1e9
    return hw.bw_hbm / max(hw.c_edges, 1e-9)


def _terms(info: PartitionInfo, geom: Geometry, kind: str, hw: HW):
    """Return (t_edges, t_vertices, t_compute, t_store) for one partition.
    Uses the EXACT padded block count of each pipeline's brick layout
    (computed during partitioning, paper §IV-A: estimation happens while
    enumerating edges) — padding waste is precisely what makes Little
    lose on sparse partitions."""
    exact = info.blocks_little if kind == "little" else info.blocks_big
    e_blocks = exact or -(-max(info.num_edges, 1) // geom.E_BLK)
    padded_e = e_blocks * geom.E_BLK
    t_edges = padded_e * S_EDGE / hw.bw_hbm
    if kind == "little":
        t_vertices = info.num_src_windows * geom.W * S_PROP / hw.bw_hbm
    else:
        t_vertices = hw.gather_a * info.num_unique_src + hw.gather_b
    # one-hot gather (E*W) + router (E*T) MACs per block
    macs = padded_e * (geom.W + geom.T)
    t_compute = macs / hw.mac_rate
    t_store = info.num_dst_tiles * geom.T * S_PROP / hw.bw_hbm
    ce = (hw.c_edges_big or hw.c_edges) if kind == "big" else hw.c_edges
    return (ce * t_edges, hw.c_vertices * t_vertices,
            hw.c_compute * t_compute, hw.c_store * t_store)


def _combine(te, tv, tc, hw: HW) -> float:
    """"max" (TPU/FPGA dataflow): edge and vertex streams SHARE the HBM
    channel (they add), compute overlaps behind memory — max(te+tv, tc).
    "sum" (serial host): everything adds."""
    if hw.combine == "max":
        return max(te + tv, tc)
    return te + tv + tc


def estimate(info: PartitionInfo, geom: Geometry, kind: str,
             hw: HW = TPU_V5E) -> float:
    te, tv, tc, ts = _terms(info, geom, kind, hw)
    return _combine(te, tv, tc, hw) + ts + hw.t_const


def estimate_big_batch(infos: Sequence[PartitionInfo], geom: Geometry,
                       hw: HW = TPU_V5E) -> float:
    """A Big execution covers a batch of sparse partitions (the data-routing
    amortisation): one t_const for the whole batch, unique sources dedup'd
    across the batch (approximated by the sum, an upper bound)."""
    if not infos:
        return 0.0
    tot = 0.0
    for i in infos:
        te, tv, tc, ts = _terms(i, geom, "big", hw)
        tot += _combine(te, tv, tc, hw) + ts
    return tot + hw.t_const


def classify(infos: Iterable[PartitionInfo], geom: Geometry,
             hw: HW = TPU_V5E) -> List[PartitionInfo]:
    """Paper §IV-B step 1: dense iff modelled Little time < Big time.
    Annotates infos in place and returns them (so callers holding fresh
    copies — the Planner never classifies the GraphStore's pristine
    infos — can chain)."""
    out = []
    for i in infos:
        i.t_little = estimate(i, geom, "little", hw)
        i.t_big = estimate(i, geom, "big", hw)
        i.is_dense = bool(i.t_little < i.t_big)
        out.append(i)
    return out


def feature_row(info: PartitionInfo, geom: Geometry, kind: str,
                hw: HW) -> List[float]:
    """The additive-model design row of one (partition, kind) sample:
    ``[te_little, te_big, tv, tc, ts, 1.0]`` with unit multipliers —
    the column order :func:`fit_terms` fits coefficients for. Rows
    depend only on the base rate constants (bw/mac/gather), not the
    multipliers, so they stay valid across recalibrations."""
    te, tv, tc, ts = _terms(info, geom, kind, hw.clone(
        c_edges=1, c_edges_big=0, c_vertices=1, c_compute=1, c_store=1))
    is_big = 1.0 if kind == "big" else 0.0
    return [te * (1 - is_big), te * is_big, tv, tc, ts, 1.0]


def fit_terms(rows: Sequence[Sequence[float]], ys: Sequence[float],
              hw: HW, min_per_class: int = 3, max_cond: float = 1e8,
              max_residual: float = 0.75) -> Tuple[HW, dict]:
    """Fit the five term multipliers + t_const from design rows (see
    :func:`feature_row`) against measured seconds. The guarded core of
    :func:`calibrate` — also fed directly by the autotune Calibrator
    with per-LANE rows (sums of entry rows).

    Guards (the un-guarded fit silently returned ~0 coefficients on
    underdetermined systems, collapsing every estimate of the starved
    term class):

    * a term class (Little edges / Big edges) with fewer than
      ``min_per_class`` samples keeps its PRIOR coefficient and its
      column is excluded from the solve;
    * fewer usable rows than active columns keeps the prior entirely;
    * the solve is weakly regularized toward the prior, so directions
      the data cannot identify (te and tc are exactly collinear within
      a kind: both scale with padded edges) stay at the prior instead
      of being zeroed arbitrarily;
    * a relative residual above ``max_residual`` (inconsistent
      timings) keeps the prior entirely.

    Returns ``(fitted HW (combine="sum"), diagnostics)`` — diagnostics
    carry n/n_little/n_big, the scaled design's condition number, the
    relative residual, which coefficients kept their prior, and a
    ``fallback`` reason (None when the fit was used).
    """
    A = np.asarray(rows, dtype=float)
    y = np.asarray(ys, dtype=float)
    diag = {"n": int(A.shape[0]) if A.ndim == 2 else 0,
            "n_little": 0, "n_big": 0, "cond": None,
            "residual_rel": None, "kept_prior": [], "fallback": None}
    if A.ndim != 2 or A.shape[0] == 0:
        diag["fallback"] = "no_samples"
        return hw, diag
    names = ["c_edges", "c_edges_big", "c_vertices", "c_compute",
             "c_store", "t_const"]
    prior = np.array([hw.c_edges, hw.c_edges_big or hw.c_edges,
                      hw.c_vertices, hw.c_compute, hw.c_store,
                      max(hw.t_const, 0.0)])
    diag["n_little"] = int(np.count_nonzero(A[:, 0] > 0))
    diag["n_big"] = int(np.count_nonzero(A[:, 1] > 0))

    active = []
    for j in range(6):
        if j == 0 and diag["n_little"] < min_per_class:
            continue
        if j == 1 and diag["n_big"] < min_per_class:
            continue
        if j < 5 and not np.any(A[:, j] > 0):
            continue
        active.append(j)
    inactive = [j for j in range(6) if j not in active]
    diag["kept_prior"] = [names[j] for j in inactive]
    if not active or A.shape[0] < len(active):
        diag["fallback"] = "insufficient_samples"
        return hw, diag

    Aa = A[:, active]
    # residual target: measured minus what the PRIOR attributes to the
    # frozen (inactive) columns
    ya = y - A[:, inactive] @ prior[inactive] if inactive else y.copy()
    norms = np.linalg.norm(Aa, axis=0)
    norms[norms == 0] = 1.0
    As = Aa / norms
    sv = np.linalg.svd(As, compute_uv=False)
    tiny = sv[0] * 1e-12 if sv.size else 0.0
    diag["cond"] = float(sv[0] / sv[-1]) if sv.size and sv[-1] > tiny \
        else float("inf")
    # weak Tikhonov pull toward the prior: negligible where the data
    # identifies a coefficient, decisive in null-space directions
    # (exactly-collinear te/tc) and near max_cond conditioning
    reg = 1e-3 if diag["cond"] <= max_cond else 3e-2
    prior_scaled = prior[active] * norms
    A_solve = np.vstack([As, reg * np.eye(len(active))])
    y_solve = np.concatenate([ya, reg * prior_scaled])
    try:
        from scipy.optimize import nnls
        coef_s, _ = nnls(A_solve, y_solve)
    except Exception:
        coef_s, *_ = np.linalg.lstsq(A_solve, y_solve, rcond=None)
        coef_s = np.clip(coef_s, 0.0, None)
    coef_active = coef_s / norms

    pred = Aa @ coef_active
    ref = np.linalg.norm(ya)
    diag["residual_rel"] = (float(np.linalg.norm(pred - ya) / ref)
                            if ref > 0 else 0.0)
    if diag["residual_rel"] is not None \
            and diag["residual_rel"] > max_residual:
        diag["fallback"] = "high_residual"
        return hw, diag

    coef = prior.copy()
    coef[active] = coef_active
    c = [float(max(x, 1e-12)) for x in coef[:5]]
    if 1 in inactive and hw.c_edges_big == 0.0:
        # preserve the "share c_edges" sentinel: a Big class that kept
        # its prior must track the FITTED little edge coefficient, not
        # a stale absolute value
        c[1] = 0.0
    return hw.clone(c_edges=c[0], c_edges_big=c[1], c_vertices=c[2],
                    c_compute=c[3], c_store=c[4],
                    t_const=float(max(coef[5], 0.0)),
                    combine="sum"), diag


def calibrate_full(samples: Sequence[tuple], hw: HW,
                   min_per_class: int = 3) -> Tuple[HW, dict]:
    """Fit per-term multipliers from measured (info, geom, kind, seconds)
    samples via guarded non-negative least squares on the additive form
    (see :func:`fit_terms`). Mirrors the paper's latency benchmarking
    used to fit Eq. (4)'s a and b. Returns ``(HW, fit diagnostics)`` —
    the diagnostics end up in the persisted DeviceSpec."""
    if not samples:
        return hw, {"n": 0, "fallback": "no_samples"}
    rows = [feature_row(info, geom, kind, hw)
            for info, geom, kind, _secs in samples]
    ys = [secs for *_ignored, secs in samples]
    return fit_terms(rows, ys, hw, min_per_class=min_per_class)


def calibrate(samples: Sequence[tuple], hw: HW) -> HW:
    """Back-compat wrapper over :func:`calibrate_full` (HW only)."""
    return calibrate_full(samples, hw)[0]


def lane_feature_rows(bundle) -> List[np.ndarray]:
    """Per-LANE design rows for a PlanBundle: each lane's row is the
    sum of its entries' :func:`feature_row` vectors, scaled by the
    entry's block fraction of its work (entries on one lane run
    serially, so their term contributions add), with the constant
    column counting kernel launches (one per (lane, kind) packed
    payload). Zipped against measured lane times (``time_lanes`` or
    traced runs) these feed the Calibrator's :func:`fit_terms`."""
    hw = bundle.config.hw
    infos_by_pid = {i.pid: i for i in bundle.infos}
    rows = []
    for lane in bundle.plan.lanes:
        row = np.zeros(6)
        kinds = set()
        for e in lane:
            work = (bundle.little_works[e.work_id] if e.kind == "little"
                    else bundle.big_works[e.work_id])
            batch = [infos_by_pid[p] for p in work.pids]
            n_blocks = max(int(work.n_blocks), 1)
            frac = (e.block_hi - e.block_lo) / n_blocks
            for info in batch:
                r = np.asarray(feature_row(info, work.geom, e.kind, hw))
                r[5] = 0.0           # const handled per payload below
                row += frac * r
            kinds.add(e.kind)
        row[5] = float(len(kinds))   # one launch per (lane, kind)
        rows.append(row)
    return rows


def lane_estimates(plan) -> List[tuple]:
    """Per-lane ``(estimated_seconds, kind)`` for a SchedulePlan — the
    model-side half of the obs drift report. A lane's estimate is the
    sum of its entries' ``est_time`` (entries on one lane run serially);
    ``kind`` is the shared entry kind, ``"mixed"`` when a lane runs both
    pipelines (fewer lanes than pipeline classes), ``"idle"`` when the
    lane got no work."""
    out: List[tuple] = []
    for lane in plan.lanes:
        est = sum(e.est_time for e in lane)
        kinds = {e.kind for e in lane}
        if not kinds:
            kind = "idle"
        elif len(kinds) == 1:
            kind = kinds.pop()
        else:
            kind = "mixed"
        out.append((float(est), kind))
    return out
