"""Analytic performance model of the Big and Little pipelines.

Paper Eqs. (1)-(4) estimate per-partition execution cycles as
  C_p = sum_i max(C_acs_v, C_acs_e, C_proc) + C_store + C_const
with pipeline-specific vertex-access terms. On TPU the same skeleton
holds with bandwidth/issue-rate terms (DESIGN.md §6):

  T(p) = combine(T_edges, T_vertices, T_compute) + T_store + T_const

where combine = max(...) on TPU (pipelined, overlapped stages — the
FPGA/TPU dataflow case) and combine = sum(...) on CPU (serial execution,
no overlap — used when validating the model against measured CPU times).
The Big vertex term keeps the paper's linear a*x+b law with x = number of
unique sources (request-dedup moved the independent variable from stride
to unique count; the law is unchanged).

Constants are either analytic TPU targets (v5e-like) or calibrated on the
host by ``calibrate()`` (least squares on measured lane timings), mirroring
the paper's approach of benchmarking memory latency to fit a and b.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence

import numpy as np

from .types import Geometry, PartitionInfo


@dataclasses.dataclass
class HW:
    """Hardware constants. Defaults: TPU v5e-like target."""

    bw_hbm: float = 819e9          # B/s sequential stream
    mac_rate: float = 98.5e12      # MAC/s bf16 MXU (197 TFLOP/s / 2)
    vpu_rate: float = 2.5e12       # elementwise ops/s
    gather_a: float = 64.0 / 819e9  # s per unique vertex (transaction-granular)
    gather_b: float = 2e-6         # base gather latency
    t_const: float = 5e-6          # kernel launch / partition switch
    combine: str = "max"           # "max" (TPU overlap) | "sum" (CPU serial)
    # calibrated multipliers (unity for analytic mode)
    c_edges: float = 1.0
    c_edges_big: float = 0.0       # 0 -> share c_edges (big's indirection
    c_vertices: float = 1.0        # costs differ per padded edge on hosts)
    c_compute: float = 1.0
    c_store: float = 1.0

    def clone(self, **kw) -> "HW":
        return dataclasses.replace(self, **kw)


TPU_V5E = HW()
# Scale-model profile: CPU-feasible graphs are ~100x smaller than the
# paper's; scaling bandwidth/compute down 100x (t_const fixed) puts them
# in the same operating regime (edge-bound, not switch-bound) as the
# paper's graphs on the real machine. Used by the Fig.10/Tab.V model-space
# sweeps; absolute TPU projections always use TPU_V5E.
TPU_V5E_SCALED = HW(bw_hbm=819e9 / 100, mac_rate=98.5e12 / 100,
                    vpu_rate=2.5e12 / 100, gather_a=64.0 / 819e9 * 100)
S_EDGE = 12          # src + dst + weight, 4 B each
S_PROP = 4           # scalar f32/int32 property


def _terms(info: PartitionInfo, geom: Geometry, kind: str, hw: HW):
    """Return (t_edges, t_vertices, t_compute, t_store) for one partition.
    Uses the EXACT padded block count of each pipeline's brick layout
    (computed during partitioning, paper §IV-A: estimation happens while
    enumerating edges) — padding waste is precisely what makes Little
    lose on sparse partitions."""
    exact = info.blocks_little if kind == "little" else info.blocks_big
    e_blocks = exact or -(-max(info.num_edges, 1) // geom.E_BLK)
    padded_e = e_blocks * geom.E_BLK
    t_edges = padded_e * S_EDGE / hw.bw_hbm
    if kind == "little":
        t_vertices = info.num_src_windows * geom.W * S_PROP / hw.bw_hbm
    else:
        t_vertices = hw.gather_a * info.num_unique_src + hw.gather_b
    # one-hot gather (E*W) + router (E*T) MACs per block
    macs = padded_e * (geom.W + geom.T)
    t_compute = macs / hw.mac_rate
    t_store = info.num_dst_tiles * geom.T * S_PROP / hw.bw_hbm
    ce = (hw.c_edges_big or hw.c_edges) if kind == "big" else hw.c_edges
    return (ce * t_edges, hw.c_vertices * t_vertices,
            hw.c_compute * t_compute, hw.c_store * t_store)


def _combine(te, tv, tc, hw: HW) -> float:
    """"max" (TPU/FPGA dataflow): edge and vertex streams SHARE the HBM
    channel (they add), compute overlaps behind memory — max(te+tv, tc).
    "sum" (serial host): everything adds."""
    if hw.combine == "max":
        return max(te + tv, tc)
    return te + tv + tc


def estimate(info: PartitionInfo, geom: Geometry, kind: str,
             hw: HW = TPU_V5E) -> float:
    te, tv, tc, ts = _terms(info, geom, kind, hw)
    return _combine(te, tv, tc, hw) + ts + hw.t_const


def estimate_big_batch(infos: Sequence[PartitionInfo], geom: Geometry,
                       hw: HW = TPU_V5E) -> float:
    """A Big execution covers a batch of sparse partitions (the data-routing
    amortisation): one t_const for the whole batch, unique sources dedup'd
    across the batch (approximated by the sum, an upper bound)."""
    if not infos:
        return 0.0
    tot = 0.0
    for i in infos:
        te, tv, tc, ts = _terms(i, geom, "big", hw)
        tot += _combine(te, tv, tc, hw) + ts
    return tot + hw.t_const


def classify(infos: Iterable[PartitionInfo], geom: Geometry,
             hw: HW = TPU_V5E) -> List[PartitionInfo]:
    """Paper §IV-B step 1: dense iff modelled Little time < Big time.
    Annotates infos in place and returns them (so callers holding fresh
    copies — the Planner never classifies the GraphStore's pristine
    infos — can chain)."""
    out = []
    for i in infos:
        i.t_little = estimate(i, geom, "little", hw)
        i.t_big = estimate(i, geom, "big", hw)
        i.is_dense = bool(i.t_little < i.t_big)
        out.append(i)
    return out


def calibrate(samples: Sequence[tuple], hw: HW) -> HW:
    """Fit per-term multipliers from measured (info, geom, kind, seconds)
    samples via non-negative least squares on the additive form. Mirrors
    the paper's latency benchmarking used to fit Eq. (4)'s a and b."""
    if not samples:
        return hw
    rows, ys = [], []
    for info, geom, kind, secs in samples:
        te, tv, tc, ts = _terms(info, geom, kind, hw.clone(
            c_edges=1, c_edges_big=0, c_vertices=1, c_compute=1, c_store=1))
        is_big = 1.0 if kind == "big" else 0.0
        rows.append([te * (1 - is_big), te * is_big, tv, tc, ts, 1.0])
        ys.append(secs)
    A = np.asarray(rows)
    y = np.asarray(ys)
    try:
        from scipy.optimize import nnls
        coef, _ = nnls(A, y)
    except Exception:
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        coef = np.clip(coef, 0.0, None)
    c = [float(max(x, 1e-12)) for x in coef[:5]]
    return hw.clone(c_edges=c[0], c_edges_big=c[1], c_vertices=c[2],
                    c_compute=c[3], c_store=c[4],
                    t_const=float(max(coef[5], 0.0)), combine="sum")


def lane_estimates(plan) -> List[tuple]:
    """Per-lane ``(estimated_seconds, kind)`` for a SchedulePlan — the
    model-side half of the obs drift report. A lane's estimate is the
    sum of its entries' ``est_time`` (entries on one lane run serially);
    ``kind`` is the shared entry kind, ``"mixed"`` when a lane runs both
    pipelines (fewer lanes than pipeline classes), ``"idle"`` when the
    lane got no work."""
    out: List[tuple] = []
    for lane in plan.lanes:
        est = sum(e.est_time for e in lane)
        kinds = {e.kind for e in lane}
        if not kinds:
            kind = "idle"
        elif len(kinds) == 1:
            kind = kinds.pop()
        else:
            kind = "mixed"
        out.append((float(est), kind))
    return out
