"""Planner — model-guided scheduling over a GraphStore (paper §IV-B).

The planner is the cheap, per-configuration layer: it classifies
partitions with the analytic perf model (on a private copy of the
store's stats), pulls the memoized Little/Big blockings it needs from
the store, and builds the lane schedule. ``PlanConfig`` replaces the
legacy ``plan_mode: str | tuple`` union with a validated dataclass.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Literal, Optional

from .. import obs
from . import perf_model, schedule
from .types import BlockedEdges, PartitionInfo, SchedulePlan

PlanMode = Literal["model", "monolithic", "fixed"]
_MODES = ("model", "monolithic", "fixed")


def _quantize_sig(x: float, sig: int = 3) -> float:
    """Round to ``sig`` significant digits (0.0 and non-finite pass
    through). Used to coarsen calibrated-HW floats in plan cache keys."""
    if x == 0.0 or x != x or x in (float("inf"), float("-inf")):
        return x
    return float(f"{x:.{sig}g}")


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    """Typed scheduling configuration.

    mode:
      "model"      — paper's model-guided heterogeneous plan (default)
      "monolithic" — homogeneous Big-only baseline (ThunderGP-like SOTA)
      "fixed"      — forced ``forced_little``:``forced_big`` lane split
                     (paper Fig. 10 sweep); must sum to ``n_lanes``
    """

    mode: PlanMode = "model"
    forced_little: int = 0
    forced_big: int = 0
    n_lanes: int = 8
    hw: perf_model.HW = dataclasses.field(
        default_factory=lambda: perf_model.TPU_V5E)

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got "
                             f"{self.mode!r}")
        if self.n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {self.n_lanes}")
        if self.forced_little < 0 or self.forced_big < 0:
            raise ValueError("forced lane counts must be >= 0, got "
                             f"{self.forced_little}:{self.forced_big}")
        if self.mode == "fixed":
            if self.forced_little + self.forced_big != self.n_lanes:
                raise ValueError(
                    "fixed split must cover all lanes: forced_little + "
                    f"forced_big = {self.forced_little + self.forced_big} "
                    f"!= n_lanes = {self.n_lanes}")
        elif self.forced_little or self.forced_big:
            raise ValueError(
                f"forced_little/forced_big require mode='fixed' "
                f"(got mode={self.mode!r})")

    def cache_key(self) -> tuple:
        """Hashable identity for the store's plan cache (HW is an
        unhashable plain dataclass, so flatten it).

        HW floats are quantized to 3 significant digits IN THE KEY ONLY:
        host calibration (``perf_model.calibrate``) refits every
        coefficient from noisy timings, so two back-to-back calibrations
        differ in the 5th digit while describing the same machine.
        Keying on exact floats would give every recalibration its own
        cached plan (and its own pinned device entries); quantizing
        makes near-identical calibrations share one plan. The config's
        own ``hw`` is untouched — only the cache identity coarsens.
        """
        hw_key = tuple(_quantize_sig(v) if isinstance(v, float) else v
                       for v in dataclasses.astuple(self.hw))
        return (self.mode, self.forced_little, self.forced_big,
                self.n_lanes, hw_key)

    @classmethod
    def from_legacy(cls, plan_mode, n_lanes: int,
                    hw: Optional[perf_model.HW] = None) -> "PlanConfig":
        """Convert the legacy ``plan_mode: str | tuple`` union."""
        hw = hw or perf_model.TPU_V5E
        if plan_mode == "model":
            return cls(mode="model", n_lanes=n_lanes, hw=hw)
        if plan_mode == "monolithic":
            return cls(mode="monolithic", n_lanes=n_lanes, hw=hw)
        if isinstance(plan_mode, tuple) and len(plan_mode) == 3:
            _, m, n = plan_mode
            # legacy semantics: the tuple overrides n_lanes entirely
            return cls(mode="fixed", forced_little=int(m), forced_big=int(n),
                       n_lanes=int(m) + int(n), hw=hw)
        raise ValueError(f"unrecognized legacy plan_mode: {plan_mode!r}")


@dataclasses.dataclass
class PlanBundle:
    """A plan plus everything the Executor needs to materialize it:
    classified partition stats and the blocked works the lanes refer to."""

    config: PlanConfig
    infos: List[PartitionInfo]               # classified copies
    little_works: Dict[int, BlockedEdges]    # pid -> Little blocking
    big_works: List[BlockedEdges]            # batched sparse blockings
    big_ests: List[float]                    # modelled batch times
    plan: SchedulePlan
    t_plan: float                            # planning wall time (s)
    t_block: float = 0.0                     # blocking paid BY this plan
                                             # (cache hits cost 0)
    _lane_entries: Optional[list] = dataclasses.field(
        default=None, repr=False, compare=False)
    _packed_lanes: Optional[list] = dataclasses.field(
        default=None, repr=False, compare=False)
    _mat_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)
    # streaming carry-over: lane idx -> packed device payloads reused
    # from a pre-delta bundle (consumed by packed_lanes(); see
    # repro.streaming.apply_delta)
    _packed_seed: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False)
    packed_lanes_reused: int = dataclasses.field(
        default=0, repr=False, compare=False)
    packed_bytes_reused: int = dataclasses.field(
        default=0, repr=False, compare=False)
    # sharded (multi-device) materializations: device tuple ->
    # sharding.ShardedLanes (lane payloads resident on owner devices)
    _sharded: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False)
    # streaming carry-over for the sharded form: (devices, keep, seed)
    # — keep: lane idx -> owner device idx to pin, seed: lane idx ->
    # resident payload list (see repro.streaming.apply_delta)
    _shard_seed: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def dense(self) -> List[PartitionInfo]:
        return [i for i in self.infos if i.is_dense and i.num_edges > 0]

    @property
    def sparse(self) -> List[PartitionInfo]:
        return [i for i in self.infos if not i.is_dense and i.num_edges > 0]

    def lane_entries(self) -> list:
        """Device-resident lane payloads, materialized once per bundle
        (lock-guarded: plan caches share bundles across service worker
        threads, and double-materializing would silently double device
        memory). Entries hold only plan-derived arrays (edges, tiles,
        windows) — the app's scatter/gather UDFs bind at run time — so
        every app executing this plan shares them."""
        with self._mat_lock:
            if self._lane_entries is None:
                from ..kernels import ops
                self._lane_entries = ops.materialize_lanes(
                    self.plan, self.little_works, self.big_works)
            return self._lane_entries

    def packed_lanes(self) -> list:
        """Fused device payloads: one packed payload per (lane, kind)
        instead of one per entry (see ``kernels.ops.pack_lanes``).
        Memoized exactly like :meth:`lane_entries` — and independently
        of it, so a fused-only workload never pays for (or pins) the
        per-entry materialization. Bundles rebuilt after a streaming
        delta carry a ``_packed_seed`` of pre-delta payloads for
        structurally-unchanged lanes; those are spliced in here instead
        of re-packed/re-uploaded (``packed_lanes_reused`` /
        ``packed_bytes_reused`` record what was carried over)."""
        with self._mat_lock:
            if self._packed_lanes is None:
                from ..kernels import ops
                seed = self._packed_seed
                with obs.span("plan.pack", "planner",
                              lanes=len(self.plan.lanes),
                              reused=len(seed) if seed else 0):
                    self._packed_lanes = ops.pack_lanes(
                        self.plan, self.little_works, self.big_works,
                        reuse=seed,
                        max_working_set=self.config.hw.vmem_lane_budget)
                if seed:
                    self.packed_lanes_reused = len(seed)
                    self.packed_bytes_reused = sum(
                        ops.payload_nbytes(p)
                        for lane in seed.values() for p in lane)
                self._packed_seed = None   # release pre-delta bundle refs
            return self._packed_lanes

    def sharded_lanes(self, devices):
        """Multi-device lane payloads: each lane packed (as in
        :meth:`packed_lanes`) and uploaded to the OWNER device chosen by
        the LPT placement (see ``repro.sharding``). Memoized per device
        tuple, so every app executing this plan on the same devices
        shares one resident copy. Bundles rebuilt after a streaming
        delta may carry a ``_shard_seed`` pinning clean lanes to their
        old owners and splicing their resident payloads in without
        re-transfer (``ShardedLanes.moved``/``reused`` account for it).
        """
        from ..sharding.executor import materialize_sharded
        devices = tuple(devices)
        with self._mat_lock:
            if self._sharded is None:
                self._sharded = {}
            sharded = self._sharded.get(devices)
            if sharded is None:
                keep = seed = None
                if self._shard_seed is not None:
                    seed_devs, keep, seed = self._shard_seed
                    if tuple(seed_devs) != devices:
                        keep = seed = None   # seed targets other devices
                    else:
                        self._shard_seed = None  # release pre-delta refs
                sharded = materialize_sharded(self, devices,
                                              keep=keep, seed=seed)
                self._sharded[devices] = sharded
            return sharded

    def device_bytes(self) -> dict:
        """Device bytes pinned by whichever payload forms this bundle
        has materialized so far (feeds the store's plan-cache byte
        accounting and the serving executor LRU's budget).

        Deliberately lock-free: callers reach here while holding the
        store's plan lock (``memory_footprint``), and taking
        ``_mat_lock`` would stall every ``plan()`` behind an in-flight
        materialization. Snapshot reads of the memoized lists are safe —
        they flip once from None to an immutable value."""
        from ..kernels import ops
        out = {"entry_bytes": 0, "packed_bytes": 0, "sharded_bytes": 0}
        entries, packed = self._lane_entries, self._packed_lanes
        if entries is not None:
            out["entry_bytes"] = sum(
                ops.payload_nbytes(p) for lane in entries for p in lane)
        if packed is not None:
            out["packed_bytes"] = sum(
                ops.payload_nbytes(p) for lane in packed for p in lane)
        sharded = self._sharded
        if sharded:
            out["sharded_bytes"] = sum(
                s.nbytes() for s in list(sharded.values()))
        out["total_bytes"] = (out["entry_bytes"] + out["packed_bytes"]
                              + out["sharded_bytes"])
        return out


class Planner:
    """Builds a PlanBundle from a GraphStore + PlanConfig. Stateless
    beyond its inputs; ``GraphStore.plan`` caches the result."""

    def __init__(self, store, config: PlanConfig):
        self.store = store
        self.config = config

    def build(self) -> PlanBundle:
        store, cfg = self.store, self.config
        geom = store.geom
        t0 = time.perf_counter()
        t_block0 = store.t_block

        with obs.span("plan.classify", "planner", mode=cfg.mode) as sp:
            infos = store.copy_infos()
            perf_model.classify(infos, geom, cfg.hw)
            if cfg.mode == "monolithic":
                for i in infos:
                    i.is_dense = False
            elif cfg.mode == "fixed":
                if cfg.forced_little == 0:  # all work through Big pipelines
                    for i in infos:
                        i.is_dense = False
                elif cfg.forced_big == 0:   # all through Little pipelines
                    for i in infos:
                        i.is_dense = True

            dense = [i for i in infos if i.is_dense and i.num_edges > 0]
            sparse = [i for i in infos
                      if not i.is_dense and i.num_edges > 0]
            sp.set(dense=len(dense), sparse=len(sparse))

        with obs.span("plan.blockings", "planner"):
            little_works = {i.pid: store.little_work(i.pid) for i in dense}
            big_works, big_ests = [], []
            for batch in schedule.batch_sparse(sparse, geom.big_batch):
                big_works.append(
                    store.big_work(tuple(i.pid for i in batch)))
                big_ests.append(perf_model.estimate_big_batch(batch, geom,
                                                              cfg.hw))

        with obs.span("plan.schedule", "planner"):
            plan = schedule.plan_from_config(infos, little_works,
                                             big_works, big_ests, geom, cfg)
        t_block = store.t_block - t_block0
        return PlanBundle(config=cfg, infos=infos, little_works=little_works,
                          big_works=big_works, big_ests=big_ests, plan=plan,
                          t_plan=time.perf_counter() - t0 - t_block,
                          t_block=t_block)
