# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public layering (see repro/api.py for the facade):
#   store.GraphStore  — app-independent graph prep, built once
#   planner.Planner   — PlanConfig -> SchedulePlan (cached on the store)
#   executor.Executor — per-(plan, app) jit'd run loop
#   engine            — deprecated monolithic shim over the above
