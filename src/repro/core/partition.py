"""Graph partitioning, degree-based grouping, and brick blocking.

Mirrors ReGraph §II-A (dst-range partitioning of src-sorted COO + DBG) and
adds the TPU brick layout described in DESIGN.md §4: edges are re-sorted
per partition by (dst-tile, src-window, src) and padded into E_BLK blocks
that are homogeneous in (src window, dst tile). This is the structural
change from the FPGA design (which kept pure src order): the destination
"data router" becomes an MXU one-hot product per tile, so a block must
target a single tile. Complexity stays O(E log E) (sorts), preprocessing
measured in benchmarks/bench_preprocessing.py (paper Table IV).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from ..graphs.formats import Graph, relabel
from .types import BlockedEdges, Geometry, PartitionInfo


# ---------------------------------------------------------------------------
# Degree-based grouping (DBG) — Faldu et al. [12], used by the paper to
# concentrate high-in-degree vertices into the first (dense) partitions.
# ---------------------------------------------------------------------------

def dbg_permutation(g: Graph) -> np.ndarray:
    """perm[old_id] = new_id. Vertices grouped by floor(log2(in_deg+1)),
    groups ordered by descending degree, original order kept inside a group
    (stable → preserves whatever locality the original ids had)."""
    ind = g.in_degrees()
    group = np.floor(np.log2(ind + 1)).astype(np.int64)
    # stable argsort on descending group
    order = np.argsort(-group, kind="stable")
    perm = np.empty(g.num_vertices, dtype=np.int32)
    perm[order] = np.arange(g.num_vertices, dtype=np.int32)
    return perm


def apply_dbg(g: Graph) -> Tuple[Graph, np.ndarray]:
    perm = dbg_permutation(g)
    return relabel(g, perm), perm


# ---------------------------------------------------------------------------
# Destination-range partitioning (paper Fig. 1): partition i owns dst in
# [i*U, (i+1)*U); edge lists kept src-sorted inside each partition.
# ---------------------------------------------------------------------------

def partition_info(pid: int, s: np.ndarray, d: np.ndarray, edge_lo: int,
                   num_vertices: int, geom: Geometry) -> PartitionInfo:
    """Stats of one dst-range partition from its (src, dst)-sorted
    segment arrays. The single source of truth for partition stats:
    both the cold build (:func:`partition_graph`) and the streaming
    dirty-partition rebuild (:mod:`repro.streaming`) go through here, so
    the two paths produce bit-identical :class:`PartitionInfo`."""
    U, W, T, E_BLK = geom.U, geom.W, geom.T, geom.E_BLK
    lo = int(edge_lo)
    hi = lo + int(s.shape[0])
    n_uniq = int(np.unique(s).shape[0]) if hi > lo else 0
    n_win = int(np.unique(s // W).shape[0]) if hi > lo else 0
    n_tile = int(np.unique((d - pid * U) // T).shape[0]) if hi > lo else 0
    # exact padded block counts per pipeline kind (brick group-by)
    if hi > lo:
        tile = (d // T).astype(np.int64)
        bricks_l = tile * (1 + int(s.max()) // W) + s // W
        _, cnt_l = np.unique(bricks_l, return_counts=True)
        blocks_l = int((-(-cnt_l // E_BLK)).sum())
        uniq, cidx = np.unique(s, return_inverse=True)
        bricks_b = tile * (1 + uniq.shape[0] // W) + cidx // W
        _, cnt_b = np.unique(bricks_b, return_counts=True)
        blocks_b = int((-(-cnt_b // E_BLK)).sum())
    else:
        blocks_l = blocks_b = 0
    return PartitionInfo(
        pid=pid, dst_lo=pid * U, dst_hi=min((pid + 1) * U, num_vertices),
        edge_lo=lo, edge_hi=hi, num_edges=hi - lo,
        num_unique_src=n_uniq, num_src_windows=n_win, num_dst_tiles=n_tile,
        blocks_little=blocks_l, blocks_big=blocks_b,
    )


def partition_graph(g: Graph, geom: Geometry) -> Tuple[List[PartitionInfo], dict]:
    """Return per-partition infos plus partition-sorted edge arrays.

    The returned dict has 'src','dst','weights' arrays sorted by
    (partition, src, dst) — the canonical order all blocking starts from.
    """
    U = geom.U
    num_parts = max(1, -(-g.num_vertices // U))
    pids = g.dst // U
    order = np.lexsort((g.dst, g.src, pids))
    src = g.src[order]
    dst = g.dst[order]
    wts = (g.weights[order] if g.weights is not None
           else np.zeros(src.shape[0], dtype=np.float32))
    bounds = np.searchsorted(pids[order], np.arange(num_parts + 1))
    infos: List[PartitionInfo] = []
    for p in range(num_parts):
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        infos.append(partition_info(p, src[lo:hi], dst[lo:hi], lo,
                                    g.num_vertices, geom))
    edges = {"src": src, "dst": dst, "weights": wts}
    return infos, edges


# ---------------------------------------------------------------------------
# Brick blocking
# ---------------------------------------------------------------------------

def _block_groups(src_sorted, dst_sorted, w_sorted, win_of_edge, tile_of_edge,
                  src_local_fn, dst_local_fn, geom: Geometry):
    """Given edges already sorted by (tile, window, src), emit padded blocks."""
    E_BLK = geom.E_BLK
    n = src_sorted.shape[0]
    if n == 0:
        z = np.zeros((0, E_BLK), np.int32)
        return (z, z.copy(), np.zeros((0, E_BLK), np.float32),
                np.zeros((0, E_BLK), bool), np.zeros(0, np.int32),
                np.zeros(0, np.int32))
    # group key changes where (tile, window) changes
    key_change = np.ones(n, dtype=bool)
    key_change[1:] = (tile_of_edge[1:] != tile_of_edge[:-1]) | (
        win_of_edge[1:] != win_of_edge[:-1])
    group_id = np.cumsum(key_change) - 1
    n_groups = int(group_id[-1]) + 1
    counts = np.bincount(group_id, minlength=n_groups)
    blocks_per_group = -(-counts // E_BLK)
    n_blocks = int(blocks_per_group.sum())
    tot = n_blocks * E_BLK

    src_l = np.zeros(tot, np.int32)
    dst_l = np.zeros(tot, np.int32)
    wts = np.zeros(tot, np.float32)
    valid = np.zeros(tot, bool)
    # destination offset of each edge in the padded layout
    grp_starts_pad = np.concatenate([[0], np.cumsum(blocks_per_group) * E_BLK])[:-1]
    grp_starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
    pos = grp_starts_pad[group_id] + (np.arange(n) - grp_starts[group_id])
    src_l[pos] = src_local_fn(src_sorted)
    dst_l[pos] = dst_local_fn(dst_sorted)
    wts[pos] = w_sorted
    valid[pos] = True

    blk_win = np.zeros(n_blocks, np.int32)
    blk_tile = np.zeros(n_blocks, np.int32)
    # block index of the first block of each group
    grp_blk_start = np.concatenate([[0], np.cumsum(blocks_per_group)])[:-1]
    for gi in range(n_groups):
        b0, nb = int(grp_blk_start[gi]), int(blocks_per_group[gi])
        e0 = int(grp_starts[gi])
        blk_win[b0:b0 + nb] = win_of_edge[e0]
        blk_tile[b0:b0 + nb] = tile_of_edge[e0]
    return (src_l.reshape(n_blocks, E_BLK), dst_l.reshape(n_blocks, E_BLK),
            wts.reshape(n_blocks, E_BLK), valid.reshape(n_blocks, E_BLK),
            blk_win, blk_tile)


def _finalize(blk_win, blk_tile, geom, kind, pids, n_real,
              src_l, dst_l, wts, valid, unique_src=None,
              tile_global_base=None):
    """Compress touched tiles to a dense local index & compute metadata."""
    n_blocks = blk_win.shape[0]
    if n_blocks:
        touched, tile_local = np.unique(blk_tile, return_inverse=True)
    else:
        touched = np.zeros(0, np.int64)
        tile_local = np.zeros(0, np.int64)
    tile_local = tile_local.astype(np.int32)
    tile_first = np.ones(n_blocks, np.int32)
    tile_first[1:] = (tile_local[1:] != tile_local[:-1]).astype(np.int32)
    tile_dst_start = (tile_global_base(touched) if tile_global_base is not None
                      else touched * geom.T).astype(np.int32)
    return BlockedEdges(
        geom=geom, kind=kind, n_blocks=n_blocks,
        src_local=src_l, dst_local=dst_l, weights=wts, valid=valid,
        window_id=blk_win.astype(np.int32), tile_id=tile_local,
        tile_first=tile_first, n_out_tiles=int(touched.shape[0]),
        tile_dst_start=tile_dst_start, unique_src=unique_src,
        pids=tuple(pids), num_real_edges=n_real,
    )


def block_little(edges: dict, info: PartitionInfo, geom: Geometry) -> BlockedEdges:
    """Blocking for the Little pipeline: src windows index the RAW vprops
    array (streamed windows, the ping-pong-buffer analogue)."""
    W, T, U = geom.W, geom.T, geom.U
    lo, hi = info.edge_lo, info.edge_hi
    s = edges["src"][lo:hi]
    d = edges["dst"][lo:hi]
    w = edges["weights"][lo:hi]
    tile = d // T  # global tile id (dst already global)
    win = s // W
    order = np.lexsort((s, win, tile))
    s, d, w, tile, win = s[order], d[order], w[order], tile[order], win[order]
    out = _block_groups(s, d, w, win, tile,
                        lambda x: x % W, lambda x: x % T, geom)
    return _finalize(out[4], out[5], geom, "little", [info.pid], s.shape[0],
                     out[0], out[1], out[2], out[3])


def block_big(edges: dict, infos: Sequence[PartitionInfo],
              geom: Geometry) -> BlockedEdges:
    """Blocking for the Big pipeline: a *batch* of sparse partitions.

    Unique sources across the batch are compacted (the Vertex Loader's
    request-dedup moved to preprocessing); src windows index the compact
    array which ops.big_pipeline gathers once per execution.
    """
    W, T = geom.W, geom.T
    segs = [(edges["src"][i.edge_lo:i.edge_hi],
             edges["dst"][i.edge_lo:i.edge_hi],
             edges["weights"][i.edge_lo:i.edge_hi]) for i in infos]
    if segs:
        s = np.concatenate([x[0] for x in segs])
        d = np.concatenate([x[1] for x in segs])
        w = np.concatenate([x[2] for x in segs])
    else:
        s = np.zeros(0, np.int32); d = np.zeros(0, np.int32)
        w = np.zeros(0, np.float32)
    uniq, inv = np.unique(s, return_inverse=True)
    n_uniq_pad = max(W, int(-(-max(1, uniq.shape[0]) // W) * W))
    unique_src = np.zeros(n_uniq_pad, np.int32)
    unique_src[:uniq.shape[0]] = uniq
    cidx = inv.astype(np.int32)           # compact src index
    tile = d // T                          # global dst tile
    win = cidx // W                        # compact window
    order = np.lexsort((cidx, win, tile))
    s2, d2, w2 = cidx[order], d[order], w[order]
    tile, win = tile[order], win[order]
    out = _block_groups(s2, d2, w2, win, tile,
                        lambda x: x % W, lambda x: x % T, geom)
    return _finalize(out[4], out[5], geom, "big",
                     [i.pid for i in infos], s.shape[0],
                     out[0], out[1], out[2], out[3], unique_src=unique_src)


def padded_num_vertices(num_vertices: int, geom: Geometry) -> int:
    return int(-(-num_vertices // geom.U) * geom.U)
