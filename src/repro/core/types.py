"""Core datatypes for the heterogeneous Big/Little graph engine."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

# ---------------------------------------------------------------------------
# Tunable geometry (TPU-aligned defaults; all multiples of 128 lanes).
#   U      — partition vertex-set size (paper: 32K-64K per Gather PE cluster)
#   W      — source-vertex window (Little ping-pong window / Big compact window)
#   T      — destination accumulator tile (the MXU "router" width)
#   E_BLK  — edges per kernel grid step
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Geometry:
    U: int = 8192
    W: int = 512
    T: int = 512
    E_BLK: int = 256
    big_batch: int = 8  # paper: Big pipelines process N_gpe(=8) partitions/exec

    def __post_init__(self):
        assert self.U % self.T == 0 and self.U % self.W == 0
        assert self.W % 128 == 0 and self.T % 128 == 0 and self.E_BLK % 128 == 0


@dataclasses.dataclass
class PartitionInfo:
    """Stats of one dst-range partition (drives the perf model)."""

    pid: int
    dst_lo: int
    dst_hi: int
    edge_lo: int          # range into the partition-sorted edge arrays
    edge_hi: int
    num_edges: int
    num_unique_src: int
    num_src_windows: int  # distinct W-windows of raw vprops touched
    num_dst_tiles: int    # distinct T-tiles of the dst range touched
    blocks_little: int = 0  # exact padded E_BLK blocks in Little layout
    blocks_big: int = 0     # exact padded E_BLK blocks in Big layout

    # Filled in by the scheduler:
    is_dense: Optional[bool] = None
    t_little: float = 0.0
    t_big: float = 0.0


@dataclasses.dataclass
class BlockedEdges:
    """Edges of one Little partition (or one Big batch) in brick layout.

    Every block of E_BLK edges is homogeneous in (src window, dst tile).
    Blocks are sorted by dst tile so output-tile revisits are consecutive
    (safe VMEM accumulation on TPU).
    """

    geom: Geometry
    kind: str                      # "little" | "big"
    n_blocks: int
    src_local: np.ndarray          # (n_blocks, E_BLK) int32, offset in window
    dst_local: np.ndarray          # (n_blocks, E_BLK) int32, offset in tile
    weights: np.ndarray            # (n_blocks, E_BLK) float32
    valid: np.ndarray              # (n_blocks, E_BLK) bool
    window_id: np.ndarray          # (n_blocks,) int32 — W-window of source input
    tile_id: np.ndarray            # (n_blocks,) int32 — local output tile index
    tile_first: np.ndarray         # (n_blocks,) int32 — 1 on first block of a tile
    n_out_tiles: int
    tile_dst_start: np.ndarray     # (n_out_tiles,) int32 — global dst id of tile[0]
    unique_src: Optional[np.ndarray] = None  # big only: (n_unique_pad,) int32
    pids: tuple = ()               # partitions covered
    num_real_edges: int = 0

    @property
    def num_padded_edges(self) -> int:
        return self.n_blocks * self.geom.E_BLK


@dataclasses.dataclass
class PlanEntry:
    """A unit of work for one lane: a block-range of one BlockedEdges."""

    kind: str          # "little" | "big"
    work_id: int       # index into engine's list of BlockedEdges
    block_lo: int
    block_hi: int
    est_time: float


@dataclasses.dataclass
class SchedulePlan:
    """Static plan: per-lane queues (paper §IV-B, inter+intra cluster)."""

    geometry: Geometry
    num_little_lanes: int          # M
    num_big_lanes: int             # N
    lanes: List[List[PlanEntry]]   # len == M + N; little lanes first
    dense_pids: List[int]
    sparse_pids: List[int]
    est_makespan: float

    @property
    def num_lanes(self) -> int:
        return self.num_little_lanes + self.num_big_lanes
