"""Executor — per-(plan, app) materialization and the jit'd run loop.

The Executor is the only layer that touches the device: it turns the
plan's lane queues into device-resident payloads, builds the jit'd
iteration (Scatter+Gather kernels → merge → Apply), and owns ``run`` /
``time_iteration`` / ``time_lanes``. The store's aux (out-degrees etc.)
is shared across every Executor on the same store, so running five apps
re-uploads nothing app-independent.

Execution is FUSED by default: each lane is one packed payload run as a
single ``pallas_call`` (``kernels.ops.run_lane``) and the per-iteration
merge is one tile-indexed scatter-set over all lanes' output tiles —
kernel dispatches and trace size scale with the number of lanes, not
the number of materialized plan entries. ``fuse_lanes=False`` restores
the one-launch-per-entry path (bit-identical results; useful for A/B
benchmarks and for debugging a single entry).
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..kernels import ops
from . import perf_model
from .gas import GASApp, GATHER_IDENTITY
from .planner import PlanBundle


def init_props(store, app: GASApp):
    """Initial padded property vector for one app on a store (in DBG
    ids). Needs only store-level state — callers that never execute a
    plan (e.g. perf-model calibration) use this directly instead of
    building an Executor."""
    aux = store.aux
    p = app.init(aux | {
        "outdeg": np.asarray(aux["outdeg"]),
        "perm": store.perm,
    })
    full = np.full(store.V_pad, GATHER_IDENTITY[app.gather],
                   np.int32 if app.gather == "or" else np.float32)
    full[:p.shape[0]] = p[:store.V_pad]
    if app.name == "pagerank":
        full[store.graph.num_vertices:] = 0.0
    return jnp.asarray(full)


def _sub_jaxprs(v):
    """Yield every jaxpr held by one eqn param value: raw Jaxpr,
    ClosedJaxpr, or tuples/lists of either (lax.cond's ``branches``)."""
    if hasattr(v, "eqns"):                        # raw Jaxpr
        yield v
    elif hasattr(getattr(v, "jaxpr", None), "eqns"):
        yield v.jaxpr                             # ClosedJaxpr
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _sub_jaxprs(x)


def _count_jaxpr_eqns(jaxpr) -> int:
    """Total equations including nested (pjit / pallas / cond branch)
    sub-jaxprs — the trace-size measure the fused path collapses."""
    n = len(jaxpr.eqns)
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                n += _count_jaxpr_eqns(sub)
    return n


class Executor:
    """Per-(plan, app) single-device executor.

    Parameters
    ----------
    store:   the :class:`~.store.GraphStore` the plan was built on
             (supplies aux, V_pad, perm; shared across executors).
    bundle:  the (cached) :class:`~.planner.PlanBundle` to execute;
             its materialized payloads are memoized on the bundle, so
             every app on the same plan shares device memory.
    app:     the :class:`~.gas.GASApp` whose scatter/gather/apply UDFs
             bind at run time.
    path:    kernel path — "pallas" (compiled on TPU, interpret
             elsewhere) or "ref" (pure-jnp oracle; the CPU default).
    fuse_lanes: True (default) runs each lane as ONE packed kernel
             launch; False launches per plan entry. Both paths are
             bit-identical (they share the single-merge program
             structure) — see the module docstring.

    Invariants: ``run`` returns properties in ORIGINAL vertex ids;
    one iteration dispatches exactly one merge (``dispatch_stats``);
    the multi-device counterpart is
    :class:`repro.sharding.executor.ShardedExecutor` (same surface,
    minus ``time_lanes``/``trace_stats``).
    """

    def __init__(self, store, bundle: PlanBundle, app: GASApp,
                 path: Optional[str] = None, fuse_lanes: bool = True,
                 drift_parent: Optional[obs.DriftAccumulator] = None,
                 calibrator=None,
                 util_parent: Optional[obs.UtilizationAccumulator] = None,
                 profile: bool = True):
        self.store = store
        self.bundle = bundle
        self.app = app
        self.geom = store.geom
        self.path = path or ops.default_path()
        self.V_pad = store.V_pad
        self.fuse_lanes = bool(fuse_lanes)
        # measured-vs-model drift; chains to the service-level
        # accumulator when this executor runs under a GraphService
        self.drift = obs.DriftAccumulator(parent=drift_parent)
        # pipeline utilization profiler (repro.obs.profile): analytic
        # lane footprints × measured lane times → achieved GB/s and
        # %-of-peak; chains to the service-level accumulator like drift.
        # profile=False skips footprint derivation and sampling entirely
        # (the A/B knob bench_profile's overhead gate exercises).
        self.profile = bool(profile)
        self.util = obs.UtilizationAccumulator(parent=util_parent)
        self._peak_bps = perf_model.effective_peak_bandwidth_bps(
            bundle.config.hw)
        self._footprints = None  # lazy obs.lane_footprints
        self._lane_est = perf_model.lane_estimates(bundle.plan)
        # the estimate a measured iteration is compared against for the
        # "makespan" drift kind: plan.est_makespan assumes lanes run in
        # parallel (the device model); under a serial-host calibration
        # (combine == "sum") this executor runs lanes back-to-back, so
        # the like-for-like estimate is the SUM of lane estimates —
        # otherwise a perfectly-fitted model on a well-balanced plan
        # would show ~n_lanes of phantom drift and thrash the retuner
        if bundle.config.hw.combine == "sum":
            self._est_iteration = sum(e for e, _ in self._lane_est)
        else:
            self._est_iteration = bundle.plan.est_makespan
        # optional autotune sink: measured lane timings land here as
        # (feature row, kind, seconds) calibration samples — both from
        # traced runs and from time_lanes sweeps (repro.autotune)
        self._calibrator = calibrator
        self._lane_rows = None   # lazy perf_model.lane_feature_rows

        t0 = time.perf_counter()
        # shared across every app on this plan (memoized on the bundle);
        # only the form this executor runs is materialized
        if self.fuse_lanes:
            self.packed_lanes: List[List[dict]] = bundle.packed_lanes()
            self._payloads = [p for lane in self.packed_lanes for p in lane]
        else:
            self.packed_lanes = None
            self._payloads = [p for lane in bundle.lane_entries()
                              for p in lane]
        self.t_materialize = time.perf_counter() - t0

        self.aux = store.aux
        self._iter_fn = None
        self._lane_fns = None   # cached per-lane jits for time_lanes
        self._traced_fns = None  # cached (lane fns, merge_apply) pair

    @property
    def plan(self):
        return self.bundle.plan

    @property
    def lane_entries(self) -> List[List[dict]]:
        """Per-entry payloads (legacy surface; the fused executor only
        materializes these on first access)."""
        return self.bundle.lane_entries()

    # ------------------------------------------------------------------
    @property
    def accum_dtype(self):
        return jnp.int32 if self.app.gather == "or" else jnp.float32

    def footprints(self):
        """Per-lane analytic :class:`~repro.obs.profile.LaneFootprint`
        (None for snapped-away lanes), derived once from the payload
        structure this executor actually runs — the byte model the
        utilization samples and ``jaxpr_lane_bytes`` validation share."""
        if self._footprints is None:
            lanes = (self.packed_lanes if self.fuse_lanes
                     else self.bundle.lane_entries())
            self._footprints = obs.lane_footprints(lanes, self.V_pad)
        return self._footprints

    def _util_add(self, lane_idx: int, kind: str, measured_s: float,
                  span=None):
        """Fold one measured lane execution into the utilization
        accumulator (and onto the live ``executor.lane`` span when one
        is open). No-op with ``profile=False``."""
        if not self.profile:
            return None
        fps = self.footprints()
        fp = fps[lane_idx] if lane_idx < len(fps) else None
        if fp is None:
            return None
        gbps = (fp.hbm_bytes / measured_s / 1e9 if measured_s > 0
                else 0.0)
        if span is not None:
            span.set(hbm_bytes=fp.hbm_bytes, flops=fp.flops,
                     gbps=round(gbps, 3))
        self.util.add(fp.kind, fp.hbm_bytes, fp.flops, measured_s,
                      peak_bps=self._peak_bps, lane=lane_idx)
        return gbps

    def _run_payload(self, payload, vprops):
        """Dispatch one device payload (packed lane or single entry)."""
        run = ops.run_lane if self.fuse_lanes else ops.run_entry
        return run(payload, vprops, self.app.scatter, self.app.gather,
                   self.path)

    def _iteration_fn(self):
        """The raw (un-jitted) one-iteration function — separate from
        :meth:`_build_iteration` so trace-size reporting can inspect the
        jaxpr without a compiled-call wrapper in the way.

        Both paths share the SAME single ``merge_all`` (one tile-indexed
        scatter-set over every payload's output tiles) and differ only
        in kernel-launch granularity — one launch per packed lane vs one
        per entry. Keeping the merge+apply region structurally identical
        is what makes the two paths bit-identical: XLA re-fuses
        value-equal scatter chains differently per program shape, which
        shows up as 1-ULP drift in 'sum' apps."""
        app, geom = self.app, self.geom
        payloads = self._payloads
        ident = GATHER_IDENTITY[app.gather]
        dt = self.accum_dtype

        def iteration(vprops, aux, it):
            accum = jnp.full((self.V_pad,), ident, dt)
            outs = [self._run_payload(p, vprops) for p in payloads]
            accum = ops.merge_all(accum, outs, geom.T)
            return app.apply(accum, vprops, aux, it)

        return iteration

    def _build_iteration(self):
        return jax.jit(self._iteration_fn())

    def init_props(self):
        return init_props(self.store, self.app)

    def _build_traced_fns(self):
        """Per-lane jitted fns returning the RAW (tiles, tile_idx)
        outputs — no merge — plus ONE jitted merge+apply. Together they
        run an iteration with per-lane timing visibility while keeping
        the single-merge+apply program region of :meth:`_iteration_fn`
        (the structure bit-identity depends on); only kernel-launch
        granularity differs."""
        lanes = (self.packed_lanes if self.fuse_lanes
                 else self.bundle.lane_entries())
        lane_fns = []
        for lane in lanes:
            if not lane:
                lane_fns.append(None)
                continue

            def lane_fn(vp, lane=lane):
                return [self._run_payload(p, vp) for p in lane]

            lane_fns.append(jax.jit(lane_fn))

        app, geom = self.app, self.geom
        ident = GATHER_IDENTITY[app.gather]
        dt = self.accum_dtype

        def merge_apply(vprops, outs, aux, it):
            accum = jnp.full((self.V_pad,), ident, dt)
            accum = ops.merge_all(accum, outs, geom.T)
            return app.apply(accum, vprops, aux, it)

        return lane_fns, jax.jit(merge_apply)

    def _run_iteration_traced(self, vprops, it):
        """One iteration under an active tracer with lane detail: a span
        per lane (carrying the model estimate, so every trace doubles as
        a calibration sample), one for merge+apply, drift samples for
        both levels."""
        lane_fns, merge_apply = self._traced_fns
        est = self._lane_est
        with obs.span("executor.iteration", "executor", it=it):
            outs = []
            for li, f in enumerate(lane_fns):
                if f is None:
                    continue
                e_i, kind_i = est[li] if li < len(est) else (0.0, "mixed")
                t0 = time.perf_counter()
                n_entries = (len(self.plan.lanes[li])
                             if li < len(self.plan.lanes) else 0)
                with obs.span("executor.lane", "executor", lane=li,
                              kind=kind_i, est_time=e_i,
                              n_entries=n_entries) as lane_sp:
                    lane_out = f(vprops)
                    jax.block_until_ready(lane_out)
                    measured = time.perf_counter() - t0
                    # achieved-bandwidth counters ride on the span the
                    # trace already carries (bytes are analytic, so the
                    # only run-path cost is the divide + dict update)
                    self._util_add(li, kind_i, measured, span=lane_sp)
                self.drift.add(kind_i, e_i, measured)
                self._calib_add(li, kind_i, measured)
                outs.extend(lane_out)
            with obs.span("executor.merge_apply", "executor", it=it):
                new = merge_apply(vprops, outs, self.aux, it)
                new.block_until_ready()
        return new

    def run(self, max_iters: Optional[int] = None, collect_history=False):
        """Run to convergence; returns props in ORIGINAL vertex ids.

        When a tracer with ``lane_detail`` is active on this thread, the
        iteration switches to the traced per-lane path (extra dispatches
        per iteration, bit-identical results — see
        :meth:`_build_traced_fns`); otherwise the single fused jit runs
        and only the per-iteration makespan drift sample is taken."""
        tracer = obs.current_tracer()
        lane_detail = (tracer is not None and tracer.lane_detail
                       and obs.current_ctx() is not None)
        if lane_detail:
            if self._traced_fns is None:
                self._traced_fns = self._build_traced_fns()
        elif self._iter_fn is None:
            self._iter_fn = self._build_iteration()
        vprops = self.init_props()
        iters = max_iters or self.app.max_iters
        est_makespan = self._est_iteration
        history = []
        it_done = 0
        for it in range(iters):
            t_it = time.perf_counter()
            if lane_detail:
                new = self._run_iteration_traced(vprops, it)
            else:
                new = self._iter_fn(vprops, self.aux, it)
                new.block_until_ready()
            self.drift.add("makespan", est_makespan,
                           time.perf_counter() - t_it)
            it_done = it + 1
            if collect_history:
                history.append(np.asarray(new))
            if self.app.converged(vprops, new, it):
                vprops = new
                break
            vprops = new
        out = np.asarray(vprops)[self.store.perm]  # back to original ids
        return out, {"iterations": it_done, "history": history}

    # ------------------------------------------------------------------
    def time_iteration(self, repeats: int = 5) -> float:
        """Median wall time of one full iteration (all lanes, serialised —
        single host device). Used by benchmarks."""
        if self._iter_fn is None:
            self._iter_fn = self._build_iteration()
        vprops = self.init_props()
        self._iter_fn(vprops, self.aux, 0).block_until_ready()  # warmup
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            self._iter_fn(vprops, self.aux, 0).block_until_ready()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    def _build_lane_fns(self):
        """One jitted fn per lane, built once and cached for the life of
        the executor (same lifetime as ``_iter_fn``) — repeated
        ``time_lanes`` sweeps must not pay a re-trace per call."""
        ident = GATHER_IDENTITY[self.app.gather]
        dt = self.accum_dtype
        lanes = (self.packed_lanes if self.fuse_lanes
                 else self.bundle.lane_entries())
        fns = []
        for lane in lanes:
            if not lane:
                fns.append(None)
                continue

            def lane_fn(vp, lane=lane):
                accum = jnp.full((self.V_pad,), ident, dt)
                outs = [self._run_payload(p, vp) for p in lane]
                return ops.merge_all(accum, outs, self.geom.T)

            fns.append(jax.jit(lane_fn))
        return fns

    def time_lanes(self, repeats: int = 3):
        """Per-lane wall times — the quantity the scheduler balances.
        On real hardware lanes run concurrently; on the host we time them
        one by one and report max() as the modelled makespan analogue."""
        if self._lane_fns is None:
            self._lane_fns = self._build_lane_fns()
        vprops = self.init_props()
        out = []
        for i, f in enumerate(self._lane_fns):
            if f is None:
                out.append(0.0)
                continue
            f(vprops).block_until_ready()
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                f(vprops).block_until_ready()
                ts.append(time.perf_counter() - t0)
            med = float(np.median(ts))
            out.append(med)
            # every calibration sweep is also a drift + utilization sample
            if i < len(self._lane_est):
                e_i, kind_i = self._lane_est[i]
                self.drift.add(kind_i, e_i, med)
                self._calib_add(i, kind_i, med)
                self._util_add(i, kind_i, med)
        return out

    def _calib_add(self, lane_idx: int, kind: str, measured_s: float):
        """Forward one measured lane time to the attached Calibrator as a
        (feature row, kind, seconds) sample. Rows are per-lane sums of
        unit-coefficient model terms (perf_model.lane_feature_rows) and
        depend only on the plan + base HW constants, so they are computed
        once per executor."""
        if self._calibrator is None:
            return
        if self._lane_rows is None:
            self._lane_rows = perf_model.lane_feature_rows(self.bundle)
        if lane_idx < len(self._lane_rows):
            self._calibrator.add_lane(self._lane_rows[lane_idx], kind,
                                      measured_s)

    # ------------------------------------------------------------------
    def memory_footprint(self) -> int:
        """Device bytes pinned by this executor's payloads. NOTE:
        payloads are memoized on the bundle, so executors sharing a plan
        share these bytes — treat this as an attribution for cache
        budgeting, not an exclusive-ownership measure."""
        return sum(ops.payload_nbytes(p) for p in self._payloads)

    def dispatch_stats(self) -> dict:
        """Static launch accounting: what one iteration dispatches. The
        fused path turns O(entries) kernel launches + merges into
        O(lanes) launches + ONE merge — the per-entry numbers are
        reported alongside so callers can see the delta."""
        num_entries = sum(p["n_entries"] for p in self._payloads)
        return {
            "fuse_lanes": self.fuse_lanes,
            "num_entries": num_entries,
            "kernel_dispatches": len(self._payloads),
            "merge_dispatches": 1 if self._payloads else 0,
            "payload_bytes": self.memory_footprint(),
        }

    def trace_stats(self) -> dict:
        """Abstractly trace one iteration and measure the jaxpr — the
        trace/compile-size cost the fused path collapses. Traces fresh
        on every call (no caching) so fused/per-entry A/Bs are honest;
        don't call it on a hot path."""
        fn = self._iteration_fn()
        vprops = self.init_props()
        t0 = time.perf_counter()
        jaxpr = jax.make_jaxpr(fn)(vprops, self.aux, 0)
        t_trace = time.perf_counter() - t0
        return {
            "jaxpr_eqns": _count_jaxpr_eqns(jaxpr.jaxpr),
            "t_trace_ms": t_trace * 1e3,
        }

    def utilization(self) -> dict:
        """The pipeline-utilization report: the accumulator's per-kind
        achieved GB/s / %-of-peak / intensity plus this executor's
        static per-lane footprints and bandwidth ceiling. Empty
        ``kinds``/``lanes`` until a traced run or ``time_lanes`` sweep
        has produced measured samples."""
        rep = self.util.report()
        rep["peak_bandwidth_gbps"] = self._peak_bps / 1e9
        rep["profile"] = self.profile
        rep["footprints"] = [fp.as_dict() if fp is not None else None
                             for fp in (self.footprints()
                                        if self.profile else [])]
        return rep

    def stats(self) -> dict:
        b, store = self.bundle, self.store
        padded_edges = sum(p["n_blocks"] for p in self._payloads) \
            * self.geom.E_BLK
        real_edges = sum(p["num_real_edges"] for p in self._payloads)
        return {
            "V": store.graph.num_vertices, "E": store.graph.num_edges,
            "partitions": len(b.infos),
            "dense": len(b.dense), "sparse": len(b.sparse),
            "little_lanes": b.plan.num_little_lanes,
            "big_lanes": b.plan.num_big_lanes,
            "est_makespan": b.plan.est_makespan,
            "t_dbg_ms": store.t_dbg * 1e3,
            # plan-local: partitioning + blocking THIS plan paid for
            # (cache-hit blockings cost 0) + scheduling
            "t_partition_schedule_ms":
                (store.t_partition + b.t_block + b.t_plan) * 1e3,
            "t_plan_ms": b.t_plan * 1e3,
            # padding efficiency of the brick layout actually executed
            "num_real_edges": real_edges,
            "num_padded_edges": padded_edges,
            "padding_efficiency": (real_edges / padded_edges
                                   if padded_edges else 1.0),
            "drift": self.drift.report(),
            "utilization": self.utilization(),
            **self.dispatch_stats(),
        }
