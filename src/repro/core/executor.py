"""Executor — per-(plan, app) materialization and the jit'd run loop.

The Executor is the only layer that touches the device: it turns the
plan's lane queues into device-resident entry payloads, builds the jit'd
iteration (Scatter+Gather kernels → merge → Apply), and owns ``run`` /
``time_iteration`` / ``time_lanes``. The store's aux (out-degrees etc.)
is shared across every Executor on the same store, so running five apps
re-uploads nothing app-independent.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .gas import GASApp, GATHER_IDENTITY
from .planner import PlanBundle


def init_props(store, app: GASApp):
    """Initial padded property vector for one app on a store (in DBG
    ids). Needs only store-level state — callers that never execute a
    plan (e.g. perf-model calibration) use this directly instead of
    building an Executor."""
    aux = store.aux
    p = app.init(aux | {
        "outdeg": np.asarray(aux["outdeg"]),
        "perm": store.perm,
    })
    full = np.full(store.V_pad, GATHER_IDENTITY[app.gather],
                   np.int32 if app.gather == "or" else np.float32)
    full[:p.shape[0]] = p[:store.V_pad]
    if app.name == "pagerank":
        full[store.graph.num_vertices:] = 0.0
    return jnp.asarray(full)


class Executor:
    def __init__(self, store, bundle: PlanBundle, app: GASApp,
                 path: Optional[str] = None):
        self.store = store
        self.bundle = bundle
        self.app = app
        self.geom = store.geom
        self.path = path or ops.default_path()
        self.V_pad = store.V_pad

        t0 = time.perf_counter()
        # shared across every app on this plan (memoized on the bundle)
        self.lane_entries: List[List[dict]] = bundle.lane_entries()
        self.t_materialize = time.perf_counter() - t0

        self.aux = store.aux
        self._iter_fn = None

    @property
    def plan(self):
        return self.bundle.plan

    # ------------------------------------------------------------------
    @property
    def accum_dtype(self):
        return jnp.int32 if self.app.gather == "or" else jnp.float32

    def _build_iteration(self):
        app, geom, path = self.app, self.geom, self.path
        entries = [p for lane in self.lane_entries for p in lane]
        ident = GATHER_IDENTITY[app.gather]
        dt = self.accum_dtype

        def iteration(vprops, aux, it):
            accum = jnp.full((self.V_pad,), ident, dt)
            for p in entries:
                tiles, idx = ops.run_entry(p, vprops, app.scatter, app.gather,
                                           path)
                accum = ops.merge_tiles(accum, tiles, idx, geom.T)
            return app.apply(accum, vprops, aux, it)

        return jax.jit(iteration)

    def init_props(self):
        return init_props(self.store, self.app)

    def run(self, max_iters: Optional[int] = None, collect_history=False):
        """Run to convergence; returns props in ORIGINAL vertex ids."""
        if self._iter_fn is None:
            self._iter_fn = self._build_iteration()
        vprops = self.init_props()
        iters = max_iters or self.app.max_iters
        history = []
        it_done = 0
        for it in range(iters):
            new = self._iter_fn(vprops, self.aux, it)
            new.block_until_ready()
            it_done = it + 1
            if collect_history:
                history.append(np.asarray(new))
            if self.app.converged(vprops, new, it):
                vprops = new
                break
            vprops = new
        out = np.asarray(vprops)[self.store.perm]  # back to original ids
        return out, {"iterations": it_done, "history": history}

    # ------------------------------------------------------------------
    def time_iteration(self, repeats: int = 5) -> float:
        """Median wall time of one full iteration (all lanes, serialised —
        single host device). Used by benchmarks."""
        if self._iter_fn is None:
            self._iter_fn = self._build_iteration()
        vprops = self.init_props()
        self._iter_fn(vprops, self.aux, 0).block_until_ready()  # warmup
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            self._iter_fn(vprops, self.aux, 0).block_until_ready()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    def time_lanes(self, repeats: int = 3):
        """Per-lane wall times — the quantity the scheduler balances.
        On real hardware lanes run concurrently; on the host we time them
        one by one and report max() as the modelled makespan analogue."""
        app, geom, path = self.app, self.geom, self.path
        ident = GATHER_IDENTITY[app.gather]
        dt = self.accum_dtype
        vprops = self.init_props()
        out = []
        for lane in self.lane_entries:
            if not lane:
                out.append(0.0)
                continue

            def lane_fn(vp, lane=lane):
                accum = jnp.full((self.V_pad,), ident, dt)
                for p in lane:
                    tiles, idx = ops.run_entry(p, vp, app.scatter, app.gather,
                                               path)
                    accum = ops.merge_tiles(accum, tiles, idx, geom.T)
                return accum

            f = jax.jit(lane_fn)
            f(vprops).block_until_ready()
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                f(vprops).block_until_ready()
                ts.append(time.perf_counter() - t0)
            out.append(float(np.median(ts)))
        return out

    def stats(self) -> dict:
        b, store = self.bundle, self.store
        return {
            "V": store.graph.num_vertices, "E": store.graph.num_edges,
            "partitions": len(b.infos),
            "dense": len(b.dense), "sparse": len(b.sparse),
            "little_lanes": b.plan.num_little_lanes,
            "big_lanes": b.plan.num_big_lanes,
            "est_makespan": b.plan.est_makespan,
            "t_dbg_ms": store.t_dbg * 1e3,
            # plan-local: partitioning + blocking THIS plan paid for
            # (cache-hit blockings cost 0) + scheduling
            "t_partition_schedule_ms":
                (store.t_partition + b.t_block + b.t_plan) * 1e3,
            "t_plan_ms": b.t_plan * 1e3,
        }
