"""Gather-Apply-Scatter programming interface (paper §V-B, Listing 1).

Users supply three UDFs, exactly like ReGraph's accScatter/accGather/
accApply. The scatter UDF runs inside the Pallas kernels (traceable jnp
on (E_BLK,) vectors); gather is one of the supported associative modes
(the MXU/VPU "router" implements it); apply is a vertex-wise jnp function.

Built-in applications mirror the paper's benchmarks (PR, BFS, CC) plus
SSSP and WCC (both supported by ThunderGP, the paper's main baseline).
CC here is Closeness Centrality computed via 32-source bit-parallel BFS
(OR-aggregation), the standard accelerator formulation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

INF = np.float32(3.0e38)

# gather modes and their identity elements
GATHER_IDENTITY = {
    "sum": 0.0,
    "min": INF,
    "max": -INF,
    "or": 0,           # int32 bitwise OR
}


@dataclasses.dataclass(frozen=True)
class GASApp:
    """A graph application in the GAS model.

    prop is a scalar per-vertex property (f32, or i32 for 'or' mode).
    scatter(src_prop, edge_weight) -> update value        [runs in-kernel]
    gather mode in {'sum','min','max','or'}               [the router]
    apply(accum, prop, aux, iteration) -> new prop        [vertex-wise]
    init(graph_aux) -> initial prop                        (numpy)
    converged(old_prop, new_prop, iteration) -> bool
    """

    name: str
    gather: str
    scatter: Callable
    apply: Callable
    init: Callable
    converged: Callable
    needs_weights: bool = False
    prop_dtype: str = "float32"
    max_iters: int = 64


# ---------------------------------------------------------------------------
# PageRank (paper Listing 1): pull model. The stored property is
# rank/out_degree so scatter is the identity — exactly the paper's UDF.
# ---------------------------------------------------------------------------

def make_pagerank(damping: float = 0.85, max_iters: int = 16) -> GASApp:
    def scatter(src_prop, w):
        return src_prop

    def apply(accum, prop, aux, it):
        outdeg, num_v = aux["outdeg"], aux["num_v"]
        rank = (1.0 - damping) / num_v + damping * accum
        return rank / jnp.maximum(outdeg, 1.0)

    def init(aux):
        v = aux["outdeg"].shape[0]
        return (np.full(v, 1.0 / aux["num_v"], np.float32)
                / np.maximum(aux["outdeg"], 1.0)).astype(np.float32)

    def converged(old, new, it):
        return bool(jnp.max(jnp.abs(old - new)) < 1e-7)

    return GASApp("pagerank", "sum", scatter, apply, init, converged,
                  max_iters=max_iters)


# ---------------------------------------------------------------------------
# BFS: pull-based level propagation; prop = level (INF = unvisited).
# ---------------------------------------------------------------------------

def make_bfs(root: int = 0, max_iters: int = 64) -> GASApp:
    def scatter(src_prop, w):
        return src_prop

    def apply(accum, prop, aux, it):
        reachable = accum < INF
        return jnp.where((prop >= INF) & reachable, accum + 1.0, prop)

    def init(aux):
        p = np.full(aux["num_v_pad"], INF, np.float32)
        perm = aux.get("perm")
        p[int(perm[root]) if perm is not None else root] = 0.0
        return p

    def converged(old, new, it):
        return bool(jnp.all(old == new))

    return GASApp("bfs", "min", scatter, apply, init, converged,
                  max_iters=max_iters)


# ---------------------------------------------------------------------------
# SSSP: prop = distance; scatter adds edge weight; gather = min.
# ---------------------------------------------------------------------------

def make_sssp(root: int = 0, max_iters: int = 64) -> GASApp:
    def scatter(src_prop, w):
        return src_prop + w

    def apply(accum, prop, aux, it):
        return jnp.minimum(prop, accum)

    def init(aux):
        p = np.full(aux["num_v_pad"], INF, np.float32)
        perm = aux.get("perm")
        p[int(perm[root]) if perm is not None else root] = 0.0
        return p

    def converged(old, new, it):
        return bool(jnp.all(old == new))

    return GASApp("sssp", "min", scatter, apply, init, converged,
                  needs_weights=True, max_iters=max_iters)


# ---------------------------------------------------------------------------
# WCC: prop = component label, gather = min label.
# ---------------------------------------------------------------------------

def make_wcc(max_iters: int = 64) -> GASApp:
    def scatter(src_prop, w):
        return src_prop

    def apply(accum, prop, aux, it):
        return jnp.minimum(prop, accum)

    def init(aux):
        return np.arange(aux["num_v_pad"], dtype=np.float32)

    def converged(old, new, it):
        return bool(jnp.all(old == new))

    return GASApp("wcc", "min", scatter, apply, init, converged,
                  max_iters=max_iters)


# ---------------------------------------------------------------------------
# CC (Closeness Centrality): 32-source bit-parallel BFS with OR gather.
# prop = int32 visited bitmask; aux accumulates per-iteration coverage.
# The final centrality is derived by the engine from the per-iteration
# newly-visited counts (sum over sources of distances).
# ---------------------------------------------------------------------------

def make_closeness(sources: Optional[np.ndarray] = None,
                   max_iters: int = 32) -> GASApp:
    def scatter(src_prop, w):
        return src_prop

    def apply(accum, prop, aux, it):
        return prop | accum

    def init(aux):
        p = np.zeros(aux["num_v_pad"], np.int32)
        srcs = sources
        if srcs is None:
            srcs = np.arange(min(32, int(aux["num_v"])), dtype=np.int64)
        perm = aux.get("perm")
        for bit, s in enumerate(np.asarray(srcs)[:32]):
            s = int(perm[int(s)]) if perm is not None else int(s)
            mask = (1 << bit) & 0xFFFFFFFF
            if mask >= (1 << 31):      # wrap to signed int32
                mask -= 1 << 32
            p[s] |= np.int32(mask)
        return p

    def converged(old, new, it):
        return bool(jnp.all(old == new))

    return GASApp("closeness", "or", scatter, apply, init, converged,
                  prop_dtype="int32", max_iters=max_iters)


BUILTIN_APPS = {
    "pagerank": make_pagerank,
    "bfs": make_bfs,
    "sssp": make_sssp,
    "wcc": make_wcc,
    "closeness": make_closeness,
}
