"""Batched serving engine (prefill + decode waves).

Wave-based continuous batching: queued requests are grouped into waves
(padded to a shared prompt length), prefilled once, then decoded in
lockstep; finished sequences are masked out and the wave ends when all
sequences hit EOS/max-new-tokens, at which point freed slots are refilled
from the queue. Per-slot ragged decode (paged attention) is the TPU
extension point — the cache layout in configs.cache_specs is already
slot-indexed for it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    tokens: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: int = -1              # -1: never
    out: Optional[np.ndarray] = None
    ttft_s: float = 0.0
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, max_batch: int = 8, max_seq: int = 512,
                 temperature: float = 0.0, pad_id: int = 0):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.pad_id = pad_id
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def _sample(self, logits, rng):
        lf = logits[:, -1, :self.cfg.vocab_size].astype(jnp.float32)
        if self.temperature <= 0:
            return jnp.argmax(lf, axis=-1).astype(jnp.int32)
        return jax.random.categorical(rng, lf / self.temperature) \
            .astype(jnp.int32)

    def _grow_cache(self, cache, extra: int):
        """Extend the KV time axis so decode can write new positions."""
        def grow(x):
            if x.ndim >= 3 and x.shape[2] in range(1, self.max_seq * 4):
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, extra)
                return jnp.pad(x, pad)
            return x
        if self.cfg.family in ("ssm", "hybrid"):
            return cache  # recurrent state: nothing to grow
        return jax.tree.map(grow, cache)

    def run_wave(self, reqs: List[Request], rng=None) -> List[Request]:
        cfg = self.cfg
        rng = rng if rng is not None else jax.random.key(0)
        B = len(reqs)
        plen = max(r.tokens.shape[0] for r in reqs)
        toks = np.full((B, plen), self.pad_id, np.int32)
        for i, r in enumerate(reqs):
            toks[i, -r.tokens.shape[0]:] = r.tokens  # left-pad
        max_new = max(r.max_new_tokens for r in reqs)
        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(toks)}
        cache, logits = self._prefill(self.params, batch)
        cache = self._grow_cache(cache, max_new + 1)
        ttft = time.perf_counter() - t0
        cur = self._sample(logits, rng)
        outs = [[int(cur[i])] for i in range(B)]
        done = np.zeros(B, bool)
        for step in range(max_new - 1):
            rng, sub = jax.random.split(rng)
            logits, cache = self._decode(self.params, cache, cur[:, None],
                                         jnp.int32(plen + step))
            cur = self._sample(logits, sub)
            for i in range(B):
                if done[i]:
                    continue
                tok = int(cur[i])
                outs[i].append(tok)
                if tok == reqs[i].eos_id or \
                        len(outs[i]) >= reqs[i].max_new_tokens:
                    done[i] = True
            if done.all():
                break
        for i, r in enumerate(reqs):
            r.out = np.asarray(outs[i], np.int32)
            r.ttft_s = ttft
            r.done = True
        return reqs

    def serve(self, requests: List[Request]) -> dict:
        """Drain a queue in waves of max_batch; returns throughput stats."""
        t0 = time.perf_counter()
        pending = list(requests)
        n_tokens = 0
        while pending:
            wave = pending[:self.max_batch]
            pending = pending[self.max_batch:]
            self.run_wave(wave)
            n_tokens += sum(len(r.out) for r in wave)
        dt = time.perf_counter() - t0
        return {
            "requests": len(requests),
            "generated_tokens": n_tokens,
            "wall_s": dt,
            "tokens_per_s": n_tokens / max(dt, 1e-9),
            "mean_ttft_s": float(np.mean([r.ttft_s for r in requests])),
        }
