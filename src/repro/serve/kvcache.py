"""KV-cache utilities for batched serving."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, cache_specs


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    """Zero-initialised decode state matching configs.cache_specs."""
    specs = cache_specs(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)


def cache_bytes(cfg: ArchConfig, batch: int, max_seq: int) -> int:
    specs = cache_specs(cfg, batch, max_seq)
    return sum(int(jnp.dtype(s.dtype).itemsize) *
               int(jnp.prod(jnp.asarray(s.shape)))
               for s in jax.tree.leaves(specs))


def trim_left_pad(cache_entry, new_len: int):
    """Keep the trailing new_len positions (sliding retention policy)."""
    return cache_entry[:, :, -new_len:]
