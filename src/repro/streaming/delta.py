"""GraphDelta — the validated edge-update format for streaming graphs.

A delta names the snapshot it applies to (``base_fp``) and carries three
strictly-disjoint edge lists in ORIGINAL vertex ids:

  * adds     — edges that must NOT exist in the base graph,
  * removes  — edges that MUST exist,
  * updates  — weight changes to edges that MUST exist (weighted only).

Strictness is the point: a delta is a claim about a specific snapshot,
so applying it anywhere else (wrong fingerprint, missing edge, already-
present edge) fails loudly instead of silently diverging replicas. The
same-edge-in-two-lists case is rejected at construction — a weight
change is an ``update``, never a remove+add pair — which keeps apply
order-independent.

Snapshot identities chain: ``chain_fingerprint(base_fp, delta_fp)``
names the post-delta snapshot WITHOUT re-hashing the full edge list.
Chained fingerprints live in the same namespace the serving layer keys
stores on, but differ from the content hash of the materialized
post-delta graph — a delta chain is an identity lineage, not a content
address (two different edit paths to the same edge set get different
fingerprints, exactly like git commits).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

import numpy as np

from ..graphs.formats import Graph

__all__ = ["GraphDelta", "make_delta", "chain_fingerprint",
           "apply_delta_to_graph", "random_delta", "edge_keys",
           "grown_num_vertices", "compose_deltas", "compact_deltas"]


def edge_keys(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Combined int64 key ``(src << 32) | dst`` — order-isomorphic to
    (src, dst) lexicographic order for non-negative int32 ids, so a
    (src, dst)-sorted edge list has strictly-increasing keys and
    ``np.searchsorted`` locates edges exactly."""
    return (src.astype(np.int64) << 32) | dst.astype(np.int64)


def locate_edges(key: np.ndarray, k: np.ndarray, describe) -> np.ndarray:
    """Positions of every key in ``k`` within the strictly-ascending
    ``key`` array; raises ``ValueError(describe(i))`` naming the first
    key that is absent. The single existence-check used by BOTH apply
    paths (oracle removes/updates and the incremental per-partition
    merge), so the boundary handling can never diverge between them."""
    pos = np.searchsorted(key, k)
    ok = ((pos < key.shape[0])
          & (key[np.minimum(pos, max(key.shape[0] - 1, 0))] == k)
          if key.size else np.zeros(k.shape[0], dtype=bool))
    if not np.all(ok):
        raise ValueError(describe(int(np.argmin(ok))))
    return pos


def _own(a, dtype) -> np.ndarray:
    """Contiguous COPY of the input: make_delta freezes its arrays, and
    freezing must never reach back into a caller-owned buffer (an
    ascontiguousarray that happens to be a no-op would)."""
    return np.array(a, dtype=dtype, copy=True)


def _as_edge_arrays(edges, what: str) -> Tuple[np.ndarray, np.ndarray]:
    src = _own(edges[0], np.int32)
    dst = _own(edges[1], np.int32)
    if src.ndim != 1 or src.shape != dst.shape:
        raise ValueError(f"{what} src/dst must be equal-length 1-D arrays, "
                         f"got shapes {src.shape} and {dst.shape}")
    if src.size and (src.min() < 0 or dst.min() < 0):
        raise ValueError(f"{what} contains negative vertex ids")
    return src, dst


@dataclasses.dataclass(frozen=True, eq=False)
class GraphDelta:
    """A validated set of edge changes against one base snapshot.

    Construct via :func:`make_delta` (which normalizes dtypes, checks
    the disjointness invariants and freezes the arrays); the raw
    dataclass exists so deltas can be serialized/deserialized
    field-by-field. ``eq=False``: dataclass-generated equality would
    compare ndarray fields elementwise (raising on bool coercion) —
    deltas compare by identity; use :meth:`fingerprint` for value
    comparison (it is also the hashable stand-in for dict/set keys).
    """

    base_fp: str
    add_src: np.ndarray
    add_dst: np.ndarray
    add_weights: Optional[np.ndarray]
    remove_src: np.ndarray
    remove_dst: np.ndarray
    update_src: np.ndarray
    update_dst: np.ndarray
    update_weights: np.ndarray
    # vertex-growth floor: the post-delta graph has at least this many
    # vertices even when no surviving add references them (a composed
    # grow-then-remove must still grow V). None = growth is implied by
    # the add lists alone (ids >= base V extend the vertex set).
    grow_to: Optional[int] = None

    @property
    def num_adds(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def num_removes(self) -> int:
        return int(self.remove_src.shape[0])

    @property
    def num_updates(self) -> int:
        return int(self.update_src.shape[0])

    @property
    def num_changes(self) -> int:
        return self.num_adds + self.num_removes + self.num_updates

    def max_vertex(self) -> int:
        """Largest vertex id referenced (-1 when empty)."""
        return int(max(
            (int(a.max()) for a in (self.add_src, self.add_dst,
                                    self.remove_src, self.remove_dst,
                                    self.update_src, self.update_dst)
             if a.size), default=-1))

    def fingerprint(self) -> str:
        """Stable content hash of the delta (base_fp included, so the
        same edit against two snapshots hashes differently)."""
        cached = getattr(self, "_fp_cache", None)
        if cached is not None:
            return cached
        h = hashlib.blake2b(digest_size=16)
        h.update(f"delta;base={self.base_fp};"
                 f"a={self.num_adds};r={self.num_removes};"
                 f"u={self.num_updates};".encode())
        for a in (self.add_src, self.add_dst, self.remove_src,
                  self.remove_dst, self.update_src, self.update_dst):
            h.update(a.tobytes())
        h.update(b";aw=" + (b"none" if self.add_weights is None
                            else self.add_weights.tobytes()))
        h.update(b";uw=" + self.update_weights.tobytes())
        if self.grow_to is not None:   # absent -> legacy digest unchanged
            h.update(f";g={self.grow_to}".encode())
        fp = h.hexdigest()
        object.__setattr__(self, "_fp_cache", fp)
        return fp


def make_delta(base_fp: str, add=None, remove=None, update=None,
               grow_to: Optional[int] = None) -> GraphDelta:
    """Build a validated :class:`GraphDelta`.

    Parameters
    ----------
    base_fp: fingerprint of the snapshot this delta applies to (a graph
        content hash or a chained streaming fingerprint).
    add:    ``(src, dst)`` or ``(src, dst, weights)`` arrays of edges to
        insert. Weights are required iff the base graph is weighted
        (checked at apply time — the delta itself doesn't see the base).
        Ids at or beyond the base vertex count GROW the vertex set (new
        vertices extend the tail of the frozen DBG id space).
    remove: ``(src, dst)`` arrays of edges to delete.
    update: ``(src, dst, weights)`` arrays of weight changes.
    grow_to: optional floor on the post-delta vertex count (see
        :attr:`GraphDelta.grow_to`); ids below the base count are
        harmless — the apply takes ``max(V, ...)``.

    Raises ``ValueError`` on duplicate edges within a list or the same
    edge appearing in two lists (remove+add of one edge is expressed as
    an ``update``).
    """
    if not isinstance(base_fp, str) or not base_fp:
        raise ValueError(f"base_fp must be a non-empty fingerprint string, "
                         f"got {base_fp!r}")
    if grow_to is not None:
        grow_to = int(grow_to)
        if grow_to < 0:
            raise ValueError(f"grow_to must be >= 0, got {grow_to}")
    empty_i = np.zeros(0, np.int32)
    empty_f = np.zeros(0, np.float32)

    a_src, a_dst, a_w = empty_i, empty_i, None
    if add is not None:
        a_src, a_dst = _as_edge_arrays(add, "add")
        if len(add) > 2 and add[2] is not None:
            a_w = _own(add[2], np.float32)
            if a_w.shape != a_src.shape:
                raise ValueError("add weights must match add src/dst length")
    r_src, r_dst = (_as_edge_arrays(remove, "remove") if remove is not None
                    else (empty_i, empty_i))
    if update is not None:
        if len(update) < 3:
            raise ValueError("update needs (src, dst, weights)")
        u_src, u_dst = _as_edge_arrays(update[:2], "update")
        u_w = _own(update[2], np.float32)
        if u_w.shape != u_src.shape:
            raise ValueError("update weights must match update src/dst "
                             "length")
    else:
        u_src, u_dst, u_w = empty_i, empty_i, empty_f

    ka, kr, ku = (edge_keys(a_src, a_dst), edge_keys(r_src, r_dst),
                  edge_keys(u_src, u_dst))
    for name, k in (("add", ka), ("remove", kr), ("update", ku)):
        if np.unique(k).shape[0] != k.shape[0]:
            raise ValueError(f"duplicate edges in the {name} list")
    for (na, A), (nb, B) in ((("add", ka), ("remove", kr)),
                             (("add", ka), ("update", ku)),
                             (("remove", kr), ("update", ku))):
        if A.size and B.size and np.intersect1d(A, B).size:
            raise ValueError(
                f"the same edge appears in both the {na} and {nb} lists "
                f"(express a weight change as an update, not remove+add)")

    for a in (a_src, a_dst, r_src, r_dst, u_src, u_dst, u_w):
        a.setflags(write=False)
    if a_w is not None:
        a_w.setflags(write=False)
    return GraphDelta(base_fp=base_fp, add_src=a_src, add_dst=a_dst,
                      add_weights=a_w, remove_src=r_src, remove_dst=r_dst,
                      update_src=u_src, update_dst=u_dst, update_weights=u_w,
                      grow_to=grow_to)


def chain_fingerprint(base_fp: str, delta_fp: str) -> str:
    """Fingerprint of the post-delta snapshot, chained from the base
    identity and the delta's content hash — O(1), no re-hash of the
    full edge list. Same digest width as graph content fingerprints, so
    the serving layer keys stores on either interchangeably."""
    h = hashlib.blake2b(digest_size=16)
    h.update(f"chain;{base_fp};{delta_fp}".encode())
    return h.hexdigest()


def grown_num_vertices(num_vertices: int, delta: GraphDelta) -> int:
    """Vertex count of the post-delta graph. Add edges referencing ids
    at or beyond the base count grow the vertex set (intermediate ids
    materialize as zero-degree vertices), and :attr:`GraphDelta.grow_to`
    floors the result so a composed grow-then-remove still grows.
    Removes and updates can never grow (enforced by
    :func:`_validate_against`)."""
    mv = -1
    if delta.add_src.size:
        mv = max(int(delta.add_src.max()), int(delta.add_dst.max()))
    return max(int(num_vertices), mv + 1, int(delta.grow_to or 0))


def _validate_against(graph: Graph, delta: GraphDelta) -> None:
    """Weights-shape and vertex-range checks shared by both apply paths
    (per-edge existence checks happen inside each path, where the keyed
    arrays already exist). Adds may reference ids >= the base vertex
    count — that is the vertex-growth path — but removes/updates target
    edges that MUST already exist, so out-of-range ids there are
    errors."""
    mv = max((int(a.max()) for a in (delta.remove_src, delta.remove_dst,
                                     delta.update_src, delta.update_dst)
              if a.size), default=-1)
    if mv >= graph.num_vertices:
        raise ValueError(
            f"delta remove/update references vertex {mv} but the base "
            f"graph has only {graph.num_vertices} vertices (vertex growth "
            f"happens through the add list — or grow_to= — and only adds "
            f"may reference new ids)")
    weighted = graph.weights is not None
    if weighted and delta.num_adds and delta.add_weights is None:
        raise ValueError("base graph is weighted: adds must carry weights")
    if not weighted and delta.add_weights is not None:
        raise ValueError("base graph is unweighted: adds must not carry "
                         "weights")
    if not weighted and delta.num_updates:
        raise ValueError("base graph is unweighted: weight updates are "
                         "invalid")


def apply_delta_to_graph(graph: Graph, delta: GraphDelta,
                         check_fp: bool = True) -> Graph:
    """Plain (non-incremental) application: returns the canonical
    post-delta :class:`Graph`. This is the semantic oracle the
    incremental store path (:func:`repro.streaming.apply_delta`) is
    tested against, and what the serving layer replays to rebuild an
    evicted store from a delta chain.

    ``check_fp=False`` skips the base-fingerprint match — chain
    replays track identity themselves (a chained fp never equals the
    materialized graph's content hash).
    """
    if check_fp and delta.base_fp != graph.fingerprint():
        raise ValueError(
            f"delta targets snapshot {delta.base_fp[:12]}… but the graph's "
            f"fingerprint is {graph.fingerprint()[:12]}…")
    _validate_against(graph, delta)
    weighted = graph.weights is not None

    key = edge_keys(graph.src, graph.dst)   # canonical order -> ascending
    kr = edge_keys(delta.remove_src, delta.remove_dst)
    ku = edge_keys(delta.update_src, delta.update_dst)
    ka = edge_keys(delta.add_src, delta.add_dst)

    def _locate(k: np.ndarray, what: str) -> np.ndarray:
        return locate_edges(key, k, lambda i: (
            f"delta {what} targets edge "
            f"({int(k[i] >> 32)} -> {int(k[i] & 0xFFFFFFFF)}) which is "
            f"not in the base graph"))

    weights = graph.weights.copy() if weighted else None
    if ku.size:
        weights[_locate(ku, "update")] = delta.update_weights
    keep = np.ones(key.shape[0], dtype=bool)
    if kr.size:
        keep[_locate(kr, "remove")] = False
    if ka.size and key.size:
        pos = np.minimum(np.searchsorted(key, ka), key.shape[0] - 1)
        present = (key[pos] == ka) & keep[pos]
        if np.any(present):
            i = int(np.argmax(present))
            raise ValueError(
                f"delta adds edge ({int(ka[i] >> 32)} -> "
                f"{int(ka[i] & 0xFFFFFFFF)}) which already exists in the "
                f"base graph (use an update to change its weight)")

    src = np.concatenate([graph.src[keep], delta.add_src])
    dst = np.concatenate([graph.dst[keep], delta.add_dst])
    aw = (delta.add_weights if delta.add_weights is not None
          else np.zeros(delta.num_adds, np.float32))   # add-free delta
    w = np.concatenate([weights[keep], aw]) if weighted else None
    from ..graphs.formats import from_edges
    return from_edges(src, dst,
                      num_vertices=grown_num_vertices(graph.num_vertices,
                                                      delta),
                      weights=w, name=graph.name, dedup=False)


def random_delta(graph: Graph, churn: float = 0.01, seed: int = 0,
                 base_fp: Optional[str] = None,
                 update_frac: float = 0.0,
                 hot_frac: Optional[float] = None,
                 grow_frac: float = 0.0) -> GraphDelta:
    """Synthesize an edge-churn delta: ``churn * E`` total changes,
    half removals of existing edges and half insertions of non-edges
    (plus optionally ``update_frac * E`` weight updates on a weighted
    graph). ``base_fp`` defaults to the graph's content fingerprint;
    pass the chained fingerprint when generating churn against a
    streamed snapshot.

    ``hot_frac`` models how evolving power-law graphs actually churn:
    preferential attachment concentrates new/retired edges on the top
    ``hot_frac`` fraction of vertices by in-degree. Because DBG groups
    exactly those vertices into the first dst-range partitions, skewed
    churn keeps the dirty partition set small — the locality
    :func:`~repro.streaming.apply_delta` exploits. ``None`` = uniform
    destinations (the no-locality worst case: every partition goes
    dirty once changes outnumber partitions).

    ``grow_frac`` emits ``grow_frac * E`` additional edges to
    OUT-OF-RANGE vertex ids (ids >= V), exercising the vertex-growth
    path: new vertices take the tail of the id space and attach
    preferentially — sources are drawn by out-degree (sampling edge
    endpoints), and later growth edges concentrate on the earlier new
    vertices, the usual rich-get-richer arrival model."""
    rng = np.random.default_rng(seed)
    E, V = graph.num_edges, graph.num_vertices
    n_half = max(1, int(E * churn / 2))
    weighted = graph.weights is not None

    if hot_frac:
        k = max(1, int(V * hot_frac))
        ind = graph.in_degrees()
        hot = np.argpartition(ind, -k)[-k:]        # top-k by in-degree
        rm_pool = np.flatnonzero(np.isin(graph.dst, hot))
    else:
        hot = None
        rm_pool = np.arange(E)

    rm_idx = rng.choice(rm_pool, size=min(n_half, rm_pool.shape[0]),
                        replace=False)
    remove = (graph.src[rm_idx], graph.dst[rm_idx])

    # vectorized non-edge sampling: membership via searchsorted on the
    # sorted key array (no O(E) Python set). Bounded: a (near-)saturated
    # candidate space (e.g. a star hub already fed by every vertex)
    # yields fewer adds instead of spinning forever — the delta stays
    # valid either way.
    base_keys = np.sort(edge_keys(graph.src, graph.dst))
    got_s, got_d = [], []
    picked_keys = np.zeros(0, np.int64)
    stalled, n_picked = 0, 0
    while n_picked < n_half and stalled < 16:
        cs = rng.integers(0, V, size=4 * n_half)
        cd = (rng.choice(hot, size=4 * n_half) if hot is not None
              else rng.integers(0, V, size=4 * n_half))
        ok = cs != cd
        cand_s = cs[ok].astype(np.int32)
        cand_d = cd[ok].astype(np.int32)
        k, first = np.unique(edge_keys(cand_s, cand_d),
                             return_index=True)
        cand_s, cand_d = cand_s[first], cand_d[first]
        pos = np.minimum(np.searchsorted(base_keys, k),
                         max(base_keys.size - 1, 0))
        fresh = (base_keys[pos] != k if base_keys.size
                 else np.ones(k.shape[0], dtype=bool))
        if picked_keys.size:
            fresh &= ~np.isin(k, picked_keys)
        sel = np.flatnonzero(fresh)[:n_half - n_picked]
        if sel.size:
            got_s.append(cand_s[sel])
            got_d.append(cand_d[sel])
            picked_keys = np.concatenate([picked_keys, k[sel]])
            n_picked += sel.size
            stalled = 0
        else:
            stalled += 1
    a_src = (np.concatenate(got_s) if got_s else np.zeros(0, np.int32))
    a_dst = (np.concatenate(got_d) if got_d else np.zeros(0, np.int32))

    grow_to = None
    if grow_frac > 0 and E:
        n_grow = max(1, int(E * grow_frac))
        n_new = max(1, n_grow // 2)
        new_ids = np.arange(V, V + n_new, dtype=np.int32)
        # sources by preferential attachment: sampling edge slots picks
        # a vertex with probability proportional to its out-degree
        g_src = graph.src[rng.integers(0, E, size=n_grow)].astype(np.int32)
        # every new vertex gets at least one in-edge; the surplus lands
        # on the earliest arrivals (rich-get-richer within the batch)
        extra = (new_ids[rng.integers(0, max(1, n_new // 2),
                                      size=n_grow - n_new)]
                 if n_grow > n_new else np.zeros(0, np.int32))
        g_dst = np.concatenate([new_ids, extra])
        _, first = np.unique(edge_keys(g_src, g_dst), return_index=True)
        sel = np.sort(first)                  # dedupe, keep arrival order
        a_src = np.concatenate([a_src, g_src[sel]])
        a_dst = np.concatenate([a_dst, g_dst[sel]])
        grow_to = V + n_new
    add = ((a_src, a_dst, rng.random(a_src.shape[0]).astype(np.float32))
           if weighted else (a_src, a_dst))

    update = None
    if weighted and update_frac > 0:
        candidates = np.setdiff1d(rm_pool, rm_idx)
        n_upd = min(max(1, int(E * update_frac)), candidates.shape[0])
        if n_upd:
            up_idx = rng.choice(candidates, size=n_upd, replace=False)
            update = (graph.src[up_idx], graph.dst[up_idx],
                      rng.random(n_upd).astype(np.float32))

    return make_delta(base_fp or graph.fingerprint(), add=add,
                      remove=remove, update=update, grow_to=grow_to)


def compose_deltas(first: GraphDelta, second: GraphDelta) -> GraphDelta:
    """One delta equivalent to applying ``first`` then ``second``.

    Per-edge-key resolution against the shared base snapshot:
    add+remove cancels, add+update keeps the add with the new weight,
    remove+add becomes an update (weighted) or cancels (unweighted —
    the identical edge is restored), update+update keeps the last
    weight, update+remove collapses to the remove. Combinations that
    could never have applied in sequence (adding an edge that exists
    post-``first``, removing/updating one that doesn't) raise — the
    inputs are assumed to be a VALID chain, and composition surfaces
    corruption instead of hiding it.

    The composed ``grow_to`` covers every vertex either delta could
    have created, so grow-then-remove still grows the vertex set (the
    floor is taken under ``max`` with the base count, so ids below it
    are harmless). ``base_fp`` is ``first``'s — the composed delta
    applies where ``first`` did. Its chained fingerprint differs from
    the original chain's tip (a different edit path); callers that
    compact a chain keep the ORIGINAL tip identity (see
    :func:`compact_deltas`).
    """
    weighted = (first.add_weights is not None
                or second.add_weights is not None
                or first.num_updates > 0 or second.num_updates > 0)

    state = {}   # edge key -> ("A"|"R"|"U", weight) relative to the base
    aw1 = (first.add_weights if first.add_weights is not None
           else np.zeros(first.num_adds, np.float32))
    for k, w in zip(edge_keys(first.add_src, first.add_dst), aw1):
        state[int(k)] = ("A", float(w))
    for k in edge_keys(first.remove_src, first.remove_dst):
        state[int(k)] = ("R", 0.0)
    for k, w in zip(edge_keys(first.update_src, first.update_dst),
                    first.update_weights):
        state[int(k)] = ("U", float(w))

    def _edge(k):
        return f"({k >> 32} -> {k & 0xFFFFFFFF})"

    aw2 = (second.add_weights if second.add_weights is not None
           else np.zeros(second.num_adds, np.float32))
    for k, w in zip(edge_keys(second.add_src, second.add_dst), aw2):
        k = int(k)
        prev = state.get(k)
        if prev is None:
            state[k] = ("A", float(w))
        elif prev[0] == "R":
            if weighted:
                state[k] = ("U", float(w))   # remove+re-add = weight change
            else:
                del state[k]                 # identical edge restored
        else:
            raise ValueError(f"compose: second delta adds edge {_edge(k)} "
                             f"which exists after the first delta")
    for k in edge_keys(second.remove_src, second.remove_dst):
        k = int(k)
        prev = state.get(k)
        if prev is None:
            state[k] = ("R", 0.0)
        elif prev[0] == "A":
            del state[k]                     # added then removed: no-op
        elif prev[0] == "U":
            state[k] = ("R", 0.0)
        else:
            raise ValueError(f"compose: second delta removes edge "
                             f"{_edge(k)} which the first already removed")
    for k, w in zip(edge_keys(second.update_src, second.update_dst),
                    second.update_weights):
        k = int(k)
        prev = state.get(k)
        if prev is None or prev[0] == "U":
            state[k] = ("U", float(w))
        elif prev[0] == "A":
            state[k] = ("A", float(w))
        else:
            raise ValueError(f"compose: second delta updates edge "
                             f"{_edge(k)} which the first removed")

    adds, removes, updates = [], [], []
    for k in sorted(state):
        op, w = state[k]
        (adds if op == "A" else removes if op == "R" else updates).append(
            (k >> 32, k & 0xFFFFFFFF, w))

    def _cols(rows):
        s = np.array([r[0] for r in rows], np.int32)
        d = np.array([r[1] for r in rows], np.int32)
        w = np.array([r[2] for r in rows], np.float32)
        return s, d, w

    a_s, a_d, a_w = _cols(adds)
    r_s, r_d, _ = _cols(removes)
    u_s, u_d, u_w = _cols(updates)
    grow_to = max(int(first.grow_to or 0), int(second.grow_to or 0),
                  first.max_vertex() + 1, second.max_vertex() + 1)
    return make_delta(
        first.base_fp,
        add=((a_s, a_d, a_w) if weighted else (a_s, a_d)),
        remove=(r_s, r_d),
        update=((u_s, u_d, u_w) if len(updates) else None),
        grow_to=(grow_to if grow_to > 0 else None))


def compact_deltas(deltas, strict: bool = True):
    """Squash a contiguous delta chain into ONE equivalent delta.

    Returns ``(composed, tip_fp)`` where ``tip_fp`` is the chain's
    ORIGINAL tip fingerprint — ``chain_fingerprint`` folded over the
    input deltas. Compaction changes the replay (one delta instead of
    N) but must not change the snapshot's identity, so callers keep
    addressing the compacted snapshot by ``tip_fp``, never by
    re-chaining the composed delta.

    ``strict`` verifies lineage: every delta's ``base_fp`` must equal
    the chained fingerprint its predecessor produced.
    """
    deltas = list(deltas)
    if not deltas:
        raise ValueError("compact_deltas needs at least one delta")
    out = deltas[0]
    tip = chain_fingerprint(out.base_fp, out.fingerprint())
    for d in deltas[1:]:
        if strict and d.base_fp != tip:
            raise ValueError(
                f"delta chain is not contiguous: delta targets snapshot "
                f"{d.base_fp[:12]}… but the chain's tip is {tip[:12]}…")
        out = compose_deltas(out, d)
        tip = chain_fingerprint(tip, d.fingerprint())
    return out, tip
