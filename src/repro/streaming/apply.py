"""Incremental GraphStore rebuild under a GraphDelta (dirty ranges only).

A cold :class:`~repro.core.store.GraphStore` build pays DBG, a full
edge lexsort, per-partition stats, and (through the first plans) the
Little/Big brick blockings. A delta touches few destination-range
partitions, so :func:`apply_delta` redoes only those:

  1. map the delta's edges through the store's FROZEN permutation and
     bucket them by dst-range partition — the touched set is "dirty";
  2. merge the delta into the dirty segments. Two interchangeable
     merge paths produce bit-identical edges, chosen by the dirty
     fraction (the cost-model guidance ROADMAP item 4 asks for):
     *splice* — per-partition searchsorted insert/mask, no sort of
     clean data, wins when few partitions are dirty; *bulk sort* — one
     global lexsort of (kept dirty edges + adds), wins when churn is
     uniform and most partitions are dirty (per-partition splices then
     degenerate into many small sorts' worth of passes and lose to the
     single lexsort a cold rebuild would do — the 0.41x uniform-churn
     regression in BENCH_streaming.json). Above
     ``bulk_threshold`` dirty fraction the bulk path is taken, so
     incremental apply is never slower than a rebuild; the chosen path
     lands in ``DeltaApplyResult.stats["path"]``. Either way each dirty
     partition's :class:`PartitionInfo` is recomputed via the same
     helper the cold build uses;
  3. splice the new segments between the untouched ones (one
     concatenate per array — memcpy, not sort) into a *derived* store
     that shares the base's permutation and every clean blocking;
  4. rebuild each cached plan against the new stats (clean partitions
     keep bit-identical stats, so re-classification and re-scheduling
     are milliseconds) and seed structurally-unchanged lanes with the
     pre-delta packed device payloads — untouched lanes are neither
     re-packed nor re-uploaded. Sharded materializations carry over the
     same way, with clean lanes additionally PINNED to their owner
     device (only dirty lanes are re-placed by LPT around them);
     ``shards_moved`` / ``shard_bytes_moved`` account what transferred;
  5. chain the new snapshot fingerprint from ``(base_fp, delta_fp)``.

Vertex growth rides the same machinery: add edges referencing ids >= V
extend the vertex set, with new vertices mapped identity-wise onto the
TAIL of the frozen DBG id space (so every clean partition and blocking
survives untouched). Grown tail partitions are built purely from the
delta's adds; the one V-dependent stat (the last old partition's
``dst_hi``) is patched; ``V_pad`` and the extended permutation land on
the derived store so the lazy aux rebuilds correctly.

The permutation is frozen across a delta chain (recomputing DBG would
dirty every partition); under heavy churn DBG quality decays slowly and
a full re-registration re-optimizes it (see ``repro.streaming.regroup``
for the drift metric and policy trigger). Equivalence guarantee: the
derived store's edge arrays, partition stats, blockings, plans and app
results are bit-identical to a cold ``GraphStore(post_graph,
perm=base.perm)`` build (tests/test_streaming.py holds this for all
five builtin apps on both ref and pallas-interpret paths). A cold build
that recomputes DBG from the post-delta degrees may instead differ by
reduction order (1-ULP drift in 'sum' apps) — identical for min/or/max.

The base store is never mutated: in-flight executors keep running
against the old snapshot while the serving layer re-keys its cache to
the new fingerprint.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import partition as part
from ..core.store import GraphStore
from ..graphs.formats import Graph, freeze
from .delta import (GraphDelta, _validate_against, chain_fingerprint,
                    edge_keys, grown_num_vertices, locate_edges)

__all__ = ["apply_delta", "splice_delta", "rebuild_plans",
           "DeltaApplyResult", "BULK_THRESHOLD"]

# dirty-partition fraction above which the one-shot bulk lexsort beats
# per-partition splices (measured crossover is broad — splices lose
# badly at ~100% dirty, win badly at ~5%; 0.5 splits the flat middle)
BULK_THRESHOLD = 0.5


@dataclasses.dataclass
class DeltaApplyResult:
    """Outcome of one incremental apply: the derived store, its chained
    snapshot fingerprint, and the reuse/invalidation accounting the
    serving metrics aggregate."""

    store: GraphStore
    fingerprint: str
    base_fingerprint: str
    dirty_pids: Tuple[int, ...]
    stats: dict


def _orig_edge(store: GraphStore, s_dbg: int, d_dbg: int) -> str:
    """Original-id rendering of a DBG-space edge (error messages).
    Grown tail ids sit beyond the frozen permutation and map to
    themselves (growth extends the id space identity-wise)."""
    inv = np.argsort(store.perm)

    def _orig(i: int) -> int:
        return int(inv[i]) if i < inv.shape[0] else int(i)

    return f"({_orig(s_dbg)} -> {_orig(d_dbg)})"


def _merge_segment(store: GraphStore, s, d, w,
                   adds, removes, updates, weighted: bool):
    """Merge one dirty partition's delta into its (src, dst)-sorted
    segment. Pure searchsorted/mask/insert — O(segment + changes), no
    sort of pre-existing edges. Validates existence/absence exactly."""
    key = edge_keys(s, d)

    def _missing(what, ks, kd):
        return lambda i: (f"delta {what} targets edge "
                          f"{_orig_edge(store, int(ks[i]), int(kd[i]))} "
                          f"which is not in the base graph")

    w = w.copy()
    u_src, u_dst, u_w = updates
    if u_src.size:
        pos = locate_edges(key, edge_keys(u_src, u_dst),
                           _missing("update", u_src, u_dst))
        w[pos] = u_w

    keep = np.ones(key.shape[0], dtype=bool)
    r_src, r_dst = removes
    if r_src.size:
        pos = locate_edges(key, edge_keys(r_src, r_dst),
                           _missing("remove", r_src, r_dst))
        keep[pos] = False

    s_k, d_k, w_k = s[keep], d[keep], w[keep]
    a_src, a_dst, a_w = adds
    if a_src.size:
        ka = edge_keys(a_src, a_dst)
        order = np.argsort(ka)       # np.insert keeps given order within
        a_src, a_dst, ka = a_src[order], a_dst[order], ka[order]
        a_w = a_w[order] if weighted else np.zeros(a_src.shape[0],
                                                   np.float32)
        kept_key = key[keep]
        ins = np.searchsorted(kept_key, ka)
        if kept_key.size:
            at = np.minimum(ins, kept_key.shape[0] - 1)
            present = kept_key[at] == ka
            if np.any(present):
                i = int(np.argmax(present))
                raise ValueError(
                    f"delta adds edge "
                    f"{_orig_edge(store, int(a_src[i]), int(a_dst[i]))} "
                    f"which already exists in the base graph (use an "
                    f"update to change its weight)")
        s_k = np.insert(s_k, ins, a_src)
        d_k = np.insert(d_k, ins, a_dst)
        w_k = np.insert(w_k, ins, a_w)
    return s_k, d_k, w_k


def _merge_dirty_bulk(store, dirty_pids, adds, removes, updates,
                      weighted: bool) -> Dict[int, tuple]:
    """High-churn merge path: validate removes/updates per dirty
    partition (identical checks to :func:`_merge_segment`), then build
    the post-delta dirty edges with ONE global ``np.lexsort`` over
    (partition, src, dst) instead of per-partition splices. Returns
    ``pid -> (src, dst, weights)`` segments bit-identical to what the
    splice path produces (keys are unique, so the sort order is exactly
    the splice order)."""
    a_src, a_dst, a_w = adds
    r_src, r_dst, r_pid = removes
    u_src, u_dst, u_w, u_pid = updates
    U = store.geom.U

    def _missing(what, ks, kd):
        return lambda i: (f"delta {what} targets edge "
                          f"{_orig_edge(store, int(ks[i]), int(kd[i]))} "
                          f"which is not in the base graph")

    kept_s, kept_d, kept_w = [], [], []
    for p in dirty_pids:
        info = store.infos[p]
        lo, hi = info.edge_lo, info.edge_hi
        s = store.edges["src"][lo:hi]
        d = store.edges["dst"][lo:hi]
        w = store.edges["weights"][lo:hi]
        key = edge_keys(s, d)
        m_u = u_pid == p
        if np.any(m_u):
            su, du = u_src[m_u], u_dst[m_u]
            pos = locate_edges(key, edge_keys(su, du),
                               _missing("update", su, du))
            w = w.copy()
            w[pos] = u_w[m_u]
        m_r = r_pid == p
        if np.any(m_r):
            sr, dr = r_src[m_r], r_dst[m_r]
            pos = locate_edges(key, edge_keys(sr, dr),
                               _missing("remove", sr, dr))
            keep = np.ones(key.shape[0], dtype=bool)
            keep[pos] = False
            s, d, w, key = s[keep], d[keep], w[keep], key[keep]
        kept_s.append(s)
        kept_d.append(d)
        kept_w.append(w)

    # adds validated against the post-remove kept keys, like the splice
    # path ("already exists" must fire for true duplicates but not for
    # a removed-then-referenced slot — removes cannot coexist with adds
    # on one edge by delta construction, so kept keys are the oracle)
    if a_src.size:
        kept_key = np.concatenate(
            [edge_keys(s, d) for s, d in zip(kept_s, kept_d)]
            or [np.zeros(0, np.int64)])
        kept_key.sort()
        ka = edge_keys(a_src, a_dst)
        if kept_key.size:
            at = np.minimum(np.searchsorted(kept_key, ka),
                            kept_key.shape[0] - 1)
            present = kept_key[at] == ka
            if np.any(present):
                i = int(np.argmax(present))
                raise ValueError(
                    f"delta adds edge "
                    f"{_orig_edge(store, int(a_src[i]), int(a_dst[i]))} "
                    f"which already exists in the base graph (use an "
                    f"update to change its weight)")
    add_w = (a_w if (weighted and a_src.size)
             else np.zeros(a_src.shape[0], np.float32))

    all_s = np.concatenate(kept_s + [a_src])
    all_d = np.concatenate(kept_d + [a_dst])
    all_w = np.concatenate(kept_w + [add_w])
    pid = all_d // U
    order = np.lexsort((all_d, all_s, pid))     # (pid, src, dst) asc
    all_s, all_d, all_w, pid = (all_s[order], all_d[order], all_w[order],
                                pid[order])
    dirty_arr = np.asarray(dirty_pids, dtype=pid.dtype)
    los = np.searchsorted(pid, dirty_arr)
    his = np.searchsorted(pid, dirty_arr + 1)
    return {int(p): (all_s[lo:hi], all_d[lo:hi], all_w[lo:hi])
            for p, lo, hi in zip(dirty_pids, los, his)}


def _lane_signature(lane, big_works) -> tuple:
    """Structural identity of one lane's packed payload: the entry
    list's (work identity, block range) sequence. Payload content is a
    pure function of this plus the underlying blockings, so a matching
    signature over clean partitions means the packed device arrays are
    bit-identical and can be carried over without re-upload."""
    return tuple(
        ((("little", e.work_id) if e.kind == "little"
          else ("big",) + tuple(big_works[e.work_id].pids)),
         e.block_lo, e.block_hi)
        for e in lane)


def _lane_pids(lane, big_works) -> set:
    pids = set()
    for e in lane:
        if e.kind == "little":
            pids.add(e.work_id)
        else:
            pids.update(big_works[e.work_id].pids)
    return pids


def splice_delta(store: GraphStore, delta: GraphDelta, *,
                 bulk_threshold=BULK_THRESHOLD) -> DeltaApplyResult:
    """Steps 1–3 + 5 of the apply: merge the delta into the dirty
    segments (splice or bulk-sort path by dirty fraction), build the
    derived store, chain the fingerprint. Plan rebuild (step 4) is NOT
    done here — call :func:`rebuild_plans` against the base afterwards,
    or use :func:`apply_delta` which composes both.

    Split out so the control plane's process pool can run the
    numpy-heavy merge in a worker (the derived store pickles) while the
    parent, which owns the base store's plan cache and device-resident
    payloads, rebuilds plans in-process.

    ``bulk_threshold=None`` forces the splice path regardless of dirty
    fraction (parity tests pin one path against the other).
    """
    t0 = time.perf_counter()
    base_fp = store.fingerprint()
    if delta.base_fp != base_fp:
        raise ValueError(
            f"delta targets snapshot {delta.base_fp[:12]}… but the store's "
            f"fingerprint is {base_fp[:12]}…")

    g = store.graph
    V = g.num_vertices
    weighted = g.weights is not None
    _validate_against(g, delta)   # range + weights-shape, shared oracle
    new_V = grown_num_vertices(V, delta)
    grown = new_V - V

    # -- 1. relabel into the frozen DBG id space & bucket by partition --
    perm, U = store.perm, store.geom.U
    if grown:
        # new vertices take the TAIL of the frozen DBG id space,
        # identity-mapped — the same place a cold rebuild under the
        # extended permutation puts them, so the frozen-perm invariant
        # (and every clean blocking) survives growth untouched
        perm = np.concatenate([perm, np.arange(V, new_V, dtype=np.int32)])
        perm.setflags(write=False)
    a_src, a_dst = perm[delta.add_src], perm[delta.add_dst]
    r_src, r_dst = perm[delta.remove_src], perm[delta.remove_dst]
    u_src, u_dst = perm[delta.update_src], perm[delta.update_dst]
    a_pid, r_pid, u_pid = a_dst // U, r_dst // U, u_dst // U
    dirty = np.unique(np.concatenate([a_pid, r_pid, u_pid]))
    dirty_set = set(int(p) for p in dirty)

    # -- 2./3. merge dirty segments, splice, recompute dirty stats -----
    num_parts = len(store.infos)
    new_num_parts = max(1, -(-new_V // U))
    # the splice-vs-bulk choice is about merging BASE segments, so the
    # dirty fraction counts old partitions only; grown tail partitions
    # have no base segment (their edges are purely the delta's adds)
    dirty_old = [int(p) for p in dirty if p < num_parts]
    dirty_fraction = (len(dirty_old) / num_parts) if num_parts else 0.0
    use_bulk = (bulk_threshold is not None and dirty_old
                and dirty_fraction >= bulk_threshold)
    if use_bulk:
        bulk_segs = _merge_dirty_bulk(
            store, dirty_old,
            (a_src, a_dst,
             delta.add_weights if weighted and delta.num_adds else None),
            (r_src, r_dst, r_pid),
            (u_src, u_dst, delta.update_weights, u_pid),
            weighted)
    empty_i, empty_f = np.zeros(0, np.int32), np.zeros(0, np.float32)
    seg_src: List[np.ndarray] = []
    seg_dst: List[np.ndarray] = []
    seg_w: List[np.ndarray] = []
    new_infos = []
    off = 0
    for p in range(new_num_parts):
        info = store.infos[p] if p < num_parts else None
        if p in dirty_set:
            if info is None:
                # grown tail partition: its segment is purely the
                # delta's adds, in the (src, dst) order the cold
                # build's global lexsort would produce
                m_a = a_pid == p
                s, d = a_src[m_a], a_dst[m_a]
                w = (delta.add_weights[m_a] if weighted
                     else np.zeros(s.shape[0], np.float32))
                order = np.lexsort((d, s))
                s, d, w = s[order], d[order], w[order]
            elif use_bulk:
                s, d, w = bulk_segs[p]
            else:
                lo, hi = info.edge_lo, info.edge_hi
                m_a, m_r, m_u = a_pid == p, r_pid == p, u_pid == p
                s, d, w = _merge_segment(
                    store,
                    store.edges["src"][lo:hi], store.edges["dst"][lo:hi],
                    store.edges["weights"][lo:hi],
                    (a_src[m_a], a_dst[m_a],
                     delta.add_weights[m_a] if weighted and delta.num_adds
                     else None),
                    (r_src[m_r], r_dst[m_r]),
                    (u_src[m_u], u_dst[m_u], delta.update_weights[m_u]),
                    weighted)
            new_infos.append(part.partition_info(p, s, d, off, new_V,
                                                 store.geom))
        elif info is None:
            # grown id range with no edges yet (grow_to growth): the
            # cold build still emits an empty partition info for it
            s, d, w = empty_i, empty_i, empty_f
            new_infos.append(part.partition_info(p, s, d, off, new_V,
                                                 store.geom))
        else:
            lo, hi = info.edge_lo, info.edge_hi
            s = store.edges["src"][lo:hi]
            d = store.edges["dst"][lo:hi]
            w = store.edges["weights"][lo:hi]
            # dst_hi is the one V-dependent stat: the last old partition
            # widens when growth lands inside its dst range (blockings
            # never read it, so they carry over bit-identical)
            new_infos.append(dataclasses.replace(
                info, edge_lo=off, edge_hi=off + (hi - lo),
                dst_hi=min((p + 1) * U, new_V)))
        seg_src.append(s)
        seg_dst.append(d)
        seg_w.append(w)
        off += s.shape[0]

    if dirty_set:
        edges = {"src": np.concatenate(seg_src),
                 "dst": np.concatenate(seg_dst),
                 "weights": np.concatenate(seg_w)}
        infos = new_infos
    elif grown:                # grow_to-only: edges shared, infos grown
        edges = store.edges
        infos = new_infos
    else:                      # empty delta: share everything
        edges = store.edges
        infos = list(store.infos)

    # the derived graph aliases the partition-sorted edge arrays
    # (zero-copy; NOT canonical (src, dst) order — use
    # apply_delta_to_graph for a canonical post-delta Graph). The store
    # only consumes it for order-independent quantities (V/E, degree
    # counts, byte accounting).
    new_graph = freeze(Graph(
        num_vertices=new_V, src=edges["src"], dst=edges["dst"],
        weights=edges["weights"] if weighted else None,
        name=g.name + "+d"))

    new_fp = chain_fingerprint(base_fp, delta.fingerprint())
    # snapshot under the plan lock: workers planning on the leased base
    # store insert blockings into these dicts concurrently (Planner.build
    # runs under the same lock), and iterating them bare would race
    with store._plan_lock:
        little_carried = {pid: w for pid, w in store._little_cache.items()
                          if pid not in dirty_set}
        big_carried = {pids: w for pids, w in store._big_cache.items()
                       if not (set(pids) & dirty_set)}
        n_little_base = len(store._little_cache)
        n_big_base = len(store._big_cache)
    t_splice = time.perf_counter() - t0

    new_store = GraphStore._derived(
        store, graph=new_graph, infos=infos, edges=edges,
        little_cache=little_carried, big_cache=big_carried,
        fingerprint=new_fp, t_partition=t_splice,
        perm=perm if grown else None,
        V_pad=(part.padded_num_vertices(new_V, store.geom) if grown
               else None))

    stats = {
        "num_adds": delta.num_adds,
        "num_removes": delta.num_removes,
        "num_updates": delta.num_updates,
        "partitions": new_num_parts,
        "grown_vertices": grown,
        "new_partitions": new_num_parts - num_parts,
        "dirty_partitions": len(dirty_set),
        "dirty_fraction": dirty_fraction,
        "path": "bulk_sort" if use_bulk else "splice",
        "little_blockings_reused": len(little_carried),
        "little_blockings_dropped": n_little_base - len(little_carried),
        "big_blockings_reused": len(big_carried),
        "big_blockings_dropped": n_big_base - len(big_carried),
        "t_splice_ms": t_splice * 1e3,
    }
    return DeltaApplyResult(store=new_store, fingerprint=new_fp,
                            base_fingerprint=base_fp,
                            dirty_pids=tuple(int(p) for p in dirty),
                            stats=stats)


def rebuild_plans(base_store: GraphStore, new_store: GraphStore,
                  dirty_pids, *,
                  rebalance_threshold: Optional[float] = None) -> dict:
    """Step 4 of the apply: rebuild every plan cached on ``base_store``
    against ``new_store``'s stats, seeding structurally-unchanged clean
    lanes with the pre-delta packed device payloads (and, for sharded
    forms, pinning clean lanes to their owner devices). Runs in the
    process that owns the base store's plan cache — the device payloads
    it carries over never cross a process boundary. Returns the
    plan-side stats dict that :func:`apply_delta` merges into
    :attr:`DeltaApplyResult.stats`.

    ``rebalance_threshold`` is the placement-drift bound: ``keep=``
    pinning trades balance for zero-move carry-over, and across a long
    delta chain the pinned placement can drift arbitrarily far from
    what a fresh LPT would choose. When a rebuilt sharded form's
    measured imbalance (max/mean device load) exceeds the bound, its
    pins are dropped and the lanes are re-placed (and re-uploaded) from
    scratch — the same observe/threshold/swap shape the autotuner uses
    for plans. ``None`` keeps pinning unconditionally."""
    dirty_set = set(int(p) for p in dirty_pids)
    t1 = time.perf_counter()
    with base_store._plan_lock:
        old_bundles = list(base_store._plan_cache.values())
    plans_rebuilt = 0
    packed_reused = packed_repacked = 0
    packed_bytes_reused = 0
    shards_moved = shards_reused = 0
    shard_bytes_moved = shard_bytes_reused = 0
    placements_rebalanced = 0
    worst_imbalance = 0.0
    for old in old_bundles:
        bundle = new_store.plan(old.config)
        plans_rebuilt += 1
        old_packed = old._packed_lanes       # snapshot (flips once)
        old_sharded = dict(old._sharded or {})
        if old_packed is None and not old_sharded:
            continue                          # base never materialized any
        sig_to_lane = {}
        for j, lane in enumerate(old.plan.lanes):
            sig = _lane_signature(lane, old.big_works)
            if sig:                           # empty lanes pack for free
                sig_to_lane.setdefault(sig, j)

        # (new lane idx, old lane idx) pairs whose entry structure
        # survived re-scheduling and touch no dirty partition — the
        # lanes whose device payloads are bit-identical pre/post.
        # Computed once; the packed and every sharded form reuse it.
        matches = []
        for i, lane in enumerate(bundle.plan.lanes):
            sig = _lane_signature(lane, bundle.big_works)
            j = sig_to_lane.get(sig)
            if (j is not None
                    and not (_lane_pids(lane, bundle.big_works)
                             & dirty_set)):
                matches.append((i, j))

        if old_packed is not None:
            seed = {i: old_packed[j] for i, j in matches}
            bundle._packed_seed = seed or None
            packed = bundle.packed_lanes()    # eager: keep serving warm
            packed_reused += bundle.packed_lanes_reused
            packed_bytes_reused += bundle.packed_bytes_reused
            packed_repacked += (sum(1 for lane in packed if lane)
                                - bundle.packed_lanes_reused)
        # sharded forms: clean lanes KEEP their owner device (only dirty
        # lanes are re-placed by LPT around them) and their resident
        # per-device payloads are spliced in without re-transfer
        for devices, old_sh in old_sharded.items():
            keep, sseed = {}, {}
            for i, j in matches:
                keep[i] = old_sh.placement.device_of_lane[j]
                sseed[i] = old_sh.lanes[j]
            bundle._shard_seed = (devices, keep, sseed)
            new_sh = bundle.sharded_lanes(devices)   # eager, like packed
            if (rebalance_threshold is not None
                    and new_sh.placement.needs_rebalance(
                        rebalance_threshold)):
                # pinned placement drifted past the bound: drop the
                # memoized form and re-place every lane by fresh LPT
                # (payloads re-upload — the cost rebalancing amortizes)
                with bundle._mat_lock:
                    if bundle._sharded:
                        bundle._sharded.pop(devices, None)
                new_sh = bundle.sharded_lanes(devices)   # no pins, no seed
                placements_rebalanced += 1
            worst_imbalance = max(worst_imbalance,
                                  new_sh.placement.imbalance)
            shards_moved += new_sh.moved
            shard_bytes_moved += new_sh.bytes_moved
            shards_reused += new_sh.reused
            shard_bytes_reused += new_sh.bytes_reused
    t_replan = time.perf_counter() - t1

    return {
        "plans_rebuilt": plans_rebuilt,
        "packed_lanes_reused": packed_reused,
        "packed_lanes_repacked": packed_repacked,
        "packed_bytes_reused": int(packed_bytes_reused),
        "shards_moved": shards_moved,
        "shard_bytes_moved": int(shard_bytes_moved),
        "shards_reused": shards_reused,
        "shard_bytes_reused": int(shard_bytes_reused),
        "placements_rebalanced": placements_rebalanced,
        "placement_imbalance": float(worst_imbalance),
        "t_replan_ms": t_replan * 1e3,
    }


def apply_delta(store: GraphStore, delta: GraphDelta, *,
                bulk_threshold=BULK_THRESHOLD,
                rebalance_threshold: Optional[float] = None
                ) -> DeltaApplyResult:
    """Apply a :class:`GraphDelta` to a prepared store incrementally.

    Returns a :class:`DeltaApplyResult` whose ``store`` is a NEW
    derived :class:`GraphStore` (the base is left untouched as the old
    snapshot) and whose ``stats`` record the merge path taken
    (``"splice"`` vs ``"bulk_sort"``, by dirty fraction against
    ``bulk_threshold``) and exactly what was reused: blockings and
    per-partition stats of clean partitions, and — for every plan
    cached on the base — the packed device payloads of lanes whose
    structure survived re-scheduling.
    """
    t0 = time.perf_counter()
    res = splice_delta(store, delta, bulk_threshold=bulk_threshold)
    res.stats.update(rebuild_plans(
        store, res.store, res.dirty_pids,
        rebalance_threshold=rebalance_threshold))
    res.stats["t_apply_ms"] = (time.perf_counter() - t0) * 1e3
    return res
