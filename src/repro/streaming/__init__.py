"""Streaming graphs: delta updates with dirty-range incremental rebuild.

The static layers (GraphStore → Planner → Executor) prepare a graph
once; this package is the sanctioned way a prepared graph CHANGES.
A :class:`GraphDelta` (validated add/remove/update edge lists against a
base fingerprint) applied with :func:`apply_delta` re-partitions and
re-blocks only the dirty dst-range partitions, splices them into a
derived store, chains the snapshot fingerprint from
``(base_fp, delta_fp)``, and carries over every clean blocking and
every structurally-unchanged lane's packed device payload. The serving
layer surfaces it as ``GraphService.update(fp, delta)`` with snapshot
semantics (in-flight requests finish on the old store; new submits see
the new fingerprint).

Structural completeness: deltas can also GROW the vertex set (adds to
ids >= V extend the tail of the frozen DBG id space), long chains
compact into one equivalent delta with the original lineage preserved
(:func:`compact_deltas`), and grouping-quality decay under churn is
measured (:func:`grouping_drift`) and repaired by a policy-triggered
re-registration (:func:`reregister`) with an atomic store swap.
"""
from .apply import (BULK_THRESHOLD, DeltaApplyResult, apply_delta,
                    rebuild_plans, splice_delta)
from .delta import (GraphDelta, apply_delta_to_graph, chain_fingerprint,
                    compact_deltas, compose_deltas, edge_keys,
                    grown_num_vertices, make_delta, random_delta)
from .regroup import RegroupPolicy, grouping_drift, reregister

__all__ = [
    "BULK_THRESHOLD", "DeltaApplyResult", "GraphDelta", "RegroupPolicy",
    "apply_delta", "apply_delta_to_graph", "chain_fingerprint",
    "compact_deltas", "compose_deltas", "edge_keys", "grouping_drift",
    "grown_num_vertices", "make_delta", "random_delta", "rebuild_plans",
    "reregister", "splice_delta",
]
