"""Streaming graphs: delta updates with dirty-range incremental rebuild.

The static layers (GraphStore → Planner → Executor) prepare a graph
once; this package is the sanctioned way a prepared graph CHANGES.
A :class:`GraphDelta` (validated add/remove/update edge lists against a
base fingerprint) applied with :func:`apply_delta` re-partitions and
re-blocks only the dirty dst-range partitions, splices them into a
derived store, chains the snapshot fingerprint from
``(base_fp, delta_fp)``, and carries over every clean blocking and
every structurally-unchanged lane's packed device payload. The serving
layer surfaces it as ``GraphService.update(fp, delta)`` with snapshot
semantics (in-flight requests finish on the old store; new submits see
the new fingerprint).
"""
from .apply import (BULK_THRESHOLD, DeltaApplyResult, apply_delta,
                    rebuild_plans, splice_delta)
from .delta import (GraphDelta, apply_delta_to_graph, chain_fingerprint,
                    edge_keys, make_delta, random_delta)

__all__ = [
    "BULK_THRESHOLD", "DeltaApplyResult", "GraphDelta", "apply_delta",
    "apply_delta_to_graph", "chain_fingerprint", "edge_keys", "make_delta",
    "random_delta", "rebuild_plans", "splice_delta",
]
