"""DBG re-registration: grouping-quality drift and the regroup policy.

The streaming layer freezes the DBG permutation across a delta chain
(recomputing it would dirty every partition), and vertex growth appends
new vertices to the TAIL of the id space regardless of their degree.
Both decisions trade grouping quality for incrementality: after enough
churn, high-degree vertices no longer concentrate in the first
partitions and the planner's dense/sparse classification drifts away
from what a fresh degree-based grouping would produce.

:func:`grouping_drift` measures that decay — the edge-weighted
dense/sparse misclassification rate of the store's partitions against a
fresh DBG pass over the SAME graph. Past :class:`RegroupPolicy`'s
threshold the serving layer re-registers: :func:`reregister` builds a
fresh-DBG store carrying the SAME chained fingerprint, and
``GraphService`` swaps it into the store cache atomically (``put`` on
the live key), exactly like the autotuner's ``adopt_plan`` swap one
layer down. Results are unaffected: executors return properties in
ORIGINAL vertex ids, so two stores over the same edge set are
interchangeable (bit-identical for min/max apps; sum apps may differ by
reduction order, the same 1-ULP caveat a cold DBG rebuild has).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ..core import partition as part
from ..core import perf_model
from ..core.store import GraphStore
from ..graphs.formats import relabel

__all__ = ["RegroupPolicy", "grouping_drift", "reregister"]


@dataclasses.dataclass(frozen=True)
class RegroupPolicy:
    """When to check grouping drift, and when drift forces a regroup.

    drift_threshold: edge-weighted misclassification rate (see
        :func:`grouping_drift`) above which re-registration triggers.
    min_churn_frac:  cumulative changed-edge fraction (changes since the
        last registration / current E) below which the drift metric is
        not even computed — a drift check costs a DBG pass plus a
        partition pass (O(E log E)), so it must not run on every small
        delta.
    cooldown_s:      minimum wall-clock seconds between drift checks on
        one store key.
    sync:            run the re-registration inline in ``update()``
        instead of on a background thread (deterministic tests; the
        default keeps the update path latency-flat).
    hw:              perf-model profile the drift check classifies
        with (``None`` = the analytic ``TPU_V5E``). Deployments should
        pass the SAME calibrated/scaled profile their plans are built
        with — dense/sparse classification, and therefore drift, is
        profile-relative (scale-model runs use ``TPU_V5E_SCALED``).
    """

    drift_threshold: float = 0.15
    min_churn_frac: float = 0.25
    cooldown_s: float = 0.0
    sync: bool = False
    hw: Optional[perf_model.HW] = None

    def __post_init__(self):
        if not (0.0 < self.drift_threshold):
            raise ValueError(f"drift_threshold must be > 0, got "
                             f"{self.drift_threshold}")
        if self.min_churn_frac < 0:
            raise ValueError(f"min_churn_frac must be >= 0, got "
                             f"{self.min_churn_frac}")

    def churn_ready(self, churn_edges: int, num_edges: int) -> bool:
        """True once cumulative churn justifies paying for a drift
        check."""
        return churn_edges >= self.min_churn_frac * max(num_edges, 1)


def grouping_drift(store: GraphStore, hw=None) -> dict:
    """Edge-weighted dense/sparse misclassification of the store's
    partitions vs a fresh DBG pass over its current graph.

    Both the frozen-perm layout and a fresh regrouping are partitioned
    into the same number of dst-range partitions (same V, same U), and
    position is meaningful under DBG — partition p is the p-th
    highest-degree block. Comparing the perf model's dense/sparse class
    at each position measures how far the dense frontier has drifted;
    weighting by the store's resident edge counts makes the metric "the
    fraction of resident edges whose partition the planner now
    classifies differently than a fresh grouping would".
    """
    hw = hw or perf_model.TPU_V5E
    geom = store.geom
    t0 = time.perf_counter()
    g = store.graph                       # current (frozen-perm) id space
    fresh_perm = part.dbg_permutation(g)
    fresh_g = relabel(g, fresh_perm, name_suffix="_redbg")
    fresh_infos, _ = part.partition_graph(fresh_g, geom)
    cur_infos = perf_model.classify(store.copy_infos(), geom, hw)
    perf_model.classify(fresh_infos, geom, hw)

    total = sum(i.num_edges for i in cur_infos)
    mismatched = [p for p, (a, b) in enumerate(zip(cur_infos, fresh_infos))
                  if a.is_dense != b.is_dense]
    drifted_edges = sum(cur_infos[p].num_edges for p in mismatched)
    return {
        "drift": (drifted_edges / total) if total else 0.0,
        "partitions": len(cur_infos),
        "mismatched_partitions": len(mismatched),
        "dense_current": sum(1 for i in cur_infos if i.is_dense),
        "dense_fresh": sum(1 for i in fresh_infos if i.is_dense),
        "t_drift_ms": (time.perf_counter() - t0) * 1e3,
    }


def reregister(store: GraphStore,
               fingerprint: Optional[str] = None) -> GraphStore:
    """Rebuild a store from scratch with a FRESH degree-based grouping,
    preserving its (chained) identity.

    The store's graph is mapped back to original vertex ids through the
    inverse of the frozen permutation, then a cold ``GraphStore`` build
    recomputes DBG, partitions and (lazily) blockings. Every plan
    config cached on the old store is re-planned eagerly so the swapped
    store serves warm. The returned store answers ``fingerprint()``
    with the OLD store's identity (or ``fingerprint=`` if given) — re-
    registration changes layout, never the snapshot a key addresses.
    """
    V = store.graph.num_vertices
    inv = np.empty(V, np.int32)
    inv[store.perm] = np.arange(V, dtype=np.int32)
    orig = relabel(store.graph, inv, name_suffix="_orig")
    fresh = GraphStore(orig, store.geom, use_dbg=store.use_dbg,
                       max_plans=store.max_plans,
                       fingerprint=fingerprint or store.fingerprint())
    with store._plan_lock:
        configs = [b.config for b in store._plan_cache.values()]
    for cfg in configs:
        fresh.plan(cfg)
    return fresh
