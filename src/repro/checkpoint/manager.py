"""Sharded, atomic, async checkpointing with elastic re-shard on restore.

Layout (one directory per step):
    ckpt_dir/step_000123.tmp/        # written first
        meta.json                    # tree structure, shapes, dtypes, step
        shard_<host>.npz             # this host's param/opt shards
    ckpt_dir/step_000123/            # atomic rename when complete

Fault-tolerance properties:
  * atomicity — a crash mid-write leaves only a .tmp dir, never a
    half-valid checkpoint; restore picks the newest complete dir;
  * async — the serialize+write runs on a background thread so the train
    loop only blocks on device->host transfer (double-buffered);
  * elastic — arrays are saved with their GLOBAL shapes; restore resharding
    is just device_put with the new mesh's shardings, so a 512-chip
    checkpoint restores onto 256 or 1024 chips unchanged;
  * self-describing — meta.json carries the pytree def, so restore works
    without constructing params first (e.g. for inspection tools).
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory, max_to_keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.max_to_keep = max_to_keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot ``tree`` (params/opt_state/anything pytree) at step."""
        self.wait()  # one in-flight save at a time
        leaves, treedef = _flatten(tree)
        # device->host copy happens here (synchronous, consistent snapshot)
        host_leaves = [np.asarray(l) for l in leaves]
        meta = {
            "step": step,
            "treedef": str(treedef),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "time": time.time(),
        }

        def write():
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                final = self.dir / f"step_{step:08d}"
                if final.exists():
                    return  # idempotent: this step is already durable
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                (tmp / "meta.json").write_text(json.dumps(meta))
                # raw-byte serialisation: npz has no codecs for bf16/f8
                np.savez(tmp / "shard_0.npz",
                         **{f"leaf_{i}": np.frombuffer(
                             l.tobytes(), dtype=np.uint8)
                            for i, l in enumerate(host_leaves)})
                tmp.rename(final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {e}") from e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.max_to_keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") \
                    and not p.name.endswith(".tmp"):
                out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, like: Any = None,
                shardings: Any = None):
        """Load a checkpoint. ``like`` provides the pytree structure;
        ``shardings`` (optional) re-shards onto a (possibly different)
        mesh — elastic restore."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = self.dir / f"step_{step:08d}"
        meta = json.loads((d / "meta.json").read_text())
        data = np.load(d / "shard_0.npz")
        import ml_dtypes  # noqa: F401 — registers bfloat16/f8 with numpy
        leaves = []
        for i in range(len(data.files)):
            raw = data[f"leaf_{i}"]
            dt = np.dtype(meta["dtypes"][i])
            leaves.append(np.frombuffer(raw.tobytes(), dtype=dt)
                          .reshape(meta["shapes"][i]))
        assert like is not None, "restore requires `like` for the treedef"
        _, treedef = _flatten(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        else:
            import jax.numpy as jnp
            tree = jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(l) for l in leaves])
        return step, tree
