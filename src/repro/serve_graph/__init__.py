"""Graph serving subsystem: multi-tenant front-end over the layered API.

Layering (each piece usable on its own):

    fingerprint  — content identity of a Graph; (fp, Geometry, use_dbg)
                   keys one GraphStore
    store_cache  — byte-budgeted LRU of GraphStores with pinning
    service      — GraphService: FIFO request queue, worker draining,
                   coalescing of identical in-flight requests
    metrics      — per-request latency breakdown + service counters

Streaming graphs plug in through ``GraphService.update(fp, delta)``
(see repro/streaming/): the cached store is spliced incrementally, the
cache re-keys to the chained snapshot fingerprint under lease-pinning,
and the delta chain is recorded for cold rebuilds.

See README.md §Serving / §Streaming and examples/serving.py,
examples/streaming.py.
"""
from .fingerprint import StoreKey, graph_fingerprint, store_key
from .metrics import RequestMetrics, ServiceMetrics
from .service import (GraphService, RequestHandle, ServiceClosed,
                      UpdateResult)
from .store_cache import GraphStoreCache

__all__ = [
    "GraphService", "GraphStoreCache", "RequestHandle", "RequestMetrics",
    "ServiceClosed", "ServiceMetrics", "StoreKey", "UpdateResult",
    "graph_fingerprint", "store_key",
]
