"""Graph serving subsystem: multi-tenant front-end over the layered API.

Layering (each piece usable on its own):

    fingerprint  — content identity of a Graph; (fp, Geometry, use_dbg)
                   keys one GraphStore
    store_cache  — byte-budgeted LRU of GraphStores with pinning
    service      — GraphService: FIFO request queue, worker draining,
                   coalescing of identical in-flight requests
    metrics      — per-request latency breakdown + service counters

See README.md §Serving and examples/serving.py.
"""
from .fingerprint import StoreKey, graph_fingerprint, store_key
from .metrics import RequestMetrics, ServiceMetrics
from .service import GraphService, RequestHandle, ServiceClosed
from .store_cache import GraphStoreCache

__all__ = [
    "GraphService", "GraphStoreCache", "RequestHandle", "RequestMetrics",
    "ServiceClosed", "ServiceMetrics", "StoreKey", "graph_fingerprint",
    "store_key",
]
