"""Graph/store identity for the serving layer.

A served GraphStore is identified by ``(graph fingerprint, geometry,
use_dbg)`` — everything :class:`~repro.core.store.GraphStore` is a pure
function of. The content hash itself lives in
:func:`repro.graphs.formats.fingerprint` (next to the COO container);
this module builds the composite cache key and normalizes the
"graph-or-fingerprint" argument the service accepts.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

from ..core.types import Geometry
from ..graphs.formats import Graph
from ..graphs.formats import fingerprint as graph_fingerprint

__all__ = ["StoreKey", "graph_fingerprint", "store_key", "resolve_fingerprint"]

# (graph fingerprint hex, geometry, use_dbg) — hashable, order-stable
StoreKey = Tuple[str, Geometry, bool]


def store_key(fp: str, geom: Geometry, use_dbg: bool) -> StoreKey:
    """Composite identity of one GraphStore in the serving cache."""
    if not isinstance(fp, str) or not fp:
        raise ValueError(f"fingerprint must be a non-empty hex string, "
                         f"got {fp!r}")
    return (fp, geom, bool(use_dbg))


def resolve_fingerprint(graph_or_fp: Union[Graph, str, None],
                        fingerprint: Optional[str] = None) -> str:
    """Normalize the service's ``(graph | fingerprint)`` submission
    argument to a fingerprint string. Exactly one identity source must
    be present."""
    if isinstance(graph_or_fp, str):
        if fingerprint is not None and fingerprint != graph_or_fp:
            raise ValueError("two different fingerprints given")
        return graph_or_fp
    if graph_or_fp is not None:
        fp = graph_or_fp.fingerprint()
        if fingerprint is not None and fingerprint != fp:
            raise ValueError(f"fingerprint= {fingerprint!r} does not match "
                             f"the graph's own fingerprint {fp!r}")
        return fp
    if fingerprint is None:
        raise ValueError("submit() needs a graph or a fingerprint")
    return fingerprint
