"""Serving metrics: per-request latency breakdown + service counters.

Every request carries a :class:`RequestMetrics` record filled in as it
moves through the service (queue wait → store build/fetch → plan →
execute); :class:`ServiceMetrics` aggregates them into hit/miss
counters and bounded latency reservoirs with percentile queries. All
mutation is lock-guarded — worker threads record concurrently.

Two export forms feed the control plane's ``GET /metrics`` endpoint
and the benchmark artifact dumps: :meth:`ServiceMetrics.snapshot_json`
(the snapshot dict as JSON) and :meth:`ServiceMetrics.render_prometheus`
(Prometheus text exposition — counters, gauges, and the stage latency
percentiles as ``quantile``-labeled gauges, with per-tenant admission
outcomes as labeled series).
"""
from __future__ import annotations

import dataclasses
import json
import threading
from collections import deque
from typing import Deque, Dict, Optional

from ..obs import DriftAccumulator, UtilizationAccumulator

__all__ = ["RequestMetrics", "ServiceMetrics", "merge_expositions"]


def _escape_label(v) -> str:
    """Escape a label VALUE per the Prometheus text exposition grammar:
    backslash, double-quote and newline must be escaped (backslash
    first, or the other escapes get double-escaped)."""
    return (str(v).replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def merge_expositions(*texts: str) -> str:
    """Merge Prometheus text expositions into one valid document.

    The control plane concatenates ``ServiceMetrics.render_prometheus``
    with its own scheduler/pool/job blocks; a metric family appearing
    in more than one input would then carry duplicate ``# HELP`` /
    ``# TYPE`` headers (invalid — parsers reject repeated metadata).
    This groups samples by family, keeps the FIRST help/type header of
    each, and preserves first-appearance family order."""
    help_: Dict[str, str] = {}
    type_: Dict[str, str] = {}
    samples: Dict[str, list] = {}
    for text in texts:
        for line in text.splitlines():
            if not line.strip():
                continue
            if line.startswith("# HELP ") or line.startswith("# TYPE "):
                parts = line.split(None, 3)
                if len(parts) < 3:
                    continue
                name = parts[2]
                target = help_ if parts[1] == "HELP" else type_
                target.setdefault(name, line)
                samples.setdefault(name, [])
            elif line.startswith("#"):
                continue
            else:
                name = line.split("{", 1)[0].split(" ", 1)[0]
                samples.setdefault(name, []).append(line)
    out = []
    for name, lines in samples.items():
        if name in help_:
            out.append(help_[name])
        if name in type_:
            out.append(type_[name])
        out.extend(lines)
    return "\n".join(out) + "\n"


@dataclasses.dataclass
class RequestMetrics:
    """Latency breakdown and cache outcomes of one serviced request.
    Times are milliseconds; ``None`` means the stage never ran (e.g. a
    failed request, or a coalesced duplicate that piggybacked on
    another request's execution). Coalesced duplicates still carry
    their own end-to-end ``t_total_ms`` and the hit flags of the
    execution that produced their result."""

    request_id: int
    app: str
    fingerprint: str
    tenant: str = "default"
    coalesced: bool = False           # attached to an in-flight twin job
    store_hit: Optional[bool] = None
    plan_hit: Optional[bool] = None
    t_queue_ms: Optional[float] = None    # submit -> worker pickup
    t_store_ms: Optional[float] = None    # GraphStore fetch-or-build
    t_plan_ms: Optional[float] = None     # Planner (cache hit ~ 0)
    t_execute_ms: Optional[float] = None  # Executor materialize + run
    t_total_ms: Optional[float] = None    # submit -> result available
    error: Optional[str] = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Reservoir:
    """Bounded sample reservoir (keeps the most recent ``maxlen``)."""

    def __init__(self, maxlen: int = 2048):
        self._samples: Deque[float] = deque(maxlen=maxlen)

    def add(self, x: float) -> None:
        self._samples.append(float(x))

    def __len__(self) -> int:
        return len(self._samples)

    def percentile(self, p: float) -> Optional[float]:
        """Nearest-rank percentile of the retained samples (p in
        [0, 100]); None when empty."""
        if not self._samples:
            return None
        xs = sorted(self._samples)
        rank = max(0, min(len(xs) - 1, int(round(p / 100.0 * (len(xs) - 1)))))
        return xs[rank]

    def mean(self) -> Optional[float]:
        if not self._samples:
            return None
        return sum(self._samples) / len(self._samples)


class ServiceMetrics:
    """Aggregate counters + latency distributions for a GraphService."""

    STAGES = ("queue", "store", "plan", "execute", "total", "update")

    def __init__(self, reservoir_size: int = 2048):
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.executions = 0          # jobs actually run (post-coalescing)
        self.coalesced = 0           # requests that rode an in-flight job
        self.store_hits = 0
        self.store_misses = 0
        self.plan_hits = 0
        self.plan_misses = 0
        self.store_evictions = 0
        self.executor_evictions = 0
        # streaming delta updates (GraphService.update)
        self.updates = 0
        self.update_failures = 0
        self.updates_deferred = 0     # applied lazily (store not cached)
        self.stores_retired = 0       # old snapshots re-keyed out
        self.plans_rebuilt = 0
        self.packed_lanes_reused = 0
        self.packed_lanes_repacked = 0
        self.packed_bytes_reused = 0
        # streaming lifecycle: drift-triggered DBG re-registrations,
        # delta-chain compactions, placement-drift re-placements
        self.regroups = 0
        self.compactions = 0
        self.placements_rebalanced = 0
        self._chain_depth_fn = None   # wired by the service
        # control-plane admission outcomes
        self.rejected_queue_full = 0
        self.rejected_quota = 0
        self.shed_deadline = 0        # expired-deadline jobs load-shed
        # tenant -> outcome counters (submitted/completed/failed/
        # coalesced/rejected/shed); bounds itself to tenants seen
        self._tenants: Dict[str, Dict[str, int]] = {}
        self._stage: Dict[str, _Reservoir] = {
            s: _Reservoir(reservoir_size) for s in self.STAGES}
        self._queue_depth_fn = None  # wired by the service
        # drift-triggered recalibrations (repro.autotune); the gauge
        # details (version, age) come from the pull hook below
        self.retunes = 0
        self._calibration_info_fn = None  # wired when autotune= is on
        # service-level perf-model drift sink: executors chain their
        # per-run accumulators to this one (see repro.obs.drift)
        self.drift = DriftAccumulator()
        # service-level pipeline-utilization sink (repro.obs.profile):
        # executors chain their per-lane achieved-GB/s samples here the
        # same way; feeds the regraph_lane_bandwidth_gbps /
        # regraph_pipeline_utilization gauges and the dashboard bars
        self.utilization = UtilizationAccumulator()

    def _tenant(self, tenant: str) -> Dict[str, int]:
        t = self._tenants.get(tenant)
        if t is None:
            t = self._tenants[tenant] = {
                "submitted": 0, "completed": 0, "failed": 0,
                "coalesced": 0, "rejected": 0, "shed": 0}
        return t

    # -- recording ------------------------------------------------------
    def record_submit(self, coalesced: bool,
                      tenant: str = "default") -> None:
        with self._lock:
            self.submitted += 1
            t = self._tenant(tenant)
            t["submitted"] += 1
            if coalesced:
                self.coalesced += 1
                t["coalesced"] += 1

    def record_rejected(self, kind: str, tenant: str = "default") -> None:
        """Typed admission rejection: ``kind`` is ``"queue_full"`` or
        ``"quota"`` (matching the scheduler's exception types)."""
        with self._lock:
            if kind == "queue_full":
                self.rejected_queue_full += 1
            elif kind == "quota":
                self.rejected_quota += 1
            else:
                raise ValueError(f"unknown rejection kind {kind!r}")
            self._tenant(tenant)["rejected"] += 1

    def record_shed(self, tenant: str = "default") -> None:
        """A queued job's deadline expired before a worker reached it."""
        with self._lock:
            self.shed_deadline += 1
            self._tenant(tenant)["shed"] += 1

    def record_execution(self, store_hit: bool, plan_hit: bool) -> None:
        with self._lock:
            self.executions += 1
            if store_hit:
                self.store_hits += 1
            else:
                self.store_misses += 1
            if plan_hit:
                self.plan_hits += 1
            else:
                self.plan_misses += 1

    def record_eviction(self, n: int = 1) -> None:
        with self._lock:
            self.store_evictions += n

    def record_executor_eviction(self, n: int = 1) -> None:
        """Warm-path executor LRU evictions (count or byte budget)."""
        with self._lock:
            self.executor_evictions += n

    def record_retune(self, n: int = 1) -> None:
        """An applied drift-triggered recalibration + plan swap."""
        with self._lock:
            self.retunes += n

    def _calibration_info(self):
        fn = self._calibration_info_fn
        if fn is None:
            return None
        try:
            return fn()
        except Exception:
            return None

    def record_update(self, t_ms: float, stats: Optional[dict] = None,
                      deferred: bool = False, retired: bool = False) -> None:
        """One GraphService.update: latency plus the apply's
        reuse/invalidation accounting (None when deferred)."""
        with self._lock:
            self.updates += 1
            if deferred:
                self.updates_deferred += 1
            if retired:
                self.stores_retired += 1
            if stats is not None:
                self.plans_rebuilt += stats.get("plans_rebuilt", 0)
                self.packed_lanes_reused += stats.get(
                    "packed_lanes_reused", 0)
                self.packed_lanes_repacked += stats.get(
                    "packed_lanes_repacked", 0)
                self.packed_bytes_reused += stats.get(
                    "packed_bytes_reused", 0)
                self.placements_rebalanced += stats.get(
                    "placements_rebalanced", 0)
            self._stage["update"].add(t_ms)

    def record_update_failure(self) -> None:
        with self._lock:
            self.update_failures += 1

    def record_regroup(self, n: int = 1) -> None:
        """An applied drift-triggered DBG re-registration + store swap."""
        with self._lock:
            self.regroups += n

    def record_compaction(self, n: int = 1) -> None:
        """A delta chain squashed into one composed delta."""
        with self._lock:
            self.compactions += n

    @property
    def max_chain_depth(self) -> int:
        """Deepest registered delta chain (0 without the service hook)."""
        fn = self._chain_depth_fn
        if fn is None:
            return 0
        try:
            return int(fn())
        except Exception:
            return 0

    def record_done(self, m: RequestMetrics) -> None:
        with self._lock:
            t = self._tenant(m.tenant)
            if m.error is None:
                self.completed += 1
                t["completed"] += 1
            else:
                self.failed += 1
                t["failed"] += 1
            if m.coalesced:
                # INVARIANT: a coalesced duplicate never contributes to
                # the per-stage reservoirs — it did not queue, build, or
                # run anything; only its own end-to-end latency counts.
                # The service keeps stage times None on coalesced
                # records, but this guard is the layer that enforces it
                # even if a caller fills them in.
                if m.t_total_ms is not None:
                    self._stage["total"].add(m.t_total_ms)
                return
            for stage, val in (("queue", m.t_queue_ms),
                               ("store", m.t_store_ms),
                               ("plan", m.t_plan_ms),
                               ("execute", m.t_execute_ms),
                               ("total", m.t_total_ms)):
                if val is not None:
                    self._stage[stage].add(val)

    # -- queries --------------------------------------------------------
    def latency_ms(self, stage: str = "total", p: float = 50.0):
        with self._lock:    # workers append concurrently via record_done
            return self._stage[stage].percentile(p)

    @property
    def store_hit_rate(self) -> float:
        n = self.store_hits + self.store_misses
        return self.store_hits / n if n else 0.0

    @property
    def plan_hit_rate(self) -> float:
        n = self.plan_hits + self.plan_misses
        return self.plan_hits / n if n else 0.0

    @property
    def queue_depth(self) -> int:
        fn = self._queue_depth_fn
        return int(fn()) if fn is not None else 0

    def snapshot(self) -> dict:
        with self._lock:
            snap = {
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "executions": self.executions,
                "coalesced": self.coalesced,
                "store_hits": self.store_hits,
                "store_misses": self.store_misses,
                "plan_hits": self.plan_hits,
                "plan_misses": self.plan_misses,
                "store_evictions": self.store_evictions,
                "executor_evictions": self.executor_evictions,
                "updates": self.updates,
                "update_failures": self.update_failures,
                "updates_deferred": self.updates_deferred,
                "stores_retired": self.stores_retired,
                "plans_rebuilt": self.plans_rebuilt,
                "packed_lanes_reused": self.packed_lanes_reused,
                "packed_lanes_repacked": self.packed_lanes_repacked,
                "packed_bytes_reused": self.packed_bytes_reused,
                "rejected_queue_full": self.rejected_queue_full,
                "rejected_quota": self.rejected_quota,
                "shed_deadline": self.shed_deadline,
                "retunes": self.retunes,
                "regroups": self.regroups,
                "compactions": self.compactions,
                "placements_rebalanced": self.placements_rebalanced,
                "tenants": {t: dict(c) for t, c in self._tenants.items()},
                "queue_depth": self.queue_depth,
            }
            for s in self.STAGES:
                snap[f"p50_{s}_ms"] = self._stage[s].percentile(50)
                snap[f"p99_{s}_ms"] = self._stage[s].percentile(99)
        snap["store_hit_rate"] = self.store_hit_rate
        snap["plan_hit_rate"] = self.plan_hit_rate
        # OUTSIDE the metrics lock: the hook re-enters the service lock,
        # which other paths take BEFORE this one (record_rejected under
        # submit) — pulling it under our lock would invert the order
        snap["max_chain_depth"] = self.max_chain_depth
        snap["drift"] = self.drift.report()   # its own lock
        snap["utilization"] = self.utilization.report()   # its own lock
        snap["calibration"] = self._calibration_info()
        return snap

    def snapshot_json(self, **extra) -> str:
        """The snapshot (plus any ``extra`` top-level keys — services
        merge cache/scheduler/pool stats in) as a JSON document."""
        snap = self.snapshot()
        snap.update(extra)
        return json.dumps(snap, indent=2, sort_keys=True, default=str)

    def render_prometheus(self, prefix: str = "regraph") -> str:
        """Prometheus text exposition of the snapshot: monotonic counts
        as ``counter``, point-in-time values as ``gauge``, stage latency
        percentiles as ``quantile``-labeled gauges, and the per-tenant
        breakdown as ``tenant``/``outcome``-labeled series."""
        snap = self.snapshot()
        out = []

        def metric(name, mtype, help_, samples):
            out.append(f"# HELP {prefix}_{name} {help_}")
            out.append(f"# TYPE {prefix}_{name} {mtype}")
            for labels, val in samples:
                if val is None:
                    val = "NaN"
                lab = ("{" + ",".join(
                    f'{k}="{_escape_label(v)}"' for k, v in labels)
                    + "}") if labels else ""
                out.append(f"{prefix}_{name}{lab} {val}")

        metric("requests_total", "counter", "Requests by final outcome.",
               [((("outcome", o),), snap[o])
                for o in ("submitted", "completed", "failed", "coalesced")])
        metric("rejected_total", "counter",
               "Admission rejections by typed reason.",
               [((("reason", "queue_full"),), snap["rejected_queue_full"]),
                ((("reason", "quota"),), snap["rejected_quota"])])
        metric("shed_total", "counter",
               "Jobs load-shed after their deadline expired in queue.",
               [((), snap["shed_deadline"])])
        metric("cache_events_total", "counter",
               "Store/plan cache outcomes and evictions.",
               [((("layer", "store"), ("event", "hit")), snap["store_hits"]),
                ((("layer", "store"), ("event", "miss")),
                 snap["store_misses"]),
                ((("layer", "store"), ("event", "eviction")),
                 snap["store_evictions"]),
                ((("layer", "plan"), ("event", "hit")), snap["plan_hits"]),
                ((("layer", "plan"), ("event", "miss")),
                 snap["plan_misses"]),
                ((("layer", "executor"), ("event", "eviction")),
                 snap["executor_evictions"])])
        metric("updates_total", "counter",
               "Streaming delta updates by outcome.",
               [((("outcome", "applied"),), snap["updates"]),
                ((("outcome", "failed"),), snap["update_failures"]),
                ((("outcome", "deferred"),), snap["updates_deferred"])])
        metric("queue_depth", "gauge", "Jobs currently queued.",
               [((), snap["queue_depth"])])
        metric("latency_ms", "gauge",
               "Stage latency percentiles over the sample reservoir.",
               [((("stage", s), ("quantile", q)), snap[f"p{p}_{s}_ms"])
                for s in self.STAGES
                for p, q in ((50, "0.5"), (99, "0.99"))])
        metric("tenant_requests_total", "counter",
               "Per-tenant request outcomes.",
               [((("tenant", t), ("outcome", o)), c)
                for t, cs in sorted(snap["tenants"].items())
                for o, c in cs.items()])
        drift = snap["drift"]
        metric("perf_model_drift", "gauge",
               "Measured/estimated time ratio per pipeline kind "
               "(1.0 = the perf model is exact).",
               [((("kind", k),), rep["ratio"])
                for k, rep in sorted(drift.items())])
        metric("perf_model_drift_samples", "counter",
               "Measured-vs-estimated samples folded into the drift "
               "report, per pipeline kind.",
               [((("kind", k),), rep["n"])
                for k, rep in sorted(drift.items())])
        util_kinds = (snap.get("utilization") or {}).get("kinds") or {}
        metric("lane_bandwidth_gbps", "gauge",
               "Achieved bandwidth per pipeline kind: analytic lane "
               "footprint bytes over measured lane seconds "
               "(repro.obs.profile).",
               [((("kind", k),), rep.get("gbps"))
                for k, rep in sorted(util_kinds.items())])
        metric("pipeline_utilization", "gauge",
               "Achieved bandwidth as a fraction of the calibrated "
               "device peak (HW.peak_bandwidth_gbps), per pipeline "
               "kind.",
               [((("kind", k),), rep.get("utilization"))
                for k, rep in sorted(util_kinds.items())])
        metric("retunes_total", "counter",
               "Applied drift-triggered recalibrations (perf-model "
               "refit + plan re-derivation + atomic swap).",
               [((), snap["retunes"])])
        metric("regroups_total", "counter",
               "Applied grouping-drift re-registrations (fresh DBG "
               "rebuild + atomic store swap).",
               [((), snap["regroups"])])
        metric("compactions_total", "counter",
               "Delta chains squashed into one composed delta.",
               [((), snap["compactions"])])
        metric("placements_rebalanced_total", "counter",
               "Sharded lane placements re-placed from scratch after "
               "keep-pinned drift exceeded the rebalance threshold.",
               [((), snap["placements_rebalanced"])])
        metric("chain_depth", "gauge",
               "Deepest delta chain behind any registered snapshot "
               "(replay length of a cold rebuild).",
               [((), snap["max_chain_depth"])])
        calib = snap.get("calibration")
        if calib is not None:
            metric("calibration_version", "gauge",
                   "Device-spec version of the active calibrated HW "
                   "constants (0 = analytic defaults).",
                   [((), calib.get("version", 0))])
            metric("calibration_age_seconds", "gauge",
                   "Seconds since the active calibration was fitted "
                   "(NaN until the first fit).",
                   [((), calib.get("age_s"))])
        return "\n".join(out) + "\n"
