"""Byte-budgeted LRU of GraphStores for multi-graph serving.

A production service holds many prepared graphs at once; each
GraphStore pins the partition-sorted edge arrays, memoized blockings,
and (via its plan LRU) device-resident lane entries. This cache bounds
that by bytes (``GraphStore.memory_footprint()``) and/or entry count,
evicting least-recently-used stores first.

Two safety properties the serving layer relies on:

* **Pinning** — a worker leases a store for the duration of a request
  (``with cache.lease(key): ...``). Pinned entries are never evicted,
  so an in-flight Executor's store can't be torn down under it; the
  budget is exceeded temporarily rather than breaking the request.
* **Eviction releases device memory** — evicting calls
  ``store.clear_plans()``, dropping the cached PlanBundles and the
  device lane entries they pin. Executors still running on an evicted
  store keep their own bundle references and finish normally.
"""
from __future__ import annotations

import contextlib
import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

from ..core.store import GraphStore
from .fingerprint import StoreKey

__all__ = ["GraphStoreCache"]


class _Entry:
    __slots__ = ("store", "nbytes", "pins", "ready", "retired")

    def __init__(self, store: Optional[GraphStore], nbytes: int):
        self.store = store
        self.nbytes = nbytes
        self.pins = 0
        # unset while a lease() builder is constructing the store OUTSIDE
        # the cache lock; waiters block on it instead of on the lock
        self.ready = threading.Event()
        # retire(): evict as soon as the last lease releases (streaming
        # re-key — the old snapshot drains, it is never torn down)
        self.retired = False
        if store is not None:
            self.ready.set()


class GraphStoreCache:
    """LRU of GraphStores keyed by (fingerprint, Geometry, use_dbg).

    Parameters
    ----------
    byte_budget: soft cap on the summed ``memory_footprint()`` of cached
        stores; None = unbounded. Exceeding the cap evicts unpinned LRU
        entries until back under (or until only pinned entries remain —
        the budget is a target, never a reason to break a request).
    max_stores: cap on the number of cached stores; None = unbounded.
    on_evict: optional callback ``(key, store) -> None`` (metrics).
    """

    def __init__(self, byte_budget: Optional[int] = None,
                 max_stores: Optional[int] = None,
                 on_evict: Optional[Callable] = None):
        if byte_budget is not None and byte_budget <= 0:
            raise ValueError(f"byte_budget must be positive, got "
                             f"{byte_budget}")
        if max_stores is not None and max_stores < 1:
            raise ValueError(f"max_stores must be >= 1, got {max_stores}")
        self.byte_budget = byte_budget
        self.max_stores = max_stores
        self.on_evict = on_evict
        self._lock = threading.RLock()
        self._entries: "OrderedDict[StoreKey, _Entry]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.freed_plan_bytes = 0   # device bytes released by evictions

    # -- core ops -------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: StoreKey) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self):
        with self._lock:
            return list(self._entries)

    @property
    def current_bytes(self) -> int:
        with self._lock:
            return sum(e.nbytes for e in self._entries.values())

    def get(self, key: StoreKey) -> Optional[GraphStore]:
        """Fetch + touch (counts as hit/miss). An entry still being
        built by a concurrent lease() is waited for; if that build
        fails, this falls through to a miss (never a None "hit")."""
        while True:
            with self._lock:
                e = self._entries.get(key)
                if e is None:
                    self.misses += 1
                    return None
                if e.ready.is_set():
                    self.hits += 1
                    self._entries.move_to_end(key)
                    return e.store
                waiter = e
            waiter.ready.wait()     # then re-examine: ready or removed

    def peek(self, key: StoreKey) -> Optional[GraphStore]:
        """Non-counting, non-touching read: the store if it is cached
        and ready, else None. The scheduler's cost estimator uses this —
        an estimate must not distort hit rates or LRU order, and must
        never block on an in-flight build."""
        with self._lock:
            e = self._entries.get(key)
            if e is None or not e.ready.is_set():
                return None
            return e.store

    def get_or_build(self, key: StoreKey,
                     builder: Callable[[], GraphStore]
                     ) -> Tuple[GraphStore, bool]:
        """Return ``(store, was_hit)``; on miss, run ``builder`` and
        insert. Concurrent misses on one key build exactly once (the
        first caller builds, the rest wait on its latch), and the build
        itself runs outside the cache lock."""
        with self.lease(key, builder) as (store, hit):
            return store, hit

    def put(self, key: StoreKey, store: GraphStore) -> None:
        with self._lock:
            if key in self._entries:
                self._entries[key].store = store
                self.refresh_bytes(key)
                self._entries.move_to_end(key)
            else:
                self._insert(key, store)

    def refresh_bytes(self, key: StoreKey) -> None:
        """Re-measure one store's footprint (it grows as plans/blockings
        are cached on it) and re-enforce the budget. Measurement happens
        off-lock (it takes the store's plan lock — see lease())."""
        with self._lock:
            e = self._entries.get(key)
            if e is None or not e.ready.is_set():
                return
            store = e.store
        nbytes = store.memory_footprint()["total_bytes"]
        with self._lock:
            if self._entries.get(key) is e:
                e.nbytes = nbytes
                self._evict_as_needed()

    # -- pinning --------------------------------------------------------
    @contextlib.contextmanager
    def lease(self, key: StoreKey,
              builder: Optional[Callable[[], GraphStore]] = None):
        """Pin a store for the duration of a with-block; yields
        ``(store, was_hit)``. Pinned stores are never evicted, so the
        leased store outlives any concurrent budget pressure.

        On a miss the builder runs OUTSIDE the cache lock (store builds
        take seconds — serializing every worker behind one build would
        defeat ``workers > 1``): the missing key gets a pinned
        placeholder whose latch concurrent leases of the same key wait
        on, while leases of other keys proceed untouched.
        """
        e, hit, must_build = self._acquire(key, builder)
        if must_build:
            try:
                store = builder()
            except BaseException:
                with self._lock:
                    e.pins -= 1
                    if self._entries.get(key) is e:
                        del self._entries[key]
                e.ready.set()       # waiters retry and become builders
                raise
            # measure OUTSIDE the cache lock: memory_footprint() takes
            # the store's plan lock, which another worker may hold for
            # seconds while planning — blocking the whole cache on it
            # would stall every key
            nbytes = store.memory_footprint()["total_bytes"]
            with self._lock:
                e.store = store
                e.nbytes = nbytes
                e.ready.set()
                self._evict_as_needed()
        try:
            yield e.store, hit
        finally:
            # re-measure (plans/blockings accrued during the lease)
            # off-lock, then re-enforce the budget now it's evictable
            nbytes = e.store.memory_footprint()["total_bytes"]
            with self._lock:
                e.pins -= 1
                e.nbytes = nbytes
                if (e.retired and e.pins == 0
                        and self._entries.get(key) is e):
                    self._evict_one(key)   # deferred retire: drained now
                self._evict_as_needed()

    def _acquire(self, key: StoreKey, builder) -> Tuple[_Entry, bool, bool]:
        """Pin an entry for lease(); returns (entry, was_hit,
        caller_must_build). Blocks (outside the lock) while another
        thread is building the same key."""
        while True:
            with self._lock:
                e = self._entries.get(key)
                if e is None:
                    if builder is None:
                        raise KeyError(f"store {key!r} not cached and "
                                       f"no builder given")
                    self.misses += 1
                    e = _Entry(None, 0)        # building placeholder
                    self._entries[key] = e
                    e.pins += 1     # pinned before any budget check, so
                    return e, False, True      # it can't be the victim
                if e.ready.is_set():
                    self.hits += 1
                    self._entries.move_to_end(key)
                    e.pins += 1
                    return e, True, False
                waiter = e
            # build in flight: wait on its latch, then re-examine — the
            # entry is either ready (hit) or gone (failed build; we
            # become the next builder)
            waiter.ready.wait()

    def pin_count(self, key: StoreKey) -> int:
        with self._lock:
            e = self._entries.get(key)
            return e.pins if e is not None else 0

    # -- eviction -------------------------------------------------------
    def evict(self, key: StoreKey, force: bool = False) -> bool:
        """Explicitly drop one entry. Pinned entries are only dropped
        with ``force=True`` (the leasing worker keeps its reference, so
        even a forced drop never invalidates in-flight work)."""
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                return False
            if e.pins > 0 and not force:
                return False
            self._evict_one(key)
            return True

    def retire(self, key: StoreKey) -> str:
        """Streaming re-key: evict ``key`` as soon as it is unpinned.
        Unlike :meth:`evict`, a pinned (or still-building) entry is not
        skipped but *marked* — the last lease release evicts it, so
        in-flight requests finish against the old snapshot and the
        entry disappears the moment it drains. A re-lease racing the
        drain simply extends it: the old fingerprint remains a valid
        identity for the old graph until the entry actually goes.

        Returns ``"now"`` (evicted immediately), ``"deferred"``
        (pinned/building; will evict on drain) or ``"absent"``."""
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                return "absent"
            if e.pins == 0 and e.ready.is_set():
                self._evict_one(key)
                return "now"
            e.retired = True
            return "deferred"

    def clear(self) -> int:
        with self._lock:
            n = 0
            for key in list(self._entries):
                if self._entries[key].pins == 0:
                    self._evict_one(key)
                    n += 1
            return n

    def _insert(self, key: StoreKey, store: GraphStore) -> None:
        self._entries[key] = _Entry(
            store, store.memory_footprint()["total_bytes"])
        self._evict_as_needed()

    def _evict_one(self, key: StoreKey) -> None:
        e = self._entries.pop(key)
        if e.store is not None:    # release device-resident lane entries
            self.freed_plan_bytes += e.store.clear_plans()["freed_bytes"]
        self.evictions += 1
        if self.on_evict is not None:
            self.on_evict(key, e.store)

    def _evict_as_needed(self) -> None:
        """LRU-evict until under both budgets. Callers hold the lock.
        Pinned entries and the MRU entry are never victims — a single
        store bigger than the whole budget is admitted (soft cap) rather
        than thrashing the cache empty; the budget is re-enforced on the
        next insert/release."""

        def over() -> bool:
            if (self.max_stores is not None
                    and len(self._entries) > self.max_stores):
                return True
            if self.byte_budget is not None:
                total = sum(e.nbytes for e in self._entries.values())
                return total > self.byte_budget
            return False

        while over():
            mru = next(reversed(self._entries))
            victim = next((k for k, e in self._entries.items()
                           if e.pins == 0 and k != mru), None)
            if victim is None:     # all pinned (or only MRU left)
                break
            self._evict_one(victim)

    # -- reporting ------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "stores": len(self._entries),
                "current_bytes": sum(e.nbytes
                                     for e in self._entries.values()),
                "byte_budget": self.byte_budget,
                "max_stores": self.max_stores,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "freed_plan_bytes": self.freed_plan_bytes,
                "hit_rate": (self.hits / (self.hits + self.misses)
                             if (self.hits + self.misses) else 0.0),
                "pinned": sum(1 for e in self._entries.values()
                              if e.pins > 0),
            }
