"""GraphService — multi-tenant serving front-end over the layered API.

The service turns the library's GraphStore → Planner → Executor stack
into a long-lived system: requests (graph-or-fingerprint, app, config)
go into a scheduled queue, worker threads drain it, and two cache
layers do the heavy lifting — a byte-budgeted LRU of GraphStores
across graphs (:class:`~.store_cache.GraphStoreCache`) and each
store's bounded plan LRU within a graph. Identical in-flight requests
are coalesced: N concurrent PageRank submissions on the same graph
execute once and fan the result out to every caller's handle.

Dispatch is model-guided, not FIFO: each job is pushed into a
:class:`~repro.control.scheduler.JobScheduler` with a priority, an
optional deadline, and a cost estimate (a measured per-(store, app)
EWMA when the service has run the job shape before, else the perf
model's ``PlanBundle.plan.est_makespan`` rescaled by an adaptive
calibration factor), so urgent work preempt-orders the queue and
cheap jobs don't starve behind giant builds of equal rank. Admission
is typed — a full queue raises
:class:`~repro.control.scheduler.QueueFull`, an over-quota tenant
:class:`~repro.control.scheduler.QuotaExceeded` — and queued jobs
whose deadline passes are load-shed with
:class:`~repro.control.scheduler.DeadlineExpired` on their handles.

With ``pool=`` set, CPU-heavy store builds and delta splices run in a
:class:`~repro.control.pool.WorkerPool` of separate *processes*, so
their seconds of hot numpy stop stealing GIL timeslices from
``update()`` and the jit'd execution path; plan rebuilds and execution
stay on in-process threads.

Quickstart::

    from repro.serve_graph import GraphService

    with GraphService(byte_budget=512 << 20, workers=2) as svc:
        h1 = svc.submit(graph, "pagerank", n_lanes=8)
        h2 = svc.submit(graph, "bfs", app_kwargs={"root": 0})
        props, meta = h1.result(timeout=60)

Submission by fingerprint (no graph payload on the hot path)::

    fp = svc.register(graph)          # prepare + remember the graph
    h = svc.submit(fingerprint=fp, app="pagerank")
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
import traceback
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from .. import obs
from ..control.pool import WorkerCrashed, WorkerPool
from ..control.scheduler import (DeadlineExpired, JobScheduler, QueueFull,
                                 QuotaExceeded, RejectedJob, TenantQuota)
from ..core.executor import Executor
from ..core.gas import BUILTIN_APPS, GASApp
from ..core.planner import PlanConfig
from ..core.store import GraphStore
from ..core.types import Geometry
from ..graphs.formats import Graph
from ..streaming import (GraphDelta, RegroupPolicy, apply_delta,
                         apply_delta_to_graph, chain_fingerprint,
                         compact_deltas, grouping_drift, rebuild_plans,
                         reregister)
from .fingerprint import StoreKey, resolve_fingerprint, store_key
from .metrics import RequestMetrics, ServiceMetrics
from .store_cache import GraphStoreCache

__all__ = ["GraphService", "RequestHandle", "ServiceClosed", "UpdateResult"]

_SENTINEL = object()


class _LazyGraph:
    """Registry entry for a delta-chained snapshot: the post-delta graph
    is materialized (base graph + delta replay) only if a rebuild is
    actually needed — a store eviction followed by a fingerprint-only
    resubmit — so the update hot path never pays the full-graph apply.
    Once materialized, the chain link collapses to the graph and drops
    its base/delta references."""

    _MAT_LOCK = threading.Lock()   # materialization is rare; one lock
                                   # keeps multi-node chain walks simple

    __slots__ = ("_base", "_delta", "_graph")

    def __init__(self, base, delta: GraphDelta):
        self._base = base          # Graph | _LazyGraph
        self._delta = delta
        self._graph: Optional[Graph] = None

    def materialize(self) -> Graph:
        with self._MAT_LOCK:
            if self._graph is None:
                stack = [self]
                base = self._base
                while isinstance(base, _LazyGraph) and base._graph is None:
                    stack.append(base)
                    base = base._base
                g = base._graph if isinstance(base, _LazyGraph) else base
                for node in reversed(stack):
                    # chained fps are identity, not content: skip fp check
                    g = apply_delta_to_graph(g, node._delta, check_fp=False)
                    node._graph = g
                    node._base = node._delta = None
            return self._graph


@dataclasses.dataclass
class UpdateResult:
    """Outcome of :meth:`GraphService.update`.

    fingerprint: the NEW chained snapshot fingerprint — submit against
        this from now on.
    mode: ``"incremental"`` (cached store spliced in place) or
        ``"deferred"`` (store wasn't cached; the delta was validated
        and applied at graph level, and the STORE builds on the next
        cold submit).
    retired: what happened to the old snapshot's cache entry
        (``"now"`` / ``"deferred"`` until in-flight leases drain /
        ``"absent"``).
    stats: the :class:`~repro.streaming.DeltaApplyResult` accounting
        (None when deferred).
    """

    fingerprint: str
    base_fingerprint: str
    mode: str
    retired: str
    stats: Optional[dict]
    t_update_ms: float
    trace_id: Optional[str] = None   # set when the service has a tracer


class ServiceClosed(RuntimeError):
    """Raised by submit() after close()."""


class RequestHandle:
    """Future-like handle for one submitted request.

    ``result(timeout)`` blocks for (props, meta); ``exception()``
    returns the failure instead of raising. Coalesced duplicates share
    one execution, so their handles resolve to the *same* result
    objects — treat returned arrays as read-only.
    """

    def __init__(self, request_id: int, metrics: RequestMetrics):
        self.request_id = request_id
        self.metrics = metrics
        self._t_submit = time.perf_counter()   # this handle's own clock
        self._event = threading.Event()
        self._result: Optional[tuple] = None
        self._exception: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not done within {timeout}s")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not done within {timeout}s")
        return self._exception

    # service-side
    def _set_result(self, value: tuple) -> None:
        self._result = value
        self._event.set()

    def _set_exception(self, exc: BaseException) -> None:
        self._exception = exc
        self._event.set()


class _Job:
    """One unit of execution: a coalescing group of identical requests."""

    __slots__ = ("key", "skey", "graph", "app_name", "make_app", "config",
                 "use_dbg", "geom", "max_iters", "path", "shard", "handles",
                 "t_submit", "tenant", "priority", "model_est", "observers",
                 "trace_ctx", "root_span", "queue_span")

    def __init__(self, key, skey: StoreKey, graph: Optional[Graph],
                 app_name: str, make_app, config: PlanConfig,
                 geom: Geometry, use_dbg: bool,
                 max_iters: Optional[int], path: Optional[str],
                 shard=None, tenant: str = "default", priority: int = 0):
        self.key = key
        self.skey = skey
        self.graph = graph
        self.app_name = app_name
        self.make_app = make_app
        self.config = config
        self.geom = geom
        self.use_dbg = use_dbg
        self.max_iters = max_iters
        self.path = path
        self.shard = shard
        self.tenant = tenant          # the FIRST submitter's tenant; the
        self.priority = priority      # scheduler charges only that quota
        self.model_est = None         # est_makespan behind the cost, if any
        # guarded by the service lock: attachment of coalesced twins and
        # the finishing snapshot must be mutually atomic
        self.handles: List[RequestHandle] = []
        self.observers: List = []     # control-plane lifecycle callbacks
        self.t_submit = time.perf_counter()
        # tracing carrier across the queue hand-off: the submitting
        # thread starts these, the draining worker ends/activates them
        self.trace_ctx: Optional[obs.SpanContext] = None
        self.root_span: Optional[obs.Span] = None
        self.queue_span: Optional[obs.Span] = None


class GraphService:
    """Multi-tenant graph-processing service (request queue + caches).

    Parameters
    ----------
    byte_budget / max_stores: forwarded to the internal
        :class:`GraphStoreCache` (ignored when ``cache=`` is given).
    workers: number of draining threads. 1 gives strict FIFO execution;
        more overlap store builds of different graphs.
    default_geom / default_use_dbg / default_path / default_shard:
        per-request defaults; each submit() may override (``shard``
        selects multi-device execution with per-device lane ownership
        — see ``repro.sharding``; ``submit(shard=False)`` opts a single
        request out of a service-wide default).
    max_plans_per_store: bound of each store's plan LRU.
    max_executors: bound of the warm-path Executor LRU. Store and plan
        caches make re-PLANNING cheap, but a fresh Executor re-traces
        the jit'd iteration on every request; caching executors keyed
        like coalescing keys (store, app, config, path, shard) lets
        warm repeats reuse the compiled function (each shard variant of
        an otherwise-identical request is its own entry). Executors of
        an evicted
        store are purged with it (they would otherwise keep its device
        arrays alive behind the byte budget's back).
    executor_byte_budget: optional device-byte bound on the same LRU,
        using each Executor's ``memory_footprint()`` (the bundle's
        materialized/packed payload bytes). Executors sharing a plan
        share payloads, so the sum over-attributes shared bytes — it is
        a conservative budget, not an exact accounting. The
        most-recently-inserted executor always stays (a single oversized
        plan must still be servable). NOTE: evicting an executor frees
        its jitted programs immediately, but its payloads stay pinned by
        the store's plan cache until that plan is evicted there — pair
        this budget with ``max_plans_per_store`` (and the store cache's
        ``byte_budget``, which counts those payload bytes) to bound
        actual device memory.
    max_queue_depth: bound on queued jobs; submits past it raise
        :class:`~repro.control.scheduler.QueueFull` (typed, so callers
        can shed or retry). None = unbounded.
    default_quota / quotas: per-tenant token-bucket admission
        (:class:`~repro.control.scheduler.TenantQuota`; ``quotas`` maps
        tenant name to an override). An over-quota submit raises
        :class:`~repro.control.scheduler.QuotaExceeded`. Coalesced
        duplicates attach to the in-flight job without charging quota
        or queue depth.
    pool: CPU offload tier — a
        :class:`~repro.control.pool.WorkerPool`, or an int to have the
        service own one with that many worker processes (closed with
        the service, warmed at construction). When set, store builds
        and delta splices run in worker processes instead of holding
        the GIL under a worker thread.
    max_chain_depth: bound on the delta-chain length behind any
        registered snapshot. An :meth:`update` that pushes a chain past
        it auto-compacts (see :meth:`compact_chain`): the chain's
        deltas are composed into ONE equivalent delta, so a cold
        rebuild after eviction replays O(1) deltas instead of O(chain).
        None = never auto-compact (explicit :meth:`compact_chain`
        still works).
    regroup: grouping-drift repair policy — a
        :class:`~repro.streaming.RegroupPolicy`, True (defaults), or a
        kwargs dict. When set, :meth:`update` tracks cumulative churn
        per served snapshot; once churn passes the policy's floor the
        drift metric runs (:func:`~repro.streaming.grouping_drift`) and
        past its threshold the store is re-registered with a fresh DBG
        grouping (:func:`~repro.streaming.reregister`) and swapped into
        the cache atomically — in the background unless the policy says
        ``sync=True``. None = never regroup automatically
        (:meth:`regroup_now` still works).
    rebalance_threshold: placement-drift bound forwarded to
        :func:`~repro.streaming.rebuild_plans` on every update: a
        sharded lane placement whose max/mean device load exceeds it
        after a ``keep=``-pinned re-placement is dropped and re-placed
        from scratch (fresh LPT, no residency pins). None = keep pins
        regardless of skew.
    """

    def __init__(self, *, cache: Optional[GraphStoreCache] = None,
                 byte_budget: Optional[int] = None,
                 max_stores: Optional[int] = None,
                 workers: int = 1,
                 default_geom: Optional[Geometry] = None,
                 default_use_dbg: bool = True,
                 default_path: Optional[str] = None,
                 default_shard=None,
                 max_plans_per_store: Optional[int] = None,
                 max_executors: int = 64,
                 executor_byte_budget: Optional[int] = None,
                 max_queue_depth: Optional[int] = None,
                 default_quota: Optional[TenantQuota] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 pool: Union[WorkerPool, int, None] = None,
                 metrics: Optional[ServiceMetrics] = None,
                 tracer: Optional[obs.Tracer] = None,
                 autotune=None,
                 max_chain_depth: Optional[int] = None,
                 regroup: Union[RegroupPolicy, bool, dict, None] = None,
                 rebalance_threshold: Optional[float] = None):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if executor_byte_budget is not None and executor_byte_budget < 1:
            raise ValueError("executor_byte_budget must be >= 1, got "
                             f"{executor_byte_budget}")
        if max_chain_depth is not None and max_chain_depth < 1:
            raise ValueError(f"max_chain_depth must be >= 1, got "
                             f"{max_chain_depth}")
        if rebalance_threshold is not None and rebalance_threshold < 1.0:
            # imbalance is max/mean load, >= 1.0 by construction; a
            # threshold below that would re-place on EVERY update
            raise ValueError(f"rebalance_threshold must be >= 1.0, got "
                             f"{rebalance_threshold}")
        self.metrics = metrics or ServiceMetrics()
        # optional end-to-end tracing (repro.obs): every job gets a root
        # span carried across the queue/pool boundaries; None = off
        self.tracer = tracer
        self.cache = cache or GraphStoreCache(
            byte_budget=byte_budget, max_stores=max_stores,
            on_evict=self._on_store_evicted)
        self.default_geom = default_geom or Geometry()
        self.default_use_dbg = default_use_dbg
        self.default_path = default_path
        self.default_shard = default_shard
        self.max_plans_per_store = max_plans_per_store
        self.max_executors = max_executors
        self.executor_byte_budget = executor_byte_budget
        # key -> (Executor, footprint bytes frozen at insert time)
        self._executors: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()
        self._executor_bytes = 0

        self._scheduler = JobScheduler(
            max_depth=max_queue_depth, default_quota=default_quota,
            quotas=quotas, on_shed=self._on_shed)
        self.metrics._queue_depth_fn = self._scheduler.qsize
        self._own_pool = isinstance(pool, int)
        self._pool: Optional[WorkerPool] = (
            WorkerPool(workers=pool, warm=True) if self._own_pool else pool)
        # measured job-cost model: (skey, app) -> EWMA seconds, plus an
        # adaptive scale mapping plan est_makespan (model units) onto
        # measured seconds — its own lock, it is touched outside the
        # service lock (cost estimation reads cache state)
        self._cost_lock = threading.Lock()
        self._cost_ewma: Dict[tuple, float] = {}
        self._cost_alpha = 0.3
        self._model_scale = 1.0
        self._cost_sum = 0.0
        self._cost_n = 0
        self._lock = threading.Lock()
        self._inflight: Dict[tuple, _Job] = {}
        # fp -> Graph | _LazyGraph (delta chain); enables cold rebuilds
        self._registry: Dict[str, object] = {}
        # streaming lifecycle policies (see the class docstring)
        self.max_chain_depth = max_chain_depth
        self.rebalance_threshold = rebalance_threshold
        if regroup is True:
            regroup = RegroupPolicy()
        elif isinstance(regroup, dict):
            regroup = RegroupPolicy(**regroup)
        elif regroup is not None and not isinstance(regroup, RegroupPolicy):
            raise TypeError(f"regroup= accepts a RegroupPolicy, True, or "
                            f"a kwargs dict, got {regroup!r}")
        self._regroup = regroup or None
        # skey -> cumulative changed edges since registration/regroup;
        # carried across re-keys so churn accrues over the whole chain
        self._churn: Dict[StoreKey, int] = {}
        self._regroup_last: Dict[StoreKey, float] = {}   # cooldown clock
        self._regroup_busy: set = set()   # one regroup per key at a time
        self.metrics._chain_depth_fn = self._max_chain_depth
        # skey -> count of queued/executing jobs; update() defers store
        # retirement while any exist, so even jobs still WAITING in the
        # queue (not yet lease-pinned) finish on the old snapshot
        self._skey_jobs: Dict[StoreKey, int] = {}
        self._retire_pending: set = set()
        self._next_id = 0
        self._closed = False
        # optional drift-driven autotuning (repro.autotune): accepts an
        # AutoTuner instance, True (defaults), or a kwargs dict. The
        # tuner's clearable drift accumulator is spliced ABOVE the
        # service-level one so every executor sample reaches both.
        self._autotuner = None
        if autotune:
            from ..autotune import AutoTuner
            if isinstance(autotune, AutoTuner):
                self._autotuner = autotune
            elif isinstance(autotune, dict):
                self._autotuner = AutoTuner(**autotune)
            else:
                self._autotuner = AutoTuner()
            self._autotuner.load(self.default_geom)
            self.metrics.drift.set_parent(self._autotuner.drift)
            self.metrics._calibration_info_fn = \
                self._autotuner.calibration_info
        self._workers = [
            threading.Thread(target=self._worker_loop, daemon=True,
                             name=f"graph-serve-{i}")
            for i in range(workers)]
        for w in self._workers:
            w.start()

    # -- lifecycle ------------------------------------------------------
    def __enter__(self) -> "GraphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, wait: bool = True) -> None:
        """Stop accepting work; by default drain the queue and join the
        workers (each worker eats one sentinel and exits — sentinels
        sort after every queued job, so the drain finishes real work
        first). The closed flag and the sentinels go in under the
        service lock, atomically with submit()'s enqueue — a racing
        submit either lands before the sentinels (and is drained) or
        raises ServiceClosed."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for _ in self._workers:
                self._scheduler.push_sentinel(_SENTINEL)
        if wait:
            for w in self._workers:
                w.join()
            with self._lock:
                self._executors.clear()
                self._executor_bytes = 0
        if self._own_pool and self._pool is not None:
            self._pool.close(wait=wait)

    @property
    def accepting(self) -> bool:
        """True while submit() would enqueue work (i.e. not closed) —
        the scheduler half of the control plane's readiness probe."""
        with self._lock:
            return not self._closed

    # -- registration ---------------------------------------------------
    def register(self, graph: Graph, *, geom: Optional[Geometry] = None,
                 use_dbg: Optional[bool] = None,
                 prepare: bool = True) -> str:
        """Remember a graph so later submits can pass only its
        fingerprint, and (by default) prepare its GraphStore eagerly so
        the first request is a warm hit. Returns the fingerprint."""
        fp = graph.fingerprint()
        with self._lock:
            self._registry[fp] = graph
        if prepare:
            geom = geom or self.default_geom
            use_dbg = (self.default_use_dbg if use_dbg is None
                       else use_dbg)
            skey = store_key(fp, geom, use_dbg)
            self.cache.get_or_build(
                skey, lambda: self._build_store(graph, geom, use_dbg,
                                                fp=fp))
        return fp

    def unregister(self, fingerprint: str) -> bool:
        """Forget a registered graph (its cached store, if any, stays
        until normally evicted; it just can't be REBUILT from the
        registry afterwards). Returns whether it was registered."""
        with self._lock:
            return self._registry.pop(fingerprint, None) is not None

    # -- streaming updates ----------------------------------------------
    def update(self, fingerprint: str, delta: GraphDelta, *,
               geom: Optional[Geometry] = None,
               use_dbg: Optional[bool] = None,
               keep_base: bool = False) -> UpdateResult:
        """Trace-wrapping front door for :meth:`_update_impl` — updates
        run in the CALLER's thread, so the root span starts and the
        context activates here (there is no queue hand-off). See
        :meth:`_update_impl` for semantics."""
        tr = self.tracer
        if tr is None:
            return self._update_impl(fingerprint, delta, geom=geom,
                                     use_dbg=use_dbg, keep_base=keep_base)
        root = tr.start_trace("service.update", "service",
                              fingerprint=fingerprint[:12])
        try:
            with tr.activate(root.context):
                res = self._update_impl(fingerprint, delta, geom=geom,
                                        use_dbg=use_dbg,
                                        keep_base=keep_base)
            res.trace_id = root.trace_id
            root.end(outcome="done", mode=res.mode)
            return res
        except BaseException as exc:
            root.end(outcome="failed", error=str(exc))
            raise

    def _update_impl(self, fingerprint: str, delta: GraphDelta, *,
                     geom: Optional[Geometry] = None,
                     use_dbg: Optional[bool] = None,
                     keep_base: bool = False) -> UpdateResult:
        """Apply a :class:`~repro.streaming.GraphDelta` to a served
        graph and re-key the store cache to the new chained snapshot
        fingerprint.

        Snapshot semantics: the base store is never mutated — requests
        against the OLD fingerprint that are executing *or still
        waiting in the queue* at update time finish against the old
        snapshot; its cache entry is retired once the last of them
        drains (lease pins cover executing work, a per-key job count
        covers queued work). Submits against the returned
        ``UpdateResult.fingerprint`` see the post-delta graph, warm
        from the incremental apply (clean blockings, cached plans
        rebuilt from carried-over per-partition stats, untouched lanes'
        packed device payloads reused). An old-fingerprint submit that
        races the retirement itself may still lose the store; the
        worker then rebuilds it when the Graph is known (submitted or
        registered) and fails the request with a clear KeyError
        otherwise.

        When the base store is cached the delta is applied
        incrementally in the CALLER's thread (store builds queue behind
        workers; a splice is milliseconds and callers usually want the
        new fingerprint synchronously). When it is not cached but the
        base graph is registered, the update is *deferred*: the delta
        is validated and applied at graph level (so a bad delta fails
        here, never on a later submit) and the store itself builds only
        if a cold submit needs it. Two updates racing on one base both
        succeed and branch the snapshot lineage (like git commits);
        neither invalidates the other.

        ``keep_base=False`` (default) drops the base fingerprint from
        the registry — the base Graph object itself stays referenced by
        the delta chain, so memory grows only by the (small) deltas.
        A base that was never registered still gets its lineage
        anchored (on the store's own source graph), so the chained
        fingerprint remains rebuildable after eviction.
        """
        if delta.base_fp != fingerprint:
            raise ValueError(
                f"delta targets snapshot {delta.base_fp[:12]}… but "
                f"update() was called for {fingerprint[:12]}…")
        geom = geom or self.default_geom
        use_dbg = self.default_use_dbg if use_dbg is None else bool(use_dbg)
        old_key = store_key(fingerprint, geom, use_dbg)
        t0 = time.perf_counter()

        with self._lock:
            if self._closed:
                raise ServiceClosed("update() after close()")
            base_entry = self._registry.get(fingerprint)

        result = None
        base_src = None
        if old_key in self.cache:
            try:
                with self.cache.lease(old_key) as (store, _hit):
                    if self._pool is not None:
                        # numpy-heavy splice in a worker PROCESS; the
                        # plan rebuild stays here — the packed device
                        # payloads it carries over live in this process
                        t_p = time.perf_counter()
                        tr = obs.current_tracer()
                        if tr is not None and obs.current_ctx() is not None:
                            with obs.span("pool.apply", "pool") as sp:
                                result, wspans = self._pool.apply(
                                    store, delta, trace=True)
                            tr.adopt(wspans, sp.context)
                        else:
                            result = self._pool.apply(store, delta)
                        with obs.span("plan.rebuild", "planner"):
                            result.stats.update(rebuild_plans(
                                store, result.store, result.dirty_pids,
                                rebalance_threshold=self
                                .rebalance_threshold))
                        result.stats["t_apply_ms"] = \
                            (time.perf_counter() - t_p) * 1e3
                    else:
                        with obs.span("store.apply_delta", "store"):
                            result = apply_delta(
                                store, delta,
                                rebalance_threshold=self
                                .rebalance_threshold)
                    # lineage anchor for UNREGISTERED bases: a root
                    # store still knows its source Graph, and capturing
                    # it keeps the chained fingerprint rebuildable after
                    # eviction (a content-hash re-register could never
                    # re-associate with the chained identity)
                    base_src = store.source
            except KeyError:
                result = None       # eviction raced us: defer instead
            except Exception:
                self.metrics.record_update_failure()
                raise
        if result is None and base_entry is None:
            self.metrics.record_update_failure()
            raise KeyError(
                f"cannot update {fingerprint[:12]}…: store not cached and "
                f"graph not registered — register() it or submit a Graph "
                f"first")

        new_fp = (result.fingerprint if result is not None
                  else chain_fingerprint(fingerprint, delta.fingerprint()))
        retired = "absent"
        post_graph: Optional[Graph] = None
        if result is not None:
            self.cache.put(store_key(new_fp, geom, use_dbg), result.store)
            # the old snapshot drains out; its executors are purged by
            # the eviction hook when the entry actually goes. Jobs still
            # WAITING in the queue against the old key haven't leased
            # the store yet, so retirement is deferred until the last of
            # them finishes (_finish fires it) — queue wait never turns
            # a legal old-snapshot request into a miss.
            with self._lock:
                busy = self._skey_jobs.get(old_key, 0) > 0
                if busy:
                    self._retire_pending.add(old_key)
            retired = "deferred" if busy else self.cache.retire(old_key)
        else:
            # deferred: no cached store to splice, so validate + apply
            # at graph level NOW (much cheaper than a store build). An
            # invalid delta must fail THIS call — recording it
            # unvalidated would poison the lineage: every later cold
            # submit against new_fp would fail inside a worker with no
            # way to recover the dropped base fingerprint.
            base_graph = (base_entry.materialize()
                          if isinstance(base_entry, _LazyGraph)
                          else base_entry)
            try:
                post_graph = apply_delta_to_graph(base_graph, delta,
                                                  check_fp=False)
            except Exception:
                self.metrics.record_update_failure()
                raise
        with self._lock:
            # incremental updates register a lazy chain (already
            # validated by apply_delta; materialized only if a cold
            # rebuild needs it) — anchored on the registry entry when
            # the base was registered, else on the root store's source
            # graph; deferred updates register the post-delta graph
            # they just materialized
            anchor = base_entry if base_entry is not None else base_src
            chained = False
            if post_graph is not None:
                self._registry[new_fp] = post_graph
            elif anchor is not None:
                self._registry[new_fp] = _LazyGraph(anchor, delta)
                chained = True
            if base_entry is not None and not keep_base:
                self._registry.pop(fingerprint, None)
            # churn follows the lineage across the re-key: it measures
            # edges changed since the last (re-)registration, not since
            # the last delta
            new_key = store_key(new_fp, geom, use_dbg)
            self._churn[new_key] = (self._churn.pop(old_key, 0)
                                    + delta.num_changes)
        if (chained and self.max_chain_depth is not None
                and self._chain_depth(new_fp) > self.max_chain_depth):
            try:
                self.compact_chain(new_fp)
            except ValueError:
                pass   # a branch-poisoned chain stays long, never fails
                       # the update that happened to trip the bound
        if result is not None and self._regroup is not None:
            self._maybe_regroup(new_key)

        t_ms = (time.perf_counter() - t0) * 1e3
        stats = result.stats if result is not None else None
        self.metrics.record_update(
            t_ms, stats, deferred=result is None,
            retired=retired in ("now", "deferred"))
        return UpdateResult(
            fingerprint=new_fp, base_fingerprint=fingerprint,
            mode="incremental" if result is not None else "deferred",
            retired=retired, stats=stats, t_update_ms=t_ms)

    # -- streaming lifecycle (compaction + regroup) ---------------------
    def _chain_depth(self, fingerprint: str) -> int:
        """Length of the lazy delta chain behind a registered snapshot
        (0 for a plain or already-materialized Graph, and for unknown
        fingerprints). Chain links are read without the materialize
        lock — they are assigned atomically, and a depth racing a
        concurrent materialize/compact only ever overestimates."""
        with self._lock:
            node = self._registry.get(fingerprint)
        depth = 0
        while isinstance(node, _LazyGraph) and node._graph is None:
            depth += 1
            node = node._base
        return depth

    def _max_chain_depth(self) -> int:
        """Deepest delta chain across every registered snapshot — the
        ``regraph_chain_depth`` gauge's pull hook."""
        with self._lock:
            fps = list(self._registry)
        return max((self._chain_depth(fp) for fp in fps), default=0)

    def compact_chain(self, fingerprint: str) -> dict:
        """Squash the delta chain behind a registered snapshot into ONE
        composed delta, preserving the chained-fingerprint lineage.

        The registry keeps the SAME key — compaction shortens the path
        from the anchor graph to the snapshot, never its identity — so
        a cold rebuild after a store eviction replays O(1) deltas
        instead of the whole chain. Chains that another snapshot still
        branches from are safe: intermediate nodes stay referenced by
        the other chain; only this entry's link is rewired. The
        chain's lineage is verified link by link before anything is
        mutated (a mismatch raises ValueError and leaves the chain
        intact): each delta must target the registry identity of the
        node below it. The check is structural — against registry keys,
        not refolded digests — because a PREVIOUSLY composed delta is
        content-equivalent to the links it replaced but hashes
        differently, so repeated compaction cannot rely on
        ``compact_deltas``'s strict digest fold. Returns an accounting
        dict; an unregistered fingerprint raises KeyError."""
        with self._lock:
            entry = self._registry.get(fingerprint)
            ident = {id(v): k for k, v in self._registry.items()}
        if entry is None:
            raise KeyError(f"fingerprint {fingerprint[:12]}… is not "
                           f"registered; nothing to compact")
        t0 = time.perf_counter()
        out = {"fingerprint": fingerprint, "depth_before": 0,
               "depth_after": 0, "compacted": False}
        if not isinstance(entry, _LazyGraph):
            out["t_compact_ms"] = (time.perf_counter() - t0) * 1e3
            return out
        with _LazyGraph._MAT_LOCK:
            if entry._graph is None:
                nodes = []
                base = entry
                while isinstance(base, _LazyGraph) and base._graph is None:
                    nodes.append(base)
                    base = base._base
                anchor = base._graph if isinstance(base, _LazyGraph) \
                    else base
                nodes.reverse()
                out["depth_before"] = out["depth_after"] = len(nodes)
                if len(nodes) > 1:
                    # lineage check: every delta targets the identity
                    # of the node it chains onto
                    below = ident.get(id(base))
                    for node in nodes:
                        want = node._delta.base_fp
                        if below is not None and want != below:
                            raise ValueError(
                                f"chain behind {fingerprint[:12]}… has a "
                                f"delta targeting {want[:12]}… where the "
                                f"parent snapshot is {below[:12]}… — "
                                f"lineage mismatch, not compacting")
                        below = ident.get(id(node))
                    if below != fingerprint:
                        raise ValueError(
                            f"chain tip registered as "
                            f"{'?' if below is None else below[:12]}… != "
                            f"{fingerprint[:12]}… — lineage mismatch, "
                            f"not compacting")
                    # compose BEFORE rewiring: a failed composition
                    # leaves the entry untouched and replayable
                    composed, _ = compact_deltas(
                        [n._delta for n in nodes], strict=False)
                    entry._base = anchor
                    entry._delta = composed
                    out["depth_after"] = 1
                    out["compacted"] = True
                    out["composed_changes"] = composed.num_changes
        if out["compacted"]:
            self.metrics.record_compaction()
        out["t_compact_ms"] = (time.perf_counter() - t0) * 1e3
        return out

    def _maybe_regroup(self, skey: StoreKey) -> None:
        """Post-update policy gate: once cumulative churn on this key
        justifies a drift check (and the cooldown allows one), run the
        check-and-maybe-swap — inline when the policy is ``sync``, else
        on a daemon thread so update() latency stays flat."""
        policy = self._regroup
        store = self.cache.peek(skey)
        if store is None:
            return
        now = time.monotonic()
        with self._lock:
            if skey in self._regroup_busy:
                return
            if not policy.churn_ready(self._churn.get(skey, 0),
                                      store.graph.num_edges):
                return
            last = self._regroup_last.get(skey)
            if (policy.cooldown_s and last is not None
                    and now - last < policy.cooldown_s):
                return
            self._regroup_busy.add(skey)
            self._regroup_last[skey] = now
        if policy.sync:
            self._regroup_run(skey)
        else:
            threading.Thread(target=self._regroup_run, args=(skey,),
                             daemon=True, name="graph-regroup").start()

    def _regroup_run(self, skey: StoreKey) -> Optional[dict]:
        """Measure grouping drift for one cached store and, past the
        policy threshold, swap in a freshly-regrouped rebuild. Never
        raises: regrouping is an optimization and a failed check must
        not break serving."""
        policy = self._regroup or RegroupPolicy()
        try:
            store = self.cache.peek(skey)
            if store is None:
                return None
            event = grouping_drift(store, hw=policy.hw)
            event["fingerprint"] = skey[0]
            event["applied"] = False
            if event["drift"] > policy.drift_threshold:
                self._regroup_swap(skey, store)
                event["applied"] = True
            return event
        except Exception:
            return None
        finally:
            with self._lock:
                self._regroup_busy.discard(skey)

    def _regroup_swap(self, skey: StoreKey, store: GraphStore) -> None:
        """The atomic half of a regroup: rebuild with a fresh DBG
        grouping under the SAME chained fingerprint, replace the cache
        entry in place (``put`` on the live key — the swap other layers
        also use), and purge the key's cached executors explicitly —
        a put-replace fires no eviction hook, and those executors were
        compiled against the OLD store's layout."""
        fresh = reregister(store)
        self.cache.put(skey, fresh)
        with self._lock:
            self._churn[skey] = 0
            for k in [k for k in self._executors if k[0] == skey]:
                self._drop_executor(k)
        self.metrics.record_regroup()

    def regroup_now(self, graph: Union[Graph, str, None] = None, *,
                    fingerprint: Optional[str] = None,
                    geom: Optional[Geometry] = None,
                    use_dbg: Optional[bool] = None,
                    force: bool = False) -> dict:
        """Force a grouping-drift check — and, past the policy
        threshold or unconditionally with ``force=True``, the
        re-registration swap — for one served snapshot, bypassing the
        churn/cooldown gates (admin/debug path, like
        :meth:`retune_now`; the normal trigger is the post-update
        policy check). Requires the store to be cached: regrouping
        re-lays-out a LIVE store, there is nothing to do for an
        evicted one. Returns the drift event dict."""
        geom = geom or self.default_geom
        use_dbg = self.default_use_dbg if use_dbg is None else bool(use_dbg)
        fp = resolve_fingerprint(graph, fingerprint)
        skey = store_key(fp, geom, use_dbg)
        store = self.cache.peek(skey)
        if store is None:
            raise KeyError(f"no cached store for {fp[:12]}…; regroup "
                           f"operates on the cached store — submit or "
                           f"register() first")
        policy = self._regroup or RegroupPolicy()
        event = grouping_drift(store, hw=policy.hw)
        event["fingerprint"] = fp
        event["applied"] = False
        if force or event["drift"] > policy.drift_threshold:
            self._regroup_swap(skey, store)
            event["applied"] = True
        return event

    def _on_store_evicted(self, skey: StoreKey, store: GraphStore) -> None:
        """Cache-eviction hook: purge the evicted store's executors so
        they don't keep its device arrays alive past the byte budget.
        In-flight runs still hold their own executor reference and
        finish untouched."""
        self.metrics.record_eviction()
        with self._lock:
            for k in [k for k in self._executors if k[0] == skey]:
                self._drop_executor(k)
            # a later cold rebuild runs a fresh DBG pass, so the churn
            # clock (changes since last registration) restarts with it
            self._churn.pop(skey, None)
            self._regroup_last.pop(skey, None)

    def _drop_executor(self, key) -> None:
        """Remove one cached executor (caller holds the lock)."""
        _, nbytes = self._executors.pop(key)
        self._executor_bytes -= nbytes

    def _trim_executors(self) -> None:
        """Evict LRU executors past the count bound and (when set) the
        byte budget. The count bound is strict (``max_executors=0``
        still disables caching entirely); the byte bound never evicts
        the newest entry — a single oversized plan must stay servable
        (caller holds the lock)."""
        evicted = 0
        while self._executors and (
                len(self._executors) > self.max_executors
                or (self.executor_byte_budget is not None
                    and self._executor_bytes > self.executor_byte_budget
                    and len(self._executors) > 1)):
            self._drop_executor(next(iter(self._executors)))
            evicted += 1
        if evicted:
            self.metrics.record_executor_eviction(evicted)

    def _build_store(self, graph: Graph, geom: Geometry = None,
                     use_dbg: bool = None,
                     fp: Optional[str] = None) -> GraphStore:
        # fp pins the store's identity to the SERVICE's key: a store
        # rebuilt from a materialized delta chain must keep the chained
        # fingerprint (deltas validate against it), not the content
        # hash of the materialized graph
        geom = geom or self.default_geom
        use_dbg = self.default_use_dbg if use_dbg is None else use_dbg
        if self._pool is not None:
            # DBG + lexsort + partition stats run in a worker process;
            # a WorkerCrashed propagates like any builder failure (the
            # cache lease releases, the job's handles get the error)
            tr = obs.current_tracer()
            if tr is not None and obs.current_ctx() is not None:
                # trace carrier across the process boundary: the worker
                # records spans into a throwaway local tracer and ships
                # them back as dicts; adopt() re-parents them here
                with obs.span("pool.build_store", "pool") as sp:
                    store, wspans = self._pool.build_store(
                        graph, geom=geom, use_dbg=use_dbg, fp=fp,
                        max_plans=self.max_plans_per_store, trace=True)
                tr.adopt(wspans, sp.context)
                return store
            return self._pool.build_store(
                graph, geom=geom, use_dbg=use_dbg, fp=fp,
                max_plans=self.max_plans_per_store)
        return GraphStore(
            graph, geom=geom, use_dbg=use_dbg,
            max_plans=self.max_plans_per_store,
            fingerprint=fp)

    # -- submission -----------------------------------------------------
    def submit(self, graph: Union[Graph, str, None] = None,
               app: Union[GASApp, str] = "pagerank", *,
               fingerprint: Optional[str] = None,
               app_kwargs: Optional[dict] = None,
               config: Optional[PlanConfig] = None,
               geom: Optional[Geometry] = None,
               use_dbg: Optional[bool] = None,
               max_iters: Optional[int] = None,
               path: Optional[str] = None,
               shard=None,
               tenant: str = "default",
               priority: int = 0,
               deadline: Optional[float] = None,
               observer=None,
               **cfg) -> RequestHandle:
        """Enqueue one request; returns immediately with a
        :class:`RequestHandle`.

        ``graph`` may be a :class:`Graph`, a fingerprint string, or None
        with ``fingerprint=`` set (the graph must then be registered or
        its store still cached). ``app`` is a builtin name (coalescable;
        parameterize via ``app_kwargs``) or a prebuilt :class:`GASApp`
        (coalesced only with submissions of that same instance — the
        service can't see inside arbitrary closures). ``shard`` requests
        multi-device execution (``True`` = all local devices, int n =
        first n; ``False`` opts out of a service ``default_shard``;
        ``None`` = the service default) — sharded and unsharded requests
        never coalesce with each other. Extra kwargs become
        :class:`PlanConfig` fields, as in :func:`repro.api.compile`.

        Submitting a Graph does NOT retain it past the request: if its
        store is later evicted, a fingerprint-only resubmit needs the
        Graph again — or :meth:`register` it once (registered graphs
        are kept until :meth:`unregister` and always rebuildable).

        Scheduling: ``priority`` (larger drains first), ``deadline``
        (seconds from now; a job still queued past it is load-shed and
        its handles raise
        :class:`~repro.control.scheduler.DeadlineExpired`), and
        ``tenant`` (admission accounting; see ``default_quota``).
        Admission may raise the typed
        :class:`~repro.control.scheduler.QueueFull` /
        :class:`~repro.control.scheduler.QuotaExceeded` — nothing is
        enqueued then. A submit that coalesces onto an in-flight job
        bypasses admission entirely and, if its priority is higher,
        boosts the queued job's. ``observer`` is a
        ``(event, job_info_dict)`` callback for the control plane's
        job records (events: queued, coalesced, running, done, failed,
        shed).
        """
        if config is not None and cfg:
            raise ValueError("pass either config= or PlanConfig kwargs, "
                             "not both")
        config = config or PlanConfig(**cfg)
        geom = geom or self.default_geom
        use_dbg = self.default_use_dbg if use_dbg is None else bool(use_dbg)
        path = path or self.default_path
        shard = self.default_shard if shard is None else shard
        if shard is False:
            shard = None
        elif shard is True:
            # resolve to a count NOW: True == 1 in tuple keys, so leaving
            # the bool in job/executor keys would coalesce an all-devices
            # request with a one-device one
            import jax
            shard = jax.device_count()
        if shard is not None and (not isinstance(shard, int)
                                  or isinstance(shard, bool) or shard < 1):
            # device sequences aren't hashable job keys; serving keeps
            # the coalescable forms only
            raise ValueError("submit(shard=...) accepts True/False or a "
                             f"positive int device count, got {shard!r}")

        graph_obj = graph if isinstance(graph, Graph) else None
        fp = resolve_fingerprint(graph, fingerprint)
        skey = store_key(fp, geom, use_dbg)

        app_name, app_token, make_app = _normalize_app(app, app_kwargs)
        if graph_obj is None:
            # NOTE: no auto-registration on the Graph path — only
            # register() pins graphs on the service, so serving many
            # distinct graphs can't grow host memory behind the store
            # cache's byte budget
            with self._lock:
                graph_obj = self._registry.get(fp)
            if graph_obj is None and skey not in self.cache:
                raise KeyError(
                    f"fingerprint {fp[:12]}… is neither registered nor "
                    f"cached; pass the Graph or register() it first")

        if self._autotuner is not None:
            # rewrite default-shaped configs to the current calibrated HW
            # and best-known split BEFORE keying: coalescing, cost
            # estimation and plan lookup all see the effective config
            config = self._autotuner.resolve_config(config, skey)

        job_key = (skey, app_token, config.cache_key(), max_iters, path,
                   shard)
        # cost estimation reads the store/plan caches (their own locks;
        # the eviction hook re-enters the service lock, so peeking from
        # under it would invert the order) — do it before locking
        cost, model_est = self._estimate_cost(skey, app_name, config)
        abs_deadline = (None if deadline is None
                        else time.monotonic() + deadline)
        with self._lock:
            # closed-check is atomic with the enqueue: close() inserts
            # its sentinels under this same lock, so a submit can never
            # land a job behind them (which no worker would ever drain)
            if self._closed:
                raise ServiceClosed("submit() after close()")
            self._next_id += 1
            rid = self._next_id
            job = self._inflight.get(job_key)
            coalesced = job is not None
            m = RequestMetrics(request_id=rid, app=app_name,
                               fingerprint=fp, tenant=tenant,
                               coalesced=coalesced)
            handle = RequestHandle(rid, m)
            if coalesced:
                # piggyback on the identical in-flight job; its single
                # execution resolves every attached handle. No admission
                # charge — the work already paid its way in — but a
                # higher-priority twin boosts the queued job (quota
                # pressure must not invert priorities via coalescing)
                job.handles.append(handle)
                handle._job = job
                if observer is not None:
                    job.observers.append(observer)
                if priority > job.priority:
                    job.priority = priority
                    self._scheduler.reprioritize(job, priority)
            else:
                job = _Job(job_key, skey, graph_obj, app_name, make_app,
                           config, geom, use_dbg, max_iters, path,
                           shard=shard, tenant=tenant, priority=priority)
                job.model_est = model_est
                job.handles.append(handle)
                handle._job = job
                if observer is not None:
                    job.observers.append(observer)
                if self.tracer is not None:
                    # root + queue spans start HERE (the submit thread);
                    # the worker thread ends the queue span at pickup
                    # and activates the root context — the explicit
                    # carrier across the scheduler hand-off
                    job.root_span = self.tracer.start_trace(
                        f"job:{app_name}", "service", app=app_name,
                        fingerprint=fp[:12], tenant=tenant,
                        priority=priority, request_id=rid)
                    job.trace_ctx = job.root_span.context
                    job.queue_span = self.tracer.start_span(
                        "queue.wait", "scheduler", parent=job.trace_ctx)
                self._inflight[job_key] = job
                self._skey_jobs[skey] = self._skey_jobs.get(skey, 0) + 1
                try:
                    self._scheduler.push(job, tenant=tenant,
                                         priority=priority,
                                         deadline=abs_deadline, cost=cost)
                except RejectedJob as exc:
                    # typed rejection: nothing enqueued — unwind the
                    # bookkeeping so the key isn't poisoned in-flight
                    del self._inflight[job_key]
                    left = self._skey_jobs.get(skey, 1) - 1
                    if left <= 0:
                        self._skey_jobs.pop(skey, None)
                    else:
                        self._skey_jobs[skey] = left
                    kind = ("queue_full" if isinstance(exc, QueueFull)
                            else "quota")
                    self.metrics.record_rejected(kind, tenant)
                    if job.queue_span is not None:
                        job.queue_span.end(rejected=kind)
                    if job.root_span is not None:
                        job.root_span.end(outcome="rejected", error=kind)
                    raise
            handle.trace_ctx = job.trace_ctx   # control plane reads this
        self.metrics.record_submit(coalesced, tenant)
        self._notify(job, "coalesced" if coalesced else "queued",
                     request_id=rid)
        return handle

    def run(self, graph=None, app="pagerank", *, timeout=None, **kw):
        """Synchronous convenience: submit + wait."""
        return self.submit(graph, app, **kw).result(timeout=timeout)

    def cancel(self, handle: RequestHandle) -> bool:
        """Detach one handle from its job; the handle then raises
        :class:`concurrent.futures.CancelledError`. Returns False if
        the request already resolved. Cancelling the LAST handle of a
        still-queued job removes the job from the queue entirely; a
        job already executing runs to completion (its result simply
        has no one left to fan out to)."""
        import concurrent.futures
        job = getattr(handle, "_job", None)
        if job is None:
            return False
        do_retire = removed_job = False
        with self._lock:
            if handle.done():
                return False
            try:
                job.handles.remove(handle)
            except ValueError:       # _finish snapshotted concurrently
                return False
            if not job.handles and self._inflight.get(job.key) is job:
                if self._scheduler.remove(job):   # still queued
                    removed_job = True
                    self._inflight.pop(job.key, None)
                    left = self._skey_jobs.get(job.skey, 1) - 1
                    if left <= 0:
                        self._skey_jobs.pop(job.skey, None)
                        if job.skey in self._retire_pending:
                            self._retire_pending.discard(job.skey)
                            do_retire = True
                    else:
                        self._skey_jobs[job.skey] = left
        if do_retire:
            self.cache.retire(job.skey)
        if removed_job:
            if job.queue_span is not None:
                job.queue_span.end(outcome="cancelled")
            if job.root_span is not None:
                job.root_span.end(outcome="cancelled")
        m = handle.metrics
        m.error = "cancelled"
        m.t_total_ms = (time.perf_counter() - handle._t_submit) * 1e3
        self.metrics.record_done(m)
        handle._set_exception(concurrent.futures.CancelledError(
            f"request {handle.request_id} cancelled"))
        if removed_job:
            self._notify(job, "cancelled")
        return True

    # -- cost model ------------------------------------------------------
    def _estimate_cost(self, skey: StoreKey, app_name: str,
                       config: PlanConfig) -> Tuple[float, Optional[float]]:
        """Predict a job's runtime in seconds for queue ordering.
        Preference order: the measured EWMA for this (store, app)
        shape; the perf model's ``est_makespan`` (rescaled by the
        adaptive calibration factor) when store and plan are already
        cached; the global measured average. Returns ``(seconds,
        raw model estimate or None)`` — pure peeks only, an estimate
        must never build anything or touch LRU recency."""
        with self._cost_lock:
            ew = self._cost_ewma.get((skey, app_name))
            scale = self._model_scale
            avg = self._cost_sum / self._cost_n if self._cost_n else 0.0
        if ew is not None:
            return ew, None
        store = self.cache.peek(skey)
        if store is not None:
            bundle = store.peek_plan(config)
            if bundle is not None:
                est = float(bundle.plan.est_makespan)
                return est * scale, est
        return avg, None

    def _record_cost(self, job: _Job, seconds: float) -> None:
        """Fold one measured (store + plan + execute) duration into the
        EWMA for the job's shape, and — when the perf model estimated
        this job — into the model→wall-clock calibration scale."""
        with self._cost_lock:
            k = (job.skey, job.app_name)
            old = self._cost_ewma.get(k)
            a = self._cost_alpha
            self._cost_ewma[k] = (seconds if old is None
                                  else (1 - a) * old + a * seconds)
            if len(self._cost_ewma) > 4096:     # bound: drop the oldest
                self._cost_ewma.pop(next(iter(self._cost_ewma)))
            self._cost_sum += seconds
            self._cost_n += 1
            if job.model_est:
                ratio = seconds / job.model_est
                self._model_scale = (1 - a) * self._model_scale + a * ratio

    # -- worker ---------------------------------------------------------
    def _notify(self, job: "_Job", event: str, **info) -> None:
        """Fire the job's control-plane observers (outside all service
        locks; observers must never be able to break serving)."""
        if not isinstance(job, _Job) or not job.observers:
            return
        info.update(app=job.app_name, fingerprint=job.skey[0],
                    tenant=job.tenant)
        for cb in list(job.observers):
            try:
                cb(event, info)
            except Exception:
                pass

    def _on_shed(self, job: "_Job") -> None:
        """Scheduler callback (fired outside its lock) for a queued job
        whose deadline expired: fail every attached handle with the
        typed error and release the job's bookkeeping."""
        self.metrics.record_shed(job.tenant)
        waited = time.perf_counter() - job.t_submit
        self._finish(job, error=DeadlineExpired(
            f"job for app {job.app_name!r} load-shed: deadline expired "
            f"after {waited:.3f}s in queue"), event="shed")

    def _worker_loop(self) -> None:
        while True:
            job = self._scheduler.pop()
            if job is _SENTINEL:
                return
            self._notify(job, "running")
            try:
                self._execute(job)
            except BaseException as exc:   # never kill the worker
                self._finish(job, error=exc)

    def _execute(self, job: _Job) -> None:
        # end the queue-wait span at pickup, then run the body with the
        # job's trace context active on THIS thread so every deeper
        # obs.span (store build, plan, executor lanes) attaches to it
        if job.queue_span is not None:
            job.queue_span.end()
        if self.tracer is not None and job.trace_ctx is not None:
            with self.tracer.activate(job.trace_ctx):
                self._execute_impl(job)
        else:
            self._execute_impl(job)

    def _execute_impl(self, job: _Job) -> None:
        t_pickup = time.perf_counter()
        t_queue_ms = (t_pickup - job.t_submit) * 1e3

        def builder():
            g = job.graph
            if g is None:
                raise KeyError(
                    f"store for {job.skey[0][:12]}… was evicted and the "
                    f"graph is not registered; re-submit with the Graph")
            if isinstance(g, _LazyGraph):   # replay the delta chain
                g = g.materialize()
            return self._build_store(g, job.geom, job.use_dbg,
                                     fp=job.skey[0])

        # max_iters is a run() argument, not executor state, so it is
        # deliberately absent from the executor key (unlike the job key)
        exec_key = (job.skey, job.key[1], job.config.cache_key(), job.path,
                    job.shard)
        t0 = time.perf_counter()
        with contextlib.ExitStack() as stack:
            # the lease stays held for the whole execution, but the
            # "service.store" span must cover only its ACQUISITION
            # (fetch or build) — hence ExitStack instead of nesting
            with obs.span("service.store", "service") as sp:
                store, store_hit = stack.enter_context(
                    self.cache.lease(job.skey, builder))
                sp.set(hit=store_hit)
            t_store_ms = (time.perf_counter() - t0) * 1e3

            with self._lock:
                hit = self._executors.get(exec_key)
                if hit is not None:
                    self._executors.move_to_end(exec_key)
            if hit is not None:
                ex, plan_hit, t_plan_ms = hit[0], True, 0.0
            else:
                plan_hit = store.has_plan(job.config)
                t0 = time.perf_counter()
                with obs.span("service.plan", "service",
                              hit=plan_hit) as sp:
                    bundle = store.plan(job.config)
                t_plan_ms = (time.perf_counter() - t0) * 1e3
                if job.shard is not None:
                    from ..sharding.executor import ShardedExecutor
                    ex = ShardedExecutor(store, bundle, job.make_app(),
                                         devices=job.shard, path=job.path)
                else:
                    calib = (self._autotuner.calibrator
                             if self._autotuner is not None else None)
                    ex = Executor(store, bundle, job.make_app(),
                                  path=job.path,
                                  drift_parent=self.metrics.drift,
                                  util_parent=self.metrics.utilization,
                                  calibrator=calib)
                nbytes = ex.memory_footprint()
                with self._lock:
                    if exec_key in self._executors:
                        self._drop_executor(exec_key)   # racing build won
                    self._executors[exec_key] = (ex, nbytes)
                    self._executor_bytes += nbytes
                    self._trim_executors()

            t0 = time.perf_counter()
            with obs.span("service.execute", "service", app=job.app_name,
                          executor_hit=hit is not None) as sp:
                result = ex.run(max_iters=job.max_iters)
                sp.set(iterations=result[1]["iterations"])
            t_execute_ms = (time.perf_counter() - t0) * 1e3

        self.metrics.record_execution(store_hit, plan_hit)
        self._record_cost(job,
                          (t_store_ms + t_plan_ms + t_execute_ms) / 1e3)
        self._finish(job, result=result, store_hit=store_hit,
                     plan_hit=plan_hit, t_queue_ms=t_queue_ms,
                     t_store_ms=t_store_ms, t_plan_ms=t_plan_ms,
                     t_execute_ms=t_execute_ms)
        # drift policy check AFTER the handles resolve: a retune sweeps
        # time_lanes + rebuilds plans, and must not delay the request
        # that happened to trip it. Sharded executors have no time_lanes
        # path; single-device drift covers the same model constants.
        if self._autotuner is not None and job.shard is None:
            try:
                ev = self._autotuner.observe(store, ex, job.config,
                                             skey=job.skey)
                if ev is not None and ev.get("applied"):
                    self.metrics.record_retune()
            except Exception as e:   # autotuning must never fail serving
                self._autotuner._push_event(
                    {"error": repr(e), "applied": False})

    def _finish(self, job: _Job, result=None, error=None, store_hit=None,
                plan_hit=None, t_queue_ms=None, t_store_ms=None,
                t_plan_ms=None, t_execute_ms=None,
                event: Optional[str] = None) -> None:
        # unlink and snapshot the handle list atomically: a twin either
        # attaches before this (and is resolved below) or finds the job
        # gone and starts a fresh execution — never lost in between
        do_retire = False
        with self._lock:
            self._inflight.pop(job.key, None)
            handles = list(job.handles)
            left = self._skey_jobs.get(job.skey, 1) - 1
            if left <= 0:
                self._skey_jobs.pop(job.skey, None)
                if job.skey in self._retire_pending:
                    self._retire_pending.discard(job.skey)
                    do_retire = True   # last old-snapshot job drained
            else:
                self._skey_jobs[job.skey] = left
        if do_retire:
            # outside the service lock: retirement may evict and the
            # eviction hook re-enters the lock
            self.cache.retire(job.skey)
        if job.queue_span is not None and not job.queue_span.ended:
            # shed/cancel paths never reached pickup
            job.queue_span.end(outcome=event or "failed")
        if job.root_span is not None:
            outcome = event or ("failed" if error is not None else "done")
            if error is not None:
                job.root_span.end(outcome=outcome, error=str(error))
            else:
                job.root_span.end(outcome=outcome)
        now = time.perf_counter()
        for h in handles:
            m = h.metrics
            m.store_hit = store_hit
            m.plan_hit = plan_hit
            # each handle gets ITS OWN end-to-end latency; the stage
            # breakdown describes the one execution, so it lands only on
            # the request that triggered it — coalesced twins keep the
            # documented None stages (they did not queue/build/run)
            m.t_total_ms = (now - h._t_submit) * 1e3
            if not m.coalesced:
                m.t_queue_ms = t_queue_ms
                m.t_store_ms = t_store_ms
                m.t_plan_ms = t_plan_ms
                m.t_execute_ms = t_execute_ms
            if error is not None:
                m.error = "".join(traceback.format_exception_only(
                    type(error), error)).strip()
                self.metrics.record_done(m)
                h._set_exception(error)
            else:
                self.metrics.record_done(m)
                h._set_result(result)
        self._notify(job, event or ("failed" if error is not None
                                    else "done"),
                     error=(None if error is None else str(error)))

    # -- autotune -------------------------------------------------------
    @property
    def autotuner(self):
        """The attached :class:`~repro.autotune.AutoTuner`, or None."""
        return self._autotuner

    def retune_now(self, graph: Union[Graph, str, None] = None, *,
                   fingerprint: Optional[str] = None,
                   app="pagerank", geom: Optional[Geometry] = None,
                   use_dbg: Optional[bool] = None,
                   config: Optional[PlanConfig] = None, **cfg) -> dict:
        """Force a calibrate-and-replan cycle for one graph, bypassing
        the drift policy (admin/debug path; the normal trigger is the
        post-execution drift check). Returns the retune event dict."""
        if self._autotuner is None:
            raise RuntimeError("service was built without autotune=")
        if config is not None and cfg:
            raise ValueError("pass either config= or PlanConfig kwargs, "
                             "not both")
        config = config or PlanConfig(**cfg)
        geom = geom or self.default_geom
        use_dbg = self.default_use_dbg if use_dbg is None else bool(use_dbg)
        graph_obj = graph if isinstance(graph, Graph) else None
        fp = resolve_fingerprint(graph, fingerprint)
        skey = store_key(fp, geom, use_dbg)
        if graph_obj is None:
            with self._lock:
                graph_obj = self._registry.get(fp)
            if graph_obj is None and skey not in self.cache:
                raise KeyError(
                    f"fingerprint {fp[:12]}… is neither registered nor "
                    f"cached; pass the Graph or register() it first")
        config = self._autotuner.resolve_config(config, skey)

        def builder():
            g = graph_obj
            if g is None:
                raise KeyError("store evicted and graph not registered")
            if isinstance(g, _LazyGraph):
                g = g.materialize()
            return self._build_store(g, geom, use_dbg, fp=fp)

        _, _, make_app = _normalize_app(app, None)
        with self.cache.lease(skey, builder) as (store, _hit):
            bundle = store.plan(config)
            ex = Executor(store, bundle, make_app(),
                          path=self.default_path,
                          drift_parent=self.metrics.drift,
                          util_parent=self.metrics.utilization,
                          calibrator=self._autotuner.calibrator)
            event = self._autotuner.retune(store, ex, config, skey=skey,
                                           force=True)
        if event.get("applied"):
            self.metrics.record_retune()
        return event

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            n_exec = len(self._executors)
            exec_bytes = self._executor_bytes
        return {
            "service": self.metrics.snapshot(),
            "store_cache": self.cache.stats(),
            "scheduler": self._scheduler.stats(),
            "pool": self._pool.stats() if self._pool is not None else None,
            "registered_graphs": len(self._registry),
            "max_chain_depth": self._max_chain_depth(),
            "cached_executors": n_exec,
            "executor_bytes": exec_bytes,
            "executor_byte_budget": self.executor_byte_budget,
            "drift": self.metrics.drift.report(),
            "autotune": (self._autotuner.stats()
                         if self._autotuner is not None else None),
            "tracer": (self.tracer.stats()
                       if self.tracer is not None else None),
        }


def _normalize_app(app: Union[GASApp, str],
                   app_kwargs: Optional[dict]
                   ) -> Tuple[str, tuple, "callable"]:
    """Return (display name, coalescing token, zero-arg factory).

    Builtin apps submitted by name coalesce on (name, kwargs); a
    prebuilt GASApp instance coalesces only with itself (its parameters
    live in closures the service can't inspect, and GASApp instances
    are stateless across runs, so sharing the instance is safe).
    """
    if isinstance(app, str):
        if app not in BUILTIN_APPS:
            raise ValueError(f"unknown builtin app {app!r}; available: "
                             f"{sorted(BUILTIN_APPS)}")
        kwargs = dict(app_kwargs or {})
        token = ("builtin", app,
                 tuple((k, _hashable(v)) for k, v in sorted(kwargs.items())))
        return app, token, lambda: BUILTIN_APPS[app](**kwargs)
    if app_kwargs:
        raise ValueError("app_kwargs only apply to builtin app names")
    return app.name, ("instance", id(app)), lambda: app


def _hashable(v):
    """Coalescing keys must hash; app kwargs may hold numpy arrays
    (e.g. closeness ``sources``) or lists — fold them to value-equal
    hashable forms."""
    if isinstance(v, np.ndarray):
        return ("ndarray", v.shape, str(v.dtype), v.tobytes())
    if isinstance(v, (list, tuple)):
        return tuple(_hashable(x) for x in v)
    return v
