"""GraphService — multi-tenant serving front-end over the layered API.

The service turns the library's GraphStore → Planner → Executor stack
into a long-lived system: requests (graph-or-fingerprint, app, config)
go into a FIFO queue, worker threads drain it, and two cache layers do
the heavy lifting — a byte-budgeted LRU of GraphStores across graphs
(:class:`~.store_cache.GraphStoreCache`) and each store's bounded plan
LRU within a graph. Identical in-flight requests are coalesced: N
concurrent PageRank submissions on the same graph execute once and fan
the result out to every caller's handle.

Quickstart::

    from repro.serve_graph import GraphService

    with GraphService(byte_budget=512 << 20, workers=2) as svc:
        h1 = svc.submit(graph, "pagerank", n_lanes=8)
        h2 = svc.submit(graph, "bfs", app_kwargs={"root": 0})
        props, meta = h1.result(timeout=60)

Submission by fingerprint (no graph payload on the hot path)::

    fp = svc.register(graph)          # prepare + remember the graph
    h = svc.submit(fingerprint=fp, app="pagerank")
"""
from __future__ import annotations

import collections
import queue
import threading
import time
import traceback
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from ..core.executor import Executor
from ..core.gas import BUILTIN_APPS, GASApp
from ..core.planner import PlanConfig
from ..core.store import GraphStore
from ..core.types import Geometry
from ..graphs.formats import Graph
from .fingerprint import StoreKey, resolve_fingerprint, store_key
from .metrics import RequestMetrics, ServiceMetrics
from .store_cache import GraphStoreCache

__all__ = ["GraphService", "RequestHandle", "ServiceClosed"]

_SENTINEL = object()


class ServiceClosed(RuntimeError):
    """Raised by submit() after close()."""


class RequestHandle:
    """Future-like handle for one submitted request.

    ``result(timeout)`` blocks for (props, meta); ``exception()``
    returns the failure instead of raising. Coalesced duplicates share
    one execution, so their handles resolve to the *same* result
    objects — treat returned arrays as read-only.
    """

    def __init__(self, request_id: int, metrics: RequestMetrics):
        self.request_id = request_id
        self.metrics = metrics
        self._t_submit = time.perf_counter()   # this handle's own clock
        self._event = threading.Event()
        self._result: Optional[tuple] = None
        self._exception: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not done within {timeout}s")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not done within {timeout}s")
        return self._exception

    # service-side
    def _set_result(self, value: tuple) -> None:
        self._result = value
        self._event.set()

    def _set_exception(self, exc: BaseException) -> None:
        self._exception = exc
        self._event.set()


class _Job:
    """One unit of execution: a coalescing group of identical requests."""

    __slots__ = ("key", "skey", "graph", "app_name", "make_app", "config",
                 "use_dbg", "geom", "max_iters", "path", "handles",
                 "t_submit")

    def __init__(self, key, skey: StoreKey, graph: Optional[Graph],
                 app_name: str, make_app, config: PlanConfig,
                 geom: Geometry, use_dbg: bool,
                 max_iters: Optional[int], path: Optional[str]):
        self.key = key
        self.skey = skey
        self.graph = graph
        self.app_name = app_name
        self.make_app = make_app
        self.config = config
        self.geom = geom
        self.use_dbg = use_dbg
        self.max_iters = max_iters
        self.path = path
        # guarded by the service lock: attachment of coalesced twins and
        # the finishing snapshot must be mutually atomic
        self.handles: List[RequestHandle] = []
        self.t_submit = time.perf_counter()


class GraphService:
    """Multi-tenant graph-processing service (request queue + caches).

    Parameters
    ----------
    byte_budget / max_stores: forwarded to the internal
        :class:`GraphStoreCache` (ignored when ``cache=`` is given).
    workers: number of draining threads. 1 gives strict FIFO execution;
        more overlap store builds of different graphs.
    default_geom / default_use_dbg / default_path: per-request
        defaults; each submit() may override.
    max_plans_per_store: bound of each store's plan LRU.
    max_executors: bound of the warm-path Executor LRU. Store and plan
        caches make re-PLANNING cheap, but a fresh Executor re-traces
        the jit'd iteration on every request; caching executors keyed
        like coalescing keys (store, app, config, path) lets warm
        repeats reuse the compiled function. Executors of an evicted
        store are purged with it (they would otherwise keep its device
        arrays alive behind the byte budget's back).
    executor_byte_budget: optional device-byte bound on the same LRU,
        using each Executor's ``memory_footprint()`` (the bundle's
        materialized/packed payload bytes). Executors sharing a plan
        share payloads, so the sum over-attributes shared bytes — it is
        a conservative budget, not an exact accounting. The
        most-recently-inserted executor always stays (a single oversized
        plan must still be servable). NOTE: evicting an executor frees
        its jitted programs immediately, but its payloads stay pinned by
        the store's plan cache until that plan is evicted there — pair
        this budget with ``max_plans_per_store`` (and the store cache's
        ``byte_budget``, which counts those payload bytes) to bound
        actual device memory.
    """

    def __init__(self, *, cache: Optional[GraphStoreCache] = None,
                 byte_budget: Optional[int] = None,
                 max_stores: Optional[int] = None,
                 workers: int = 1,
                 default_geom: Optional[Geometry] = None,
                 default_use_dbg: bool = True,
                 default_path: Optional[str] = None,
                 max_plans_per_store: Optional[int] = None,
                 max_executors: int = 64,
                 executor_byte_budget: Optional[int] = None,
                 metrics: Optional[ServiceMetrics] = None):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if executor_byte_budget is not None and executor_byte_budget < 1:
            raise ValueError("executor_byte_budget must be >= 1, got "
                             f"{executor_byte_budget}")
        self.metrics = metrics or ServiceMetrics()
        self.cache = cache or GraphStoreCache(
            byte_budget=byte_budget, max_stores=max_stores,
            on_evict=self._on_store_evicted)
        self.default_geom = default_geom or Geometry()
        self.default_use_dbg = default_use_dbg
        self.default_path = default_path
        self.max_plans_per_store = max_plans_per_store
        self.max_executors = max_executors
        self.executor_byte_budget = executor_byte_budget
        # key -> (Executor, footprint bytes frozen at insert time)
        self._executors: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()
        self._executor_bytes = 0

        self._queue: "queue.Queue" = queue.Queue()
        self.metrics._queue_depth_fn = self._queue.qsize
        self._lock = threading.Lock()
        self._inflight: Dict[tuple, _Job] = {}
        self._registry: Dict[str, Graph] = {}   # fp -> graph (rebuilds)
        self._next_id = 0
        self._closed = False
        self._workers = [
            threading.Thread(target=self._worker_loop, daemon=True,
                             name=f"graph-serve-{i}")
            for i in range(workers)]
        for w in self._workers:
            w.start()

    # -- lifecycle ------------------------------------------------------
    def __enter__(self) -> "GraphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, wait: bool = True) -> None:
        """Stop accepting work; by default drain the queue and join the
        workers (each worker eats one sentinel and exits). The closed
        flag and the sentinels go in under the service lock, atomically
        with submit()'s enqueue — a racing submit either lands before
        the sentinels (and is drained) or raises ServiceClosed."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for _ in self._workers:
                self._queue.put(_SENTINEL)
        if wait:
            for w in self._workers:
                w.join()
            with self._lock:
                self._executors.clear()
                self._executor_bytes = 0

    # -- registration ---------------------------------------------------
    def register(self, graph: Graph, *, geom: Optional[Geometry] = None,
                 use_dbg: Optional[bool] = None,
                 prepare: bool = True) -> str:
        """Remember a graph so later submits can pass only its
        fingerprint, and (by default) prepare its GraphStore eagerly so
        the first request is a warm hit. Returns the fingerprint."""
        fp = graph.fingerprint()
        with self._lock:
            self._registry[fp] = graph
        if prepare:
            geom = geom or self.default_geom
            use_dbg = (self.default_use_dbg if use_dbg is None
                       else use_dbg)
            skey = store_key(fp, geom, use_dbg)
            self.cache.get_or_build(
                skey, lambda: self._build_store(graph, geom, use_dbg))
        return fp

    def unregister(self, fingerprint: str) -> bool:
        """Forget a registered graph (its cached store, if any, stays
        until normally evicted; it just can't be REBUILT from the
        registry afterwards). Returns whether it was registered."""
        with self._lock:
            return self._registry.pop(fingerprint, None) is not None

    def _on_store_evicted(self, skey: StoreKey, store: GraphStore) -> None:
        """Cache-eviction hook: purge the evicted store's executors so
        they don't keep its device arrays alive past the byte budget.
        In-flight runs still hold their own executor reference and
        finish untouched."""
        self.metrics.record_eviction()
        with self._lock:
            for k in [k for k in self._executors if k[0] == skey]:
                self._drop_executor(k)

    def _drop_executor(self, key) -> None:
        """Remove one cached executor (caller holds the lock)."""
        _, nbytes = self._executors.pop(key)
        self._executor_bytes -= nbytes

    def _trim_executors(self) -> None:
        """Evict LRU executors past the count bound and (when set) the
        byte budget. The count bound is strict (``max_executors=0``
        still disables caching entirely); the byte bound never evicts
        the newest entry — a single oversized plan must stay servable
        (caller holds the lock)."""
        evicted = 0
        while self._executors and (
                len(self._executors) > self.max_executors
                or (self.executor_byte_budget is not None
                    and self._executor_bytes > self.executor_byte_budget
                    and len(self._executors) > 1)):
            self._drop_executor(next(iter(self._executors)))
            evicted += 1
        if evicted:
            self.metrics.record_executor_eviction(evicted)

    def _build_store(self, graph: Graph, geom: Geometry = None,
                     use_dbg: bool = None) -> GraphStore:
        return GraphStore(
            graph,
            geom=geom or self.default_geom,
            use_dbg=self.default_use_dbg if use_dbg is None else use_dbg,
            max_plans=self.max_plans_per_store)

    # -- submission -----------------------------------------------------
    def submit(self, graph: Union[Graph, str, None] = None,
               app: Union[GASApp, str] = "pagerank", *,
               fingerprint: Optional[str] = None,
               app_kwargs: Optional[dict] = None,
               config: Optional[PlanConfig] = None,
               geom: Optional[Geometry] = None,
               use_dbg: Optional[bool] = None,
               max_iters: Optional[int] = None,
               path: Optional[str] = None,
               **cfg) -> RequestHandle:
        """Enqueue one request; returns immediately with a
        :class:`RequestHandle`.

        ``graph`` may be a :class:`Graph`, a fingerprint string, or None
        with ``fingerprint=`` set (the graph must then be registered or
        its store still cached). ``app`` is a builtin name (coalescable;
        parameterize via ``app_kwargs``) or a prebuilt :class:`GASApp`
        (coalesced only with submissions of that same instance — the
        service can't see inside arbitrary closures). Extra kwargs
        become :class:`PlanConfig` fields, as in :func:`repro.api.compile`.

        Submitting a Graph does NOT retain it past the request: if its
        store is later evicted, a fingerprint-only resubmit needs the
        Graph again — or :meth:`register` it once (registered graphs
        are kept until :meth:`unregister` and always rebuildable).
        """
        if config is not None and cfg:
            raise ValueError("pass either config= or PlanConfig kwargs, "
                             "not both")
        config = config or PlanConfig(**cfg)
        geom = geom or self.default_geom
        use_dbg = self.default_use_dbg if use_dbg is None else bool(use_dbg)
        path = path or self.default_path

        graph_obj = graph if isinstance(graph, Graph) else None
        fp = resolve_fingerprint(graph, fingerprint)
        skey = store_key(fp, geom, use_dbg)

        app_name, app_token, make_app = _normalize_app(app, app_kwargs)
        if graph_obj is None:
            # NOTE: no auto-registration on the Graph path — only
            # register() pins graphs on the service, so serving many
            # distinct graphs can't grow host memory behind the store
            # cache's byte budget
            with self._lock:
                graph_obj = self._registry.get(fp)
            if graph_obj is None and skey not in self.cache:
                raise KeyError(
                    f"fingerprint {fp[:12]}… is neither registered nor "
                    f"cached; pass the Graph or register() it first")

        job_key = (skey, app_token, config.cache_key(), max_iters, path)
        with self._lock:
            # closed-check is atomic with the enqueue: close() inserts
            # its sentinels under this same lock, so a submit can never
            # land a job behind them (which no worker would ever drain)
            if self._closed:
                raise ServiceClosed("submit() after close()")
            self._next_id += 1
            rid = self._next_id
            job = self._inflight.get(job_key)
            coalesced = job is not None
            m = RequestMetrics(request_id=rid, app=app_name,
                               fingerprint=fp, coalesced=coalesced)
            handle = RequestHandle(rid, m)
            if coalesced:
                # piggyback on the identical in-flight job; its single
                # execution resolves every attached handle
                job.handles.append(handle)
            else:
                job = _Job(job_key, skey, graph_obj, app_name, make_app,
                           config, geom, use_dbg, max_iters, path)
                job.handles.append(handle)
                self._inflight[job_key] = job
                self._queue.put(job)
        self.metrics.record_submit(coalesced)
        return handle

    def run(self, graph=None, app="pagerank", *, timeout=None, **kw):
        """Synchronous convenience: submit + wait."""
        return self.submit(graph, app, **kw).result(timeout=timeout)

    # -- worker ---------------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            job = self._queue.get()
            if job is _SENTINEL:
                return
            try:
                self._execute(job)
            except BaseException as exc:   # never kill the worker
                self._finish(job, error=exc)

    def _execute(self, job: _Job) -> None:
        t_pickup = time.perf_counter()
        t_queue_ms = (t_pickup - job.t_submit) * 1e3

        def builder():
            if job.graph is None:
                raise KeyError(
                    f"store for {job.skey[0][:12]}… was evicted and the "
                    f"graph is not registered; re-submit with the Graph")
            return self._build_store(job.graph, job.geom, job.use_dbg)

        # max_iters is a run() argument, not executor state, so it is
        # deliberately absent from the executor key (unlike the job key)
        exec_key = (job.skey, job.key[1], job.config.cache_key(), job.path)
        t0 = time.perf_counter()
        with self.cache.lease(job.skey, builder) as (store, store_hit):
            t_store_ms = (time.perf_counter() - t0) * 1e3

            with self._lock:
                hit = self._executors.get(exec_key)
                if hit is not None:
                    self._executors.move_to_end(exec_key)
            if hit is not None:
                ex, plan_hit, t_plan_ms = hit[0], True, 0.0
            else:
                plan_hit = store.has_plan(job.config)
                t0 = time.perf_counter()
                bundle = store.plan(job.config)
                t_plan_ms = (time.perf_counter() - t0) * 1e3
                ex = Executor(store, bundle, job.make_app(),
                              path=job.path)
                nbytes = ex.memory_footprint()
                with self._lock:
                    if exec_key in self._executors:
                        self._drop_executor(exec_key)   # racing build won
                    self._executors[exec_key] = (ex, nbytes)
                    self._executor_bytes += nbytes
                    self._trim_executors()

            t0 = time.perf_counter()
            result = ex.run(max_iters=job.max_iters)
            t_execute_ms = (time.perf_counter() - t0) * 1e3

        self.metrics.record_execution(store_hit, plan_hit)
        self._finish(job, result=result, store_hit=store_hit,
                     plan_hit=plan_hit, t_queue_ms=t_queue_ms,
                     t_store_ms=t_store_ms, t_plan_ms=t_plan_ms,
                     t_execute_ms=t_execute_ms)

    def _finish(self, job: _Job, result=None, error=None, store_hit=None,
                plan_hit=None, t_queue_ms=None, t_store_ms=None,
                t_plan_ms=None, t_execute_ms=None) -> None:
        # unlink and snapshot the handle list atomically: a twin either
        # attaches before this (and is resolved below) or finds the job
        # gone and starts a fresh execution — never lost in between
        with self._lock:
            self._inflight.pop(job.key, None)
            handles = list(job.handles)
        now = time.perf_counter()
        for h in handles:
            m = h.metrics
            m.store_hit = store_hit
            m.plan_hit = plan_hit
            # each handle gets ITS OWN end-to-end latency; the stage
            # breakdown describes the one execution, so it lands only on
            # the request that triggered it — coalesced twins keep the
            # documented None stages (they did not queue/build/run)
            m.t_total_ms = (now - h._t_submit) * 1e3
            if not m.coalesced:
                m.t_queue_ms = t_queue_ms
                m.t_store_ms = t_store_ms
                m.t_plan_ms = t_plan_ms
                m.t_execute_ms = t_execute_ms
            if error is not None:
                m.error = "".join(traceback.format_exception_only(
                    type(error), error)).strip()
                self.metrics.record_done(m)
                h._set_exception(error)
            else:
                self.metrics.record_done(m)
                h._set_result(result)

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            n_exec = len(self._executors)
            exec_bytes = self._executor_bytes
        return {
            "service": self.metrics.snapshot(),
            "store_cache": self.cache.stats(),
            "registered_graphs": len(self._registry),
            "cached_executors": n_exec,
            "executor_bytes": exec_bytes,
            "executor_byte_budget": self.executor_byte_budget,
        }


def _normalize_app(app: Union[GASApp, str],
                   app_kwargs: Optional[dict]
                   ) -> Tuple[str, tuple, "callable"]:
    """Return (display name, coalescing token, zero-arg factory).

    Builtin apps submitted by name coalesce on (name, kwargs); a
    prebuilt GASApp instance coalesces only with itself (its parameters
    live in closures the service can't inspect, and GASApp instances
    are stateless across runs, so sharing the instance is safe).
    """
    if isinstance(app, str):
        if app not in BUILTIN_APPS:
            raise ValueError(f"unknown builtin app {app!r}; available: "
                             f"{sorted(BUILTIN_APPS)}")
        kwargs = dict(app_kwargs or {})
        token = ("builtin", app,
                 tuple((k, _hashable(v)) for k, v in sorted(kwargs.items())))
        return app, token, lambda: BUILTIN_APPS[app](**kwargs)
    if app_kwargs:
        raise ValueError("app_kwargs only apply to builtin app names")
    return app.name, ("instance", id(app)), lambda: app


def _hashable(v):
    """Coalescing keys must hash; app kwargs may hold numpy arrays
    (e.g. closeness ``sources``) or lists — fold them to value-equal
    hashable forms."""
    if isinstance(v, np.ndarray):
        return ("ndarray", v.shape, str(v.dtype), v.tobytes())
    if isinstance(v, (list, tuple)):
        return tuple(_hashable(x) for x in v)
    return v
