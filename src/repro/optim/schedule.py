"""Learning-rate schedules (pure fns of the int step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak=3e-4, warmup=1000, total=100_000, floor=0.1):
    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak * jnp.minimum(warm, cos)
    return f


def constant(lr=3e-4):
    return lambda step: jnp.float32(lr)
