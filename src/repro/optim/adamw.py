"""AdamW with dtype-configurable moment states (pure-pytree, optax-free)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable        # params -> state
    update: Callable      # (grads, state, params) -> (new_params, new_state)

    def state_specs(self, params):
        return jax.eval_shape(self.init, params)


def adamw(lr: Any = 3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          state_dtype=None, grad_clip: Optional[float] = 1.0) -> Optimizer:
    """lr may be a float or a schedule fn(step)->float."""

    def init(params):
        z = lambda p: jnp.zeros_like(
            p, dtype=state_dtype or jnp.result_type(p, jnp.float32))
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        if grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(m.dtype)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * jnp.square(gf)
            mhat = m2.astype(jnp.float32) / bc1
            vhat = v2.astype(jnp.float32) / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay \
                * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), \
                m2, v2

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update)


def sgd_momentum(lr=0.1, momentum=0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        lr_t = lr(state["step"] + 1) if callable(lr) else lr
        m = jax.tree.map(lambda m, g: momentum * m + g, state["m"], grads)
        p = jax.tree.map(lambda p, m: (p - lr_t * m).astype(p.dtype),
                         params, m)
        return p, {"m": m, "step": state["step"] + 1}

    return Optimizer(init, update)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
