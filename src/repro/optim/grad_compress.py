"""Error-feedback gradient compression for the cross-pod all-reduce.

Within a pod the ICI fabric (~50 GB/s/link) absorbs full-precision
reduce-scatters; *between* pods the DCN/ICI-bridge is the thin pipe. The
framework therefore reduces within a pod at full precision (GSPMD
collectives) and crosses pods with compressed payloads + error feedback
(residual carried to the next step, provably convergent for smooth
objectives — Karimireddy et al. 2019).

Two codecs:
  int8    — per-tensor max-scaled linear quantisation (4x compression)
  topk    — magnitude top-k with bitmap-free (index,value) pairs
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def int8_encode(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q, scale):
    return q.astype(jnp.float32) * scale


def topk_encode(x, k_frac=0.05):
    xf = x.astype(jnp.float32).reshape(-1)
    k = max(1, int(xf.shape[0] * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(xf), k)
    sel = xf[idx]
    return sel, idx.astype(jnp.int32)


def topk_decode(vals, idx, shape):
    out = jnp.zeros((int(jnp.prod(jnp.asarray(shape))),), jnp.float32)
    return out.at[idx].set(vals).reshape(shape)


def compressed_psum(grads, residual, axis_name, codec="int8", k_frac=0.05):
    """All-reduce ``grads`` over ``axis_name`` with error feedback.

    Call INSIDE shard_map over the pod axis. Returns (reduced, residual').
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if codec == "int8":
            q, scale = int8_encode(gf)
            deq = int8_decode(q, scale)
            # payload crossing pods: int8 tensor + scalar scale
            red = jax.lax.psum(deq, axis_name)
        elif codec == "topk":
            vals, idx = topk_encode(gf, k_frac)
            deq = topk_decode(vals, idx, gf.shape)
            red = jax.lax.psum(deq, axis_name)
        else:
            deq = gf
            red = jax.lax.psum(gf, axis_name)
        new_r = gf - deq
        return red.astype(g.dtype), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def zero_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(codec="int8", k_frac=0.05, dtype_bits=32) -> float:
    if codec == "int8":
        return dtype_bits / 8.0
    if codec == "topk":
        return 1.0 / (k_frac * (1 + 32.0 / dtype_bits))
    return 1.0
