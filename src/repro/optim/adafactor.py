"""Adafactor (Shazeer & Stern 2018) — factored second moments.

Memory: O(r+c) per (r,c) matrix instead of O(r*c); the only optimizer
that fits the 1T-param kimi-k2 config on 512 x 16 GB chips (DESIGN.md).
No first moment (beta1=0 variant).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .adamw import Optimizer, global_norm


def adafactor(lr=1e-3, decay=0.8, eps1=1e-30, eps2=1e-3,
              clip_threshold=1.0, weight_decay=0.0) -> Optimizer:
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def st(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"s": jax.tree.map(st, params,
                                  is_leaf=lambda x: hasattr(x, "ndim")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(p, g, s):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps1
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True)[..., None], eps1)) \
                    * vc[..., None, :]
                u = gf * jax.lax.rsqrt(jnp.maximum(denom, eps1))
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(jnp.maximum(v, eps1))
                ns = {"v": v}
            # relative update clipping
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            scale = jnp.maximum(
                eps2, jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))))
            new_p = p.astype(jnp.float32) - lr_t * scale * u
            if weight_decay:
                new_p = new_p - lr_t * weight_decay * p.astype(jnp.float32)
            return new_p.astype(p.dtype), ns

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["s"])
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_s = tdef.unflatten([o[1] for o in outs])
        return new_params, {"s": new_s, "step": step}

    return Optimizer(init, update)
