"""ControlPlane — a GraphService with job records and an HTTP face.

Ties the pieces together: every submission becomes a
:class:`~repro.control.jobs.JobRecord` whose lifecycle is driven by
the service's observer callbacks (queued → running → done/failed/
expired), results are fetched by job id, and the whole thing exposes
one merged metrics snapshot (service + scheduler + pool + store cache
+ job store) for ``GET /metrics``. The service can be passed in (the
control plane then shares it and leaves closing it to the owner) or
constructed from kwargs (owned, closed with the plane).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

from .. import obs
from ..graphs.formats import Graph
from ..serve_graph.metrics import merge_expositions
from ..serve_graph.service import GraphService, RequestHandle
from ..streaming import GraphDelta
from .jobs import JobRecord, JobState, JobStore
from .scheduler import QueueFull, RejectedJob

__all__ = ["ControlPlane"]

# observer event -> job state (shed maps to EXPIRED: the deadline
# passed; cancelled is driven by cancel_job, not the observer)
_EVENT_STATE = {
    "queued": JobState.QUEUED,
    "running": JobState.RUNNING,
    "done": JobState.DONE,
    "failed": JobState.FAILED,
    "shed": JobState.EXPIRED,
    "cancelled": JobState.CANCELLED,
}


class ControlPlane:
    """Job-oriented management layer over a :class:`GraphService`.

    Parameters
    ----------
    service: an existing service to manage (not closed by this plane);
        None builds one from ``service_kwargs`` (owned).
    jobs: a :class:`JobStore` (e.g. with ``persist_path`` set); None
        builds a default one.
    tracer: the :class:`~repro.obs.Tracer` for end-to-end job traces.
        None reuses the service's tracer, or installs a fresh one on a
        service that has none — the plane always traces, so
        ``GET /jobs/{id}/trace`` works out of the box.
    """

    def __init__(self, service: Optional[GraphService] = None, *,
                 jobs: Optional[JobStore] = None,
                 tracer: Optional[obs.Tracer] = None, **service_kwargs):
        self._owns_service = service is None
        if service is None and tracer is not None:
            service_kwargs.setdefault("tracer", tracer)
        self.service = service or GraphService(**service_kwargs)
        if self.service.tracer is None:
            self.service.tracer = tracer or obs.Tracer()
        self.tracer = self.service.tracer
        self.jobs = jobs or JobStore()
        self._lock = threading.Lock()
        self._handles: Dict[str, RequestHandle] = {}
        self._http_server = None

    # -- lifecycle ------------------------------------------------------
    def __enter__(self) -> "ControlPlane":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, wait: bool = True) -> None:
        if self._http_server is not None:
            self._http_server.shutdown()
            self._http_server = None
        if self._owns_service:
            self.service.close(wait=wait)

    # -- job submission -------------------------------------------------
    def register(self, graph: Graph, **kw) -> str:
        return self.service.register(graph, **kw)

    def submit_job(self, graph=None, app: str = "pagerank", *,
                   fingerprint: Optional[str] = None,
                   tenant: str = "default", priority: int = 0,
                   deadline: Optional[float] = None,
                   **submit_kwargs) -> JobRecord:
        """Submit a run as a tracked job. Returns its record
        immediately; fetch the outcome with :meth:`result`. Admission
        rejections and bad requests still raise (typed), but the
        record survives in state ``rejected``/``failed`` so the
        refusal is queryable afterwards."""
        t_submit = time.time()
        rec = self.jobs.create(
            kind="run", tenant=tenant, priority=priority,
            deadline=deadline, app=app if isinstance(app, str) else app.name,
            fingerprint=(fingerprint if fingerprint is not None
                         else graph if isinstance(graph, str) else None))
        jid = rec.id
        handle_stored = threading.Event()

        def observer(event: str, info: dict) -> None:
            state = _EVENT_STATE.get(event)
            if event == "coalesced":
                self.jobs.mark_coalesced(jid)
                self.jobs.transition(jid, JobState.QUEUED,
                                     log="queued (coalesced)")
            elif state is not None:
                metrics = None
                if state in JobState.TERMINAL:
                    # a job can finish before submit_job() stores the
                    # handle — wait for it so terminal records always
                    # carry their request metrics
                    handle_stored.wait(5.0)
                    metrics = self._metrics_of(jid)
                self.jobs.transition(jid, state,
                                     error=info.get("error"),
                                     metrics=metrics)
        try:
            handle = self.service.submit(
                graph, app, fingerprint=fingerprint, tenant=tenant,
                priority=priority, deadline=deadline, observer=observer,
                **submit_kwargs)
        except RejectedJob as exc:
            kind = ("queue full" if isinstance(exc, QueueFull)
                    else "quota exceeded")
            self.jobs.transition(jid, JobState.REJECTED, error=str(exc),
                                 log=f"rejected at admission: {kind}")
            raise
        except Exception as exc:
            self.jobs.transition(jid, JobState.FAILED, error=str(exc))
            raise
        ctx = getattr(handle, "trace_ctx", None)
        if ctx is not None:
            self.jobs.set_trace(jid, ctx.trace_id)
            # backdated so the span covers record creation + admission
            self.tracer.start_span("control.submit", "control", parent=ctx,
                                   t_start=t_submit, job_id=jid).end()
        with self._lock:
            self._handles[jid] = handle
        handle_stored.set()
        with self._lock:
            if len(self._handles) > 4 * self.jobs.max_records:
                # results of long-forgotten jobs: drop oldest resolved
                for k in list(self._handles):
                    if len(self._handles) <= self.jobs.max_records:
                        break
                    if self._handles[k].done():
                        del self._handles[k]
        return rec

    def _metrics_of(self, job_id: str) -> Optional[dict]:
        with self._lock:
            h = self._handles.get(job_id)
        return h.metrics.as_dict() if h is not None else None

    def result(self, job_id: str, timeout: Optional[float] = None):
        """Block for a job's (props, meta); raises its failure (typed
        scheduler errors included) like ``RequestHandle.result``."""
        with self._lock:
            h = self._handles.get(job_id)
        if h is None:
            raise KeyError(f"unknown or unretained job {job_id!r}")
        return h.result(timeout=timeout)

    def cancel_job(self, job_id: str) -> bool:
        with self._lock:
            h = self._handles.get(job_id)
        if h is None or not self.service.cancel(h):
            return False
        self.jobs.transition(job_id, JobState.CANCELLED,
                             error="cancelled",
                             log="cancelled by request")
        return True

    # -- streaming updates as jobs --------------------------------------
    def update_job(self, fingerprint: str, delta: GraphDelta,
                   *, tenant: str = "default", **kw) -> JobRecord:
        """Run a streaming update synchronously as a tracked job (an
        update re-keys shared cache state; callers need the new
        fingerprint before their next submit, so there is no async
        form). The record's metrics carry the apply stats."""
        rec = self.jobs.create(kind="update", tenant=tenant,
                               app="update", fingerprint=fingerprint)
        self.jobs.transition(rec.id, JobState.RUNNING)
        try:
            res = self.service.update(fingerprint, delta, **kw)
        except Exception as exc:
            self.jobs.transition(rec.id, JobState.FAILED, error=str(exc))
            raise
        self.jobs.set_trace(rec.id, res.trace_id)
        self.jobs.transition(
            rec.id, JobState.DONE,
            metrics={"fingerprint": res.fingerprint, "mode": res.mode,
                     "retired": res.retired,
                     "t_update_ms": res.t_update_ms,
                     "stats": res.stats},
            log=f"update applied: {fingerprint[:12]}… -> "
                f"{res.fingerprint[:12]}… ({res.mode})")
        return self.jobs.get(rec.id)

    def compact_job(self, fingerprint: str, *,
                    tenant: str = "default") -> JobRecord:
        """Squash the delta chain behind a served snapshot
        (GraphService.compact_chain) as a tracked admin job; the
        record's metrics carry the before/after chain depth and the
        composed delta's change count."""
        rec = self.jobs.create(kind="compact", tenant=tenant,
                               app="compact", fingerprint=fingerprint)
        self.jobs.transition(rec.id, JobState.RUNNING)
        try:
            event = self.service.compact_chain(fingerprint)
        except Exception as exc:
            self.jobs.transition(rec.id, JobState.FAILED, error=str(exc))
            raise
        self.jobs.transition(
            rec.id, JobState.DONE, metrics=event,
            log=(f"chain compacted: depth {event['depth_before']} -> "
                 f"{event['depth_after']}") if event.get("compacted")
                else f"nothing to compact (depth "
                     f"{event['depth_before']})")
        return self.jobs.get(rec.id)

    def regroup_job(self, graph=None, *,
                    fingerprint: Optional[str] = None,
                    tenant: str = "default", force: bool = False,
                    **kw) -> JobRecord:
        """Run a grouping-drift check — and, past the threshold or
        with ``force=True``, the fresh-DBG re-registration swap
        (GraphService.regroup_now) — as a tracked admin job. The
        record's metrics carry the drift event (misclassification
        rate, dense frontier before/after, applied flag)."""
        rec = self.jobs.create(kind="regroup", tenant=tenant,
                               app="regroup",
                               fingerprint=fingerprint or "")
        self.jobs.transition(rec.id, JobState.RUNNING)
        try:
            event = self.service.regroup_now(graph,
                                             fingerprint=fingerprint,
                                             force=force, **kw)
        except Exception as exc:
            self.jobs.transition(rec.id, JobState.FAILED, error=str(exc))
            raise
        self.jobs.transition(
            rec.id, JobState.DONE, metrics=event,
            log=(f"regroup applied: drift {event['drift']:.3f}")
                if event.get("applied")
                else f"regroup skipped: drift {event['drift']:.3f} "
                     f"under threshold")
        return self.jobs.get(rec.id)

    def retune_job(self, graph=None, *, fingerprint: Optional[str] = None,
                   app: str = "pagerank", tenant: str = "default",
                   **kw) -> JobRecord:
        """Force a calibrate-and-replan cycle (GraphService.retune_now)
        as a tracked admin job. Requires the service to have been built
        with ``autotune=``; the record's metrics carry the retune event
        (fit diagnostics, candidate scores, chosen plan)."""
        rec = self.jobs.create(kind="retune", tenant=tenant, app=app,
                               fingerprint=fingerprint or "")
        self.jobs.transition(rec.id, JobState.RUNNING)
        try:
            event = self.service.retune_now(graph, fingerprint=fingerprint,
                                            app=app, **kw)
        except Exception as exc:
            self.jobs.transition(rec.id, JobState.FAILED, error=str(exc))
            raise
        chosen = event.get("chosen") or {}
        self.jobs.transition(
            rec.id, JobState.DONE, metrics=event,
            log=("retune applied: " + str(chosen)) if event.get("applied")
                else f"retune rejected: {event.get('rejected')}")
        return self.jobs.get(rec.id)

    # -- reporting ------------------------------------------------------
    def metrics_snapshot(self) -> dict:
        snap = self.service.stats()
        snap["jobs"] = self.jobs.stats()
        return snap

    def ready(self) -> dict:
        """Readiness probe body for ``GET /readyz``: the plane can take
        and execute work — the scheduler is accepting submissions AND
        (when a process pool exists) every pool worker slot is usable.
        Liveness (``/healthz``) stays unconditional; this is the
        load-balancer signal to stop routing before close()."""
        accepting = self.service.accepting
        pool = self.service._pool
        pool_alive = pool.alive() if pool is not None else True
        return {
            "ready": bool(accepting and pool_alive),
            "scheduler_accepting": bool(accepting),
            "pool_alive": bool(pool_alive),
            "queue_depth": int(
                self.service._scheduler.stats()["depth"]),
        }

    def trace(self, job_id: str) -> Optional[dict]:
        """The job's distributed trace as a Chrome-trace dict (load it
        at ``chrome://tracing`` or https://ui.perfetto.dev), or None if
        the job is unknown, predates tracing, or its trace was evicted
        from the tracer's bounded ring."""
        rec = self.jobs.get(job_id)
        if rec is None or rec.trace_id is None:
            return None
        if rec.trace_id not in self.tracer.trace_ids():
            return None
        return self.tracer.to_chrome_trace(trace_id=rec.trace_id)

    def prometheus(self) -> str:
        """Service metrics in Prometheus text form, merged with the
        control-plane gauges (scheduler depth, pool and job-store
        state) into one exposition — families are deduped so a scraper
        never sees a repeated HELP/TYPE header."""
        sched = self.service._scheduler.stats()
        blocks = [self.service.metrics.render_prometheus(),
                  "# HELP regraph_scheduler_depth Queued jobs.\n"
                  "# TYPE regraph_scheduler_depth gauge\n"
                  f"regraph_scheduler_depth {sched['depth']}\n"]
        pool = self.service._pool
        if pool is not None:
            p = pool.stats()
            blocks.append("# HELP regraph_pool_jobs_total Jobs run in "
                          "the process pool.\n"
                          "# TYPE regraph_pool_jobs_total counter\n"
                          f"regraph_pool_jobs_total {p['jobs']}\n"
                          "# HELP regraph_pool_crashes_total Worker "
                          "process crashes.\n"
                          "# TYPE regraph_pool_crashes_total counter\n"
                          f"regraph_pool_crashes_total {p['crashes']}\n")
        j = self.jobs.stats()
        job_lines = ["# HELP regraph_jobs Jobs by lifecycle state.",
                     "# TYPE regraph_jobs gauge"]
        for state, n in sorted(j["by_state"].items()):
            job_lines.append(f'regraph_jobs{{state="{state}"}} {n}')
        blocks.append("\n".join(job_lines) + "\n")
        return merge_expositions(*blocks)

    # -- HTTP -----------------------------------------------------------
    def serve_http(self, host: str = "127.0.0.1", port: int = 0):
        """Start the JSON job API on a daemon thread; returns
        ``(server, base_url)``. ``port=0`` picks a free port."""
        from .http_api import serve_jobs
        server, url = serve_jobs(self, host=host, port=port)
        self._http_server = server
        return server, url
