"""Process-pool worker tier for CPU-heavy control-plane jobs.

The GIL is why ROADMAP item 3 exists: a GraphStore build or delta
splice is seconds of hot numpy in a worker *thread*, and every one of
those seconds steals timeslices from ``GraphService.update()`` and the
jit'd execution path in the same process. This module moves exactly
those two job kinds — store builds and delta splices — into worker
*processes*, while plan rebuilds and execution stay on in-process
threads (they are jax-side and hold device state that must not cross a
process boundary).

Job specs are pickle-safe and keyed by graph fingerprint:

* **build** ships the Graph and gets back a built
  :class:`~repro.core.store.GraphStore` (its ``__getstate__`` drops
  locks, plan cache and jax aux — the parent re-plans, which the
  carried blockings make cheap). The worker retains the store in a
  small per-process cache keyed ``(fp, geom, use_dbg)``.
* **apply** ships only the delta plus the base key. A worker that
  already holds the base (it applied the previous delta in the chain)
  splices without any graph bytes on the wire; one that doesn't
  answers ``need_state`` and the parent retries once, shipping the
  pickled base store. The result is the *splice-only*
  :class:`~repro.streaming.DeltaApplyResult` — the parent runs
  :func:`~repro.streaming.rebuild_plans` itself, because the packed
  device payloads being carried over live in the parent.

**Heterogeneous lanes.** The pool is N single-process executors, not
one N-process executor, split by workload class the same way the
paper splits pipelines: applies are latency-critical and small,
builds are throughput work that runs for whole seconds — so worker 0
is the dedicated **apply lane** and workers 1..N-1 are **build
lanes**. Mixing them (one shared executor) puts a 5 ms splice in line
behind a 2 s build and the update tail latency becomes the build
duration; it also scatters a snapshot chain across processes, missing
the worker-side cache (and re-shipping the pickled base) on every
other call. With the split, a chained update stream pays one base
ship ever, then stays warm on its lane. A single-worker pool shares
the one process between both classes.

Failure containment: a worker dying mid-job (OOM-kill, segfault,
``os._exit``) breaks its executor, so :class:`WorkerPool` converts
that into a :class:`WorkerCrashed` for the one in-flight job and
respawns just that slot — the pool survives (a fresh apply-lane
process simply re-ships state on first use), and the serving layer's
cache lease for the failed job is released by its normal
builder-failure path (the lease-on-crash regression test in
tests/test_control_plane.py holds this).

The default mp context is **spawn**: fork would snapshot the parent's
jax runtime state into children, which is both large and unsafe with
live device handles.
"""
from __future__ import annotations

import collections
import multiprocessing
import threading
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Optional

from ..core.store import GraphStore
from ..core.types import Geometry
from ..graphs.formats import Graph
from ..streaming.apply import BULK_THRESHOLD, DeltaApplyResult, splice_delta
from ..streaming.delta import GraphDelta

__all__ = ["WorkerPool", "WorkerCrashed"]


class WorkerCrashed(RuntimeError):
    """The worker process executing this job died before finishing.
    The pool has already respawned; the job itself is NOT retried
    (a crash is evidence the job kills workers)."""


# ---------------------------------------------------------------------
# worker-side (runs in the child processes; module-level for pickling)
# ---------------------------------------------------------------------

_STORE_CACHE: "collections.OrderedDict[tuple, GraphStore]" = \
    collections.OrderedDict()
_STORE_CACHE_MAX = 8


def _w_cache_put(key: tuple, store: GraphStore) -> None:
    _STORE_CACHE[key] = store
    _STORE_CACHE.move_to_end(key)
    while len(_STORE_CACHE) > _STORE_CACHE_MAX:
        _STORE_CACHE.popitem(last=False)


def _w_ping() -> bool:
    return True


def _w_crash() -> None:
    """Test hook: die the way a segfault/OOM-kill does (no exception,
    no cleanup — the parent sees a broken pool)."""
    import os
    os._exit(13)


def _w_traced(root_name: str, fn):
    """Run ``fn`` under a throwaway worker-local tracer and return
    ``(result, ended spans as dicts)`` — the trace carrier across the
    pickled process boundary. Span ids are uuid-based (collision-free
    across processes) and starts are epoch time, so the parent's
    :meth:`~repro.obs.Tracer.adopt` can splice them straight into the
    submitting job's trace."""
    import os

    from ..obs import Tracer
    tracer = Tracer()
    root = tracer.start_trace(root_name, "pool-worker", pid=os.getpid())
    try:
        with tracer.activate(root.context):
            result = fn()
    finally:
        root.end()
    return result, tracer.export(root.trace_id)


def _w_build_store(graph: Graph, geom: Geometry, use_dbg: bool,
                   fp: Optional[str], max_plans: Optional[int],
                   crash: bool = False, trace: bool = False):
    if crash:
        _w_crash()

    def build() -> GraphStore:
        store = GraphStore(graph, geom=geom, use_dbg=use_dbg,
                           max_plans=max_plans, fingerprint=fp)
        _w_cache_put((store.fingerprint(), geom, use_dbg), store)
        return store

    if trace:
        return _w_traced("pool.worker.build", build)
    return build()


def _w_apply_delta(key: tuple, delta: GraphDelta, bulk_threshold,
                   base_store: Optional[GraphStore],
                   crash: bool = False, trace: bool = False):
    if crash:
        _w_crash()
    store = base_store if base_store is not None else _STORE_CACHE.get(key)
    if store is None:
        return ("need_state", None, []) if trace else ("need_state", None)

    def apply() -> DeltaApplyResult:
        res = splice_delta(store, delta, bulk_threshold=bulk_threshold)
        _w_cache_put(key, store)                   # base stays reusable
        _w_cache_put((res.fingerprint, key[1], key[2]), res.store)
        return res

    if trace:
        res, spans = _w_traced("pool.worker.apply", apply)
        return "ok", res, spans
    return "ok", apply()


# ---------------------------------------------------------------------
# parent-side
# ---------------------------------------------------------------------

class WorkerPool:
    """Lane-split, respawning process pool for store builds and delta
    splices.

    Parameters
    ----------
    workers: child process count. With 2+, worker 0 is the dedicated
        apply lane and the rest are build lanes (see the module
        docstring); with 1, both job kinds share the process.
    mp_context: multiprocessing start method (default ``"spawn"``).
    warm: submit a no-op to every worker at construction so the first
        real job doesn't pay interpreter start + import latency.
    """

    _APPLY_LANE = 0

    def __init__(self, workers: int = 2, mp_context: str = "spawn",
                 warm: bool = False):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.workers = workers
        self._ctx = multiprocessing.get_context(mp_context)
        self._lock = threading.Lock()
        self._execs = [self._spawn() for _ in range(workers)]
        self._inflight = [0] * workers
        self._build_lanes = (list(range(1, workers)) if workers > 1
                             else [0])
        self._closed = False
        self.jobs = 0
        self.crashes = 0
        self.need_state_retries = 0
        if warm:
            self.warm()

    def _spawn(self) -> ProcessPoolExecutor:
        return ProcessPoolExecutor(max_workers=1, mp_context=self._ctx)

    def warm(self) -> None:
        """Block until every worker process is up (spawn cost is paid
        here, not on the first build)."""
        futs = [ex.submit(_w_ping) for ex in list(self._execs)]
        for f in futs:
            f.result()

    def _run(self, idx: int, fn, /, *args):
        """Submit + await one job on worker ``idx``; a broken executor
        becomes WorkerCrashed for THIS job and a fresh process (with a
        cold cache) in that slot for the next one."""
        with self._lock:
            if self._closed:
                raise RuntimeError("WorkerPool is closed")
            ex = self._execs[idx]
            self.jobs += 1
            self._inflight[idx] += 1
        try:
            return ex.submit(fn, *args).result()
        except BrokenProcessPool as exc:
            with self._lock:
                self.crashes += 1
                if self._execs[idx] is ex and not self._closed:
                    self._execs[idx] = self._spawn()   # pool survives
            raise WorkerCrashed(
                f"worker process died while running {fn.__name__}; the "
                f"pool respawned but this job is not retried") from exc
        finally:
            with self._lock:
                self._inflight[idx] -= 1

    # -- job kinds ------------------------------------------------------
    def build_store(self, graph: Graph, *, geom: Geometry, use_dbg: bool,
                    fp: Optional[str] = None,
                    max_plans: Optional[int] = None,
                    _crash: bool = False, trace: bool = False):
        """Build a GraphStore in a build-lane worker process (the
        least-loaded one). The returned store has no plans and no locks
        attached (see ``GraphStore.__getstate__``); the parent plans on
        it lazily as usual. With ``trace=True`` the worker records its
        build stages into a local tracer and the call returns
        ``(store, span dicts)`` for the parent to
        :meth:`~repro.obs.Tracer.adopt`."""
        with self._lock:
            idx = min(self._build_lanes, key=lambda i: self._inflight[i])
        return self._run(idx, _w_build_store, graph, geom, use_dbg, fp,
                         max_plans, _crash, trace)

    def apply(self, store: GraphStore, delta: GraphDelta, *,
              bulk_threshold=BULK_THRESHOLD,
              _crash: bool = False, trace: bool = False):
        """Splice ``delta`` against ``store`` in the apply-lane worker
        and return the splice-only result (no plans rebuilt — run
        :func:`repro.streaming.rebuild_plans` in the parent). The lane
        never queues behind builds, and holds each snapshot chain in
        its cache: the first touch of a lineage ships the pickled base
        once, every later delta travels alone. With ``trace=True``
        returns ``(result, span dicts)`` — spans from BOTH calls when a
        ``need_state`` retry re-ships the base."""
        key = (store.fingerprint(), store.geom, store.use_dbg)
        idx = self._APPLY_LANE
        out = self._run(idx, _w_apply_delta, key, delta,
                        bulk_threshold, None, _crash, trace)
        status, res, spans = out if trace else (*out, None)
        if status == "need_state":
            with self._lock:
                self.need_state_retries += 1
            out = self._run(idx, _w_apply_delta, key, delta,
                            bulk_threshold, store, _crash, trace)
            if trace:
                status, res, retry_spans = out
                spans = list(spans) + list(retry_spans)
            else:
                status, res = out
        assert status == "ok"
        return (res, spans) if trace else res

    def alive(self) -> bool:
        """True while every worker slot is usable: the pool is open and
        no executor is broken awaiting its next-job respawn. The pool
        half of the control plane's readiness probe — a crashed slot
        flips this False only for the instant before ``_run`` respawns
        it, so a persistent False means the pool is closed or a respawn
        failed."""
        with self._lock:
            if self._closed:
                return False
            return all(not getattr(ex, "_broken", False)
                       for ex in self._execs)

    # -- lifecycle ------------------------------------------------------
    def close(self, wait: bool = True) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            execs = list(self._execs)
        for ex in execs:
            ex.shutdown(wait=wait)

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        with self._lock:
            return {"workers": self.workers, "jobs": self.jobs,
                    "crashes": self.crashes,
                    "need_state_retries": self.need_state_retries}
