"""Persistent job-status records for the control plane.

Every submitted job gets a :class:`JobRecord` that tracks its lifecycle

    submitted → queued → running → done
                   \\          \\→ failed
                    \\→ expired (deadline load-shed)
                    \\→ cancelled
    (rejected: refused at admission, never queued)

with a per-stage timestamp for each transition, a bounded log buffer
(each line stamped ``[<epoch seconds>] <LEVEL> <message>`` so lines
from different processes/machines sort and diff without timezone
games), and — once terminal — the request's latency/cache metrics. The
:class:`JobStore` holds the records thread-safely, bounds retention by
evicting the oldest *terminal* records, serves chunked log reads for
the HTTP API's streaming endpoint, and can mirror terminal records to
a JSONL file so job history survives the process (the "persistent" in
persistent job-status store; modeled on Ray's dashboard job records).
"""
from __future__ import annotations

import json
import threading
import time
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Tuple

__all__ = ["JobRecord", "JobState", "JobStore"]


class JobState:
    """String constants for the lifecycle states (kept as plain strings
    so records JSON-serialize without an enum layer)."""

    SUBMITTED = "submitted"
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"
    EXPIRED = "expired"          # deadline load-shed while queued
    REJECTED = "rejected"        # typed admission refusal; never queued

    TERMINAL = frozenset({DONE, FAILED, CANCELLED, EXPIRED, REJECTED})
    ALL = frozenset({SUBMITTED, QUEUED, RUNNING}) | TERMINAL


_RANK = {JobState.SUBMITTED: 0, JobState.QUEUED: 1, JobState.RUNNING: 2}
_RANK.update({s: 3 for s in JobState.TERMINAL})


class JobRecord:
    """One job's lifecycle. Mutated only through :class:`JobStore`
    methods (which hold the store lock); readers get copies via
    :meth:`to_dict`."""

    __slots__ = ("id", "kind", "tenant", "priority", "deadline", "app",
                 "fingerprint", "state", "error", "coalesced",
                 "timestamps", "metrics", "logs", "trace_id")

    def __init__(self, id: str, kind: str, tenant: str, priority: int,
                 deadline: Optional[float], app: str,
                 fingerprint: Optional[str], log_lines: int = 256):
        self.id = id
        self.kind = kind                  # "run" | "update"
        self.tenant = tenant
        self.priority = priority
        self.deadline = deadline          # relative seconds, as submitted
        self.app = app
        self.fingerprint = fingerprint
        self.state = JobState.SUBMITTED
        self.error: Optional[str] = None
        self.coalesced = False
        # state -> unix time of the transition INTO it
        self.timestamps: Dict[str, float] = {
            JobState.SUBMITTED: time.time()}
        self.metrics: Optional[dict] = None
        self.logs: Deque[str] = deque(maxlen=log_lines)
        self.trace_id: Optional[str] = None

    def to_dict(self, with_logs: bool = False) -> dict:
        d = {
            "id": self.id, "kind": self.kind, "tenant": self.tenant,
            "priority": self.priority, "deadline": self.deadline,
            "app": self.app, "fingerprint": self.fingerprint,
            "state": self.state, "error": self.error,
            "coalesced": self.coalesced, "trace_id": self.trace_id,
            "timestamps": dict(self.timestamps),
            "metrics": self.metrics,
            "terminal": self.state in JobState.TERMINAL,
        }
        if with_logs:
            d["logs"] = list(self.logs)
        return d


class JobStore:
    """Thread-safe registry of :class:`JobRecord`, bounded by evicting
    the oldest terminal records past ``max_records``.

    Parameters
    ----------
    max_records: retention bound. Live (non-terminal) records are never
        evicted — the bound is exceeded rather than forgetting a
        running job.
    log_lines: per-job log ring size.
    persist_path: optional JSONL file; each record is appended once, on
        reaching a terminal state (job history survives the process).
    """

    def __init__(self, max_records: int = 1024, log_lines: int = 256,
                 persist_path: Optional[str] = None):
        if max_records < 1:
            raise ValueError(f"max_records must be >= 1, got {max_records}")
        self.max_records = max_records
        self.log_lines = log_lines
        self.persist_path = persist_path
        self._lock = threading.Lock()
        self._records: "OrderedDict[str, JobRecord]" = OrderedDict()
        self._seq = 0
        # log readers need a stable offset across the deque's rotation:
        # dropped_of[id] counts lines that fell off the ring's left edge
        self._dropped: Dict[str, int] = {}

    # -- creation & transitions -----------------------------------------
    def create(self, *, kind: str, tenant: str = "default",
               priority: int = 0, deadline: Optional[float] = None,
               app: str = "", fingerprint: Optional[str] = None
               ) -> JobRecord:
        with self._lock:
            self._seq += 1
            jid = f"job-{self._seq:08d}"
            rec = JobRecord(jid, kind, tenant, priority, deadline, app,
                            fingerprint, log_lines=self.log_lines)
            self._records[jid] = rec
            self._dropped[jid] = 0
            self._evict_locked()
            self._append_log_locked(
                rec, f"submitted app={app} tenant={tenant} "
                     f"priority={priority}")
            return rec

    def transition(self, job_id: str, state: str,
                   error: Optional[str] = None,
                   metrics: Optional[dict] = None,
                   log: Optional[str] = None) -> Optional[JobRecord]:
        """Move a job to ``state`` (stamping the transition time).
        Transitions never move backwards: a job already terminal stays
        put (late observer callbacks after a cancel must not resurrect
        it), and a "queued" racing in after "running" — observers fire
        outside the service locks — is dropped. Returns the record or
        None."""
        if state not in JobState.ALL:
            raise ValueError(f"unknown job state {state!r}")
        persist = None
        with self._lock:
            rec = self._records.get(job_id)
            if (rec is None or rec.state in JobState.TERMINAL
                    or _RANK[state] < _RANK[rec.state]):
                return rec
            rec.state = state
            rec.timestamps[state] = time.time()
            if error is not None:
                rec.error = error
            if metrics is not None:
                rec.metrics = metrics
            self._append_log_locked(
                rec, log if log is not None else f"-> {state}",
                level=("error" if error is not None
                       or state == JobState.FAILED else "info"))
            if state in JobState.TERMINAL and self.persist_path:
                persist = rec.to_dict(with_logs=True)
        if persist is not None:
            self._persist(persist)
        return rec

    def set_trace(self, job_id: str, trace_id: Optional[str]) -> None:
        """Attach the distributed-trace id once the service hands the
        submission's context back (coalesced jobs get the id of the
        in-flight job they merged into)."""
        if trace_id is None:
            return
        with self._lock:
            rec = self._records.get(job_id)
            if rec is not None:
                rec.trace_id = trace_id

    def mark_coalesced(self, job_id: str) -> None:
        with self._lock:
            rec = self._records.get(job_id)
            if rec is not None:
                rec.coalesced = True
                self._append_log_locked(
                    rec, "coalesced onto an identical in-flight job")

    def append_log(self, job_id: str, line: str,
                   level: str = "info") -> None:
        with self._lock:
            rec = self._records.get(job_id)
            if rec is not None:
                self._append_log_locked(rec, line, level=level)

    def _append_log_locked(self, rec: JobRecord, line: str,
                           level: str = "info") -> None:
        # lines stay plain strings (streamed verbatim over the chunked
        # /logs endpoint): epoch-seconds stamp + upper-case level prefix
        if len(rec.logs) == rec.logs.maxlen:
            self._dropped[rec.id] = self._dropped.get(rec.id, 0) + 1
        rec.logs.append(f"[{time.time():.3f}] {level.upper()} {line}")

    # -- queries --------------------------------------------------------
    def get(self, job_id: str) -> Optional[JobRecord]:
        with self._lock:
            return self._records.get(job_id)

    def list(self, tenant: Optional[str] = None,
             state: Optional[str] = None) -> List[dict]:
        with self._lock:
            return [r.to_dict() for r in self._records.values()
                    if (tenant is None or r.tenant == tenant)
                    and (state is None or r.state == state)]

    def read_logs(self, job_id: str, offset: int = 0,
                  limit: int = 64) -> Tuple[List[str], int, bool]:
        """Chunked log read: lines ``[offset, offset+limit)`` in the
        job's absolute line numbering. Returns ``(lines, next_offset,
        done)`` — ``done`` once the job is terminal and the reader has
        caught up, so a streaming client knows to stop following. An
        ``offset`` older than the ring's left edge skips forward (those
        lines are gone)."""
        with self._lock:
            rec = self._records.get(job_id)
            if rec is None:
                raise KeyError(f"unknown job {job_id!r}")
            base = self._dropped.get(job_id, 0)
            if offset < base:
                offset = base
            lines = list(rec.logs)[offset - base: offset - base + limit]
            next_offset = offset + len(lines)
            done = (rec.state in JobState.TERMINAL
                    and next_offset >= base + len(rec.logs))
            return lines, next_offset, done

    def stats(self) -> dict:
        with self._lock:
            by_state: Dict[str, int] = {}
            for r in self._records.values():
                by_state[r.state] = by_state.get(r.state, 0) + 1
            return {"records": len(self._records), "by_state": by_state,
                    "max_records": self.max_records}

    # -- retention & persistence ----------------------------------------
    def _evict_locked(self) -> None:
        if len(self._records) <= self.max_records:
            return
        for jid in list(self._records):
            if len(self._records) <= self.max_records:
                break
            if self._records[jid].state in JobState.TERMINAL:
                del self._records[jid]
                self._dropped.pop(jid, None)

    def _persist(self, record_dict: dict) -> None:
        try:
            with open(self.persist_path, "a") as f:
                f.write(json.dumps(record_dict, default=str) + "\n")
        except OSError:
            pass    # history is best-effort; serving must not fail on it
