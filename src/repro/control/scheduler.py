"""Model-guided job scheduler: priority + deadline ordering with
admission control, replacing the serving layer's FIFO queue.

ReGraph's datapath routes each partition to the pipeline its cost
estimate says it belongs on; this is the same idea one level up. Every
queued job carries a *cost estimate* (seconds, from the perf model via
``PlanBundle.plan.est_makespan`` or a measured EWMA — the service
computes it, the scheduler just orders by it), a *priority* and an
optional *deadline*. The queue drains in

    (priority desc, deadline asc, estimated cost asc, arrival)

order: urgent work first, then earliest deadline, then
shortest-job-first among equals so cheap jobs never starve behind a
giant build of equal rank.

Admission control happens at push time and is *typed* — callers can
tell the difference and react (shed load, retry later, spill to
another service):

* :class:`QueueFull` — the bounded queue is at ``max_depth``.
* :class:`QuotaExceeded` — the tenant's token bucket is empty
  (:class:`TenantQuota` refills at ``rate`` jobs/s up to ``burst``).

Expired-deadline jobs are load-shed lazily when they surface at the
queue head (shed-on-pop): the scheduler never scans the heap, and a
worker never wastes a slot executing a job whose caller has already
given up. Shed entries fire the ``on_shed`` callback OUTSIDE the
scheduler lock — the serving layer resolves handles there and its
bookkeeping re-enters its own locks.

The heap uses lazy invalidation (``remove`` / ``reprioritize`` mark
entries dead rather than re-heapify), and sentinels — used by the
service's ``close()`` to stop workers — sort after every real job so a
drain always finishes queued work first.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import threading
import time
from collections import deque
from typing import Callable, Dict, Optional

__all__ = ["JobScheduler", "TenantQuota", "RejectedJob", "QueueFull",
           "QuotaExceeded", "DeadlineExpired"]


class RejectedJob(RuntimeError):
    """Base of all typed admission rejections (catch this to mean
    'the scheduler refused the job, nothing was enqueued')."""


class QueueFull(RejectedJob):
    """push() on a queue already holding ``max_depth`` jobs."""


class QuotaExceeded(RejectedJob):
    """push() by a tenant whose token bucket is empty."""


class DeadlineExpired(RejectedJob):
    """The job was load-shed: its deadline passed while it waited.
    Never raised by push() — the serving layer sets it on the shed
    job's handles from the ``on_shed`` callback."""


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Token-bucket admission quota: ``rate`` jobs/second sustained,
    ``burst`` jobs instantaneously. A tenant with no quota is
    unlimited."""

    rate: float
    burst: float = 1.0

    def __post_init__(self):
        if self.rate <= 0 or self.burst < 1:
            raise ValueError(f"need rate > 0 and burst >= 1, got "
                             f"rate={self.rate}, burst={self.burst}")


class _Entry:
    __slots__ = ("key", "item", "tenant", "deadline", "valid", "t_push")

    def __init__(self, key, item, tenant, deadline,
                 t_push: Optional[float] = None):
        self.key = key
        self.item = item
        self.tenant = tenant
        self.deadline = deadline
        self.valid = True
        # wall anchor for the queue-wait distribution (observability);
        # reprioritized entries inherit it so the wait stays honest
        self.t_push = time.perf_counter() if t_push is None else t_push

    def __lt__(self, other):        # heapq compares entries directly
        return self.key < other.key


class JobScheduler:
    """Priority + deadline + cost ordered job queue with per-tenant
    admission control.

    Parameters
    ----------
    max_depth: bound on queued (not yet popped) real jobs; pushes past
        it raise :class:`QueueFull`. None = unbounded.
    default_quota: :class:`TenantQuota` applied to every tenant without
        an explicit entry in ``quotas``; None = unlimited.
    quotas: per-tenant quota overrides (tenant name -> TenantQuota).
    clock: monotonic-seconds source; deadlines and bucket refills read
        it (injectable for tests).
    on_shed: callback ``(item) -> None`` fired — outside the scheduler
        lock — for each job load-shed because its deadline expired
        before a worker reached it.
    """

    def __init__(self, max_depth: Optional[int] = None,
                 default_quota: Optional[TenantQuota] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 on_shed: Optional[Callable] = None):
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = max_depth
        self.default_quota = default_quota
        self.quotas = dict(quotas or {})
        self.on_shed = on_shed
        self._clock = clock
        self._cv = threading.Condition()
        self._heap: list = []
        self._index: Dict[object, _Entry] = {}   # queued item -> entry
        self._seq = 0
        # tenant -> [tokens, last_refill_time]; created lazily
        self._buckets: Dict[str, list] = {}
        self._depth_by_tenant: Dict[str, int] = {}
        self.pushed = self.popped = self.shed = 0
        self.rejected_full = self.rejected_quota = 0
        # recent queue-wait samples (ms), popped and shed separately —
        # shed waits are deadline-censored and would skew the pop p99
        self._wait_ms: deque = deque(maxlen=2048)
        self._shed_wait_ms: deque = deque(maxlen=512)

    # -- admission ------------------------------------------------------
    def _quota_for(self, tenant: str) -> Optional[TenantQuota]:
        return self.quotas.get(tenant, self.default_quota)

    def _admit(self, tenant: str) -> None:
        """Depth + token-bucket check; charges one token on success.
        Caller holds the lock."""
        if (self.max_depth is not None
                and len(self._index) >= self.max_depth):
            self.rejected_full += 1
            raise QueueFull(
                f"queue is at max_depth={self.max_depth}; retry later or "
                f"raise the bound")
        q = self._quota_for(tenant)
        if q is None:
            return
        now = self._clock()
        b = self._buckets.get(tenant)
        if b is None:
            b = self._buckets[tenant] = [q.burst, now]
        tokens, last = b
        tokens = min(q.burst, tokens + (now - last) * q.rate)
        if tokens < 1.0:
            b[0], b[1] = tokens, now
            self.rejected_quota += 1
            raise QuotaExceeded(
                f"tenant {tenant!r} is over quota "
                f"(rate={q.rate}/s, burst={q.burst}); retry in "
                f"{(1.0 - tokens) / q.rate:.3f}s")
        b[0], b[1] = tokens - 1.0, now

    # -- producing ------------------------------------------------------
    def push(self, item, *, tenant: str = "default", priority: int = 0,
             deadline: Optional[float] = None, cost: float = 0.0) -> None:
        """Enqueue ``item``. ``priority``: larger drains first.
        ``deadline``: absolute clock() time after which the job is shed
        instead of run (None = never). ``cost``: estimated seconds of
        work — the SJF tie-break among equal priority/deadline.

        Raises :class:`QueueFull` / :class:`QuotaExceeded`; on
        rejection nothing is enqueued and no token is charged for a
        full queue."""
        with self._cv:
            self._admit(tenant)
            self._seq += 1
            key = (-priority, deadline if deadline is not None else math.inf,
                   cost, self._seq)
            e = _Entry(key, item, tenant, deadline)
            self._index[item] = e
            heapq.heappush(self._heap, e)
            self._depth_by_tenant[tenant] = \
                self._depth_by_tenant.get(tenant, 0) + 1
            self.pushed += 1
            self._cv.notify()

    def push_sentinel(self, item) -> None:
        """Enqueue a drain marker that sorts after every real job (and
        every other sentinel pushed earlier), bypassing admission —
        close() must always be able to stop the workers."""
        with self._cv:
            self._seq += 1
            e = _Entry((math.inf, math.inf, math.inf, self._seq), item,
                       None, None)
            heapq.heappush(self._heap, e)
            self._cv.notify()

    # -- mutating queued jobs -------------------------------------------
    def remove(self, item) -> bool:
        """Drop a queued job (e.g. every handle cancelled). Returns
        False if it was already popped, shed or never pushed."""
        with self._cv:
            e = self._index.pop(item, None)
            if e is None:
                return False
            e.valid = False
            self._depth_by_tenant[e.tenant] -= 1
            return True

    def reprioritize(self, item, priority: int) -> bool:
        """Raise/lower a queued job's priority in place (a coalesced
        twin with higher priority boosts the job it piggybacks on).
        Deadline, cost and arrival order are preserved."""
        with self._cv:
            e = self._index.get(item)
            if e is None:
                return False
            if e.key[0] == -priority:
                return True
            e.valid = False
            ne = _Entry((-priority,) + e.key[1:], item, e.tenant,
                        e.deadline, t_push=e.t_push)
            self._index[item] = ne
            heapq.heappush(self._heap, ne)
            self._cv.notify()
            return True

    def deadline_of(self, item) -> Optional[float]:
        with self._cv:
            e = self._index.get(item)
            return e.deadline if e is not None else None

    # -- consuming ------------------------------------------------------
    def _try_pop_locked(self, shed_out: list):
        """Pop the best live entry; expired ones go to ``shed_out``.
        Returns (found, item). Caller holds the lock."""
        while self._heap:
            e = self._heap[0]
            if not e.valid:             # lazily invalidated
                heapq.heappop(self._heap)
                continue
            if (e.deadline is not None and self._clock() >= e.deadline):
                heapq.heappop(self._heap)
                e.valid = False
                self._index.pop(e.item, None)
                self._depth_by_tenant[e.tenant] -= 1
                self.shed += 1
                self._shed_wait_ms.append(
                    (time.perf_counter() - e.t_push) * 1e3)
                shed_out.append(e.item)
                continue
            heapq.heappop(self._heap)
            e.valid = False
            if e.tenant is not None:    # sentinels aren't indexed
                self._index.pop(e.item, None)
                self._depth_by_tenant[e.tenant] -= 1
                self.popped += 1
                self._wait_ms.append(
                    (time.perf_counter() - e.t_push) * 1e3)
            return True, e.item
        return False, None

    def pop(self, timeout: Optional[float] = None):
        """Dequeue the best job, blocking up to ``timeout`` seconds
        (None = forever, 0 = non-blocking). Returns None on timeout.
        Jobs whose deadline passed while queued are shed on the way —
        their ``on_shed`` callbacks fire before this returns."""
        end = None if timeout is None else self._clock() + timeout
        while True:
            shed: list = []
            with self._cv:
                while True:
                    found, item = self._try_pop_locked(shed)
                    if found or shed:
                        break
                    if end is not None:
                        remaining = end - self._clock()
                        if remaining <= 0:
                            break
                        self._cv.wait(remaining)
                    else:
                        self._cv.wait()
            if shed and self.on_shed is not None:
                for it in shed:         # outside the lock: callbacks
                    self.on_shed(it)    # take the service's own locks
            if found:
                return item
            if not shed:                # timed out with nothing to shed
                return None

    # -- reporting ------------------------------------------------------
    def qsize(self) -> int:
        """Queued real jobs (sentinels and invalidated entries don't
        count)."""
        with self._cv:
            return len(self._index)

    @staticmethod
    def _pct(xs, p: float) -> Optional[float]:
        """Nearest-rank percentile of a sample sequence; None if empty."""
        if not xs:
            return None
        xs = sorted(xs)
        rank = max(0, min(len(xs) - 1,
                          int(round(p / 100.0 * (len(xs) - 1)))))
        return xs[rank]

    def stats(self) -> dict:
        with self._cv:
            waits = list(self._wait_ms)
            shed_waits = list(self._shed_wait_ms)
            return {
                "depth": len(self._index),
                "depth_by_tenant": {t: n for t, n
                                    in self._depth_by_tenant.items() if n},
                "pushed": self.pushed,
                "popped": self.popped,
                "shed": self.shed,
                "rejected_queue_full": self.rejected_full,
                "rejected_quota": self.rejected_quota,
                "max_depth": self.max_depth,
                # queue-wait distribution over the recent sample window
                # (popped jobs; shed waits reported separately — they
                # are deadline-censored)
                "queue_wait_p50_ms": self._pct(waits, 50),
                "queue_wait_p99_ms": self._pct(waits, 99),
                "shed_wait_p50_ms": self._pct(shed_waits, 50),
            }
