"""Job-oriented control plane for the serving layer.

Promotes :class:`~repro.serve_graph.GraphService` from worker threads
draining a FIFO to a managed fleet:

* :mod:`~repro.control.scheduler` — priority + deadline + model-cost
  ordered queue with per-tenant token-bucket admission control and
  expired-deadline load-shed (the FIFO replacement; the service uses
  it internally).
* :mod:`~repro.control.pool` — process-pool worker tier for CPU-heavy
  store builds and delta splices (the GIL relief; pass ``pool=`` to
  the service).
* :mod:`~repro.control.jobs` — persistent job-status records
  (submitted → queued → running → done/failed/cancelled/expired) with
  per-stage timestamps and bounded logs.
* :mod:`~repro.control.manager` — :class:`ControlPlane`, tying a
  service to a job store and an observer pipeline.
* :mod:`~repro.control.http_api` — minimal stdlib JSON API over the
  manager (``POST /jobs``, ``GET /jobs/{id}``, chunked
  ``GET /jobs/{id}/logs``, Prometheus ``GET /metrics``), modeled on
  Ray's dashboard job API.

The scheduler and pool are imported eagerly (the service layers on
them); the manager stack is loaded lazily via PEP 562 because it
imports the serving layer back.
"""
from .pool import WorkerCrashed, WorkerPool
from .scheduler import (DeadlineExpired, JobScheduler, QueueFull,
                        QuotaExceeded, RejectedJob, TenantQuota)

__all__ = [
    "ControlPlane", "DeadlineExpired", "JobRecord", "JobScheduler",
    "JobStore", "QueueFull", "QuotaExceeded", "RejectedJob", "TenantQuota",
    "WorkerCrashed", "WorkerPool", "serve_jobs",
]

_LAZY = {
    "ControlPlane": "manager",
    "JobRecord": "jobs",
    "JobStore": "jobs",
    "serve_jobs": "http_api",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
