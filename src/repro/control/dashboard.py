"""The control plane's ``GET /dashboard`` page.

One self-contained HTML document — no external assets, no frameworks —
served verbatim by :mod:`repro.control.http_api` and polling
``/metrics.json`` every 2 s from the browser. It shows the four things
an operator actually reaches for:

* **queue/jobs** — scheduler depth, job counts by outcome, hit rates;
* **stage latency** — p50/p99 per pipeline stage (queue → store →
  plan → execute → total, plus streaming updates);
* **model health** — perf-model drift ratio per pipeline kind and
  applied retunes;
* **pipeline utilization** — the profiler's achieved GB/s and
  %-of-peak bars per pipeline kind and per lane (repro.obs.profile),
  the repro's analogue of ReGraph's per-channel bandwidth plots.

Kept as a Python string (not a data file) so the package needs no
resource loading and the page is importable/testable directly.
"""
from __future__ import annotations

__all__ = ["DASHBOARD_HTML"]

DASHBOARD_HTML = """<!DOCTYPE html>
<html lang="en">
<head>
<meta charset="utf-8">
<meta name="viewport" content="width=device-width, initial-scale=1">
<title>regraph control plane</title>
<style>
  :root {
    color-scheme: light;
    --surface-1: #fcfcfb;
    --surface-2: #f0efec;
    --text-primary: #0b0b0b;
    --text-secondary: #52514e;
    --grid: #e3e2de;
    --seq: #2a78d6;        /* sequential hue: magnitude bars */
    --seq-track: #cde2fb;
    --status-good: #008300;
    --status-serious: #e34948;
  }
  @media (prefers-color-scheme: dark) {
    :root {
      color-scheme: dark;
      --surface-1: #1a1a19;
      --surface-2: #383835;
      --text-primary: #ffffff;
      --text-secondary: #c3c2b7;
      --grid: #3e3e3a;
      --seq: #3987e5;
      --seq-track: #104281;
      --status-good: #00a300;
      --status-serious: #e66767;
    }
  }
  body {
    margin: 0; padding: 20px 24px;
    background: var(--surface-1); color: var(--text-primary);
    font: 14px/1.45 system-ui, sans-serif;
  }
  h1 { font-size: 16px; font-weight: 600; margin: 0 0 2px; }
  h2 {
    font-size: 12px; font-weight: 600; letter-spacing: .04em;
    text-transform: uppercase; color: var(--text-secondary);
    margin: 0 0 10px;
  }
  .sub { color: var(--text-secondary); font-size: 12px; margin: 0 0 18px; }
  .grid { display: flex; flex-wrap: wrap; gap: 16px; }
  .card {
    background: var(--surface-1); border: 1px solid var(--grid);
    border-radius: 8px; padding: 14px 16px; min-width: 260px; flex: 1;
  }
  .tiles { display: flex; flex-wrap: wrap; gap: 16px 28px; }
  .tile .v { font-size: 24px; font-weight: 600; font-variant-numeric: tabular-nums; }
  .tile .k { font-size: 12px; color: var(--text-secondary); }
  table { border-collapse: collapse; width: 100%; }
  th {
    text-align: left; font-size: 11px; font-weight: 600;
    color: var(--text-secondary); padding: 3px 10px 3px 0;
    border-bottom: 1px solid var(--grid);
  }
  th.num, td.num { text-align: right; font-variant-numeric: tabular-nums; }
  td { padding: 3px 10px 3px 0; border-bottom: 1px solid var(--surface-2); }
  tr:last-child td { border-bottom: none; }
  .bar-row { display: flex; align-items: center; gap: 8px; margin: 5px 0; }
  .bar-label {
    flex: 0 0 92px; font-size: 12px; color: var(--text-secondary);
    white-space: nowrap; overflow: hidden; text-overflow: ellipsis;
  }
  .bar-track {
    flex: 1; height: 10px; background: var(--surface-2);
    border-radius: 4px; overflow: hidden;
  }
  .bar-fill {
    height: 100%; background: var(--seq);
    border-radius: 0 4px 4px 0; min-width: 2px;
  }
  .bar-val {
    flex: 0 0 120px; font-size: 12px; text-align: right;
    font-variant-numeric: tabular-nums;
  }
  .dot {
    display: inline-block; width: 8px; height: 8px; border-radius: 50%;
    margin-right: 6px; vertical-align: baseline;
  }
  .ok .dot { background: var(--status-good); }
  .bad .dot { background: var(--status-serious); }
  .muted { color: var(--text-secondary); }
  #err { color: var(--status-serious); font-size: 12px; min-height: 16px; }
</style>
</head>
<body>
<h1>regraph control plane</h1>
<p class="sub">
  <span id="ready" class="ok"><span class="dot"></span>ready</span>
  &nbsp;&middot;&nbsp; polls <code>/metrics.json</code> every 2 s
  &nbsp;&middot;&nbsp; <span id="updated" class="muted">never updated</span>
</p>
<div id="err"></div>
<div class="grid">
  <div class="card" style="flex:2 1 420px">
    <h2>Queue &amp; jobs</h2>
    <div class="tiles" id="tiles"></div>
  </div>
  <div class="card">
    <h2>Stage latency (ms)</h2>
    <table>
      <thead><tr><th>stage</th><th class="num">p50</th>
        <th class="num">p99</th></tr></thead>
      <tbody id="latency"></tbody>
    </table>
  </div>
  <div class="card">
    <h2>Perf-model drift</h2>
    <table>
      <thead><tr><th>kind</th><th class="num">ratio</th>
        <th class="num">samples</th></tr></thead>
      <tbody id="drift"></tbody>
    </table>
    <p class="muted" style="font-size:12px;margin:8px 0 0">
      measured / estimated lane time; 1.00 = model exact.
      retunes applied: <span id="retunes">0</span></p>
  </div>
  <div class="card" style="flex:2 1 420px">
    <h2>Pipeline utilization</h2>
    <div id="util-kinds"></div>
    <p class="muted" style="font-size:12px;margin:10px 0 4px">
      per lane (last sample; fraction of
      <span id="peak">?</span> GB/s peak)</p>
    <div id="util-lanes"></div>
  </div>
</div>
<script>
"use strict";
const $ = id => document.getElementById(id);
const fmt = (x, d=1) => (x === null || x === undefined || isNaN(x))
  ? "\\u2013" : Number(x).toFixed(d);

function tile(k, v) {
  return `<div class="tile"><div class="v">${v}</div>` +
         `<div class="k">${k}</div></div>`;
}

function bar(label, frac, valText) {
  const pct = Math.max(0, Math.min(1, frac || 0)) * 100;
  return `<div class="bar-row"><div class="bar-label">${label}</div>` +
    `<div class="bar-track"><div class="bar-fill" ` +
    `style="width:${pct.toFixed(1)}%"></div></div>` +
    `<div class="bar-val">${valText}</div></div>`;
}

function render(d) {
  const s = d.service || {};
  const sched = d.scheduler || {};
  const jobs = d.jobs || {};
  const by = jobs.by_state || {};
  $("tiles").innerHTML =
    tile("queue depth", sched.depth ?? s.queue_depth ?? 0) +
    tile("submitted", s.submitted ?? 0) +
    tile("completed", s.completed ?? 0) +
    tile("failed", s.failed ?? 0) +
    tile("running jobs", by.running ?? 0) +
    tile("store hit rate", fmt((s.store_hit_rate ?? 0) * 100, 0) + "%") +
    tile("plan hit rate", fmt((s.plan_hit_rate ?? 0) * 100, 0) + "%");
  const stages = ["queue", "store", "plan", "execute", "total", "update"];
  $("latency").innerHTML = stages.map(st =>
    `<tr><td>${st}</td><td class="num">${fmt(s["p50_" + st + "_ms"], 2)}` +
    `</td><td class="num">${fmt(s["p99_" + st + "_ms"], 2)}</td></tr>`
  ).join("");
  const drift = s.drift || {};
  const dk = Object.keys(drift).sort();
  $("drift").innerHTML = dk.length ? dk.map(k =>
    `<tr><td>${k}</td><td class="num">${fmt(drift[k].ratio, 3)}</td>` +
    `<td class="num">${drift[k].n ?? 0}</td></tr>`).join("")
    : '<tr><td colspan="3" class="muted">no samples yet</td></tr>';
  $("retunes").textContent = s.retunes ?? 0;
  const util = s.utilization || {};
  const kinds = util.kinds || {};
  const peak = util.peak_bandwidth_gbps;
  $("peak").textContent = fmt(peak, 1);
  const kk = Object.keys(kinds).sort();
  $("util-kinds").innerHTML = kk.length ? kk.map(k => {
    const r = kinds[k];
    const u = r.utilization;
    return bar(k, u ?? (peak ? r.gbps / peak : 0),
      `${fmt(r.gbps, 2)} GB/s` +
      (u !== null && u !== undefined ? ` \\u00b7 ${fmt(u * 100, 1)}%` : ""));
  }).join("") : '<p class="muted" style="font-size:12px">no samples yet</p>';
  const lanes = util.lanes || {};
  const lk = Object.keys(lanes).sort((a, b) => a - b);
  $("util-lanes").innerHTML = lk.length ? lk.map(l => {
    const r = lanes[l];
    const u = r.utilization;
    return bar(`lane ${l} (${r.kind})`,
      u ?? (peak ? r.gbps / peak : 0),
      `${fmt(r.gbps, 2)} GB/s` +
      (u !== null && u !== undefined ? ` \\u00b7 ${fmt(u * 100, 1)}%` : ""));
  }).join("") : '<p class="muted" style="font-size:12px">no samples yet</p>';
}

async function tick() {
  try {
    const [m, r] = await Promise.all([
      fetch("/metrics.json").then(x => x.json()),
      fetch("/readyz").then(x => x.json()).catch(() => null),
    ]);
    render(m);
    if (r) {
      const el = $("ready");
      el.className = r.ready ? "ok" : "bad";
      el.innerHTML = '<span class="dot"></span>' +
        (r.ready ? "ready" : "not ready");
    }
    $("updated").textContent =
      "updated " + new Date().toLocaleTimeString();
    $("err").textContent = "";
  } catch (e) {
    $("err").textContent = "poll failed: " + e;
  }
}
tick();
setInterval(tick, 2000);
</script>
</body>
</html>
"""
