"""Minimal stdlib JSON API over the ControlPlane (Ray-dashboard style).

Endpoints (all JSON unless noted):

* ``POST /jobs`` — submit a run by fingerprint::

      {"fingerprint": "...", "app": "pagerank", "tenant": "t",
       "priority": 5, "deadline": 2.5, "app_kwargs": {...},
       "max_iters": 10, "path": "ref"}

  → 201 with the job record. Typed admission rejections come back as
  429 with ``{"error": "queue_full" | "quota", ...}``; an unknown
  fingerprint is 404. Graph payloads never travel over HTTP — register
  graphs in-process and submit by fingerprint (jobs are keyed by it).

  ``"kind"`` selects non-run jobs on the same route:

  - ``{"kind": "update", "fingerprint": fp, "delta": {...}}`` — a
    streaming delta update (synchronous; the record carries the NEW
    chained fingerprint). The delta object takes ``add`` / ``remove``
    / ``update`` edge lists (each ``{"src": [...], "dst": [...]}``
    plus optional ``"weights"``, or a positional ``[src, dst,
    weights?]`` array) and an optional vertex-growth floor
    ``"grow_to"``; adds may reference ids past the current vertex
    count to GROW the graph. A malformed delta — wrong shapes,
    unknown fields, or a remove/update referencing an unknown (e.g.
    not-yet-grown) vertex — is a typed 400 ``bad_delta``; an unknown
    base fingerprint stays 404.
  - ``{"kind": "compact", "fingerprint": fp}`` — squash the delta
    chain behind a snapshot into one composed delta (lineage kept).
  - ``{"kind": "regroup", "fingerprint": fp, "force": true}`` — run a
    grouping-drift check and (past the threshold, or forced) the
    fresh-DBG re-registration swap.
* ``GET /jobs`` — list records (``?tenant=`` / ``?state=`` filters).
* ``GET /jobs/{id}`` — one record, with logs.
* ``GET /jobs/{id}/result?timeout=`` — block for the outcome (meta
  only; property arrays stay server-side).
* ``GET /jobs/{id}/logs?offset=&follow=1`` — **chunked
  transfer-encoding** log stream: each chunk is a JSON line batch;
  with ``follow=1`` the connection stays open until the job is
  terminal and the reader has caught up.
* ``GET /jobs/{id}/trace`` — the job's distributed trace as
  Chrome-trace JSON (open in ``chrome://tracing`` / Perfetto); 404
  until the job has a trace or after the tracer evicted it.
* ``POST /jobs/{id}/cancel`` — cancel a queued job.
* ``GET /metrics`` — Prometheus text; ``GET /metrics.json`` — the full
  merged snapshot. ``GET /healthz`` — liveness; ``GET /readyz`` —
  readiness (200 only while the scheduler accepts submissions and all
  pool workers are alive, 503 otherwise — the load-balancer signal).
* ``GET /dashboard`` — self-contained HTML ops page (stdlib-served,
  no assets) that polls ``/metrics.json``: queue depth, per-stage
  latency percentiles, perf-model drift, retunes, and the per-lane
  pipeline-utilization bars.

Built on :class:`http.server.ThreadingHTTPServer` — no dependencies,
one daemon thread per connection, fine for the control plane's request
rates (the data plane never goes through HTTP).
"""
from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional, Tuple
from urllib.parse import parse_qs, urlparse

from .scheduler import QueueFull, QuotaExceeded, RejectedJob

__all__ = ["serve_jobs"]

_JOB_PATH = re.compile(r"^/jobs/([^/]+)(/logs|/result|/cancel|/trace)?$")

_DELTA_FIELDS = frozenset({"add", "remove", "update", "grow_to"})


def _delta_from_json(base_fp: str, spec) -> "GraphDelta":
    """Parse a JSON delta body into a validated
    :class:`~repro.streaming.GraphDelta` against ``base_fp``.

    Each of ``add``/``remove``/``update`` is either an object
    ``{"src": [...], "dst": [...], "weights": [...]?}`` or a
    positional ``[src, dst]`` / ``[src, dst, weights]`` array; an
    integer ``grow_to`` sets the vertex-growth floor. Every shape or
    type problem raises ValueError/TypeError, which the route maps to
    a typed 400 — make_delta's own validation (array lengths, dtypes,
    negative ids, non-int grow_to) rides the same path."""
    from ..streaming import make_delta
    if not isinstance(spec, dict):
        raise ValueError(
            "update jobs need a 'delta' object with add/remove/update "
            "edge lists (and an optional grow_to)")
    unknown = set(spec) - _DELTA_FIELDS
    if unknown:
        raise ValueError(f"unknown delta fields {sorted(unknown)}; "
                         f"expected {sorted(_DELTA_FIELDS)}")

    def edges(name):
        v = spec.get(name)
        if v is None:
            return None
        if isinstance(v, dict):
            bad = set(v) - {"src", "dst", "weights"}
            if bad:
                raise ValueError(f"delta {name!r} has unknown keys "
                                 f"{sorted(bad)}")
            if "src" not in v or "dst" not in v:
                raise ValueError(f"delta {name!r} needs both 'src' and "
                                 f"'dst' lists")
            parts = [v["src"], v["dst"]]
            if v.get("weights") is not None:
                parts.append(v["weights"])
            return tuple(parts)
        if isinstance(v, (list, tuple)) and len(v) in (2, 3):
            return tuple(v)
        raise ValueError(f"delta {name!r} must be an object with "
                         f"src/dst(/weights) or a [src, dst(, weights)] "
                         f"array")

    return make_delta(base_fp, add=edges("add"), remove=edges("remove"),
                      update=edges("update"), grow_to=spec.get("grow_to"))


class _Handler(BaseHTTPRequestHandler):
    # the control plane is attached to the server instance
    protocol_version = "HTTP/1.1"    # required for chunked encoding

    # -- plumbing -------------------------------------------------------
    def log_message(self, fmt, *args):   # quiet by default
        pass

    @property
    def plane(self):
        return self.server.control_plane

    def _json(self, code: int, payload) -> None:
        body = json.dumps(payload, default=str).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _text(self, code: int, text: str,
              ctype: str = "text/plain; version=0.0.4") -> None:
        body = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _read_body(self) -> dict:
        n = int(self.headers.get("Content-Length") or 0)
        if n == 0:
            return {}
        return json.loads(self.rfile.read(n) or b"{}")

    # -- routes ---------------------------------------------------------
    def do_GET(self) -> None:
        url = urlparse(self.path)
        q = {k: v[-1] for k, v in parse_qs(url.query).items()}
        try:
            if url.path == "/healthz":
                return self._json(200, {"status": "ok"})
            if url.path == "/readyz":
                info = self.plane.ready()
                return self._json(200 if info["ready"] else 503, info)
            if url.path == "/dashboard":
                from .dashboard import DASHBOARD_HTML
                return self._text(200, DASHBOARD_HTML,
                                  ctype="text/html; charset=utf-8")
            if url.path == "/metrics":
                return self._text(200, self.plane.prometheus())
            if url.path == "/metrics.json":
                return self._json(200, self.plane.metrics_snapshot())
            if url.path == "/jobs":
                return self._json(200, {"jobs": self.plane.jobs.list(
                    tenant=q.get("tenant"), state=q.get("state"))})
            m = _JOB_PATH.match(url.path)
            if m and m.group(2) in (None, "/logs", "/result", "/trace"):
                jid, sub = m.group(1), m.group(2)
                if sub == "/logs":
                    return self._stream_logs(jid,
                                             int(q.get("offset", 0)),
                                             q.get("follow") == "1")
                if sub == "/result":
                    return self._result(jid, q.get("timeout"))
                if sub == "/trace":
                    trace = self.plane.trace(jid)
                    if trace is None:
                        return self._json(404, {"error": "no_trace",
                                                "job_id": jid})
                    return self._json(200, trace)
                rec = self.plane.jobs.get(jid)
                if rec is None:
                    return self._json(404, {"error": "not_found",
                                            "job_id": jid})
                return self._json(200, rec.to_dict(with_logs=True))
            self._json(404, {"error": "no_such_route",
                             "path": url.path})
        except BrokenPipeError:
            pass
        except Exception as exc:        # a handler bug must not kill the
            try:                        # connection thread silently
                self._json(500, {"error": "internal",
                                 "message": str(exc)})
            except Exception:
                pass

    def do_POST(self) -> None:
        url = urlparse(self.path)
        try:
            if url.path == "/jobs":
                return self._submit(self._read_body())
            m = _JOB_PATH.match(url.path)
            if m and m.group(2) == "/cancel":
                ok = self.plane.cancel_job(m.group(1))
                return self._json(200 if ok else 409,
                                  {"job_id": m.group(1),
                                   "cancelled": ok})
            self._json(404, {"error": "no_such_route", "path": url.path})
        except Exception as exc:
            try:
                self._json(500, {"error": "internal",
                                 "message": str(exc)})
            except Exception:
                pass

    # -- handlers -------------------------------------------------------
    def _submit(self, body: dict) -> None:
        fp = body.get("fingerprint")
        if not fp:
            return self._json(400, {"error": "bad_request",
                                    "message": "fingerprint is required "
                                    "(register graphs in-process)"})
        kind = body.get("kind", "run")
        if kind == "update":
            return self._update(fp, body)
        if kind == "compact":
            return self._compact(fp, body)
        if kind == "regroup":
            return self._regroup(fp, body)
        if kind != "run":
            return self._json(400, {"error": "bad_request",
                                    "message": f"unknown job kind "
                                    f"{kind!r}; expected run, update, "
                                    f"compact, or regroup"})
        kwargs = {}
        for k in ("app_kwargs", "max_iters", "path", "n_lanes"):
            if k in body:
                kwargs[k] = body[k]
        try:
            rec = self.plane.submit_job(
                fingerprint=fp, app=body.get("app", "pagerank"),
                tenant=body.get("tenant", "default"),
                priority=int(body.get("priority", 0)),
                deadline=body.get("deadline"), **kwargs)
        except QueueFull as exc:
            return self._json(429, {"error": "queue_full",
                                    "message": str(exc)})
        except QuotaExceeded as exc:
            return self._json(429, {"error": "quota",
                                    "message": str(exc)})
        except RejectedJob as exc:
            return self._json(429, {"error": "rejected",
                                    "message": str(exc)})
        except KeyError as exc:
            return self._json(404, {"error": "unknown_fingerprint",
                                    "message": str(exc)})
        except (ValueError, TypeError) as exc:
            return self._json(400, {"error": "bad_request",
                                    "message": str(exc)})
        self._json(201, rec.to_dict())

    def _update(self, fp: str, body: dict) -> None:
        """A streaming delta update as a job. Delta parsing and the
        apply-side validation both surface as typed 400s — a malformed
        growth delta (e.g. a remove referencing a vertex only a LATER
        add would create) must fail the HTTP call, not a worker."""
        try:
            delta = _delta_from_json(fp, body.get("delta"))
            rec = self.plane.update_job(
                fp, delta, tenant=body.get("tenant", "default"))
        except KeyError as exc:
            return self._json(404, {"error": "unknown_fingerprint",
                                    "message": str(exc)})
        except (ValueError, TypeError) as exc:
            return self._json(400, {"error": "bad_delta",
                                    "message": str(exc)})
        self._json(201, rec.to_dict())

    def _compact(self, fp: str, body: dict) -> None:
        try:
            rec = self.plane.compact_job(
                fp, tenant=body.get("tenant", "default"))
        except KeyError as exc:
            return self._json(404, {"error": "unknown_fingerprint",
                                    "message": str(exc)})
        except (ValueError, TypeError) as exc:
            return self._json(400, {"error": "bad_request",
                                    "message": str(exc)})
        self._json(201, rec.to_dict())

    def _regroup(self, fp: str, body: dict) -> None:
        try:
            rec = self.plane.regroup_job(
                fingerprint=fp, tenant=body.get("tenant", "default"),
                force=bool(body.get("force", False)))
        except KeyError as exc:
            return self._json(404, {"error": "unknown_fingerprint",
                                    "message": str(exc)})
        except (ValueError, TypeError) as exc:
            return self._json(400, {"error": "bad_request",
                                    "message": str(exc)})
        self._json(201, rec.to_dict())

    def _result(self, jid: str, timeout: Optional[str]) -> None:
        try:
            props, meta = self.plane.result(
                jid, timeout=float(timeout) if timeout else None)
        except KeyError as exc:
            return self._json(404, {"error": "not_found",
                                    "message": str(exc)})
        except TimeoutError as exc:
            return self._json(408, {"error": "timeout",
                                    "message": str(exc)})
        except Exception as exc:
            return self._json(500, {"error": type(exc).__name__,
                                    "message": str(exc)})
        # meta only: property arrays can be huge and live server-side
        return self._json(200, {"job_id": jid, "meta": meta,
                                "num_properties": len(props)
                                if hasattr(props, "__len__") else None})

    def _stream_logs(self, jid: str, offset: int, follow: bool) -> None:
        """Chunked transfer: one JSON document per chunk, each a batch
        of log lines plus the next offset. With ``follow``, poll until
        the job is terminal AND fully read."""
        try:
            lines, next_off, done = self.plane.jobs.read_logs(jid, offset)
        except KeyError:
            return self._json(404, {"error": "not_found", "job_id": jid})
        self.send_response(200)
        self.send_header("Content-Type", "application/json")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()

        def chunk(payload) -> None:
            data = (json.dumps(payload) + "\n").encode()
            self.wfile.write(f"{len(data):X}\r\n".encode() + data
                             + b"\r\n")

        try:
            while True:
                if lines or done or not follow:
                    chunk({"lines": lines, "next_offset": next_off,
                           "done": done})
                if done or not follow:
                    break
                threading.Event().wait(0.05)    # poll cadence
                lines, next_off, done = self.plane.jobs.read_logs(
                    jid, next_off)
            self.wfile.write(b"0\r\n\r\n")      # last-chunk
        except BrokenPipeError:
            pass


def serve_jobs(plane, host: str = "127.0.0.1",
               port: int = 0) -> Tuple[ThreadingHTTPServer, str]:
    """Serve the job API for ``plane`` on a daemon thread. Returns
    ``(server, base_url)``; ``port=0`` binds a free port. Stop with
    ``server.shutdown()`` (ControlPlane.close does)."""
    server = ThreadingHTTPServer((host, port), _Handler)
    server.daemon_threads = True
    server.control_plane = plane
    t = threading.Thread(target=server.serve_forever, daemon=True,
                         name="control-http")
    t.start()
    return server, f"http://{host}:{server.server_address[1]}"
