"""Fault-tolerance machinery for 1000+-node runs.

On a real multi-pod deployment each host runs this next to the train
loop. Pieces:

  * HeartbeatMonitor — per-host liveness via mtime files on shared
    storage (the same pattern GCS/NFS-coordinated TPU pods use). A host
    that misses ``timeout`` is declared dead; the monitor's decision is
    deterministic from the file states, so every surviving host reaches
    the same verdict without a coordinator.
  * StragglerDetector — per-step wall-time EWMA; a host slower than
    ``threshold`` x median is flagged so the launcher can pre-emptively
    drain/replace it (straggler mitigation, not just failure).
  * ElasticPlan — given the surviving host set, recompute the mesh and
    per-host batch shard; together with the deterministic data pipeline
    and elastic checkpoint restore this is full elastic scaling: restart
    on N' != N hosts resumes bit-exact data order at the same step.
  * retry_step — bounded retry with re-raise for genuinely fatal errors.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np


class HeartbeatMonitor:
    def __init__(self, run_dir, host_id: int, timeout: float = 60.0):
        self.dir = Path(run_dir) / "heartbeats"
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id
        self.timeout = timeout

    def beat(self, step: int):
        p = self.dir / f"host_{self.host_id}.json"
        p.write_text(json.dumps({"step": step, "time": time.time()}))

    def alive_hosts(self) -> List[int]:
        now = time.time()
        out = []
        for p in sorted(self.dir.glob("host_*.json")):
            try:
                rec = json.loads(p.read_text())
            except (json.JSONDecodeError, OSError):
                continue  # torn write — treat as missed beat this round
            if now - rec["time"] <= self.timeout:
                out.append(int(p.stem.split("_")[1]))
        return out

    def dead_hosts(self, expected: List[int]) -> List[int]:
        alive = set(self.alive_hosts())
        return [h for h in expected if h not in alive]


class StragglerDetector:
    """Flags hosts whose step time drifts above threshold x median."""

    def __init__(self, alpha: float = 0.2, threshold: float = 1.5):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma: Dict[int, float] = {}

    def record(self, host_id: int, step_seconds: float):
        prev = self.ewma.get(host_id, step_seconds)
        self.ewma[host_id] = (1 - self.alpha) * prev \
            + self.alpha * step_seconds

    def stragglers(self) -> List[int]:
        if len(self.ewma) < 2:
            return []
        med = float(np.median(list(self.ewma.values())))
        return [h for h, t in self.ewma.items()
                if t > self.threshold * med]


class ElasticPlan:
    """Recompute layout after membership change."""

    def __init__(self, global_batch: int):
        self.global_batch = global_batch

    def plan(self, alive: List[int]) -> dict:
        n = len(alive)
        assert n > 0, "no hosts alive"
        # largest per-host batch that tiles the global batch
        while self.global_batch % n:
            n -= 1  # drop spare hosts (kept warm as standbys)
        active = sorted(alive)[:n]
        return {
            "active_hosts": active,
            "local_batch": self.global_batch // n,
            "host_rank": {h: i for i, h in enumerate(active)},
        }


def retry_step(fn: Callable, max_retries: int = 2,
               retryable=(RuntimeError,)) -> Callable:
    """Bounded retry for transient step failures (preempted collective,
    DMA timeout). Deterministic steps make a retry safe: inputs are pure
    functions of (params, step)."""

    def wrapped(*a, **kw):
        for attempt in range(max_retries + 1):
            try:
                return fn(*a, **kw)
            except retryable:
                if attempt == max_retries:
                    raise
                time.sleep(0.1 * 2 ** attempt)
    return wrapped
