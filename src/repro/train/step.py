"""Train-step factory: value_and_grad + optimizer update, with optional
microbatch gradient accumulation (lax.scan) and loss/grad-norm metrics."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models import common as mc
from ..optim.adamw import global_norm


def make_train_step(model, optimizer, micro_batches: int = 1,
                    accum_dtype=None):
    """accum_dtype: microbatch gradient-accumulation dtype. f32 default;
    bf16 halves the accumulator (the difference between fitting and not
    fitting a 1T model on 16 GB chips) — the optimizer's own state/update
    still runs in f32, and the bf16 rounding error is bounded like the
    error-feedback compressors in optim/grad_compress."""
    loss_fn = model.loss

    def compute_grads(params, batch):
        if micro_batches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            b = x.shape[0]
            x = x.reshape(micro_batches, b // micro_batches, *x.shape[1:])
            # keep the PER-MICROBATCH batch dim sharded — the reshape
            # otherwise moves the data-sharding onto the (tiny) micro dim
            # and replicates every activation downstream
            return mc.constrain(x, None, ("pod", "data"),
                                *([None] * (x.ndim - 2)))

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + loss,
                    jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                 grad_acc, grads)), None

        adt = accum_dtype or jnp.float32
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), micro)
        scale = 1.0 / micro_batches
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": global_norm(grads)}
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        return {"loss": model.loss(params, batch)}
    return eval_step
