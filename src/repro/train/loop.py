"""Training loop: checkpoint/restart, heartbeats, straggler hooks,
deterministic resume. This is the same loop the examples and launch/
train.py drive; tests run it at toy scale.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..data.pipeline import DataConfig, TokenPipeline
from ..optim.adamw import Optimizer
from . import fault_tolerance as ft
from .step import make_train_step


class Trainer:
    def __init__(
        self,
        model,
        optimizer: Optimizer,
        data_cfg: DataConfig,
        run_dir: str,
        micro_batches: int = 1,
        checkpoint_every: int = 50,
        host_id: int = 0,
        num_hosts: int = 1,
        batch_transform: Optional[Callable] = None,
    ):
        self.model = model
        self.optimizer = optimizer
        self.run_dir = Path(run_dir)
        self.ckpt = CheckpointManager(self.run_dir / "ckpt")
        self.pipeline = TokenPipeline(data_cfg, host_id, num_hosts)
        self.heartbeat = ft.HeartbeatMonitor(self.run_dir, host_id)
        self.straggler = ft.StragglerDetector()
        self.checkpoint_every = checkpoint_every
        self.host_id = host_id
        self.batch_transform = batch_transform or (lambda b: b)
        self.step_fn = jax.jit(make_train_step(model, optimizer,
                                               micro_batches))

    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.key(seed))
        opt_state = self.optimizer.init(params)
        return params, opt_state

    def run(self, num_steps: int, params=None, opt_state=None,
            log_every: int = 10, on_step: Optional[Callable] = None):
        # ---- restore (elastic: works for any host count) ----------------
        start = 0
        if params is None:
            params, opt_state = self.init_state()
            like = {"params": params, "opt": opt_state}
            step0, restored = self.ckpt.restore(like=like)
            if restored is not None:
                params, opt_state = restored["params"], restored["opt"]
                start = step0 + 1
        losses = []
        for step in range(start, num_steps):
            t0 = time.perf_counter()
            batch = self.batch_transform(self.pipeline.batch(step))
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.record(self.host_id, dt)
            self.heartbeat.beat(step)
            losses.append(loss)
            if on_step:
                on_step(step, metrics)
            if log_every and step % log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"{dt*1e3:.0f}ms", flush=True)
            if self.checkpoint_every and step and \
                    step % self.checkpoint_every == 0:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
        self.ckpt.save(num_steps - 1, {"params": params, "opt": opt_state},
                       blocking=True)
        return params, opt_state, np.asarray(losses)
