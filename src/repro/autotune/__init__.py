"""Autotune: device-spec registry, online calibration, drift-driven
re-planning (ROADMAP item 1 — closing the model-guided loop).

- :mod:`.specs` — persisted, versioned calibrated HW constants keyed by
  (device kind, Geometry).
- :mod:`.calibrator` — folds measured lane timings into guarded
  ``perf_model.fit_terms`` fits.
- :mod:`.retuner` — the drift-watching policy that recalibrates,
  re-searches the plan space and atomically swaps plans into the store.

See docs/CALIBRATION.md for the end-to-end story.
"""
from .calibrator import CalibrationFit, Calibrator
from .retuner import AutoTuner, RetunePolicy, candidate_configs, search_plan
from .specs import (DeviceSpec, SpecRegistry, default_device_kind,
                    geometry_key, hw_from_dict, hw_to_dict)

__all__ = [
    "AutoTuner",
    "CalibrationFit",
    "Calibrator",
    "DeviceSpec",
    "RetunePolicy",
    "SpecRegistry",
    "candidate_configs",
    "default_device_kind",
    "geometry_key",
    "hw_from_dict",
    "hw_to_dict",
    "search_plan",
]
