"""Device-spec registry: persisted, versioned calibrated HW constants.

A :class:`DeviceSpec` is one calibrated :class:`~repro.core.perf_model.HW`
profile keyed by ``(device kind, Geometry)`` — the same pair that decides
which perf-model constants apply to a plan. Specs are stored as one JSON
file per key under a registry directory so a fresh process starts from
the last calibration instead of the analytic defaults, and every
recalibration bumps the spec ``version`` (the Prometheus
``regraph_calibration_version`` gauge is exactly this number).

Registry directory resolution: explicit ``root=`` argument, else the
``REGRAPH_SPEC_DIR`` environment variable, else ``.regraph_specs/`` under
the current working directory. Writes are atomic (tmp file + rename), so
concurrent services sharing a registry never observe a torn spec.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import tempfile
import time
from typing import Any, Dict, Optional

from ..core import perf_model
from ..core.types import Geometry

__all__ = ["DeviceSpec", "SpecRegistry", "default_device_kind",
           "geometry_key", "hw_to_dict", "hw_from_dict"]

_SPEC_FORMAT = 1


def hw_to_dict(hw: perf_model.HW) -> Dict[str, Any]:
    return dataclasses.asdict(hw)


def hw_from_dict(d: Dict[str, Any],
                 base: Optional[perf_model.HW] = None) -> perf_model.HW:
    """Tolerant deserialisation: unknown keys are dropped (older readers
    of newer specs), missing keys fall back to ``base`` (newer readers of
    older specs)."""
    base = base or perf_model.HW()
    names = {f.name for f in dataclasses.fields(perf_model.HW)}
    kept = {k: v for k, v in d.items() if k in names}
    return base.clone(**kept)


def geometry_key(geom: Geometry) -> str:
    return (f"U{geom.U}-W{geom.W}-T{geom.T}"
            f"-E{geom.E_BLK}-B{geom.big_batch}")


def default_device_kind() -> str:
    """Best-effort device identity: jax backend + device kind when jax is
    importable, host name otherwise. Calibrated constants are only
    portable across devices that share this string."""
    import platform

    host = platform.node() or "host"
    try:
        import jax

        dev = jax.devices()[0]
        kind = getattr(dev, "device_kind", None) or jax.default_backend()
        return f"{kind}@{host}"
    except Exception:
        return f"cpu@{host}"


@dataclasses.dataclass
class DeviceSpec:
    """One calibrated HW profile for a (device kind, geometry) pair."""

    device_kind: str
    geom_key: str
    hw: perf_model.HW
    version: int = 0
    created_at: float = 0.0        # unix time of the calibration
    source: str = "analytic"       # "analytic" | "calibrated" | "bench"
    fit: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def peak_bandwidth_gbps(self) -> float:
        """The %-of-peak denominator this spec implies: the explicit
        calibrated ``HW.peak_bandwidth_gbps`` when set, else the
        bandwidth the fitted stream terms believe in
        (:func:`~repro.core.perf_model.effective_peak_bandwidth_bps`).
        The utilization profiler and the dashboard read peaks through
        this so persisted specs and live executors agree."""
        return perf_model.effective_peak_bandwidth_bps(self.hw) / 1e9

    def age_s(self, now: Optional[float] = None) -> float:
        if self.created_at <= 0:
            return float("inf")
        return max(0.0, (now if now is not None else time.time())
                   - self.created_at)

    def to_json(self) -> Dict[str, Any]:
        return {
            "format": _SPEC_FORMAT,
            "device_kind": self.device_kind,
            "geom_key": self.geom_key,
            "hw": hw_to_dict(self.hw),
            "version": int(self.version),
            "created_at": float(self.created_at),
            "source": self.source,
            "fit": self.fit,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "DeviceSpec":
        fit = d.get("fit") or {}
        if not isinstance(fit, dict):
            fit = {}
        return cls(
            device_kind=str(d["device_kind"]),
            geom_key=str(d["geom_key"]),
            hw=hw_from_dict(d.get("hw") or {}),
            version=int(d.get("version", 0)),
            created_at=float(d.get("created_at", 0.0)),
            source=str(d.get("source", "calibrated")),
            fit=fit,
        )


def _safe(token: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]+", "_", token)


class SpecRegistry:
    """Filesystem-backed spec store, one JSON file per (kind, geometry).

    ``get`` returns ``None`` for absent or unreadable files (a corrupt
    spec degrades to analytic defaults, never crashes startup); ``put``
    persists atomically and creates the directory on first use.
    """

    def __init__(self, root: Optional[str] = None):
        self.root = str(root or os.environ.get("REGRAPH_SPEC_DIR")
                        or os.path.join(os.getcwd(), ".regraph_specs"))

    def path_for(self, device_kind: str, geom) -> str:
        """``geom`` is a Geometry or an already-computed geom_key string."""
        gkey = geom if isinstance(geom, str) else geometry_key(geom)
        name = f"{_safe(device_kind)}__{_safe(gkey)}.json"
        return os.path.join(self.root, name)

    def get(self, device_kind: str, geom: Geometry) -> Optional[DeviceSpec]:
        path = self.path_for(device_kind, geom)
        try:
            with open(path, "r", encoding="utf-8") as f:
                return DeviceSpec.from_json(json.load(f))
        except (OSError, ValueError, KeyError, TypeError):
            return None

    def put(self, spec: DeviceSpec) -> str:
        """Atomically persist ``spec``; returns the file path."""
        path = self.path_for(spec.device_kind, spec.geom_key)
        os.makedirs(self.root, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(spec.to_json(), f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    def get_or_default(self, device_kind: str, geom: Geometry,
                       hw: Optional[perf_model.HW] = None) -> DeviceSpec:
        spec = self.get(device_kind, geom)
        if spec is not None:
            return spec
        return DeviceSpec(device_kind=device_kind,
                          geom_key=geometry_key(geom),
                          hw=hw or perf_model.TPU_V5E,
                          source="analytic")
